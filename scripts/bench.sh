#!/usr/bin/env bash
# Benchmark runner with host tuning applied. Single source of truth for
# the bench environment: .github/workflows/ci.yml calls this script, so
# running it locally reproduces the CI bench job exactly.
#
#   bash scripts/bench.sh                         # the CI artifact set
#   bash scripts/bench.sh benchmarks.bench_serve  # one module
#
# Host flags (tcmalloc LD_PRELOAD when available, XLA fake-device count)
# come from scripts/host_tune.sh and are recorded into every BENCH_*.json
# under "host".
set -euo pipefail
cd "$(dirname "${BASH_SOURCE[0]}")/.."

source scripts/host_tune.sh
export PYTHONPATH="src${PYTHONPATH:+:${PYTHONPATH}}"

if [[ $# -gt 0 ]]; then
  for mod in "$@"; do
    python -m "$mod"
  done
  exit 0
fi

python -m benchmarks.elastic_switch
python -m benchmarks.bench_hotpath
python -m benchmarks.bench_stream
python -m benchmarks.bench_serve
python -m benchmarks.bench_profile
python -m benchmarks.bench_faults
python -m benchmarks.fig6_scaling
