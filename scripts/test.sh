#!/usr/bin/env bash
# Tier-1 test runner. Single source of truth for the test environment:
# .github/workflows/ci.yml calls this script, so running it locally
# reproduces the CI run exactly.
#
#   bash scripts/test.sh             # full tier-1 suite (-x -q)
#   bash scripts/test.sh tests/test_elastic_trainer.py   # one module
#
# 8 fake host devices (the olmax/HomebrewNLP idiom) so multi-device code
# paths lower on CPU; tests that need a specific device count spawn
# subprocesses that set their own XLA_FLAGS.
set -euo pipefail
cd "$(dirname "${BASH_SOURCE[0]}")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}"
export PYTHONPATH="src${PYTHONPATH:+:${PYTHONPATH}}"

exec python -m pytest -x -q "$@"
