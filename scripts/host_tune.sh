#!/usr/bin/env bash
# Host-side performance tuning for CPU benchmark runs. Source this (don't
# execute it) from a bench entrypoint:
#
#   source scripts/host_tune.sh
#
# Two idioms, both from large-scale JAX-on-host training setups:
#
# 1. tcmalloc via LD_PRELOAD. glibc malloc serializes the allocator under
#    XLA's multi-threaded host execution; tcmalloc's per-thread caches
#    remove that contention. Preloaded only if an installed copy is found
#    — a bare container runs unchanged.
# 2. XLA_FLAGS=--xla_force_host_platform_device_count=N so multi-device
#    code paths (pipeline stages, data-parallel chips) actually lower on
#    a CPU host instead of collapsing to one device.
#
# Everything exported here lands in the bench artifact's "host" block
# (benchmarks/common.py host_env()), so a tuned run is distinguishable
# from a bare one. Explicit env vars always win: each export below keeps
# a value the caller already set.

_repro_find_tcmalloc() {
  local candidates=(
    /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4
    /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4
    /usr/lib/libtcmalloc.so.4
    /usr/lib64/libtcmalloc.so.4
  )
  local c
  for c in "${candidates[@]}"; do
    if [[ -e "$c" ]]; then
      echo "$c"
      return 0
    fi
  done
  return 1
}

if [[ -z "${LD_PRELOAD:-}" ]]; then
  if _tcmalloc="$(_repro_find_tcmalloc)"; then
    export LD_PRELOAD="$_tcmalloc"
  fi
  unset _tcmalloc
fi

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}"

# marker: bench artifacts record that this file configured the host
export REPRO_HOST_TUNE="tcmalloc=${LD_PRELOAD:-none};${XLA_FLAGS}"
