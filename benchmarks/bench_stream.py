"""Incremental streaming benchmark: peak stream residency + take/compute
overlap, for *both* pipeline-path runners.

The materialized pipeline paths held the whole stream in host *and* device
memory (one ``jnp.asarray`` over all R rounds) before training a single
item. The incremental paths — the elastic trainer since PR 4, the
pipelined (single-plan) ``FerretTrainer`` since PR 5 — pull
``take(segment_rounds)`` per segment through a ``BufferedStreamSource``
feeder and prefetch segment k+1 on a background thread while segment k
runs on device, so:

1. **Peak stream residency** is O(segment_rounds + prefetch window), not
   O(R). Measured here: the feeder's ``peak_buffered_rounds`` (converted
   to bytes) against the R·round_bytes the materialized path resided.
2. **Arrival cost overlaps compute.** With a source that takes real time
   to produce rounds (here: a generator with a simulated per-round
   arrival cost), prefetching hides that cost behind the device scan.
   Measured here: total time blocked on the source, prefetch on vs off.
3. **Bit-exactness.** The incremental unbounded run must equal the
   materialized dict run on the same rounds — asserted, and recorded as
   ``bit_exact`` (elastic) / ``pipelined.bit_exact`` in the payload.
4. **MAS exactness.** The pipelined runner applies MAS's Ω-weighted
   parameter penalty through the ``FerretEngine`` hook (no Vanilla
   fallback): asserted by divergence from a vanilla run on identical
   data, recorded as ``pipelined.mas_engine_exact``.

Writes the machine-readable ``BENCH_stream.json`` at the repo root (CI
uploads it as an artifact next to ``BENCH_elastic.json``).

    PYTHONPATH=src python -m benchmarks.bench_stream
"""

from __future__ import annotations

import json
import math
import os
import time

import numpy as np

from benchmarks import common as C
from repro.api.streams import IterableStreamSource
from repro.core.compensation import CompensationConfig
from repro.core.ferret import FerretConfig, FerretTrainer
from repro.ocl.algorithms import OCLConfig
from repro.runtime import ElasticStreamTrainer

BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "BENCH_stream.json"
)

STREAM_LEN = 192
SEGMENT_ROUNDS = 16
ARRIVAL_COST_S = 0.002  # simulated per-round production cost of the feed


def _ferret_cfg() -> FerretConfig:
    return FerretConfig(
        budget_bytes=math.inf, lr=5e-3,
        compensation=CompensationConfig(method="iter_fisher", eta_lambda=1e-4),
        max_workers=3, max_stages=4,
    )


def _trainer(cfg) -> ElasticStreamTrainer:
    return ElasticStreamTrainer(cfg, _ferret_cfg(), batch=C.BATCH, seq=C.SEQ)


def _live_feed(arrays, arrival_cost_s: float = 0.0) -> IterableStreamSource:
    """The benchmark stream as an unbounded live feed (length undeclared)."""

    def rounds():
        R = next(iter(arrays.values())).shape[0]
        for m in range(R):
            if arrival_cost_s:
                time.sleep(arrival_cost_s)
            yield {k: v[m] for k, v in arrays.items()}

    return IterableStreamSource(rounds())


def run(write_json: bool = True) -> dict:
    cfg = C.bench_model()
    params = C.init_params(cfg)
    arrays = C.bench_stream(length=STREAM_LEN)
    round_bytes = sum(np.asarray(v[0]).nbytes for v in arrays.values())

    # --- materialized reference: dict input, same segmenting ---
    t0 = time.time()
    base = _trainer(cfg).run_stream(params, arrays, segment_rounds=SEGMENT_ROUNDS)
    base_s = time.time() - t0

    # --- incremental unbounded run (instant source): residency + exactness ---
    t0 = time.time()
    res = _trainer(cfg).run_stream(
        params, _live_feed(arrays), segment_rounds=SEGMENT_ROUNDS
    )
    incr_s = time.time() - t0
    bit_exact = bool(
        np.array_equal(np.asarray(base.losses), np.asarray(res.losses))
        and np.array_equal(base.online_acc_curve, res.online_acc_curve)
    )
    assert bit_exact, "incremental run diverged from the materialized run"
    assert res.peak_buffered_rounds < STREAM_LEN, "residency must not be O(R)"

    # --- overlap: a slow feed, prefetch on vs off ---
    slow_on = _trainer(cfg).run_stream(
        params, _live_feed(arrays, ARRIVAL_COST_S),
        segment_rounds=SEGMENT_ROUNDS, prefetch=True,
    )
    slow_off = _trainer(cfg).run_stream(
        params, _live_feed(arrays, ARRIVAL_COST_S),
        segment_rounds=SEGMENT_ROUNDS, prefetch=False,
    )

    # --- pipelined (single-plan) runner: same feeder, same guarantees ---
    def _pipelined(source, **kw):
        tr = FerretTrainer(cfg, _ferret_cfg(), batch=C.BATCH, seq=C.SEQ)
        return tr.run_stream(params, source, segment_rounds=SEGMENT_ROUNDS, **kw)

    t0 = time.time()
    pipe_base = _pipelined(arrays)
    pipe_base_s = time.time() - t0
    t0 = time.time()
    pipe_incr = _pipelined(_live_feed(arrays))
    pipe_incr_s = time.time() - t0
    pipe_bit_exact = bool(
        np.array_equal(np.asarray(pipe_base.losses), np.asarray(pipe_incr.losses))
        and np.array_equal(pipe_base.online_acc_curve, pipe_incr.online_acc_curve)
    )
    assert pipe_bit_exact, "pipelined incremental run diverged from materialized"
    assert pipe_incr.peak_buffered_rounds < STREAM_LEN, "residency must not be O(R)"

    # MAS exactness on the pipeline path: the engine penalty hook is live
    # iff the MAS trajectory diverges from vanilla on identical data
    mas_arrays = {k: v[:24] for k, v in arrays.items()}
    mas_fc = FerretConfig(
        budget_bytes=math.inf, lr=5e-3,
        compensation=CompensationConfig(method="iter_fisher", eta_lambda=1e-4),
        max_workers=3, max_stages=4,
        ocl=OCLConfig(method="mas", mas_weight=10.0),
    )
    mas_res = FerretTrainer(
        cfg, mas_fc, batch=C.BATCH, seq=C.SEQ, algorithm="mas"
    ).run_stream(params, mas_arrays, segment_rounds=SEGMENT_ROUNDS)
    van_res = FerretTrainer(
        cfg, mas_fc, batch=C.BATCH, seq=C.SEQ, algorithm="vanilla"
    ).run_stream(params, mas_arrays, segment_rounds=SEGMENT_ROUNDS)
    mas_engine_exact = bool(
        not np.allclose(np.asarray(mas_res.losses), np.asarray(van_res.losses))
        and np.isfinite(np.asarray(mas_res.losses)).all()
    )
    assert mas_engine_exact, "MAS ran as Vanilla on the pipeline path"

    residency_bytes = res.peak_buffered_rounds * round_bytes
    materialized_bytes = STREAM_LEN * round_bytes
    arrival_total_s = STREAM_LEN * ARRIVAL_COST_S
    print(
        f"stream: {STREAM_LEN} rounds × {round_bytes} B, "
        f"segment_rounds={SEGMENT_ROUNDS}"
    )
    print(
        f"peak stream residency (elastic): {res.peak_buffered_rounds} rounds "
        f"({residency_bytes} B) vs materialized {STREAM_LEN} rounds "
        f"({materialized_bytes} B) — {materialized_bytes / residency_bytes:.1f}× less"
    )
    print(f"bit-exact with materialized run: {bit_exact}")
    print(
        f"peak stream residency (pipelined): {pipe_incr.peak_buffered_rounds} "
        f"rounds ({pipe_incr.peak_buffered_rounds * round_bytes} B) — "
        f"bit-exact={pipe_bit_exact}, MAS-engine-exact={mas_engine_exact}"
    )
    print(
        f"slow feed ({1e3 * ARRIVAL_COST_S:.1f} ms/round, "
        f"{arrival_total_s:.2f}s total arrival): blocked on source "
        f"{slow_on.stream_wait_s:.2f}s with prefetch vs "
        f"{slow_off.stream_wait_s:.2f}s without "
        f"({slow_off.stream_wait_s - slow_on.stream_wait_s:+.2f}s overlapped)"
    )
    seg_rows = [
        {
            "start": s.start, "end": s.end,
            "take_s": s.take_s, "run_s": s.run_s,
            "cache_hit": s.cache_hit,
        }
        for s in slow_on.segments
    ]
    overlapped = [s for s in slow_on.segments[1:]]  # first take can't overlap
    if overlapped:
        mean_take = sum(s.take_s for s in overlapped) / len(overlapped)
        print(
            f"per-segment take (prefetch warm): {1e3 * mean_take:.2f} ms "
            f"vs segment compute "
            f"{1e3 * sum(s.run_s for s in overlapped) / len(overlapped):.2f} ms"
        )

    payload = {
        "bench": "stream",
        "host": C.host_env(),
        "stream_len": STREAM_LEN,
        "segment_rounds": SEGMENT_ROUNDS,
        "round_bytes": round_bytes,
        "peak_buffered_rounds": res.peak_buffered_rounds,
        "peak_residency_bytes": residency_bytes,
        "materialized_bytes": materialized_bytes,
        "residency_ratio": residency_bytes / materialized_bytes,
        "bit_exact": bit_exact,
        "materialized_wall_s": base_s,
        "incremental_wall_s": incr_s,
        "arrival_cost_s_per_round": ARRIVAL_COST_S,
        "arrival_total_s": arrival_total_s,
        "stream_wait_s": {
            "prefetch": slow_on.stream_wait_s,
            "no_prefetch": slow_off.stream_wait_s,
            "overlapped_s": slow_off.stream_wait_s - slow_on.stream_wait_s,
        },
        "segments": seg_rows,
        "pipelined": {
            "peak_buffered_rounds": pipe_incr.peak_buffered_rounds,
            "peak_residency_bytes": pipe_incr.peak_buffered_rounds * round_bytes,
            "residency_ratio": (
                pipe_incr.peak_buffered_rounds * round_bytes / materialized_bytes
            ),
            "bit_exact": pipe_bit_exact,
            "mas_engine_exact": mas_engine_exact,
            "materialized_wall_s": pipe_base_s,
            "incremental_wall_s": pipe_incr_s,
            "stream_wait_s": pipe_incr.stream_wait_s,
        },
    }
    if write_json:
        with open(BENCH_JSON, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"\nwrote {BENCH_JSON}")
    return payload


def main() -> None:
    t0 = time.time()
    payload = run()
    dt = (time.time() - t0) * 1e6 / STREAM_LEN
    print(
        f"bench_stream,{dt:.0f},"
        f"residency_ratio={payload['residency_ratio']:.3f}"
    )


if __name__ == "__main__":
    main()
