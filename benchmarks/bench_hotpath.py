"""Hot-path microbenchmark: packed compensation + engine-cache replans.

Two recurring costs dominate Ferret's real-time budget (Ghunaim et al.:
an OCL method that can't keep up loses accuracy to the delay itself):

1. **Per-stage-update compensation.** The per-leaf path dispatches one
   op/kernel per pytree leaf per step; the flat-packed path
   (``repro.kernels.packing``) is one pass over one contiguous buffer —
   exactly 1 kernel launch on the Pallas path regardless of leaf count.
   Measured here: jit'd ``comp.compensate`` latency, packed vs per-leaf,
   on the benchmark model's parameter tree. NOTE the packed win is a
   *launch-count* win: on the CPU jnp backend (this container / CI) the
   per-leaf loop is fully XLA-fused, so packed shows its pack/unpack copy
   cost and ``speedup_call`` < 1 is expected there — which is exactly why
   the default dispatch packs only when the Pallas kernels are in use
   (``REPRO_PACK`` forces either way).

2. **Per-switch engine compiles.** ``ElasticStreamTrainer`` pads segment
   lengths to a geometric bucket set and caches compiled engines on
   (partition, ring geometry, bucket), so an A→B→A budget schedule
   compiles 2 engines instead of 3 and every later same-shape segment is
   a cache hit. Measured here: the same A→B→A run with the cache enabled
   vs disabled.

Writes the machine-readable ``BENCH_hotpath.json`` at the repo root (CI
uploads it as an artifact) so both numbers are tracked across PRs.

    PYTHONPATH=src python -m benchmarks.bench_hotpath
"""

from __future__ import annotations

import json
import math
import os
import time

import jax
import jax.numpy as jnp

from benchmarks import common as C
from repro.core import compensation as comp
from repro.core.compensation import CompensationConfig
from repro.core.ferret import EngineCache, FerretConfig
from repro.kernels import packing
from repro.models import transformer as T
from repro.runtime import BudgetEvent, ElasticStreamTrainer

BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "BENCH_hotpath.json"
)

TAU = 4
TIMED_ITERS = 30
STREAM_LEN = 120
SWITCHES = (40, 80)


def _time_call(fn, *args, iters: int = TIMED_ITERS):
    """(compile_s, per-call ms) for a jit'd fn."""
    t0 = time.perf_counter()
    out = fn(*args)
    jax.block_until_ready(out)
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return compile_s, (time.perf_counter() - t0) * 1e3 / iters


def bench_compensation() -> dict:
    cfg = C.bench_model()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    leaves = jax.tree.leaves(params)
    odd = sum(1 for leaf in leaves if leaf.size % 128 != 0)
    deltas = jax.tree.map(
        lambda p: jnp.ones((TAU, *p.shape), jnp.float32) * 1e-3, params
    )
    ccfg = CompensationConfig(method="iter_fisher", eta_lambda=1e-3)
    state = comp.init_state(params, ccfg)

    timings = {}
    for label, env in (("packed", "1"), ("per_leaf", "0")):
        os.environ["REPRO_PACK"] = env
        try:
            fn = jax.jit(lambda s, g, d: comp.compensate(ccfg, s, g, d))
            compile_s, call_ms = _time_call(fn, state, params, deltas)
            timings[label] = {"compile_s": compile_s, "call_ms": call_ms}
        finally:
            os.environ.pop("REPRO_PACK", None)

    # Pallas launch counts (interpret mode): packed is 1+1 per step by
    # construction; the per-leaf path is one launch per leaf per kernel.
    n0 = packing.KERNEL_LAUNCHES
    packing.compensate_tree(
        params, deltas, jnp.asarray(0.2, jnp.float32), use_pallas=True, interpret=True
    )
    packed_launches = packing.KERNEL_LAUNCHES - n0

    out = {
        "leaves": len(leaves),
        "odd_sized_leaves": odd,  # previously excluded from the Pallas path
        "tau": TAU,
        "param_count": sum(leaf.size for leaf in leaves),
        "packed": timings["packed"],
        "per_leaf": timings["per_leaf"],
        "speedup_call": timings["per_leaf"]["call_ms"] / timings["packed"]["call_ms"],
        "speedup_compile": (
            timings["per_leaf"]["compile_s"] / timings["packed"]["compile_s"]
        ),
        "pallas_launches_per_compensate": {
            "packed": packed_launches,
            "per_leaf": len(leaves),
        },
    }
    print(
        f"compensation ({len(leaves)} leaves, {odd} odd-sized, tau={TAU}): "
        f"per-leaf {timings['per_leaf']['call_ms']:.3f} ms → "
        f"packed {timings['packed']['call_ms']:.3f} ms "
        f"({out['speedup_call']:.2f}x); compile "
        f"{timings['per_leaf']['compile_s']:.2f}s → "
        f"{timings['packed']['compile_s']:.2f}s; "
        f"launches {len(leaves)} → {packed_launches}"
    )
    return out


def _elastic_run(cache: EngineCache) -> dict:
    cfg = C.bench_model()
    params = C.init_params(cfg)
    stream = C.bench_stream(length=STREAM_LEN)
    fc = FerretConfig(
        budget_bytes=math.inf, lr=5e-3,
        compensation=CompensationConfig(method="iter_fisher", eta_lambda=1e-4),
        max_workers=3, max_stages=4,
    )
    et = ElasticStreamTrainer(
        cfg, fc, batch=C.BATCH, seq=C.SEQ, engine_cache=cache
    )
    full = et.plan_for(math.inf)
    schedule = [
        BudgetEvent(SWITCHES[0], full.memory * 0.3),  # A → B
        BudgetEvent(SWITCHES[1], math.inf),  # B → A (back)
    ]
    t0 = time.perf_counter()
    res = et.run_stream(params, stream, schedule)
    wall_s = time.perf_counter() - t0
    return {
        "wall_s": wall_s,
        "segments": len(res.segments),
        "num_replans": res.num_replans,
        "cache_hits": res.engine_cache_hits,
        "cache_misses": res.engine_cache_misses,
        "replan_ms_total": 1e3 * sum(s.replan_s for s in res.segments),
        "remap_ms_total": 1e3 * sum(s.remap_s for s in res.segments),
        "run_s_per_segment": [round(s.run_s, 4) for s in res.segments],
        "online_acc": res.online_acc,
    }


def bench_elastic_switch_cache() -> dict:
    cached = _elastic_run(EngineCache())
    uncached = _elastic_run(EngineCache(enabled=False))
    out = {
        "stream_len": STREAM_LEN,
        "switches": list(SWITCHES),
        "schedule": "A->B->A",
        "cached": cached,
        "uncached": uncached,
        "switch_wall_saved_s": uncached["wall_s"] - cached["wall_s"],
    }
    print(
        f"elastic A->B->A ({STREAM_LEN} rounds): cached "
        f"{cached['wall_s']:.2f}s (misses={cached['cache_misses']}, "
        f"hits={cached['cache_hits']}) vs uncached {uncached['wall_s']:.2f}s "
        f"(misses={uncached['cache_misses']})"
    )
    return out


def run(write_json: bool = True) -> dict:
    payload = {
        "bench": "hotpath",
        "backend": jax.default_backend(),
        "host": C.host_env(),
        "compensation": bench_compensation(),
        "elastic_cache": bench_elastic_switch_cache(),
    }
    if write_json:
        with open(BENCH_JSON, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {BENCH_JSON}")
    return payload


def main() -> None:
    t0 = time.time()
    payload = run()
    comp_ = payload["compensation"]
    print(
        f"bench_hotpath,{(time.time() - t0) * 1e3:.0f}ms,"
        f"packed_speedup={comp_['speedup_call']:.2f}x,"
        f"cache_hits={payload['elastic_cache']['cached']['cache_hits']}"
    )


if __name__ == "__main__":
    main()
