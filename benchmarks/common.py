"""Shared benchmark scaffolding (now on the ``repro.api`` session layer).

The paper's image datasets aren't available offline, so every benchmark runs
the paper's *protocol* over generated streams (DESIGN.md §9): a drifting
Markov token stream + a small decoder LM (the Covertype/MLP-scale analogue).
All comparisons are relative (agm/tagm against a named baseline), exactly as
in the paper's tables.
"""

from __future__ import annotations

import math
import os
from typing import Dict, Optional, Union

import jax

from repro.api import FerretSession, OCLAlgorithm, StreamResult
from repro.core.compensation import CompensationConfig
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.ocl.algorithms import OCLConfig
from repro.ocl.baselines import AdmissionPolicy
from repro.ocl.streams import StreamConfig, make_stream
from repro.runtime.topology import DeviceTopology

VOCAB = 32
SEQ = 16
BATCH = 2
STREAM_LEN = 240


def bench_model(num_layers: int = 4) -> ModelConfig:
    return ModelConfig(
        name="bench-lm",
        family="dense",
        num_layers=num_layers,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=VOCAB,
        compute_dtype="float32",
    )


def bench_stream(kind: str = "drift", length: int = STREAM_LEN, seed: int = 0) -> Dict:
    return make_stream(
        StreamConfig(
            kind=kind, modality="tokens", length=length, batch=BATCH,
            vocab=VOCAB, seq=SEQ, seed=seed, drift_rate=0.004, num_tasks=4,
        )
    )


def init_params(cfg: ModelConfig, seed: int = 0):
    return T.init_params(cfg, jax.random.PRNGKey(seed))


def bench_session(
    cfg: ModelConfig,
    params,
    stream,
    budget: float = math.inf,
    algorithm: Union[str, OCLConfig, OCLAlgorithm] = "vanilla",
    method: str = "iter_fisher",
    eta_lambda: float = 1e-4,
    ocl: Optional[OCLConfig] = None,
    lr: float = 5e-3,
    max_workers: int = 3,
    max_stages: int = 4,
    profile=None,
) -> FerretSession:
    """One benchmark-shaped ``FerretSession`` (CPU-smoke planner limits)."""
    return FerretSession(
        cfg, budget, algorithm, stream,
        ocl=ocl, lr=lr, batch=BATCH, seq=SEQ, params=params, profile=profile,
        compensation=CompensationConfig(method=method, eta_lambda=eta_lambda),
        max_workers=max_workers, max_stages=max_stages,
    )


def run_ferret(cfg, params, stream, **kwargs) -> tuple:
    """Pipelined Ferret run; returns ``(session, StreamResult)``."""
    ocl = kwargs.get("ocl")
    kwargs.setdefault("algorithm", ocl.method if ocl is not None else "vanilla")
    session = bench_session(cfg, params, stream, **kwargs)
    return session, session.run("pipelined")


def run_admission_baseline(
    cfg,
    params,
    stream,
    policy: AdmissionPolicy,
    slowdown: float = 3.0,
    lr: float = 5e-3,
) -> StreamResult:
    """Skip-style baseline: t_train = slowdown · t_d ⇒ items get dropped.

    Memory = one model copy (+ buffer items for buffered policies)."""
    session = bench_session(cfg, params, stream, lr=lr)
    return session.run("baseline", policy=policy, slowdown=slowdown)


def model_bytes(cfg: ModelConfig) -> float:
    return cfg.param_count() * 4.0


_HOST_ENV_KEYS = (
    "JAX_PLATFORMS", "XLA_FLAGS", "LD_PRELOAD", "REPRO_HOST_TUNE",
    "REPRO_USE_PALLAS", "REPRO_PACK", "REPRO_PACK_BLOCK",
    "REPRO_SEGMENT_BUCKETS", "REPRO_PROFILE_DIR",
)


def host_env() -> Dict:
    """The host-tuning flags + device topology active for this process.

    Recorded into every bench artifact so numbers are comparable across
    runs — a tcmalloc'd ``scripts/bench.sh`` run and a bare ``python -m``
    run must never be confused for each other, and a number measured on
    8 fake devices must never be compared against a 1-device run."""
    env: Dict = {k: os.environ[k] for k in _HOST_ENV_KEYS if k in os.environ}
    env["device_topology"] = DeviceTopology.discover().describe()
    return env
