"""Shared benchmark scaffolding.

The paper's image datasets aren't available offline, so every benchmark runs
the paper's *protocol* over generated streams (DESIGN.md §9): a drifting
Markov token stream + a small decoder LM (the Covertype/MLP-scale analogue).
All comparisons are relative (agm/tagm against a named baseline), exactly as
in the paper's tables.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import jax
import numpy as np

from repro.core.compensation import CompensationConfig
from repro.core.ferret import FerretConfig, FerretTrainer, sequential_oracle_run
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.ocl.algorithms import OCLConfig
from repro.ocl.baselines import AdmissionPolicy, make_admission_mask
from repro.ocl.streams import StreamConfig, make_stream

VOCAB = 32
SEQ = 16
BATCH = 2
STREAM_LEN = 240


def bench_model(num_layers: int = 4) -> ModelConfig:
    return ModelConfig(
        name="bench-lm",
        family="dense",
        num_layers=num_layers,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=VOCAB,
        compute_dtype="float32",
    )


def bench_stream(kind: str = "drift", length: int = STREAM_LEN, seed: int = 0) -> Dict:
    return make_stream(
        StreamConfig(
            kind=kind, modality="tokens", length=length, batch=BATCH,
            vocab=VOCAB, seq=SEQ, seed=seed, drift_rate=0.004, num_tasks=4,
        )
    )


def init_params(cfg: ModelConfig, seed: int = 0):
    return T.init_params(cfg, jax.random.PRNGKey(seed))


def run_ferret(
    cfg: ModelConfig,
    params,
    stream,
    budget: float = math.inf,
    method: str = "iter_fisher",
    eta_lambda: float = 1e-4,
    ocl: Optional[OCLConfig] = None,
    lr: float = 5e-3,
    max_workers: int = 3,
    max_stages: int = 4,
):
    fc = FerretConfig(
        budget_bytes=budget,
        lr=lr,
        compensation=CompensationConfig(method=method, eta_lambda=eta_lambda),
        ocl=ocl or OCLConfig(),
        max_workers=max_workers,
        max_stages=max_stages,
    )
    tr = FerretTrainer(cfg, fc, batch=BATCH, seq=SEQ)
    res = tr.run_stream(params, stream)
    return tr, res


def run_admission_baseline(
    cfg: ModelConfig,
    params,
    stream,
    policy: AdmissionPolicy,
    slowdown: float = 3.0,
    lr: float = 5e-3,
):
    """Skip-style baseline: t_train = slowdown · t_d ⇒ items get dropped.

    Memory = one model copy (+ buffer items for buffered policies)."""
    R = next(iter(stream.values())).shape[0]
    trace = make_admission_mask(policy, R, t_d=1.0, t_train=slowdown)
    out = sequential_oracle_run(cfg, params, stream, lr=lr, trained_mask=trace.admitted)
    mem = model_bytes(cfg) * 1.0
    if policy.method in ("random_n", "last_n", "camel"):
        mem += policy.buffer * BATCH * SEQ * 8  # buffered raw items
    return {
        "oacc": float(out["acc"].mean()),
        "acc": out["acc"],
        "memory": mem,
        "admitted": float(trace.admitted.mean()),
        "delays": trace.delays,
    }


def model_bytes(cfg: ModelConfig) -> float:
    return cfg.param_count() * 4.0
