"""Paper Table 2: OCL algorithms (Vanilla/ER/MIR/LwF/MAS) integrated into
Ferret vs the skip baselines — agm + tagm on a split (class-incremental)
stream, test accuracy measured on a held-out mix of all tasks.

Runs through ``repro.api.FerretSession``: the registered algorithm owns its
stream preparation (replay mixing, teacher logits), so no per-algorithm
wiring lives here anymore.
"""

from __future__ import annotations

import math
import time
from typing import Dict

import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.api import available_algorithms
from repro.models import transformer as T
from repro.ocl.algorithms import OCLConfig
from repro.ocl.baselines import AdmissionPolicy
from repro.ocl.metrics import agm, tagm

ALGOS = ["vanilla", "er", "mir", "lwf", "mas"]
assert set(ALGOS) <= set(available_algorithms())


def _test_accuracy(cfg, params, test_stream) -> float:
    accs = []
    for m in range(test_stream["tokens"].shape[0]):
        batch = {k: jnp.asarray(v[m]) for k, v in test_stream.items() if k != "new_mask"}
        logits, _ = T.forward(cfg, params, batch)
        accs.append(float(jnp.mean((jnp.argmax(logits, -1) == batch["labels"]))))
    return float(np.mean(accs))


def run(verbose: bool = True) -> Dict[str, Dict]:
    cfg = C.bench_model()
    params = C.init_params(cfg)
    stream = C.bench_stream("split")
    test_stream = C.bench_stream("iid", length=24, seed=99)

    results: Dict[str, Dict] = {}
    ocl = OCLConfig(replay_batch=2, replay_size=64)
    for algo in ALGOS:
        session = C.bench_session(
            cfg, params, stream, budget=math.inf, algorithm=algo, ocl=ocl
        )
        res = session.run("pipelined")
        tacc = _test_accuracy(cfg, res.final_params, test_stream)
        results[f"Ferret_M+/{algo}"] = {
            "oacc": res.online_acc, "tacc": tacc, "memory": res.memory_bytes,
        }

    # 1-Skip baseline (vanilla)
    r = C.run_admission_baseline(cfg, params, stream, AdmissionPolicy("one_skip"))
    results["1-Skip/vanilla"] = {"oacc": r.online_acc, "tacc": None, "memory": r.memory_bytes}

    base = results["1-Skip/vanilla"]
    t_base = results["Ferret_M+/vanilla"]["tacc"]
    for name, v in results.items():
        v["agm"] = agm(100 * v["oacc"], 100 * base["oacc"],
                       max(v["memory"], 1.0), max(base["memory"], 1.0))
        v["tagm"] = (
            tagm(100 * v["tacc"], 100 * t_base,
                 max(v["memory"], 1.0), results["Ferret_M+/vanilla"]["memory"])
            if v["tacc"] is not None else None
        )
    if verbose:
        print("\nTable 2 (OCL algorithm integration):")
        for name, v in results.items():
            t = f"{100*v['tacc']:5.2f}%" if v["tacc"] is not None else "  n/a "
            print(f"  {name:22s} oacc={100*v['oacc']:6.2f}% tacc={t} agm={v['agm']:7.2f}")
    return results


def main():
    t0 = time.time()
    res = run()
    dt = (time.time() - t0) * 1e6 / (C.STREAM_LEN * len(ALGOS))
    er_gain = res["Ferret_M+/er"]["tacc"] - res["Ferret_M+/vanilla"]["tacc"]
    print(f"table2_ocl,{dt:.0f},er_tacc_gain={er_gain:+.4f}")


if __name__ == "__main__":
    main()
