"""Paper Table 4: gradient-compensation ablation on the async pipeline.

None / Step-Aware / Gap-Aware / Fisher / Iter-Fisher applied to Ferret_M+;
reported as Δoacc vs None. Expected (paper §6.4): Step-Aware and Gap-Aware
*hurt* (they just shrink steps), Fisher ≈ none, Iter-Fisher ≥ all.
"""

from __future__ import annotations

import math
import time
from typing import Dict

from benchmarks import common as C

METHODS = ["none", "step_aware", "gap_aware", "fisher", "iter_fisher"]


def run(verbose: bool = True, seeds=(0, 1)) -> Dict[str, float]:
    # Regime where staleness matters (tracking-limited; see EXPERIMENTS.md):
    # fast drift, P=6 pipeline (τ up to 5), lr at the tracking optimum.
    from repro.models.config import ModelConfig
    from repro.ocl.streams import StreamConfig, make_stream

    cfg = ModelConfig(name="t4", family="dense", num_layers=6, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=16,
                      compute_dtype="float32")
    out: Dict[str, list] = {m: [] for m in METHODS}
    for seed in seeds:
        params = C.init_params(cfg, seed=seed)
        stream = make_stream(StreamConfig(
            kind="drift", modality="tokens", length=400, batch=2,
            vocab=16, seq=32, drift_rate=0.02, seed=seed,
        ))
        for method in METHODS:
            eta = 1e-4 if method == "iter_fisher" else 0.0
            _, res = C.run_ferret(
                cfg, params, stream, budget=math.inf, method=method,
                eta_lambda=eta, lr=1e-2, max_workers=2, max_stages=6,
            )
            out[method].append(res.online_acc)
    mean = {m: sum(v) / len(v) for m, v in out.items()}
    if verbose:
        print("\nTable 4 (Δoacc vs none, %):")
        for m in METHODS:
            print(f"  {m:12s} oacc={100*mean[m]:6.2f}%  Δ={100*(mean[m]-mean['none']):+6.2f}")
    return mean


def main():
    t0 = time.time()
    mean = run()
    dt = (time.time() - t0) * 1e6 / (C.STREAM_LEN * len(METHODS) * 2)
    gain = mean['iter_fisher'] - mean['none']
    print(f"table4_compensation,{dt:.0f},iterfisher_minus_none={gain:+.4f}")


if __name__ == "__main__":
    main()
