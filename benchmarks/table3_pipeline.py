"""Paper Table 3: pipeline-parallelism strategies (agm vs DAPPLE).

Synchronous: DAPPLE (full flush), ZB (zero-bubble — same update dynamics,
higher hardware utilization ⇒ shorter effective flush), Hanayo 1W/2W/3W
(wave pipelines — flush period P/w). Asynchronous: PipeDream (per-item
updates, τ_j staleness, no accumulation), PipeDream-2BW (async + grad
accumulation = 2 weight versions), Ferret_M (planned T1–T4). No gradient
compensation anywhere (paper's protocol for this table).

Memory comes from the Ferret cost model evaluated on each strategy's
equivalent configuration — the same accounting for everyone.
"""

from __future__ import annotations

import time
from typing import Dict

import jax

from benchmarks import common as C
from repro.core import compensation as comp
from repro.core import cost_model as cm
from repro.core import pipeline as pl
from repro.core import schedule as sch
from repro.core.planner import plan as ferret_plan
from repro.core.profiler import analytic_profile
from repro.models import transformer as T
from repro.ocl.metrics import agm
from repro.optim.optimizers import adamw

P_STAGES = 4


def _engine_run(cfg, params, stream, schedule, lr=5e-3):
    boundaries = [0] + [cfg.num_layers * (j + 1) // P_STAGES for j in range(P_STAGES)]
    staged = pl.staged_from_transformer(cfg, boundaries)
    eng = pl.FerretEngine(
        staged, schedule, adamw(lr=lr), comp.CompensationConfig(method="none"), lr=lr
    )
    state = eng.init_state(T.split_stage_params(cfg, params, boundaries))
    _, ys = eng.run(state, {k: jax.numpy.asarray(v) for k, v in stream.items()})
    import numpy as np

    return float(np.asarray(ys["acc"]).mean())


def _memory_of(stats, accum, omit_all):
    w = cm.WorkerConfig(
        0, 0, [cm.StageKnobs(accum=accum, omit=omit_all) for _ in range(P_STAGES)]
    )
    return cm.worker_memory(stats, w)


def run(verbose: bool = True) -> Dict[str, Dict]:
    cfg = C.bench_model(num_layers=P_STAGES)
    params = C.init_params(cfg)
    stream = C.bench_stream("drift")
    R = C.STREAM_LEN
    profile = analytic_profile(cfg, C.BATCH, C.SEQ)
    part = cm.Partition(tuple(range(P_STAGES + 1)))
    stats = cm.stage_stats(profile, part)
    one_worker = cm.PipelineConfig(
        workers=[cm.WorkerConfig(0, 0, [cm.StageKnobs() for _ in range(P_STAGES)])]
    )

    results: Dict[str, Dict] = {}

    def sync(name, period):
        s = sch.build_schedule(one_worker, P_STAGES, R, sync_period=period)
        acc = _engine_run(cfg, params, stream, s)
        # sync flush: every in-flight microbatch holds activations; weights 1 copy
        mem = _memory_of(stats, accum=period, omit_all=0)
        results[name] = {"oacc": acc, "memory": mem}

    sync("DAPPLE", P_STAGES)
    sync("ZB", P_STAGES)  # same updates; ZB's win is bubble wall-clock (R-side)
    sync("Hanayo_1W", P_STAGES)
    sync("Hanayo_2W", max(P_STAGES // 2, 1))
    sync("Hanayo_3W", max(P_STAGES // 3, 1))

    # async PipeDream: per-item updates with τ_j staleness
    s_async = sch.build_schedule(one_worker, P_STAGES, R)
    acc = _engine_run(cfg, params, stream, s_async)
    results["PipeDream"] = {"oacc": acc, "memory": _memory_of(stats, 1, 0)}

    # PipeDream-2BW: async + accumulation (2 weight versions)
    two_bw = cm.PipelineConfig(
        workers=[cm.WorkerConfig(0, 0, [cm.StageKnobs(accum=P_STAGES) for _ in range(P_STAGES)])]
    )
    s_2bw = sch.build_schedule(two_bw, P_STAGES, R)
    acc = _engine_run(cfg, params, stream, s_2bw)
    results["PipeDream2BW"] = {"oacc": acc, "memory": _memory_of(stats, P_STAGES, 0)}

    # Ferret_M: planner-chosen config at the 2BW memory budget (paper §6.1)
    budget = results["PipeDream2BW"]["memory"] + profile.embed_bytes
    fplan = ferret_plan(profile, t_d=1e9, budget=budget, max_workers=1, max_stages=P_STAGES)
    s_f = sch.build_schedule(fplan.config, fplan.partition.num_stages, R)
    boundaries = list(fplan.partition.bounds)
    staged = pl.staged_from_transformer(cfg, boundaries)
    eng = pl.FerretEngine(
        staged, s_f, adamw(lr=5e-3), comp.CompensationConfig(method="none"), lr=5e-3
    )
    state = eng.init_state(T.split_stage_params(cfg, params, boundaries))
    import numpy as np

    _, ys = eng.run(state, {k: jax.numpy.asarray(v) for k, v in stream.items()})
    results["Ferret_M"] = {"oacc": float(np.asarray(ys["acc"]).mean()), "memory": fplan.memory}

    base = results["DAPPLE"]
    for name, r in results.items():
        r["agm"] = agm(100 * r["oacc"], 100 * base["oacc"],
                       max(r["memory"], 1.0), max(base["memory"], 1.0))
    if verbose:
        print("\nTable 3 (agm vs DAPPLE):")
        for name, r in results.items():
            print(f"  {name:14s} oacc={100*r['oacc']:6.2f}%  mem={r['memory']/2**20:7.1f}MiB"
                  f"  agm={r['agm']:7.2f}")
    return results


def main():
    t0 = time.time()
    res = run()
    dt = (time.time() - t0) * 1e6 / C.STREAM_LEN
    async_adv = res["PipeDream"]["oacc"] - res["DAPPLE"]["oacc"]
    print(f"table3_pipeline,{dt:.0f},async_minus_sync_oacc={async_adv:+.4f}")


if __name__ == "__main__":
    main()
