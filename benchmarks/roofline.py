"""Roofline report: reads the dry-run JSON and prints the §Roofline table.

    compute    = HLO_FLOPs / peak            (per chip, s)
    memory     = HLO_bytes / HBM_bw          (per chip, s)
    collective = wire_bytes / ICI_bw         (per chip, s)
    MODEL_FLOPS = 6·N·D (train) — N active params, D tokens
    usefulness  = MODEL_FLOPS / HLO_FLOPs_total

Usage: PYTHONPATH=src python -m benchmarks.roofline [path/to/dryrun.json]
"""

from __future__ import annotations

import json
import sys
import time

from repro.configs.common import SHAPES
from repro.models.registry import get_config

DEFAULT = "results/dryrun_v2.json"


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.batch * shape.seq
    if shape.kind == "prefill":
        return 2.0 * n * shape.batch * shape.seq
    return 2.0 * n * shape.batch  # decode: one token per row


def _fallback_memory_model(rec) -> float:
    import math

    import jax

    from repro.launch.hlo_analysis import analytic_memory_bytes
    from repro.models import transformer as T

    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    chips = rec["roofline"]["chips"]
    model_shard = 1 if rec.get("variant") == "fsdp" else 16
    cache_bytes = 0
    if shape.kind != "train":
        cache_s = jax.eval_shape(lambda: T.init_cache(cfg, shape.batch, shape.seq))
        cache_bytes = sum(
            int(math.prod(leaf.shape)) * leaf.dtype.itemsize for leaf in jax.tree.leaves(cache_s)
        )
    return analytic_memory_bytes(
        cfg, shape, chips, model_shard, rec.get("microbatch", 1), cache_bytes
    )


def run(path: str = DEFAULT, verbose: bool = True):
    recs = json.load(open(path))
    rows = []
    for r in recs:
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        rf = r["roofline"]
        chips = rf["chips"]
        hlo_total = rf["flops_per_device"] * chips
        mf = model_flops(r["arch"], r["shape"])
        useful = mf / hlo_total if hlo_total else 0.0
        tMm = rf.get("t_memory_model_s")
        if tMm is None:  # older records: compute the traffic model here
            tMm = _fallback_memory_model(r) / 819e9
        step = max(rf["t_compute_s"], tMm, rf["t_collective_s"])
        frac = rf["t_compute_s"] / step if step else 0.0
        bound = max(
            [("compute", rf["t_compute_s"]), ("memory", tMm), ("collective", rf["t_collective_s"])],
            key=lambda kv: kv[1],
        )[0]
        rows.append(
            dict(
                arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
                tC=rf["t_compute_s"], tM=tMm, tMhlo=rf["t_memory_s"],
                tX=rf["t_collective_s"],
                bottleneck=bound, useful=useful, roofline_frac=frac,
                hbm=(r.get("memory_analysis") or {}).get("total_hbm_bytes", 0) / 2**30,
            )
        )
    if verbose:
        hdr = (f"{'arch':22s} {'shape':12s} {'mesh':8s} {'tC(s)':>9s} {'tM(s)':>9s} "
               f"{'tMhlo':>9s} {'tX(s)':>9s} {'bound':>10s} "
               f"{'useful':>7s} {'frac':>6s} {'HBM':>7s}")
        print(hdr)
        print("-" * len(hdr))
        for w in rows:
            print(
                f"{w['arch']:22s} {w['shape']:12s} {w['mesh']:8s} "
                f"{w['tC']:9.4f} {w['tM']:9.4f} {w['tMhlo']:9.4f} {w['tX']:9.4f} "
                f"{w['bottleneck']:>10s} "
                f"{w['useful']:7.2f} {w['roofline_frac']:6.2f} {w['hbm']:6.1f}G"
            )
    return rows


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else DEFAULT
    t0 = time.time()
    try:
        rows = run(path)
    except FileNotFoundError:
        print(f"roofline,0,missing={path}")
        return
    dt = (time.time() - t0) * 1e6 / max(len(rows), 1)
    worst = min(rows, key=lambda w: w["roofline_frac"]) if rows else None
    line = (f"roofline,{dt:.0f},worst_frac={worst['roofline_frac']:.3f}"
            if worst else "roofline,0,empty")
    print(line)


if __name__ == "__main__":
    main()
