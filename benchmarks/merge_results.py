"""Merge dry-run artifacts into the final per-cell best-variant table.

Preference order per (arch, shape, mesh): optimized records (fsdp train
sweep, int8-decode fills) over the v2 baseline. Emits
results/dryrun_final.json consumed by benchmarks.roofline.

    PYTHONPATH=src python -m benchmarks.merge_results
"""

from __future__ import annotations

import json
import os

SOURCES_OPTIMIZED = [
    "results/hc_fill_train.json",
    "results/hc_fill_decode.json",
    "results/dryrun_fsdp_train.json",
]
BASELINE = "results/dryrun_v2.json"
OUT = "results/dryrun_final.json"


def key(r):
    return (r["arch"], r["shape"], r["mesh"])


def main() -> None:
    best = {}
    for r in json.load(open(BASELINE)):
        r.setdefault("variant", "baseline")
        best[key(r)] = r
    for src in SOURCES_OPTIMIZED:
        if not os.path.exists(src):
            continue
        for r in json.load(open(src)):
            if r.get("status") != "ok":
                continue
            r.setdefault("variant", "optimized")
            ma = r.get("memory_analysis") or {}
            if ma.get("total_hbm_bytes", 0) > 16 * 2**30:
                continue  # an optimized variant must also FIT the chip
            old = best.get(key(r))
            if old is None or old.get("status") != "ok":
                best[key(r)] = r
                continue
            # keep whichever has the lower roofline step bound (using the
            # traffic-model memory term when present)
            def bound(x):
                rf = x.get("roofline")
                if not rf:
                    return float("inf")
                tm = rf.get("t_memory_model_s", rf.get("t_memory_s", 0))
                return max(rf["t_compute_s"], tm, rf["t_collective_s"])
            if bound(r) < bound(old):
                best[key(r)] = r
    records = sorted(best.values(), key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    with open(OUT, "w") as f:
        json.dump(records, f, indent=1)
    ok = sum(1 for r in records if r["status"] == "ok")
    opt = sum(1 for r in records if r["status"] == "ok" and r.get("variant") != "baseline")
    print(f"merged {len(records)} cells → {OUT} ({ok} ok, {opt} on optimized variants)")


if __name__ == "__main__":
    main()
