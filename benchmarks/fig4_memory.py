"""Paper Fig. 4/10: consumed memory across stream-learning methods.

Shows Ferret's planned footprint spanning the M-/M/M+ range while the skip
baselines sit at a fixed point (model + buffer).
"""

from __future__ import annotations

import math
import time
from typing import Dict

from benchmarks import common as C
from repro.core.planner import default_data_interval, plan
from repro.core.profiler import analytic_profile


def run(verbose: bool = True) -> Dict[str, float]:
    cfg = C.bench_model()
    profile = analytic_profile(cfg, C.BATCH, C.SEQ)
    t_d = default_data_interval(profile)
    mem: Dict[str, float] = {}
    m_plus = plan(profile, t_d, budget=math.inf, max_workers=4)
    mem["Ferret_M+"] = m_plus.memory
    for tag, frac in [("Ferret_M", 0.4), ("Ferret_M-", 0.15)]:
        planned = plan(profile, t_d, budget=m_plus.memory * frac, max_workers=4).memory
        mem[tag] = max(planned, C.model_bytes(cfg))  # floor: one live model
    base = C.model_bytes(cfg)
    mem["Oracle"] = base
    mem["1-Skip"] = base
    for pol in ("Random-N", "Last-N", "Camel"):
        mem[pol] = base + 16 * C.BATCH * C.SEQ * 8  # + B buffered items
    if verbose:
        print("\nFig. 4 (memory footprint):")
        for k, v in sorted(mem.items(), key=lambda kv: kv[1]):
            print(f"  {k:10s} {v/2**20:9.2f} MiB")
    return mem


def main():
    t0 = time.time()
    mem = run()
    dt = (time.time() - t0) * 1e6
    ratio = mem["Ferret_M+"] / mem["Ferret_M-"]
    print(f"fig4_memory,{dt:.0f},mplus_over_mminus={ratio:.2f}")


if __name__ == "__main__":
    main()
