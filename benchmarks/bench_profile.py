"""Profiling & autotuning benchmark: does measurement change anything?

Three claims, each recorded into ``BENCH_profile.json``:

1. **Plan deltas.** The planner run from a *measured* profile vs the
   analytic roofline, for two model geometries. On CPU the roofline's
   TPU-v5e times are off by orders of magnitude, so the measured plan's
   rate/memory numbers differ even when the chosen structure agrees —
   the artifact records both so the gap is visible across PRs.
2. **Tuned dispatch.** ``autotune()`` measures packed-vs-per-leaf
   Iter-Fisher latency (under the Pallas interpret path, where the packed
   megakernel is known ~7× slower on CPU) and records the winner; the
   default dispatch then follows it. Timed here: default (tuned) vs
   forced-packed vs forced-per-leaf. The tuned default must not lose to
   the per-leaf baseline.
3. **Cache hit.** Re-resolving a measured profile is a store hit —
   ``measurement_runs()`` does not move, no re-measurement runs.

The store lives in a per-run temp dir (``REPRO_PROFILE_DIR``), so the
benchmark never touches — and is never contaminated by — a user store.

    PYTHONPATH=src python -m benchmarks.bench_profile
"""

from __future__ import annotations

import json
import math
import os
import tempfile
import time

_TMP = tempfile.mkdtemp(prefix="repro-bench-profile-")
os.environ["REPRO_PROFILE_DIR"] = _TMP

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from benchmarks import common as C  # noqa: E402
from repro.core import planner as planner_lib  # noqa: E402
from repro.kernels import ops  # noqa: E402
from repro.profile import (  # noqa: E402
    autotune,
    backend_fingerprint,
    clear_tuned_cache,
    default_store,
    measurement_runs,
    resolve_profile,
)
from repro.profile.harness import default_tuning_tree, time_jit  # noqa: E402

BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "BENCH_profile.json"
)

TAU = 4


def _plan_record(profile, max_workers=3, max_stages=4) -> dict:
    t_d = planner_lib.default_data_interval(profile)
    plan = planner_lib.plan(
        profile, t_d, math.inf, max_workers=max_workers, max_stages=max_stages
    )
    return {
        "provenance": plan.profile_provenance,
        "rate": plan.rate,
        "memory_mib": plan.memory / 2**20,
        "stages": plan.partition.num_stages,
        "workers": len(plan.config.active_workers()),
        "bounds": list(plan.partition.bounds),
        "t_fwd_layer_ms": profile.layers[0].t_fwd * 1e3,
    }


def bench_plan_deltas() -> list:
    """Measured-vs-analytic plans for two geometries (claim 1)."""
    store = default_store()
    out = []
    for cfg in (C.bench_model(4), C.bench_model(8)):
        analytic = resolve_profile(cfg, C.BATCH, C.SEQ, prefer="analytic")
        measured = resolve_profile(
            cfg, C.BATCH, C.SEQ, prefer="measured", store=store, repeats=3
        )
        a, m = _plan_record(analytic), _plan_record(measured)
        rec = {
            "model": cfg.name,
            "num_layers": cfg.num_layers,
            "batch": C.BATCH,
            "seq": C.SEQ,
            "analytic": a,
            "measured": m,
            "time_scale_measured_over_analytic": (
                m["t_fwd_layer_ms"] / a["t_fwd_layer_ms"]
            ),
            "same_structure": a["bounds"] == m["bounds"] and a["workers"] == m["workers"],
        }
        out.append(rec)
        print(
            f"plan-delta {cfg.name}/{cfg.num_layers}L: analytic R={a['rate']:.4f} "
            f"P={a['stages']} vs measured R={m['rate']:.4f} P={m['stages']} "
            f"(layer fwd {a['t_fwd_layer_ms']:.4f}ms -> {m['t_fwd_layer_ms']:.4f}ms)"
        )
    return out


def _time_default_dispatch(tree, deltas, lam) -> float:
    """Mean latency of the *default* compensate dispatch (env unset)."""

    def fn(g, d):
        return ops.iter_fisher_compensate_tree(g, d, lam)

    return time_jit(fn, tree, deltas, warmup=2, repeats=5).mean_s


def bench_tuned_dispatch() -> dict:
    """Tuned default vs forced packed vs forced per-leaf (claim 2).

    Runs under ``REPRO_USE_PALLAS=1`` (interpret mode on CPU) — the
    regime where guessing "packed" used to ship the ~7× regression the
    tuner is there to prevent.
    """
    saved = {k: os.environ.get(k) for k in ("REPRO_USE_PALLAS", "REPRO_PACK")}
    os.environ["REPRO_USE_PALLAS"] = "1"
    os.environ.pop("REPRO_PACK", None)
    try:
        tuned = autotune(default_store(), repeats=3)
        tree = default_tuning_tree()
        lam = jnp.float32(0.01)
        deltas = jax.tree.map(
            lambda a: jnp.stack([a * (0.01 * (i + 1)) for i in range(TAU)]), tree
        )
        timings = {}
        for label, env in (("tuned_default", None), ("packed", "1"), ("per_leaf", "0")):
            if env is None:
                os.environ.pop("REPRO_PACK", None)
            else:
                os.environ["REPRO_PACK"] = env
            clear_tuned_cache()
            timings[label] = _time_default_dispatch(tree, deltas, lam)
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v
        clear_tuned_cache()
    out = {
        "backend": backend_fingerprint(),
        "tuned": {"pack": tuned.pack, "pack_block": tuned.pack_block},
        "mean_s": timings,
        "tuned_vs_per_leaf": timings["tuned_default"] / timings["per_leaf"],
        "tuned_vs_packed": timings["tuned_default"] / timings["packed"],
        # 2×: jitter allowance on sub-ms CPU timings — the tuned default
        # dispatches the measured winner (identical compiled code), so
        # only a gross loss (e.g. the ~7× packed-interpret regression
        # coming back as the default) should trip this
        "tuned_not_worse_than_per_leaf": (
            timings["tuned_default"] <= timings["per_leaf"] * 2.0
        ),
    }
    print(
        f"dispatch (pallas interpret): tuned(pack={tuned.pack}) "
        f"{timings['tuned_default']*1e3:.2f}ms, per-leaf "
        f"{timings['per_leaf']*1e3:.2f}ms, packed {timings['packed']*1e3:.2f}ms"
    )
    if not out["tuned_not_worse_than_per_leaf"]:
        raise SystemExit("tuned default lost to the per-leaf baseline")
    return out


def bench_cache_hit() -> dict:
    """Re-resolving a measured profile must be a store hit (claim 3)."""
    store = default_store()
    cfg = C.bench_model(4)
    before = measurement_runs()
    t0 = time.perf_counter()
    profile = resolve_profile(cfg, C.BATCH, C.SEQ, prefer="measured", store=store)
    hit_s = time.perf_counter() - t0
    remeasured = measurement_runs() > before
    out = {
        "remeasured": remeasured,
        "resolve_s": hit_s,
        "provenance": profile.provenance,
        "store_cache_hits": store.cache_hits,
        "store_disk_reads": store.disk_reads,
    }
    print(
        f"cache-hit re-resolve: remeasured={remeasured} in {hit_s*1e3:.1f}ms "
        f"(in-process hits={store.cache_hits}, disk reads={store.disk_reads})"
    )
    if remeasured:
        raise SystemExit("store hit re-ran the measurement harness")
    return out


def run(write_json: bool = True) -> dict:
    payload = {
        "bench": "profile",
        "backend": jax.default_backend(),
        "backend_fingerprint": backend_fingerprint(),
        "host": C.host_env(),
        "store_root": default_store().root,
        "plan_deltas": bench_plan_deltas(),
        "tuned_dispatch": bench_tuned_dispatch(),
        "cache_hit": bench_cache_hit(),
    }
    if write_json:
        with open(BENCH_JSON, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {BENCH_JSON}")
    return payload


def main() -> None:
    t0 = time.time()
    payload = run()
    td = payload["tuned_dispatch"]
    print(
        f"bench_profile,{(time.time() - t0) * 1e3:.0f}ms,"
        f"tuned_pack={td['tuned']['pack']},"
        f"tuned_vs_per_leaf={td['tuned_vs_per_leaf']:.2f},"
        f"remeasured={payload['cache_hit']['remeasured']}"
    )


if __name__ == "__main__":
    main()
