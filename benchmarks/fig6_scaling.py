"""Paper Fig. 6/11: adaptation rate vs memory budget + device scaling.

Two curves, one artifact (``BENCH_scaling.json``):

1. **Budget sweep** — the budget goes from minimal to unconstrained and
   the planner's (R_F, M_F) frontier is recorded; Ferret should scale
   smoothly (paper: competing strategies cannot exploit intermediate
   budgets). The adaptation rate must be monotone non-decreasing in the
   budget — asserted, so a planner regression fails the bench job.
2. **Topology sweep** — the same model planned over 1/2/4/8-device
   topologies carved out of the fake-device host (``scripts/bench.sh``
   forces 8). Data-parallel devices divide the profile's step times
   (``profile.bridge.for_topology``), so the planned adaptation rate must
   be monotone non-decreasing in the device count — also asserted.

    bash scripts/bench.sh benchmarks.fig6_scaling
"""

from __future__ import annotations

import json
import math
import os
import time
from typing import Dict, List

from benchmarks import common as C
from repro.core.planner import default_data_interval, plan
from repro.core.profiler import analytic_profile
from repro.profile.bridge import for_topology
from repro.runtime.topology import DeviceTopology

BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "BENCH_scaling.json"
)

FRACS = [0.05, 0.1, 0.2, 0.35, 0.5, 0.7, 1.0]
DEVICE_COUNTS = [1, 2, 4, 8]


def _monotone(xs: List[float]) -> bool:
    return all(a <= b + 1e-9 for a, b in zip(xs, xs[1:]))


def budget_sweep(profile, t_d, verbose: bool = True) -> List[Dict]:
    m_plus = plan(profile, t_d, budget=math.inf, max_workers=6)
    rows = []
    for f in FRACS:
        p = plan(profile, t_d, budget=m_plus.memory * f, max_workers=6)
        rows.append({
            "budget_frac": f, "memory_bytes": p.memory, "rate": p.rate,
            "num_stages": p.partition.num_stages,
            "workers": len(p.config.active_workers()),
        })
    if verbose:
        print("\nFig. 6 (R_F vs M_F across budgets):")
        print(f"  {'budget':>8s} {'M_F(MiB)':>10s} {'R_F':>10s} {'P':>3s} {'N':>3s}")
        for r in rows:
            print(f"  {r['budget_frac']:8.2f} {r['memory_bytes']/2**20:10.2f} "
                  f"{r['rate']:10.4f} {r['num_stages']:3d} {r['workers']:3d}")
    return rows


def topology_sweep(profile, t_d, verbose: bool = True) -> List[Dict]:
    import jax

    visible = len(jax.devices())
    rows = []
    for n in DEVICE_COUNTS:
        if n > visible:
            print(f"  (skipping n={n}: only {visible} devices visible)")
            continue
        topo = DeviceTopology.discover(max_devices=n)
        eff = for_topology(profile, topo)
        p = plan(eff, t_d, budget=topo.plan_budget(), max_workers=6,
                 topology=topo)
        rows.append({
            "devices": n, "mesh_shape": list(topo.mesh_shape),
            "rate": p.rate, "memory_bytes": p.memory,
            "num_stages": p.partition.num_stages,
        })
    if verbose:
        print("\nTopology scaling (R_F vs device count, data-parallel):")
        print(f"  {'devices':>8s} {'R_F':>10s} {'M_F(MiB)':>10s}")
        for r in rows:
            print(f"  {r['devices']:8d} {r['rate']:10.4f} "
                  f"{r['memory_bytes']/2**20:10.2f}")
    return rows


def run(write_json: bool = True) -> Dict:
    t0 = time.time()
    cfg = C.bench_model(num_layers=8)
    profile = analytic_profile(cfg, C.BATCH, C.SEQ)
    t_d = default_data_interval(profile)

    budget_rows = budget_sweep(profile, t_d)
    topo_rows = topology_sweep(profile, t_d)

    budget_mono = _monotone([r["rate"] for r in budget_rows])
    topo_mono = _monotone([r["rate"] for r in topo_rows])
    assert budget_mono, f"rate not monotone in budget: {budget_rows}"
    assert topo_mono, f"rate not monotone in device count: {topo_rows}"

    payload = {
        "bench": "fig6_scaling",
        "budget_sweep": budget_rows,
        "topology_sweep": topo_rows,
        "rate_monotone_in_budget": budget_mono,
        "rate_monotone_in_devices": topo_mono,
        "wall_s": time.time() - t0,
        "host": C.host_env(),
    }
    if write_json:
        with open(BENCH_JSON, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {BENCH_JSON}")
    return payload


def main():
    payload = run()
    print(f"fig6_scaling,rate_monotone={payload['rate_monotone_in_budget']}"
          f",devices_monotone={payload['rate_monotone_in_devices']}")


if __name__ == "__main__":
    main()
