"""Paper Fig. 6/11: adaptation rate vs memory budget (planner scaling).

Sweeps the budget from minimal to unconstrained and reports the planner's
(R_F, M_F) frontier — Ferret should scale smoothly (paper: competing
strategies cannot exploit intermediate budgets)."""

from __future__ import annotations

import math
import time
from typing import List, Tuple

from benchmarks import common as C
from repro.core.planner import default_data_interval, plan
from repro.core.profiler import analytic_profile

FRACS = [0.05, 0.1, 0.2, 0.35, 0.5, 0.7, 1.0]


def run(verbose: bool = True) -> List[Tuple[float, float, float]]:
    cfg = C.bench_model(num_layers=8)
    profile = analytic_profile(cfg, C.BATCH, C.SEQ)
    t_d = default_data_interval(profile)
    m_plus = plan(profile, t_d, budget=math.inf, max_workers=6)
    rows = []
    for f in FRACS:
        p = plan(profile, t_d, budget=m_plus.memory * f, max_workers=6)
        rows.append((f, p.memory, p.rate))
    if verbose:
        print("\nFig. 6 (R_F vs M_F across budgets):")
        print(f"  {'budget':>8s} {'M_F(MiB)':>10s} {'R_F':>10s} {'P':>3s} {'N':>3s}")
        for f in FRACS:
            p = plan(profile, t_d, budget=m_plus.memory * f, max_workers=6)
            rows_extra = (p.partition.num_stages, len(p.config.active_workers()))
            print(f"  {f:8.2f} {p.memory/2**20:10.2f} {p.rate:10.4f} "
                  f"{rows_extra[0]:3d} {rows_extra[1]:3d}")
    return rows


def main():
    t0 = time.time()
    rows = run()
    dt = (time.time() - t0) * 1e6 / len(FRACS)
    # monotone scaling check
    rates = [r[2] for r in rows]
    mono = all(a <= b + 1e-9 for a, b in zip(rates, rates[1:]))
    print(f"fig6_scaling,{dt:.0f},rate_monotone={mono}")


if __name__ == "__main__":
    main()
