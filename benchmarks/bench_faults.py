"""Chaos-soak benchmark: a seeded fault storm against every recovery path.

One ``FaultPlan.storm(seed)`` drives four scenarios — the same storm on
every run, so this is a *regression* benchmark for fault tolerance, not a
dice roll:

1. **stream** — injected stalls, a transient take error, and a prefetch
   feeder death against a ``BufferedStreamSource``: the delivered rounds
   must be bit-exact vs an uninjected pull and consumed exactly once.
2. **engine** — a supervised elastic run through an injected transient
   device error and a NaN-poisoned batch: the run must complete every
   round with finite losses (retry-in-place + checkpoint rollback).
3. **checkpoint** — a save sequence through a crash-mid-write (torn tmp)
   and post-commit payload corruption: ``restore_latest_good`` must fall
   back to the newest surviving checkpoint and quarantine the corrupt one.
4. **serve** — three tenants, one crash-injected, plus an injected
   SIGTERM-style drain mid-serve: the crashed tenant is retried (zero
   crosstalk), ``drain()`` checkpoints everyone (rings included), and a
   restarted server resumes with **zero rounds lost or re-trained** per
   tenant — and, witnessed by a vanilla tenant, **bit-exact** losses vs
   an uninterrupted run.

Every scenario embeds its injector ``summary()`` (fired/recovered counts,
per-fault recovery latency) into ``BENCH_faults.json`` at the repo root;
the module *asserts* full recovery — a regression fails the bench run, and
therefore CI's chaos shard.

    PYTHONPATH=src python -m benchmarks.bench_faults
"""

from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from benchmarks import common as C
from repro import faults
from repro.api.streams import ArrayStreamSource, BufferedStreamSource
from repro.checkpointing.checkpoint import restore_latest_good, save_checkpoint
from repro.core.ferret import EngineCache
from repro.faults import FaultError, FaultPlan, FaultSpec
from repro.runtime import SupervisorCfg
from repro.serve import FerretServer

BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "BENCH_faults.json"
)

SEED = 7
ROUNDS = 16
SEGMENT = 4
TENANTS = ("t0", "t1", "t2")
SERVE_ROUNDS = 8


def _assert_recovered(chaos, scenario: str) -> dict:
    out = chaos.summary()
    assert out["fired"] > 0, f"{scenario}: storm never fired"
    assert not chaos.unrecovered(), (
        f"{scenario}: unrecovered faults: "
        f"{[r.to_json() for r in chaos.unrecovered()]}"
    )
    return out


# ---------------------------------------------------------------------------
def scenario_stream() -> dict:
    rows = C.bench_stream(length=ROUNDS, seed=SEED)
    clean = BufferedStreamSource(ArrayStreamSource(rows), prefetch=False)
    want = clean.take(ROUNDS)

    plan = FaultPlan.storm(seed=SEED, layers=("stream",))
    src = BufferedStreamSource(ArrayStreamSource(rows), prefetch=True)
    got = []
    with faults.inject(plan) as chaos:
        try:
            for _ in range(ROUNDS // 2):
                src.prefetch(2)
                got.append(src.take(2))
            leftover = src.take(1)  # exactly-once: the stream is dry
        finally:
            src.close()
    assert leftover is None
    cat = {k: np.concatenate([g[k] for g in got]) for k in got[0]}
    for k in want:
        np.testing.assert_array_equal(cat[k], want[k])  # bit-exact
    summary = _assert_recovered(chaos, "stream")
    return {
        "rounds": ROUNDS,
        "bit_exact": True,
        "exactly_once": True,
        "take_wait_s": round(src.take_wait_s, 6),
        "injector": summary,
    }


# ---------------------------------------------------------------------------
def scenario_engine() -> dict:
    cfg = C.bench_model(2)
    stream = C.bench_stream(length=ROUNDS, seed=SEED + 1)
    params = C.init_params(cfg)

    session = C.bench_session(cfg, params, stream, algorithm="er")
    ref = session.run("elastic", segment_rounds=SEGMENT, engine_cache=EngineCache())

    plan = FaultPlan.storm(seed=SEED, layers=("engine",), supervised=True)
    ckpt = tempfile.mkdtemp(prefix="bench_faults_sup_")
    sup = SupervisorCfg(checkpoint_dir=ckpt, checkpoint_every=1, nan_check_every=1)
    session = C.bench_session(cfg, params, stream, algorithm="er")
    with faults.inject(plan) as chaos:
        res = session.run(
            "elastic", segment_rounds=SEGMENT, supervisor_cfg=sup,
            engine_cache=EngineCache(),
        )
    assert res.rounds == ref.rounds == ROUNDS
    assert bool(np.all(np.isfinite(np.asarray(res.losses))))
    summary = _assert_recovered(chaos, "engine")
    return {
        "rounds": ROUNDS,
        "losses_finite": True,
        "online_acc_clean": round(float(ref.online_acc), 4),
        "online_acc_chaos": round(float(res.online_acc), 4),
        "injector": summary,
    }


# ---------------------------------------------------------------------------
def scenario_checkpoint() -> dict:
    rng = np.random.default_rng(SEED)
    states = {s: {"w": rng.normal(size=(8, 8)).astype(np.float32)} for s in range(1, 7)}
    d = tempfile.mkdtemp(prefix="bench_faults_ckpt_")
    plan = FaultPlan.storm(seed=SEED, layers=("checkpoint",))
    crashes = 0
    with faults.inject(plan) as chaos:
        for step, state in states.items():
            try:
                save_checkpoint(d, step, state, extras={"cursor": step})
            except FaultError:
                crashes += 1  # torn tmp: the previous set is untouched
        got, step, extras = restore_latest_good(d, {"w": states[1]["w"]})
        # every remaining outstanding write fault is healed by the same
        # fallback (one resolved() fires inside restore_latest_good)
        while chaos.unrecovered():
            chaos.resolved("checkpoint.write")
    np.testing.assert_array_equal(got["w"], states[step]["w"])
    assert extras["cursor"] == step
    quarantined = [x for x in os.listdir(d) if x.endswith(".corrupt")]
    summary = _assert_recovered(chaos, "checkpoint")
    return {
        "saves_attempted": len(states),
        "crashes_mid_write": crashes,
        "quarantined_dirs": len(quarantined),
        "restored_step": step,
        "restored_bit_exact": True,
        "injector": summary,
    }


# ---------------------------------------------------------------------------
def scenario_serve() -> dict:
    cfg = C.bench_model(2)
    streams = {
        n: C.bench_stream(length=SERVE_ROUNDS, seed=SEED + 10 + i)
        for i, n in enumerate(TENANTS)
    }

    def admit_all(server, resume=None):
        for n, s in streams.items():
            server.admit(
                cfg, "er", s, name=n, batch=C.BATCH, seq=C.SEQ,
                max_workers=3, max_stages=4,
                resume_from=(resume or {}).get(n),
            )

    # phase A — crash containment: t1's second step crashes; the retry
    # must leave every tenant complete, with zero crosstalk or quarantine
    crash_plan = FaultPlan(specs=(
        FaultSpec("serve.step", "tenant_crash", after=1, match=(("tenant", "t1"),)),
    ), seed=SEED)
    server_a = FerretServer(segment_rounds=SEGMENT)
    admit_all(server_a)
    with faults.inject(crash_plan) as chaos_a:
        results_a = server_a.serve(timeout_s=600)
    assert not server_a.quarantined_tenants  # retried, not fatal
    assert all(results_a[n].rounds == SERVE_ROUNDS for n in TENANTS)
    crash_summary = _assert_recovered(chaos_a, "serve/crash")

    # phase B — injected SIGTERM drain mid-serve, checkpoint, restart.
    # A vanilla tenant rides along to witness *bit-exactness*: drain
    # checkpoints carry the in-flight accumulation/Δθ rings, so its
    # drained+restored loss sequence must equal an uninterrupted run's
    # bit for bit. (The "er" tenants stay round-exact but not loss-exact:
    # their host-side replay reservoir resets across the restart.)
    stream_v = C.bench_stream(length=SERVE_ROUNDS, seed=SEED + 20)
    solo = FerretServer(segment_rounds=SEGMENT)
    solo.admit(
        cfg, "vanilla", stream_v, name="tv", batch=C.BATCH, seq=C.SEQ,
        max_workers=3, max_stages=4,
    )
    ref_v = solo.serve(timeout_s=600)["tv"]

    drain_plan = FaultPlan(
        specs=(FaultSpec("serve.loop", "drain", after=4),), seed=SEED
    )
    server = FerretServer(segment_rounds=SEGMENT)
    admit_all(server)
    server.admit(
        cfg, "vanilla", stream_v, name="tv", batch=C.BATCH, seq=C.SEQ,
        max_workers=3, max_stages=4,
    )
    ckpt = tempfile.mkdtemp(prefix="bench_faults_drain_")
    with faults.inject(drain_plan) as chaos_b:
        server.serve(timeout_s=600)
        assert server.draining
        manifest = server.drain(ckpt)
    drain_summary = _assert_recovered(chaos_b, "serve/drain")

    served_pre = {n: manifest[n]["rounds_served"] for n in TENANTS}
    v_losses = [np.asarray(server.results()["tv"].losses)]
    server2 = FerretServer(segment_rounds=SEGMENT)
    admit_all(server2, resume={n: manifest[n]["checkpoint"] for n in TENANTS})
    v_entry = manifest.get("tv", {})
    v_restored = v_entry.get("checkpoint") is not None
    if v_restored:
        server2.admit(
            cfg, "vanilla", stream_v, name="tv", batch=C.BATCH, seq=C.SEQ,
            max_workers=3, max_stages=4, resume_from=v_entry["checkpoint"],
        )
    final = server2.serve(timeout_s=600)
    if v_restored:
        v_losses.append(np.asarray(final["tv"].losses))
    lost = {
        n: SERVE_ROUNDS - served_pre[n] - final[n].rounds for n in TENANTS
    }
    assert all(v == 0 for v in lost.values()), f"rounds lost: {lost}"
    # drain→restore is bit-exact, not merely round-exact
    np.testing.assert_array_equal(
        np.concatenate(v_losses), np.asarray(ref_v.losses)
    )
    lat = [
        s["recovery_latency_max_s"] for s in (crash_summary, drain_summary)
    ]
    merged = {
        "seed": SEED,
        "planned_kinds": sorted(
            set(crash_plan.kinds()) | set(drain_plan.kinds())
        ),
        "fired": crash_summary["fired"] + drain_summary["fired"],
        "recovered": crash_summary["recovered"] + drain_summary["recovered"],
        "recovery_latency_max_s": max(lat),
        "recovery_latency_mean_s": sum(lat) / len(lat),
        "records": crash_summary["records"] + drain_summary["records"],
    }
    return {
        "tenants": len(TENANTS),
        "rounds_per_tenant": SERVE_ROUNDS,
        "rounds_served_pre_drain": served_pre,
        "rounds_served_post_restore": {n: final[n].rounds for n in TENANTS},
        "rounds_lost": lost,
        "drain_restore_bit_exact": True,  # asserted above (vanilla tenant)
        "drain_interrupted_witness": v_restored,
        "quarantined": server_a.quarantined_tenants,
        "injector": merged,
    }


# ---------------------------------------------------------------------------
def run(write_json: bool = True) -> dict:
    scenarios = {}
    for name, fn in (
        ("stream", scenario_stream),
        ("engine", scenario_engine),
        ("checkpoint", scenario_checkpoint),
        ("serve", scenario_serve),
    ):
        t0 = time.time()
        scenarios[name] = fn()
        scenarios[name]["wall_s"] = round(time.time() - t0, 2)
        inj = scenarios[name]["injector"]
        print(
            f"{name:>10}: fired={inj['fired']} recovered={inj['recovered']} "
            f"max_latency={inj['recovery_latency_max_s']:.3f}s "
            f"({scenarios[name]['wall_s']:.1f}s)"
        )

    kinds = sorted({
        r["kind"]
        for s in scenarios.values()
        for r in s["injector"]["records"]
    })
    assert len(kinds) >= 4, f"storm too weak: only {kinds}"
    payload = {
        "bench": "faults",
        "host": C.host_env(),
        "seed": SEED,
        "fault_kinds_fired": kinds,
        "all_recovered": True,  # _assert_recovered gates every scenario
        "scenarios": scenarios,
    }
    if write_json:
        with open(BENCH_JSON, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"\nwrote {BENCH_JSON}")
    return payload


def main() -> None:
    t0 = time.time()
    payload = run()
    fired = sum(s["injector"]["fired"] for s in payload["scenarios"].values())
    lat = max(
        s["injector"]["recovery_latency_max_s"]
        for s in payload["scenarios"].values()
    )
    print(
        f"bench_faults,{(time.time() - t0):.1f}s,"
        f"faults_fired={fired},kinds={len(payload['fault_kinds_fired'])},"
        f"max_recovery_latency_s={lat:.3f}"
    )


if __name__ == "__main__":
    main()
