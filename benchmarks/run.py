"""Benchmark runner — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (plus human-readable tables).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run table1     # one benchmark
"""

from __future__ import annotations

import sys
import traceback

BENCHES = {
    "table1": "benchmarks.table1_agm",
    "table2": "benchmarks.table2_ocl",
    "table3": "benchmarks.table3_pipeline",
    "table4": "benchmarks.table4_compensation",
    "fig4": "benchmarks.fig4_memory",
    "fig6": "benchmarks.fig6_scaling",
    "roofline": "benchmarks.roofline",
    "elastic": "benchmarks.elastic_switch",
    "hotpath": "benchmarks.bench_hotpath",
    "stream": "benchmarks.bench_stream",
}


def main() -> None:
    selected = sys.argv[1:] or list(BENCHES)
    failures = []
    for name in selected:
        mod_name = BENCHES.get(name, name)
        print(f"\n===== {name} ({mod_name}) =====", flush=True)
        try:
            mod = __import__(mod_name, fromlist=["main"])
            mod.main()
        except Exception:
            failures.append(name)
            traceback.print_exc()
            print(f"{name},0,FAILED")
    if failures:
        raise SystemExit(f"benchmarks failed: {failures}")


if __name__ == "__main__":
    main()
