"""Elastic budget switching: replan+remap latency and accuracy retention.

Runs one drifting stream through a 3-budget schedule (∞ → 40% → 25% of the
unconstrained footprint) with the elastic runner of
``repro.api.FerretSession``, and compares the stitched online accuracy
against (a) the unconstrained single-plan run and (b) a cold-restart
baseline that re-initializes optimizer/compensation state at every switch
(what you'd get without the live state remap).

Reports per-switch replan and remap wall time — the paper's Alg. 2+3 are a
host-side search, so a budget change costs milliseconds of planning plus
one merge/re-split of the live state, not a training restart — and writes
the machine-readable ``BENCH_elastic.json`` at the repo root so the perf
trajectory is tracked across PRs (CI uploads it as an artifact).

    PYTHONPATH=src python -m benchmarks.elastic_switch
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import time

from benchmarks import common as C
from repro.api import FerretSession
from repro.core.compensation import CompensationConfig
from repro.core.ferret import FerretConfig
from repro.core.profiler import ModelProfile, analytic_profile
from repro.runtime import BudgetEvent

STREAM_LEN = 240
SWITCHES = (80, 160)
FRACTIONS = (1.0, 0.4, 0.25)
BENCH_JSON = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                          "BENCH_elastic.json")


def _hetero_profile(cfg) -> ModelProfile:
    base = analytic_profile(cfg, C.BATCH, C.SEQ)
    layers = [
        dataclasses.replace(layer, t_fwd=layer.t_fwd * (1 + i), t_bwd=layer.t_bwd * (1 + i))
        for i, layer in enumerate(base.layers)
    ]
    return ModelProfile(
        layers=layers, embed_bytes=base.embed_bytes, batch=C.BATCH, seq=C.SEQ
    )


def _ferret_cfg(budget: float = math.inf) -> FerretConfig:
    return FerretConfig(
        budget_bytes=budget, lr=5e-3,
        compensation=CompensationConfig(method="iter_fisher", eta_lambda=1e-4),
        max_workers=3, max_stages=4,
    )


def _aba_roundtrip_bit_exact(cfg, params, profile, full_plan) -> bool:
    """Bit-exactness of the A→B→A cross-partition remap round-trip.

    Splits the weights on the unconstrained plan's bounds (A), remaps
    params + synthetic ring contents onto the 40%-budget bounds (B) and
    back, and checks every leaf is bit-identical — slot contents are
    permuted between stages, never recomputed or zeroed.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models import transformer as T
    from repro.runtime import ElasticStreamTrainer
    from repro.state import remap_ring_trees, remap_stage_params

    trainer = ElasticStreamTrainer(
        cfg, _ferret_cfg(), C.BATCH, C.SEQ, profile=profile
    )
    bounds_a = list(full_plan.partition.bounds)
    bounds_b = list(
        trainer.plan_for(full_plan.memory * FRACTIONS[1]).partition.bounds
    )
    sp_a = T.split_stage_params(cfg, params, bounds_a)
    rng = np.random.default_rng(0)
    num_slots = 3
    rings_a = tuple(
        jax.tree.map(
            lambda p: jnp.asarray(
                rng.standard_normal((num_slots, *p.shape)), jnp.float32
            ),
            sp,
        )
        for sp in sp_a
    )
    sp_b = remap_stage_params(cfg, sp_a, bounds_b)
    rings_b = remap_ring_trees(cfg, rings_a, bounds_b, num_slots)
    sp_rt = remap_stage_params(cfg, sp_b, bounds_a)
    rings_rt = remap_ring_trees(cfg, rings_b, bounds_a, num_slots)

    def _eq(t1, t2) -> bool:
        l1, l2 = jax.tree.leaves(t1), jax.tree.leaves(t2)
        return len(l1) == len(l2) and all(
            np.array_equal(np.asarray(a), np.asarray(b)) for a, b in zip(l1, l2)
        )

    return _eq(sp_a, sp_rt) and _eq(rings_a, rings_rt)


def run(write_json: bool = True) -> dict:
    cfg = C.bench_model()
    params = C.init_params(cfg)
    stream = C.bench_stream(length=STREAM_LEN)
    profile = _hetero_profile(cfg)

    session = FerretSession(
        cfg, math.inf, "vanilla", stream, ferret=_ferret_cfg(),
        batch=C.BATCH, seq=C.SEQ, profile=profile, params=params,
    )
    full = session.plan
    budgets = [math.inf] + [full.memory * f for f in FRACTIONS[1:]]
    schedule = [BudgetEvent(r, b) for r, b in zip(SWITCHES, budgets[1:])]

    # --- elastic run: live replan + state remap ---
    t0 = time.time()
    res = session.run("elastic", schedule=schedule)
    elastic_s = time.time() - t0

    # --- baseline 1: unconstrained single plan, same stream ---
    base = session.run("elastic")

    # --- baseline 2: restart at each switch — weights survive (as a
    # checkpoint reload would) but optimizer/compensation state is lost,
    # i.e. exactly what you'd get without the live state remap ---
    cold_acc = []
    cuts = [0, *SWITCHES, STREAM_LEN]
    params_k = params
    for k in range(len(cuts) - 1):
        seg_stream = {kk: v[cuts[k]:cuts[k + 1]] for kk, v in stream.items()}
        sess_k = FerretSession(
            cfg, budgets[k], "vanilla", seg_stream, ferret=_ferret_cfg(budgets[k]),
            batch=C.BATCH, seq=C.SEQ, profile=profile, params=params_k,
        )
        r_k = sess_k.run("elastic")
        params_k = r_k.final_params
        cold_acc.append((r_k.online_acc, cuts[k + 1] - cuts[k]))
    cold_oacc = sum(a * n for a, n in cold_acc) / STREAM_LEN

    print(f"stream: {STREAM_LEN} items, switches at {SWITCHES}, "
          f"budgets ∞ / {FRACTIONS[1]:.0%} / {FRACTIONS[2]:.0%} of M_F(∞)\n")
    print(f"{'rounds':>12} {'budget':>10} {'P':>3} {'N':>3} {'M_F MiB':>8} "
          f"{'replan ms':>10} {'remap ms':>9} {'seg oacc':>9}")
    seg_rows = []
    for s in res.segments:
        budget = "inf" if not math.isfinite(s.budget_bytes) else f"{s.budget_bytes/2**20:.2f}"
        p = s.result.plan
        print(f"[{s.start:4d},{s.end:4d}) {budget:>10} {p.partition.num_stages:>3} "
              f"{len(p.config.active_workers()):>3} {p.memory/2**20:>8.2f} "
              f"{1e3*s.replan_s:>10.1f} {1e3*s.remap_s:>9.1f} "
              f"{100*s.result.online_acc:>8.2f}%")
        seg_rows.append({
            "start": s.start, "end": s.end,
            "budget_bytes": budget if budget == "inf" else s.budget_bytes,
            "num_stages": p.partition.num_stages,
            "memory_bytes": p.memory,
            "replan_ms": 1e3 * s.replan_s,
            "remap_ms": 1e3 * s.remap_s,
            "run_s": s.run_s,
            "cache_hit": s.cache_hit,
            "rounds_compiled": s.rounds_compiled,
            "online_acc": s.result.online_acc,
            "rounds_lost": s.rounds_lost,
        })

    # every switch must be lossless: the in-flight accumulation/Δθ rings
    # are carried (same-schedule switches) or flushed into the weights
    # (schedule-restarting switches), never silently dropped
    assert res.rounds_lost_per_switch == 0, (
        f"budget switches dropped in-flight rounds: {res.rounds_lost_per_switch}"
    )

    # merge∘re-split round-trip identity: moving live state A→B→A across
    # partitions returns bit-identical params and ring contents — the
    # property that makes cross-partition switches lossless
    switch_bit_exact = _aba_roundtrip_bit_exact(cfg, params, profile, full)
    assert switch_bit_exact, "A→B→A state remap round-trip is not bit-exact"

    switch_cost = sum(s.replan_s + s.remap_s for s in res.segments if s.replanned)
    print(f"\ntotal switch overhead: {1e3*switch_cost:.1f} ms "
          f"across {res.num_replans} replans "
          f"(vs full restart: re-init + full recompile + lost curve)")
    print(f"engine cache: {res.engine_cache_misses} compiled, "
          f"{res.engine_cache_hits} reused (bucketed segment lengths)")
    print(f"online accuracy — elastic: {100*res.online_acc:.2f}%   "
          f"unconstrained: {100*base.online_acc:.2f}%   "
          f"cold-restart: {100*cold_oacc:.2f}%")
    retention = res.online_acc / max(base.online_acc, 1e-12)
    print(f"accuracy retention vs unconstrained: {100*retention:.1f}%  "
          f"(elastic − cold-restart: {100*(res.online_acc - cold_oacc):+.2f} pts)")

    payload = {
        "bench": "elastic_switch",
        "host": C.host_env(),
        "stream_len": STREAM_LEN,
        "switches": list(SWITCHES),
        "budget_fractions": list(FRACTIONS),
        "num_replans": res.num_replans,
        "engine_cache": {
            "hits": res.engine_cache_hits,
            "misses": res.engine_cache_misses,
        },
        "replan_ms_total": sum(r["replan_ms"] for r in seg_rows),
        "remap_ms_total": sum(r["remap_ms"] for r in seg_rows),
        "switch_overhead_ms": 1e3 * switch_cost,
        "elastic_wall_s": elastic_s,
        "online_acc": {
            "elastic": res.online_acc,
            "unconstrained": base.online_acc,
            "cold_restart": cold_oacc,
        },
        "retention_vs_unconstrained": retention,
        "elastic_minus_cold_restart": res.online_acc - cold_oacc,
        "rounds_lost_per_switch": res.rounds_lost_per_switch,
        "switch_bit_exact": switch_bit_exact,
        "segments": seg_rows,
    }
    if write_json:
        with open(BENCH_JSON, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"\nwrote {BENCH_JSON}")
    return payload


def main() -> None:
    t0 = time.time()
    payload = run()
    dt = (time.time() - t0) * 1e6 / STREAM_LEN
    print(f"elastic_switch,{dt:.0f},"
          f"switch_overhead_ms={payload['switch_overhead_ms']:.1f}")


if __name__ == "__main__":
    main()
