"""Multi-tenant serving benchmark: throughput/latency vs tenant count.

For 1, 4, and 16 same-geometry tenants on one ``FerretServer``:

1. **Sustained rounds/sec** across all tenants (engine pre-warmed by a
   throwaway tenant, so the number is steady-state serving, not compile).
2. **p50/p99 round latency** — each tenant is push-fed through a bounded
   ``TenantFeed`` with per-round arrival timestamps; latency is arrival →
   completion of the segment that trained the round.
3. **Engine sharing** — every tenant has identical geometry (model config,
   algorithm, optimizer, lr, budget share), so the bucketed cache must
   compile < tenant-count engines; asserted and recorded per scenario
   (``compiles`` is cumulative across warmup + scenario: exactly 1).
4. **Exactly-once consumption** — every pushed round is trained exactly
   once per tenant; asserted per scenario.

Writes the machine-readable ``BENCH_serve.json`` at the repo root (CI
uploads it as an artifact next to the other BENCH_* files).

    PYTHONPATH=src python -m benchmarks.bench_serve
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks import common as C
from repro.serve import FerretServer

BENCH_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "BENCH_serve.json"
)

TENANT_COUNTS = (1, 4, 16)
ROUNDS_PER_TENANT = 16
SEGMENT_ROUNDS = 4
BUDGET_BYTES = 4 * 2**30


def _percentile(xs, q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else float("nan")


def _scenario(cfg, params, n_tenants: int) -> dict:
    server = FerretServer(BUDGET_BYTES, segment_rounds=SEGMENT_ROUNDS)

    # warm the shared engine with a throwaway tenant so the measured window
    # is steady-state serving (same geometry ⇒ same compiled engine)
    warm = server.admit(
        cfg, "er", C.bench_stream(length=SEGMENT_ROUNDS, seed=99),
        name="warmup", batch=C.BATCH, seq=C.SEQ, params=params,
        max_workers=3, max_stages=4,
    )
    server.serve()
    assert warm.done

    handles = []
    for i in range(n_tenants):
        h = server.admit(
            cfg, "er", None, name=f"t{i}", batch=C.BATCH, seq=C.SEQ,
            params=params, seed=i, max_workers=3, max_stages=4,
        )
        # burst-push the whole stream (arrival-stamped), then close: the
        # measured window serves a full backlog at every tenant
        rows = C.bench_stream(length=ROUNDS_PER_TENANT, seed=i)
        admitted = h.push_many(rows)
        assert admitted == ROUNDS_PER_TENANT, (admitted, ROUNDS_PER_TENANT)
        h.close_feed()
        handles.append(h)

    t0 = time.time()
    results = server.serve()
    wall_s = time.time() - t0

    total_rounds = sum(results[h.name].rounds for h in handles)
    assert total_rounds == n_tenants * ROUNDS_PER_TENANT, (
        "exactly-once violated", total_rounds)
    latencies = [lat for h in handles for lat in h.round_latencies_s]
    assert len(latencies) == total_rounds, (len(latencies), total_rounds)
    assert server.compile_count < max(2, n_tenants), (
        "geometry sharing failed", server.compile_count)

    row = {
        "tenants": n_tenants,
        "rounds_per_tenant": ROUNDS_PER_TENANT,
        "total_rounds": total_rounds,
        "wall_s": wall_s,
        "rounds_per_s": total_rounds / wall_s,
        "latency_p50_s": _percentile(latencies, 50),
        "latency_p99_s": _percentile(latencies, 99),
        "compiles": server.compile_count,  # cumulative incl. warmup
        "cache_hits": server.engine_cache.hits,
        "online_acc_mean": float(np.mean(
            [results[h.name].online_acc for h in handles])),
    }
    print(
        f"  {n_tenants:>2} tenants: {row['rounds_per_s']:7.1f} rounds/s  "
        f"p50={1e3 * row['latency_p50_s']:7.1f}ms  "
        f"p99={1e3 * row['latency_p99_s']:7.1f}ms  "
        f"compiles={row['compiles']} hits={row['cache_hits']}"
    )
    return row


def run(write_json: bool = True) -> dict:
    cfg = C.bench_model()
    params = C.init_params(cfg)
    print(
        f"serving {ROUNDS_PER_TENANT} rounds/tenant, "
        f"segment_rounds={SEGMENT_ROUNDS}, shared pool "
        f"{BUDGET_BYTES / 2**30:.0f}GiB:"
    )
    rows = [_scenario(cfg, params, n) for n in TENANT_COUNTS]
    payload = {
        "bench": "serve",
        "host": C.host_env(),
        "rounds_per_tenant": ROUNDS_PER_TENANT,
        "segment_rounds": SEGMENT_ROUNDS,
        "budget_bytes": BUDGET_BYTES,
        "scenarios": rows,
    }
    if write_json:
        with open(BENCH_JSON, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"\nwrote {BENCH_JSON}")
    return payload


def main() -> None:
    t0 = time.time()
    payload = run()
    total = sum(r["total_rounds"] for r in payload["scenarios"])
    dt = (time.time() - t0) * 1e6 / total
    peak = max(r["rounds_per_s"] for r in payload["scenarios"])
    print(f"bench_serve,{dt:.0f},peak_rounds_per_s={peak:.1f}")


if __name__ == "__main__":
    main()
