"""Paper Table 1: Online Accuracy Gain per unit of Memory (agm vs 1-Skip).

Methods: Oracle, 1-Skip (baseline B), Random-N, Last-N, Camel-style coreset,
Ferret_{M-}, Ferret_M, Ferret_{M+}. Stream: drifting Markov tokens.

Expected qualitative ordering (paper §6.2): Ferret_M+ ≈ Oracle ≫ skip
baselines; Ferret dominates at matched memory.
"""

from __future__ import annotations

import math
import time
from typing import Dict

from benchmarks import common as C
from repro.ocl.baselines import AdmissionPolicy
from repro.ocl.metrics import agm


def run(stream_kind: str = "drift", verbose: bool = True) -> Dict[str, Dict]:
    cfg = C.bench_model()
    params = C.init_params(cfg)
    stream = C.bench_stream(stream_kind)
    results: Dict[str, Dict] = {}

    # ---- admission baselines (t_train = 3 t_d: training is the bottleneck)
    for name, pol in [
        ("Oracle", AdmissionPolicy("oracle")),
        ("1-Skip", AdmissionPolicy("one_skip")),
        ("Random-N", AdmissionPolicy("random_n", buffer=16, select=4)),
        ("Last-N", AdmissionPolicy("last_n", buffer=16, select=4)),
        ("Camel", AdmissionPolicy("camel", buffer=16, select=4)),
    ]:
        r = C.run_admission_baseline(cfg, params, stream, pol)
        results[name] = {"oacc": r.online_acc, "memory": r.memory_bytes}

    # ---- Ferret at three budgets
    _, res_plus = C.run_ferret(cfg, params, stream, budget=math.inf)
    results["Ferret_M+"] = {"oacc": res_plus.online_acc, "memory": res_plus.memory_bytes}
    for tag, frac in [("Ferret_M", 0.4), ("Ferret_M-", 0.12)]:
        _, res = C.run_ferret(cfg, params, stream, budget=res_plus.memory_bytes * frac)
        results[tag] = {"oacc": res.online_acc, "memory": res.memory_bytes}

    base = results["1-Skip"]
    for name, r in results.items():
        mem = max(r["memory"], 1.0)
        r["agm"] = agm(
            100 * r["oacc"], 100 * base["oacc"], mem, max(base["memory"], 1.0)
        )
    if verbose:
        print(f"\nTable 1 (stream={stream_kind}; agm vs 1-Skip, oacc in %):")
        for name, r in results.items():
            print(
                f"  {name:10s} oacc={100*r['oacc']:6.2f}%  mem={r['memory']/2**20:8.1f}MiB"
                f"  agm={r['agm']:7.2f}"
            )
    return results


def main():
    t0 = time.time()
    res = run()
    dt = (time.time() - t0) * 1e6 / C.STREAM_LEN
    oacc_gap = res["Ferret_M+"]["oacc"] - res["Oracle"]["oacc"]
    print(f"table1_agm,{dt:.0f},ferret_vs_oracle_gap={oacc_gap:+.4f}")


if __name__ == "__main__":
    main()
