"""Multi-tenant serving: N live OCL sessions on one device.

Three tenants share one ``FerretServer``: two same-geometry learners (they
reuse one compiled engine — watch ``compile_count``) and one that joins
late with a different algorithm. Tenant ``b`` is *push-fed* through a
bounded ``TenantFeed`` by a producer thread — the admission-controlled
live path — while the others pull from pre-built streams. The global
memory pool re-divides every time a tenant joins or finishes; running
tenants pick their new share up at the next segment boundary through the
elastic re-planner.

    PYTHONPATH=src python examples/serve_tenants.py
"""

import dataclasses
import threading
import time

from repro.core.compensation import CompensationConfig
from repro.models.registry import get_config
from repro.ocl.streams import StreamConfig, make_stream
from repro.serve import FerretServer

BATCH, SEQ, VOCAB = 2, 16, 32


def token_stream(length, seed):
    return make_stream(StreamConfig(
        kind="drift", modality="tokens", length=length, batch=BATCH,
        vocab=VOCAB, seq=SEQ, seed=seed,
    ))


def main():
    # a small dense LM (reduced h2o-danube config), CPU-friendly
    cfg = dataclasses.replace(
        get_config("h2o-danube-1.8b", smoke=True),
        compute_dtype="float32", num_layers=4, vocab_size=VOCAB,
    )
    common = dict(
        batch=BATCH, seq=SEQ, lr=5e-3, max_workers=3, max_stages=4,
        compensation=CompensationConfig(method="iter_fisher", eta_lambda=1e-4),
    )

    server = FerretServer(budget_bytes=2 * 2**30, segment_rounds=8)

    # tenant a: pulls a bounded drifting stream
    a = server.admit(cfg, "er", token_stream(48, seed=1), name="a", **common)
    # tenant b: same geometry as a (shares a's compiled engine), push-fed
    b = server.admit(cfg, "er", None, name="b", **common)

    def producer():
        """A live client: rounds arrive in bursts through the bounded feed."""
        rows = token_stream(32, seed=2)
        for r in range(32):
            while not b.push({k: v[r] for k, v in rows.items()}):
                time.sleep(0.01)  # feed full: admission backpressure
            if r % 8 == 7:
                time.sleep(0.02)  # bursty arrival
        b.close_feed()

    feeder = threading.Thread(target=producer)
    feeder.start()

    # serve a while, then a third tenant joins live — the pool re-divides
    # and a/b re-plan at their next segment boundary
    server.serve(max_segments=4)
    c = server.admit(cfg, "mas", token_stream(24, seed=3), name="c",
                     weight=2.0, **common)
    print(f"tenant c joined (weight 2): shares now "
          f"{ {n: f'{s / 2**20:.0f}MiB' for n, s in server.pool.shares().items()} }")

    results = server.serve()
    feeder.join()

    for name in ("a", "b", "c"):
        print(" ", results[name].summary())
    if b.round_latencies_s:
        lat = sorted(b.round_latencies_s)
        print(f"tenant b serving latency: p50={lat[len(lat) // 2] * 1e3:.0f}ms "
              f"p99={lat[int(0.99 * (len(lat) - 1))] * 1e3:.0f}ms "
              f"(arrival → segment completion)")
    print(f"engine compiles: {server.compile_count} for 3 tenants "
          f"(a+b shared; c is a different algorithm), "
          f"cache hits: {server.engine_cache.hits}")
    assert a.result().rounds == 48 and b.result().rounds == 32
    print("handles:", c.summary())


if __name__ == "__main__":
    main()
