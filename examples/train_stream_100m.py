"""End-to-end driver: OCL-train a ~100M-parameter LM on a token stream.

Default scale is CPU-friendly (~10M params, 200 steps); ``--full`` selects
the ~100M configuration (24L × 512d) for a few hundred steps as the
deliverable prescribes — expect ~10-30 min on a few CPU cores, trivial on
one TPU host.

    PYTHONPATH=src python examples/train_stream_100m.py [--full] [--steps N]
"""

import argparse
import time

import jax
import numpy as np

from repro.launch.steps import make_train_step
from repro.models.config import ModelConfig
from repro.models import transformer as T
from repro.data.pipeline import DataPipeline, PipelineCfg, TokenStreamSource
from repro.optim.optimizers import adamw
from repro.runtime.supervisor import Supervisor, SupervisorCfg


def model_for(full: bool) -> ModelConfig:
    if full:
        return ModelConfig(  # ≈102M params
            name="stream-100m", family="dense", num_layers=24, d_model=512,
            num_heads=8, num_kv_heads=4, d_ff=2048, vocab_size=32768,
            compute_dtype="float32",
        )
    return ModelConfig(  # ≈11M params
        name="stream-10m", family="dense", num_layers=8, d_model=256,
        num_heads=8, num_kv_heads=4, d_ff=1024, vocab_size=8192,
        compute_dtype="float32",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/stream100m_ckpt")
    args = ap.parse_args()

    cfg = model_for(args.full)
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params)")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(lr=3e-4, grad_clip=1.0)
    opt_state = opt.init(params)
    raw_step = jax.jit(make_train_step(cfg, opt, remat=True))

    def step_fn(state, batch):
        p, o = state
        b = {"tokens": batch["tokens"] % cfg.vocab_size,
             "labels": batch["labels"] % cfg.vocab_size}
        p, o, m = raw_step(p, o, b)
        return (p, o), m

    sup = Supervisor(
        SupervisorCfg(checkpoint_dir=args.ckpt_dir, checkpoint_every=100,
                      step_timeout_s=3600),
        step_fn, (params, opt_state),
    )
    source = TokenStreamSource(cfg.vocab_size,
                               PipelineCfg(batch=args.batch, seq=args.seq),
                               drift_rate=0.01)
    sup.try_restore(extras_hook=lambda ex: source.seek(ex.get("cursor", 0)))
    pipe = DataPipeline(source, PipelineCfg(batch=args.batch, seq=args.seq)).start()

    t0, losses = time.time(), []
    try:
        while sup.step < args.steps:
            batch = pipe.get()
            rep = sup.run_step(batch, extras={"cursor": int(batch["_cursor"])})
            if not np.isnan(rep.loss):
                losses.append(rep.loss)
            if sup.step % 20 == 0:
                tput = sup.step * args.batch * args.seq / (time.time() - t0)
                print(f"step {sup.step:5d} loss={rep.loss:.4f} ({tput:,.0f} tok/s)",
                      flush=True)
    finally:
        pipe.stop()
        sup.finalize(extras={"cursor": source.cursor})
    print(f"done: {sup.step} steps, loss {losses[0]:.3f} → {losses[-1]:.3f}, "
          f"dropped={pipe.dropped}")


if __name__ == "__main__":
    main()
