"""Varying memory budgets at runtime — one stream, three budgets, no restart.

The paper's Ferret_M claim is adaptivity to *varying* memory constraints
(Alg. 2+3). This demo runs a single drifting token stream through the
budget-elastic trainer with two mid-stream budget cuts: at each switch the
planner re-enters (replan), the pipeline is rebuilt, and live state —
params, Adam moments, Iter-Fisher λ statistics — is remapped across the
partition boundaries. The online-accuracy curve is continuous across the
switches and every stream item is consumed exactly once.

    PYTHONPATH=src python examples/elastic_budget_demo.py
"""

import dataclasses
import math

import numpy as np

from repro.api import FerretSession
from repro.core.compensation import CompensationConfig
from repro.core.profiler import ModelProfile, analytic_profile
from repro.models.registry import get_config
from repro.ocl.streams import StreamConfig, make_stream
from repro.runtime import BudgetEvent

STREAM_LEN = 180
BATCH, SEQ = 2, 16


def hetero_profile(cfg, batch, seq) -> ModelProfile:
    """Layer i scaled (1+i)× slower, so budget changes move the partition
    (a uniform smoke model would keep the same bounds at every budget)."""
    base = analytic_profile(cfg, batch, seq)
    layers = [
        dataclasses.replace(layer, t_fwd=layer.t_fwd * (1 + i), t_bwd=layer.t_bwd * (1 + i))
        for i, layer in enumerate(base.layers)
    ]
    return ModelProfile(layers=layers, embed_bytes=base.embed_bytes, batch=batch, seq=seq)


def main():
    cfg = dataclasses.replace(
        get_config("h2o-danube-1.8b", smoke=True),
        compute_dtype="float32", num_layers=4, vocab_size=32,
    )
    stream = make_stream(StreamConfig(
        kind="drift", modality="tokens", length=STREAM_LEN,
        batch=BATCH, vocab=32, seq=SEQ,
    ))

    session = FerretSession(
        cfg, math.inf, "vanilla", stream, lr=5e-3,
        compensation=CompensationConfig(method="iter_fisher", eta_lambda=1e-4),
        max_workers=3, max_stages=4, profile=hetero_profile(cfg, BATCH, SEQ),
        batch=BATCH, seq=SEQ,
    )
    full = session.plan
    schedule = [
        BudgetEvent(round=60, budget_bytes=full.memory * 0.4),
        BudgetEvent(round=120, budget_bytes=full.memory * 0.3),
    ]
    print(f"budget schedule: ∞ → {full.memory*0.4/2**20:.2f} MiB @60 "
          f"→ {full.memory*0.3/2**20:.2f} MiB @120  ({STREAM_LEN} stream items)\n")

    res = session.run("elastic", schedule=schedule)

    for s in res.segments:
        p = s.result.plan
        budget = "∞" if not math.isfinite(s.budget_bytes) else f"{s.budget_bytes/2**20:.2f} MiB"
        tag = (f"  (replan {1e3*s.replan_s:.0f} ms, remap {1e3*s.remap_s:.0f} ms)"
               if s.replanned else "")
        print(f"rounds [{s.start:3d},{s.end:3d})  budget {budget:>9}  "
              f"plan: P={p.partition.num_stages} bounds={tuple(p.partition.bounds)} "
              f"N={len(p.config.active_workers())} M_F={p.memory/2**20:.2f} MiB"
              f"{tag}")
        print(f"    segment online acc {100*s.result.online_acc:.2f}%  "
              f"loss {s.result.losses[0]:.3f}→{s.result.losses[-1]:.3f}")

    curve = res.online_acc_curve
    marks = [0, 59, 60, 119, 120, STREAM_LEN - 1]
    print("\ncontinuous online-accuracy curve (cumulative, across switches):")
    print("  " + "  ".join(f"r{m}: {100*curve[m]:.2f}%" for m in marks))
    assert res.rounds == STREAM_LEN, "stream items lost or double-consumed!"
    assert np.isfinite(res.losses).all()
    print(f"\nstitched online accuracy: {100*res.online_acc:.2f}%  "
          f"({res.rounds}/{STREAM_LEN} items consumed exactly once, "
          f"{res.num_replans} live replans, no restart)")


if __name__ == "__main__":
    main()
