"""Serving example: batched prefill + decode over any assigned architecture.

Prompts arrive through the same ``repro.api.StreamSource`` abstraction the
trainers consume — here a drifting Markov token stream pulled one round at
a time through a ``BufferedStreamSource``, exactly like the incremental
elastic trainer consumes a live feed: the next prompt batch is prefetched
on a background thread while the current one decodes, and each served
round is ``ack``ed once its generation completes (a crashed round would be
re-served from the retained buffer — exactly-once serving).

    PYTHONPATH=src python examples/serve_stream.py --arch mamba2-780m
    PYTHONPATH=src python examples/serve_stream.py --arch gemma3-12b --gen 32
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import BufferedStreamSource, as_stream_source
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import transformer as T
from repro.models.registry import ARCHITECTURES, get_config
from repro.ocl.streams import StreamConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m", choices=sorted(ARCHITECTURES))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=1, help="prompt batches to serve")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)  # reduced config: CPU-friendly
    rng = jax.random.PRNGKey(0)
    params = T.init_params(cfg, rng)
    max_len = args.prompt_len + args.gen
    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(make_decode_step(cfg))

    # prompt feed: any StreamSource works; a generated drifting stream here,
    # pulled through the same replay-buffered prefetching feeder the
    # incremental elastic trainer uses on live feeds
    feeder = BufferedStreamSource(as_stream_source(StreamConfig(
        kind="drift", modality="tokens", length=args.rounds, batch=args.batch,
        vocab=min(cfg.vocab_size, 256), seq=args.prompt_len,
    )))

    round_idx = 0
    while True:
        got = feeder.take(1)
        if got is None:
            break
        feeder.prefetch(1)  # next prompt batch arrives while this one decodes
        row = {k: v[0] for k, v in got.items()}
        round_rng = jax.random.fold_in(rng, round_idx)
        if cfg.embed_inputs:
            batch = {"tokens": jnp.asarray(row["tokens"]) % cfg.vocab_size}
        else:  # stubbed modality frontend provides embeddings
            batch = {"embeds": jax.random.normal(
                round_rng, (args.batch, args.prompt_len, cfg.d_model),
                dtype=jnp.dtype(cfg.compute_dtype))}

        t0 = time.time()
        logits, cache = jax.block_until_ready(prefill(params, batch))
        t_pre = time.time() - t0

        outs = []
        t0 = time.time()
        tok = jnp.argmax(logits, axis=-1)
        for i in range(args.gen):
            outs.append(np.asarray(tok))
            if cfg.embed_inputs:
                step = {"tokens": tok[:, None]}
            else:
                step = {"embeds": jax.random.normal(
                    jax.random.fold_in(round_rng, i), (args.batch, 1, cfg.d_model),
                    dtype=jnp.dtype(cfg.compute_dtype))}
            logits, cache = decode(params, cache, step)
            tok = jnp.argmax(logits, axis=-1)
        jax.block_until_ready(logits)
        t_dec = time.time() - t0

        print(f"{cfg.name} round {round_idx}: prefill {t_pre*1e3:.1f} ms, "
              f"decode {t_dec/args.gen*1e3:.2f} ms/tok "
              f"({args.batch*args.gen/t_dec:.0f} tok/s)")
        print("sample:", [int(t[0]) for t in outs][:12])
        feeder.ack()  # round served: drop its replay copy
        round_idx += 1
    feeder.close()


if __name__ == "__main__":
    main()
