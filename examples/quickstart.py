"""Quickstart: the `repro.api` session layer in ~40 lines.

One `FerretSession` runs the same stream through the planned async
pipeline, a tighter memory budget, and the exact sequential Oracle — one
call signature, one result shape.

    PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses
import math

from repro.api import FerretSession
from repro.core.compensation import CompensationConfig
from repro.models.registry import get_config
from repro.ocl.streams import StreamConfig, make_stream


def main():
    # a small dense LM (reduced h2o-danube config)
    cfg = dataclasses.replace(
        get_config("h2o-danube-1.8b", smoke=True),
        compute_dtype="float32", num_layers=4, vocab_size=32,
    )

    # a drifting token stream: 200 items arriving one microbatch at a time
    stream = make_stream(StreamConfig(
        kind="drift", modality="tokens", length=200, batch=2, vocab=32, seq=16,
    ))

    # Ferret_M+: plan with unconstrained memory
    session = FerretSession(
        cfg, math.inf, "er", stream, lr=5e-3,
        compensation=CompensationConfig(method="iter_fisher", eta_lambda=1e-4),
        max_workers=3, max_stages=4,
    )
    plan = session.plan
    print(f"planned pipeline: P={plan.partition.num_stages} stages, "
          f"N={len(plan.config.active_workers())} workers, "
          f"M_F={plan.memory/2**20:.1f} MiB, R_F={plan.rate:.3f}")

    res = session.run()  # default runner: the pipelined engine
    lam = res.lam_curve
    print(f"online accuracy: {100*res.online_acc:.2f}%  "
          f"(loss {res.losses[0]:.3f} → {res.losses[-1]:.3f}, "
          f"admitted {100*res.admitted_frac:.0f}%, λ→{lam[-1]:.3f})")

    # same model under a 3× tighter budget: the planner deploys T1–T4
    s2 = FerretSession(
        cfg, plan.memory * 0.3, "er", stream, lr=5e-3,
        compensation=CompensationConfig(method="iter_fisher", eta_lambda=1e-4),
        max_workers=3, max_stages=4, params=session.params,
    )
    p2 = s2.plan
    knobs = p2.config.active_workers()[0]
    print(f"\nconstrained plan (30% budget): P={p2.partition.num_stages}, "
          f"N={len(p2.config.active_workers())}, M_F={p2.memory/2**20:.1f} MiB")
    print(f"  T1 recompute={knobs.recompute}  "
          f"T2 accum={[s.accum for s in knobs.stages]}  "
          f"T3 omit={[s.omit for s in knobs.stages]}")
    res2 = s2.run()
    print(f"  online accuracy: {100*res2.online_acc:.2f}% at "
          f"{100*p2.memory/plan.memory:.0f}% of the memory")

    # the exact sequential Oracle on the same stream, same call signature
    res3 = session.run("sequential")
    print(f"\nsequential Oracle: {100*res3.online_acc:.2f}% "
          f"(Ferret_M+ tracks it within a few points)")


if __name__ == "__main__":
    main()
