"""Planner demo: memory-budget sweep over a real assigned architecture.

Reproduces the Fig. 6 trend — adaptation rate scales smoothly with budget —
and shows the T1–T4 knobs the planner chose at each point.

    PYTHONPATH=src python examples/planner_sweep.py --arch stablelm-12b
"""

import argparse
import math

from repro.core.planner import default_data_interval, plan
from repro.core.profiler import analytic_profile
from repro.models.registry import ARCHITECTURES, get_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b", choices=sorted(ARCHITECTURES))
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=2048)
    ap.add_argument("--chips", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    profile = analytic_profile(cfg, args.batch, args.seq, chips=args.chips)
    t_d = default_data_interval(profile)
    m_plus = plan(profile, t_d, budget=math.inf, max_workers=6)
    print(f"{args.arch}: t_d={t_d*1e3:.2f} ms, unconstrained plan: "
          f"P={m_plus.partition.num_stages} N={len(m_plus.config.active_workers())} "
          f"M={m_plus.memory/2**30:.2f} GiB R={m_plus.rate:.4f}\n")

    print(f"{'budget':>8} {'M_F(GiB)':>9} {'R_F':>9} {'P':>3} {'N':>3} "
          f"{'T1':>3} {'T2(max accum)':>14} {'T3(omitted)':>12}")
    for frac in (0.03, 0.08, 0.15, 0.3, 0.5, 0.75, 1.0):
        p = plan(profile, t_d, budget=m_plus.memory * frac, max_workers=6)
        ws = p.config.active_workers()
        t1 = max((w.recompute for w in ws), default=0)
        t2 = max((s.accum for w in ws for s in w.stages), default=0)
        t3 = sum(1 for w in ws for s in w.stages if s.omit > 0)
        print(f"{frac:8.2f} {p.memory/2**30:9.2f} {p.rate:9.4f} "
              f"{p.partition.num_stages:3d} {len(ws):3d} {t1:3d} {t2:14d} {t3:12d}")


if __name__ == "__main__":
    main()
