"""Spatial shard_map pipeline: wavefront forward/backward equivalences.

Runs in a subprocess with 8 host devices (keeps the main test process on
1 device)."""

import json
import os
import subprocess
import sys
import textwrap

CODE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.registry import get_config
    from repro.models import transformer as T
    from repro.core.stage_parallel import spatial_pipeline_logits, spatial_pipeline_loss

    cfg = dataclasses.replace(get_config("h2o-danube-1.8b", smoke=True),
                              compute_dtype="float32", num_layers=8, vocab_size=64)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    mesh = jax.make_mesh((4,), ("stage",))
    M, b, s = 3, 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (M, b, s + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :, :-1], "labels": toks[:, :, 1:]}
    with mesh:
        logits = spatial_pipeline_logits(cfg, params, batch, mesh, num_stages=4)
    for m in range(M):
        ref, _ = T.forward(cfg, params, {"tokens": batch["tokens"][m]})
        np.testing.assert_allclose(np.asarray(logits[m]), np.asarray(ref), rtol=2e-4, atol=2e-4)
    with mesh:
        g_sp = jax.grad(lambda p: spatial_pipeline_loss(cfg, p, batch, mesh, 4))(params)
    def plain_loss(p):
        tot = 0.0
        for m in range(M):
            tot = tot + T.loss_fn(cfg, p, {"tokens": batch["tokens"][m],
                                           "labels": batch["labels"][m]})[0]
        return tot / M
    g_ref = jax.grad(plain_loss)(params)
    for a, b_ in zip(jax.tree.leaves(g_sp), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=3e-3, atol=3e-4)
    print(json.dumps({"ok": True}))
    """
)


def test_spatial_pipeline_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.run(
        [sys.executable, "-c", CODE], capture_output=True, text=True,
        timeout=600, cwd=root, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert json.loads(proc.stdout.strip().splitlines()[-1])["ok"]
