"""repro.profile: store durability, autotune determinism, planner bridge.

Covers the subsystem's contracts:
- store roundtrip, v1→v2 schema migration, corrupt-entry quarantine,
  forward compatibility (newer schema ignored);
- pure choice functions: same measurements → same knobs, documented
  tie-breaks;
- knob precedence: explicit env var > tuned store record > built-in
  heuristic, at every consumer (kernels.ops dispatch, EngineCache
  buckets);
- planner parity: a stored measurement that numerically equals the
  analytic roofline produces the identical plan (only provenance moves);
- online refinement: observed wall-clock reshapes the stored profile and
  the next replan picks it up.
"""

import dataclasses
import json
import math
import os

import numpy as np
import pytest

from repro.core import planner as planner_lib
from repro.core.compensation import CompensationConfig
from repro.core.ferret import (
    DEFAULT_SEGMENT_BUCKETS,
    EngineCache,
    FerretConfig,
    _buckets_from_env,
)
from repro.core.profiler import analytic_profile, profile_for
from repro.kernels import ops
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.ocl.streams import StreamConfig, make_stream
import importlib

# the package re-exports the autotune() *function* under the same name as
# the submodule, so attribute-style import would resolve to the function
tune_lib = importlib.import_module("repro.profile.autotune")
from repro.profile import store as store_lib  # noqa: E402
from repro.profile.autotune import (
    TUNE_KIND,
    bucket_cost,
    choose_buckets,
    choose_pack,
    clear_tuned_cache,
)
from repro.profile.bridge import (
    PROFILE_KIND,
    observe_segment,
    profile_from_payload,
    profile_to_payload,
    resolve_profile,
)
from repro.profile.store import (
    SCHEMA_VERSION,
    ProfileStore,
    profile_key,
    reset_default_stores,
)
from repro.runtime import BudgetEvent, ElasticStreamTrainer


def _cfg(num_layers=4):
    return ModelConfig(
        name="prof-lm", family="dense", num_layers=num_layers, d_model=64,
        num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=32,
        compute_dtype="float32",
    )


@pytest.fixture
def pstore(tmp_path, monkeypatch):
    """An isolated store that is also the process default (env-routed)."""
    root = str(tmp_path / "profile")
    monkeypatch.setenv("REPRO_PROFILE_DIR", root)
    reset_default_stores()
    clear_tuned_cache()
    yield ProfileStore(root)
    reset_default_stores()
    clear_tuned_cache()


# ---------------------------------------------------------------------------
# Store: roundtrip, migration, corruption, forward compatibility
# ---------------------------------------------------------------------------


def test_store_roundtrip_and_cache(pstore):
    key = {"backend": "test", "model": "abc"}
    payload = {"pack": True, "pack_block": 4096}
    assert pstore.get(TUNE_KIND, key) is None
    pstore.put(TUNE_KIND, key, payload)
    assert pstore.get(TUNE_KIND, key) == payload
    # second read is the in-process cache, not the filesystem
    reads = pstore.disk_reads
    assert pstore.get(TUNE_KIND, key) == payload
    assert pstore.disk_reads == reads
    # a fresh instance reads the same bytes back
    assert ProfileStore(pstore.root).get(TUNE_KIND, key) == payload
    assert pstore.delete(TUNE_KIND, key)
    assert pstore.get(TUNE_KIND, key) is None


def test_store_migrates_v1_layers(pstore):
    cfg = _cfg()
    key = profile_key(cfg, 2, 16, backend="test")
    v1 = {
        "schema": 1,
        "kind": PROFILE_KIND,
        "key": key,
        "payload": {
            "batch": 2, "seq": 16, "embed_bytes": 1024,
            "layers": [[0.5, 1.0, 100, 200, 50]],
        },
    }
    path = pstore._path(PROFILE_KIND, key)
    os.makedirs(pstore.root, exist_ok=True)
    with open(path, "w") as f:
        json.dump(v1, f)
    payload = pstore.get(PROFILE_KIND, key)
    assert payload["layers"][0] == {
        "t_fwd": 0.5, "t_bwd": 1.0, "w_bytes": 100,
        "a_bytes": 200, "a_internal_bytes": 50,
    }
    assert payload["provenance"] == "measured"  # v1 stores only held measurements
    profile = profile_from_payload(payload)
    assert profile.layers[0].t_bwd == 1.0
    # the upgraded form was persisted: on-disk record is now current-schema
    with open(path) as f:
        assert json.load(f)["schema"] == SCHEMA_VERSION


def test_store_quarantines_corrupt_entry(pstore):
    key = {"backend": "test"}
    pstore.put(TUNE_KIND, key, {"pack": False})
    path = pstore._path(TUNE_KIND, key)
    with open(path, "w") as f:
        f.write("{not json")
    pstore.clear_cache()
    assert pstore.get(TUNE_KIND, key) is None
    assert not os.path.exists(path)
    assert os.path.exists(path + ".corrupt")
    # quarantine is terminal, not fatal: the slot is writable again
    pstore.put(TUNE_KIND, key, {"pack": True})
    assert pstore.get(TUNE_KIND, key) == {"pack": True}


def test_store_ignores_newer_schema(pstore):
    key = {"backend": "future"}
    path = pstore._path(TUNE_KIND, key)
    os.makedirs(pstore.root, exist_ok=True)
    with open(path, "w") as f:
        json.dump({"schema": SCHEMA_VERSION + 1, "payload": {"pack": True}}, f)
    assert pstore.get(TUNE_KIND, key) is None
    assert os.path.exists(path)  # untouched, not quarantined


# ---------------------------------------------------------------------------
# Autotune: deterministic choices + precedence
# ---------------------------------------------------------------------------


def test_choose_pack_deterministic_and_tie_broken():
    meas = {
        "per_leaf": {"mean_s": 2.0},
        "packed@1024": {"mean_s": 1.0, "block": 1024},
        "packed@4096": {"mean_s": 1.5, "block": 4096},
    }
    assert choose_pack(meas) == (True, 1024)
    assert choose_pack(dict(reversed(list(meas.items())))) == (True, 1024)
    # exact tie: per_leaf wins (no packing machinery on equal evidence)
    tie = {
        "per_leaf": {"mean_s": 1.0},
        "packed@1024": {"mean_s": 1.0, "block": 1024},
    }
    assert choose_pack(tie) == (False, None)
    with pytest.raises(ValueError):
        choose_pack({"packed@1024": {"mean_s": 1.0, "block": 1024}})


def test_choose_buckets_trades_compile_vs_padding():
    # compile dominates → the sparsest ladder (fewest distinct buckets)
    sparse = choose_buckets(compile_s=100.0, per_round_s=1e-9)
    # padding dominates → a denser ladder than the compile-dominated one
    dense = choose_buckets(compile_s=1e-9, per_round_s=100.0)
    assert len(sparse) <= len(dense)
    assert sparse == choose_buckets(compile_s=100.0, per_round_s=1e-9)
    # cost model sanity: padding cost is monotone in per_round_s
    c1 = bucket_cost((8, 64), 0.0, 1.0)
    c2 = bucket_cost((8, 64), 0.0, 2.0)
    assert c2 == pytest.approx(2 * c1)


def test_env_beats_tuned_record_for_pack(pstore, monkeypatch):
    # tuned record says "pack with block 1024"
    pstore.put(TUNE_KIND, {"backend": store_lib.backend_fingerprint()},
               {"pack": True, "pack_block": 1024})
    clear_tuned_cache()
    monkeypatch.delenv("REPRO_PACK", raising=False)
    monkeypatch.delenv("REPRO_PACK_BLOCK", raising=False)
    assert ops._use_packed() is True
    assert ops._pack_block() == 1024
    # explicit env always wins
    monkeypatch.setenv("REPRO_PACK", "0")
    monkeypatch.setenv("REPRO_PACK_BLOCK", "2048")
    assert ops._use_packed() is False
    assert ops._pack_block() == 2048


def test_heuristic_when_no_tuned_record(pstore, monkeypatch):
    monkeypatch.delenv("REPRO_PACK", raising=False)
    # empty store, CPU backend: per-leaf is the default (the measured ~7×
    # interpret regression must not be the default dispatch)
    assert ops._use_packed() is False
    monkeypatch.setenv("REPRO_PACK", "1")
    assert ops._use_packed() is True


def test_bucket_precedence(pstore, monkeypatch):
    monkeypatch.delenv("REPRO_SEGMENT_BUCKETS", raising=False)
    assert _buckets_from_env() == DEFAULT_SEGMENT_BUCKETS
    pstore.put(TUNE_KIND, {"backend": store_lib.backend_fingerprint()},
               {"pack": False, "segment_buckets": [8, 32, 128]})
    clear_tuned_cache()
    assert _buckets_from_env() == (8, 32, 128)
    assert EngineCache().buckets == (8, 32, 128)
    monkeypatch.setenv("REPRO_SEGMENT_BUCKETS", "16,64")
    assert _buckets_from_env() == (16, 64)


def test_autotune_persists_and_rereads(pstore, monkeypatch):
    monkeypatch.delenv("REPRO_PACK", raising=False)
    calls = []

    def fake_measure(**kwargs):
        calls.append(kwargs)
        return {
            "per_leaf": {"mean_s": 5.0},
            "packed@1024": {"mean_s": 1.0, "block": 1024},
        }

    monkeypatch.setattr(
        "repro.profile.harness.measure_kernel_variants",
        lambda **kw: fake_measure(**kw),
    )
    tuned = tune_lib.autotune(pstore, repeats=1)
    assert (tuned.pack, tuned.pack_block) == (True, 1024)
    assert len(calls) == 1
    # the read side (fresh cache) reconstructs the same defaults from disk
    clear_tuned_cache()
    again = tune_lib.tuned_defaults(pstore)
    assert (again.pack, again.pack_block, again.source) == (True, 1024, "store")
    # and dispatch follows it
    assert ops._use_packed() is True


# ---------------------------------------------------------------------------
# Planner bridge: parity, resolution modes, measurement dedupe
# ---------------------------------------------------------------------------


def test_payload_roundtrip_preserves_profile():
    profile = analytic_profile(_cfg(), 2, 16)
    back = profile_from_payload(profile_to_payload(profile))
    assert back == profile


def test_planner_parity_measured_equals_analytic(pstore):
    """A stored measurement numerically equal to the roofline must yield
    the identical plan — measurement changes numbers, never semantics."""
    cfg = _cfg()
    analytic = analytic_profile(cfg, 2, 16)
    as_measured = dataclasses.replace(analytic, provenance="measured")
    pstore.put(PROFILE_KIND, profile_key(cfg, 2, 16),
               profile_to_payload(as_measured))
    resolved = resolve_profile(cfg, 2, 16, prefer="auto", store=pstore)
    assert resolved.provenance == "measured"
    t_d = planner_lib.default_data_interval(analytic)
    p_a = planner_lib.plan(analytic, t_d, math.inf, max_workers=3)
    p_m = planner_lib.plan(resolved, t_d, math.inf, max_workers=3)
    assert p_a.partition.bounds == p_m.partition.bounds
    assert p_a.rate == p_m.rate
    assert p_a.memory == p_m.memory
    assert (p_a.profile_provenance, p_m.profile_provenance) == ("analytic", "measured")


def test_resolve_modes(pstore):
    cfg = _cfg()
    assert resolve_profile(cfg, 2, 16, prefer="analytic").provenance == "analytic"
    # auto + empty store: exact analytic fallback (tier-1 parity)
    assert resolve_profile(cfg, 2, 16, prefer="auto", store=pstore) == \
        analytic_profile(cfg, 2, 16)
    with pytest.raises(ValueError):
        resolve_profile(cfg, 2, 16, prefer="wrong")


def test_measured_hit_skips_remeasurement(pstore, monkeypatch):
    cfg = _cfg()
    measured = dataclasses.replace(analytic_profile(cfg, 2, 16), provenance="measured")
    runs = []
    monkeypatch.setattr(
        "repro.profile.harness.measure_model_profile",
        lambda *a, **kw: (runs.append(1) or (measured, {})),
    )
    first = resolve_profile(cfg, 2, 16, prefer="measured", store=pstore)
    assert first.provenance == "measured" and len(runs) == 1
    # second resolve: store hit, the harness never runs again
    second = resolve_profile(cfg, 2, 16, prefer="measured", store=pstore)
    assert second == first and len(runs) == 1
    # profiler facade goes through the same path
    assert profile_for(cfg, 2, 16, prefer="auto") == first
    assert len(runs) == 1


def test_observe_segment_refines_and_persists(pstore):
    cfg = _cfg()
    profile = analytic_profile(cfg, 2, 16)
    t_d = planner_lib.default_data_interval(profile)
    plan = planner_lib.plan(profile, t_d, math.inf, max_workers=3)
    from repro.core.cost_model import expected_round_seconds

    expected = expected_round_seconds(plan.stats, plan.config) * 10
    # observed 3× slower than planned → damped move halfway (alpha=0.5)
    refined, scale = observe_segment(
        cfg, 2, 16, profile, plan, rounds=10, run_s=3.0 * expected, store=pstore
    )
    assert scale == pytest.approx(3.0, rel=1e-6)
    assert refined.provenance == "online"
    assert refined.layers[0].t_fwd == pytest.approx(profile.layers[0].t_fwd * 2.0)
    # byte facts untouched
    assert refined.layers[0].w_bytes == profile.layers[0].w_bytes
    # persisted: the next auto-resolution (i.e. the next replan) sees it
    assert resolve_profile(cfg, 2, 16, prefer="auto", store=pstore) == refined
    # no signal → no update
    assert observe_segment(cfg, 2, 16, profile, plan, 0, 1.0, store=pstore) is None
    assert observe_segment(cfg, 2, 16, profile, plan, 10, 0.0, store=pstore) is None


# ---------------------------------------------------------------------------
# Feedback → replan, end to end on the elastic trainer
# ---------------------------------------------------------------------------


def test_elastic_feedback_refines_profile_and_replans(pstore, rng):
    cfg = _cfg()
    fc = FerretConfig(
        budget_bytes=math.inf, lr=5e-3,
        compensation=CompensationConfig(method="iter_fisher", eta_lambda=1e-4),
        max_workers=3, max_stages=4, profile_feedback=True,
    )
    params = T.init_params(cfg, rng)
    stream = make_stream(StreamConfig(
        kind="drift", modality="tokens", length=24, batch=2, vocab=32, seq=16,
    ))
    et = ElasticStreamTrainer(cfg, fc, batch=2, seq=16)
    assert et.profile.provenance == "analytic"
    # same-budget events split the run into equal bucketed segments, so
    # segments 2 and 3 are engine-cache hits → feedback fires there
    res = et.run_stream(params, stream, schedule=[
        BudgetEvent(8, math.inf), BudgetEvent(16, math.inf),
    ])
    assert res.rounds == 24
    assert any(s.cache_hit for s in res.segments)
    assert np.isfinite(np.asarray(res.losses)).all()
    # the observation refined the trainer's live profile and the store
    assert et.profile.provenance == "online"
    stored = pstore.get(PROFILE_KIND, profile_key(cfg, 2, 16))
    assert stored is not None and stored["provenance"] == "online"
    # a post-fault/budget replan now plans from the refined numbers
    replan = et.plan_for(math.inf)
    assert replan.profile_provenance == "online"
