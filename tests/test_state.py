"""The unified engine-state plane: ``repro.state``.

Property tests for the invariants the elastic runtime leans on:

(a) ``EngineState`` is tuple-compatible (legacy positional unpacking) and
    a registered keyed pytree whose metadata survives ``jax.tree.map``;
(b) merge → re-split is the identity on the whole-model view for *any*
    pair of partitions (hypothesis over the cut-point bitmask) — the
    property that makes cross-partition switches lossless;
(c) ring trees remap slot-wise: an A→B→A round-trip is bit-exact;
(d) the in-flight accounting (``pending_groups`` / ``rounds_in_flight`` /
    ``applied_updates``) is conservative against the schedule arrays;
(e) ``StateRemapper`` flushes every pending accumulation group through
    the optimizer on a schedule-restarting switch (bit-compared against a
    manual replay), and ``carry_rings=False`` drops the rings but
    *reports* the in-flight rounds it discarded;
(f) ``retime_deltas`` re-indexes Δθ history onto a new ring depth with
    newest-first alignment and zero padding.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import compensation as comp_lib
from repro.core import schedule as sched_lib
from repro.core.compensation import CompensationConfig
from repro.core.cost_model import PipelineConfig, StageKnobs, WorkerConfig
from repro.models import transformer as T
from repro.models.registry import get_config
from repro.optim.optimizers import adamw
from repro.state import (
    StateRemapper,
    applied_updates,
    pending_groups,
    remap_ring_trees,
    remap_stage_params,
    retime_deltas,
    rounds_in_flight,
)
from repro.state.engine_state import EngineState

pytestmark = pytest.mark.state

L = 4  # layers in the test model → partition bounds over [0, 4]


@functools.lru_cache(maxsize=1)
def _cfg():
    return dataclasses.replace(
        get_config("h2o-danube-1.8b", smoke=True),
        compute_dtype="float32", num_layers=L, vocab_size=32,
    )


@functools.lru_cache(maxsize=1)
def _params():
    return T.init_params(_cfg(), jax.random.PRNGKey(0))


def _bounds_from_mask(mask: int):
    """Interior cut points of [0, L] from a bitmask — every partition of
    the layer range is reachable, which is what the property quantifies
    over (bit i set → a stage boundary after layer i+1)."""
    return [0] + [i + 1 for i in range(L - 1) if (mask >> i) & 1] + [L]


def _pipe_config(P: int, workers: int = 2, accum: int = 2) -> PipelineConfig:
    return PipelineConfig(workers=[
        WorkerConfig(delay=0, stages=[StageKnobs(accum=accum) for _ in range(P)])
        for _ in range(workers)
    ])


def _tree_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


# ---------------------------------------------------------------------------
# (a) EngineState: tuple compatibility + pytree registration
# ---------------------------------------------------------------------------


def _dummy_state() -> EngineState:
    sp = T.split_stage_params(_cfg(), _params(), [0, 2, L])
    rings = tuple(
        jax.tree.map(lambda p: jnp.zeros((3, *p.shape), jnp.float32), s) for s in sp
    )
    return EngineState(
        stage_params=tuple(sp), rings=rings, deltas=None,
        opt_states=None, comp_states=None,
        bounds=(0, 2, L),
        geometry=sched_lib.RingGeometry(ring_size=3, delta_ring=2),
        sched_origin=7,
    )


def test_engine_state_tuple_compat():
    state = _dummy_state()
    assert len(state) == 5
    sp, rings, deltas, opts, comps = state  # 5-way unpacking
    assert sp is state.stage_params and rings is state.rings
    assert deltas is None and opts is None and comps is None
    assert state[0] is state.stage_params and state[1] is state.rings
    assert state.as_tuple() == (sp, rings, None, None, None)
    rt = EngineState.from_tuple(
        state.as_tuple(), bounds=state.bounds,
        geometry=state.geometry, sched_origin=state.sched_origin,
    )
    assert rt.bounds == state.bounds and rt.sched_origin == 7
    assert _tree_equal(rt.stage_params, state.stage_params)


def test_engine_state_is_keyed_pytree():
    state = _dummy_state()
    # identity map preserves the static metadata (it rides as aux data)
    mapped = jax.tree.map(lambda x: x * 2.0, state)
    assert isinstance(mapped, EngineState)
    assert mapped.bounds == state.bounds
    assert mapped.geometry == state.geometry
    assert mapped.sched_origin == state.sched_origin
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(mapped)[0]),
        2.0 * np.asarray(jax.tree.leaves(state)[0]),
    )
    # key paths name the fields (checkpoint key paths depend on this)
    paths = {
        str(path[0]) for path, _ in jax.tree_util.tree_flatten_with_path(state)[0]
    }
    assert {".stage_params", ".rings"} <= paths


# ---------------------------------------------------------------------------
# (b) merge → re-split identity over all partition pairs
# ---------------------------------------------------------------------------


@settings(max_examples=30)
@given(
    mask_a=st.integers(0, 2 ** (L - 1) - 1),
    mask_b=st.integers(0, 2 ** (L - 1) - 1),
)
def test_merge_resplit_identity(mask_a, mask_b):
    cfg, params = _cfg(), _params()
    bounds_a, bounds_b = _bounds_from_mask(mask_a), _bounds_from_mask(mask_b)
    sp_a = T.split_stage_params(cfg, params, bounds_a)
    sp_b = remap_stage_params(cfg, sp_a, bounds_b)
    assert len(sp_b) == len(bounds_b) - 1
    # the whole-model view is invariant under any remap
    assert _tree_equal(T.merge_stage_params(cfg, list(sp_b)), params)
    # and the round-trip restores the per-stage split bit-exactly
    assert _tree_equal(remap_stage_params(cfg, sp_b, bounds_a), sp_a)


@settings(max_examples=15)
@given(
    mask_b=st.integers(0, 2 ** (L - 1) - 1),
    num_slots=st.integers(1, 4),
)
def test_ring_remap_roundtrip_is_bit_exact(mask_b, num_slots):
    cfg = _cfg()
    bounds_a, bounds_b = [0, 1, 2, L], _bounds_from_mask(mask_b)
    sp_a = T.split_stage_params(cfg, _params(), bounds_a)
    rng = np.random.default_rng(num_slots)
    rings_a = tuple(
        jax.tree.map(
            lambda p: jnp.asarray(
                rng.standard_normal((num_slots, *p.shape)), jnp.float32
            ),
            sp,
        )
        for sp in sp_a
    )
    rings_b = remap_ring_trees(cfg, rings_a, bounds_b, num_slots)
    assert len(rings_b) == len(bounds_b) - 1
    rings_rt = remap_ring_trees(cfg, rings_b, bounds_a, num_slots)
    assert _tree_equal(rings_rt, rings_a)


# ---------------------------------------------------------------------------
# (d) in-flight accounting against the schedule arrays
# ---------------------------------------------------------------------------


def test_pending_groups_sync_schedule_exact():
    """The synchronous schedule makes the in-flight count closed-form:
    every stage accumulates K items then applies, so after ``upto``
    rounds exactly ``upto % K`` grads are pending."""
    K, P = 4, 2
    sched = sched_lib.build_schedule(_pipe_config(P), P, 32, sync_period=K)
    for upto in range(33):
        assert rounds_in_flight(sched, upto) == upto % K, upto


def test_pending_groups_conservation_async():
    """Per stage, every pushed backward round is either applied by a pop
    within the prefix or still pending — nothing vanishes."""
    P = 2
    config = _pipe_config(P, workers=3, accum=2)
    sched = sched_lib.build_schedule(config, P, 48)
    for upto in (0, 1, 5, 13, 24, 48):
        pending = pending_groups(sched, upto)
        for j in range(P):
            pushed = int(np.sum(sched.push_slot[:upto, j] >= 0))
            pops = [
                round(1.0 / sched.pop_scale[m, j])
                for m in range(upto) if sched.pop_slot[m, j] >= 0
            ]
            assert pushed == sum(pops) + sum(pending[j].values()), (upto, j)
    assert rounds_in_flight(sched, 0) == 0
    # full-schedule update count agrees with the schedule's own stats
    assert sum(applied_updates(sched, 48)) == sched.stats()["updates"]


# ---------------------------------------------------------------------------
# (e) StateRemapper: flush correctness + the carry_rings escape hatch
# ---------------------------------------------------------------------------


def _live_state(bounds, config, upto):
    """A mid-schedule EngineState whose ring contents are random but whose
    geometry/schedule coordinates are real."""
    cfg = _cfg()
    P = len(bounds) - 1
    sp = T.split_stage_params(cfg, _params(), bounds)
    opt = adamw(lr=1e-2)
    opts = tuple(opt.init(s) for s in sp)
    comps = tuple(
        comp_lib.init_state(s, CompensationConfig(method="iter_fisher")) for s in sp
    )
    geom = sched_lib.ring_geometry(config, P)
    rng = np.random.default_rng(upto)
    rings = tuple(
        jax.tree.map(
            lambda p: jnp.asarray(
                rng.standard_normal((geom.ring_size, *p.shape)), jnp.float32
            ),
            s,
        )
        for s in sp
    )
    deltas = tuple(
        jax.tree.map(
            lambda p: jnp.asarray(
                rng.standard_normal((geom.delta_ring, *p.shape)), jnp.float32
            ),
            s,
        )
        for s in sp
    )
    state = EngineState(
        stage_params=tuple(sp), rings=rings, deltas=deltas,
        opt_states=opts, comp_states=comps,
        bounds=tuple(bounds), geometry=geom, sched_origin=0,
    )
    return state, opt


def test_restart_switch_flushes_pending_groups():
    """A schedule-restarting remap applies every in-flight accumulation
    group through the optimizer — bit-compared against a manual replay of
    ``pending_groups`` on the old schedule prefix."""
    bounds_a, bounds_b = [0, 2, L], [0, L]
    config_a = _pipe_config(2, workers=2, accum=2)
    upto = 9
    sched = sched_lib.build_schedule(config_a, 2, 16)
    state, opt = _live_state(bounds_a, config_a, upto)
    pending = pending_groups(sched, upto)
    assert any(g for g in pending), "prefix must leave groups in flight"

    remapper = StateRemapper(_cfg(), opt)
    new_geom = sched_lib.ring_geometry(_pipe_config(1), 1)
    out, lost = remapper.remap(
        state, bounds_b, new_geometry=new_geom, same_schedule=False,
        old_schedule=sched, rounds_into_schedule=upto,
    )
    assert lost == 0
    assert out.rings is None  # nothing in flight after the flush
    assert out.sched_origin is None  # the schedule restarts

    # manual replay: apply each pending mean gradient, then merge/re-split
    sp = list(state.stage_params)
    opts = list(state.opt_states)
    for j, groups in enumerate(pending):
        for slot, count in groups.items():
            g = jax.tree.map(lambda a: a[slot] / count, state.rings[j])
            sp[j], opts[j] = opt.update(sp[j], g, opts[j])
    expect_sp = remap_stage_params(_cfg(), sp, bounds_b)
    assert _tree_equal(out.stage_params, expect_sp)
    # flushed Δθ history is carried at the *destination* ring depth
    assert out.deltas is not None
    for d in out.deltas:
        for leaf in jax.tree.leaves(d):
            assert leaf.shape[0] == new_geom.delta_ring


def test_carry_rings_false_drops_and_reports():
    bounds_a = [0, 2, L]
    config_a = _pipe_config(2, workers=2, accum=2)
    upto = 9
    sched = sched_lib.build_schedule(config_a, 2, 16)
    state, opt = _live_state(bounds_a, config_a, upto)
    remapper = StateRemapper(_cfg(), opt)
    out, lost = remapper.remap(
        state, [0, L], new_geometry=sched_lib.ring_geometry(_pipe_config(1), 1),
        same_schedule=False, old_schedule=sched, rounds_into_schedule=upto,
        carry_rings=False,
    )
    assert lost == rounds_in_flight(sched, upto) > 0
    assert out.rings is None and out.deltas is None
    # the weights were NOT flushed: pure merge/re-split of the old params
    assert _tree_equal(
        out.stage_params, remap_stage_params(_cfg(), state.stage_params, [0, L])
    )


def test_topology_shrink_remap_is_lossless():
    """The device-loss path: a replan under the survivors' topology remaps
    mid-schedule state with ``rounds_lost == 0`` on the default
    ``carry_rings`` path — in-flight accumulation groups are flushed
    through the optimizer, never dropped — while the shrunken topology
    re-keys the engine cache (distinct fingerprint)."""
    from repro.runtime.topology import DeviceTopology

    topo = DeviceTopology(device_count=4, mesh_shape=(4, 1))
    shrunk = topo.shrink(1)
    assert shrunk.mesh_shape == (3, 1)
    assert shrunk.fingerprint() != topo.fingerprint()

    bounds_a, bounds_b = [0, 2, L], [0, L]
    config_a = _pipe_config(2, workers=2, accum=2)
    upto = 9
    sched = sched_lib.build_schedule(config_a, 2, 16)
    state, opt = _live_state(bounds_a, config_a, upto)
    assert rounds_in_flight(sched, upto) > 0  # the shrink hits live state

    remapper = StateRemapper(_cfg(), opt)
    out, lost = remapper.remap(
        state, bounds_b, new_geometry=sched_lib.ring_geometry(_pipe_config(1), 1),
        same_schedule=False, old_schedule=sched, rounds_into_schedule=upto,
        carry_rings=True,
    )
    assert lost == 0
    assert out.bounds == tuple(bounds_b)


def test_same_schedule_switch_carries_rings_and_origin():
    bounds_a, bounds_b = [0, 1, L], [0, 3, L]
    config = _pipe_config(2, workers=2, accum=2)
    state, opt = _live_state(bounds_a, config, upto=5)
    remapper = StateRemapper(_cfg(), opt)
    out, lost = remapper.remap(state, bounds_b, same_schedule=True)
    assert lost == 0
    assert out.sched_origin == state.sched_origin  # schedule continues
    assert out.geometry == state.geometry
    # slot-wise lossless: remapping back restores the ring contents
    assert _tree_equal(
        remap_ring_trees(_cfg(), out.rings, bounds_a, state.geometry.ring_size),
        state.rings,
    )
    assert _tree_equal(
        remap_stage_params(_cfg(), out.stage_params, bounds_a), state.stage_params
    )


# ---------------------------------------------------------------------------
# (f) Δθ re-time-indexing
# ---------------------------------------------------------------------------


def test_retime_deltas_alignment():
    k_old, upd = 3, 5
    # fill slot u % k_old with 1+u (latest write wins), mirroring how the
    # engine writes Δθ slots round-robin; 0 marks never-written
    arr = np.zeros((k_old, 2), np.float32)
    for u in range(upd):
        arr[u % k_old] = 1 + u
    ring = {"w": jnp.asarray(arr)}

    # newest carried entry lands at slot k_new-1, older ones walk back
    shrunk = retime_deltas([ring], [upd], k_old, 2)[0]["w"]
    np.testing.assert_array_equal(np.asarray(shrunk), [[4, 4], [5, 5]])

    grown = retime_deltas([ring], [upd], k_old, 5)[0]["w"]
    np.testing.assert_array_equal(
        np.asarray(grown), [[0, 0], [0, 0], [3, 3], [4, 4], [5, 5]]
    )

    # fewer updates than slots: only written entries are carried
    one = np.zeros((k_old, 2), np.float32)
    one[0] = 1
    fresh = retime_deltas([{"w": jnp.asarray(one)}], [1], k_old, 2)[0]["w"]
    np.testing.assert_array_equal(np.asarray(fresh), [[0, 0], [1, 1]])
