"""Minimal, deterministic stand-in for ``hypothesis`` when it isn't installed.

The container that runs the tier-1 suite has no network access, so the real
``hypothesis`` may be absent even though it's declared in the dev deps.
``conftest.py`` registers this module under ``sys.modules['hypothesis']``
only in that case; with hypothesis installed, the real library is used.

Coverage is intentionally tiny — exactly the API surface the test suite
uses: ``given``, ``settings``, and ``strategies.integers / floats /
sampled_from / booleans``. ``given`` enumerates the strategy bounds first
(hypothesis-style edge cases), then deterministic pseudo-random draws up to
``max_examples`` — no shrinking, no database, fully reproducible.
"""

from __future__ import annotations

import random
from typing import Any, Callable, Sequence

DEFAULT_MAX_EXAMPLES = 20
_MAX_EXAMPLES_ATTR = "_stub_max_examples"


class _Strategy:
    def edge_values(self) -> Sequence[Any]:
        return ()

    def draw(self, rng: random.Random) -> Any:
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = int(lo), int(hi)

    def edge_values(self):
        return (self.lo, self.hi) if self.lo != self.hi else (self.lo,)

    def draw(self, rng):
        return rng.randint(self.lo, self.hi)


class _Floats(_Strategy):
    def __init__(self, lo: float, hi: float):
        self.lo, self.hi = float(lo), float(hi)

    def edge_values(self):
        return (self.lo, self.hi) if self.lo != self.hi else (self.lo,)

    def draw(self, rng):
        return rng.uniform(self.lo, self.hi)


class _SampledFrom(_Strategy):
    def __init__(self, options: Sequence[Any]):
        self.options = list(options)

    def edge_values(self):
        return (self.options[0], self.options[-1])

    def draw(self, rng):
        return rng.choice(self.options)


class _Booleans(_Strategy):
    def edge_values(self):
        return (False, True)

    def draw(self, rng):
        return rng.random() < 0.5


class strategies:  # noqa: N801 — mirrors the hypothesis module name
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Floats(min_value, max_value)

    @staticmethod
    def sampled_from(options: Sequence[Any]) -> _Strategy:
        return _SampledFrom(options)

    @staticmethod
    def booleans() -> _Strategy:
        return _Booleans()


def settings(*, max_examples: int = DEFAULT_MAX_EXAMPLES, **_ignored) -> Callable:
    """Decorator: records max_examples on the (already given-wrapped) test."""

    def deco(fn):
        setattr(fn, _MAX_EXAMPLES_ATTR, int(max_examples))
        return fn

    return deco


def given(**strategy_kwargs) -> Callable:
    names = sorted(strategy_kwargs)

    def deco(fn):
        def runner():
            n = getattr(runner, _MAX_EXAMPLES_ATTR, DEFAULT_MAX_EXAMPLES)
            # First examples pin every strategy to one of its bounds in turn;
            # the rest are seeded draws (seed = test name, so runs repeat).
            examples = []
            for k in names:
                for edge in strategy_kwargs[k].edge_values():
                    rng = random.Random(f"{fn.__name__}:{k}:{edge!r}")
                    ex = {
                        kk: (edge if kk == k else strategy_kwargs[kk].draw(rng))
                        for kk in names
                    }
                    examples.append(ex)
            i = 0
            while len(examples) < n:
                rng = random.Random(f"{fn.__name__}:{i}")
                examples.append({k: strategy_kwargs[k].draw(rng) for k in names})
                i += 1
            for ex in examples[:n]:
                try:
                    fn(**ex)
                except Exception as e:  # noqa: BLE001 — re-raise with the example
                    raise AssertionError(
                        f"falsifying example (hypothesis stub): {fn.__name__}({ex})"
                    ) from e

        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner

    return deco


# ``from hypothesis import given, settings, strategies as st`` compatibility
st = strategies
