"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 CPU device;
multi-device sharding tests spawn subprocesses that set the flag first."""

import dataclasses
import importlib.util
import os
import sys
import tempfile

import jax
import pytest

# Hermetic profile store: planner/dispatch defaults must come from code,
# never from whatever ~/.cache/repro/profile happens to hold on this
# machine. Set before any repro import resolves the store root. Tests
# that exercise the store point REPRO_PROFILE_DIR at their own tmp_path
# (and reset_default_stores()/clear_tuned_cache() around it).
os.environ.setdefault(
    "REPRO_PROFILE_DIR", tempfile.mkdtemp(prefix="repro-test-profile-")
)

# The container has no network access: if the real hypothesis isn't
# installed, register the deterministic fallback before test collection so
# the property-based modules still collect and run (see _hypothesis_fallback).
# conftest executes fully before any test module imports hypothesis, so
# registering after the imports above is safe.
if importlib.util.find_spec("hypothesis") is None:
    import _hypothesis_fallback as _hyp_stub

    sys.modules["hypothesis"] = _hyp_stub


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def smoke_cfg(arch: str, **overrides):
    from repro.models.registry import get_config

    cfg = get_config(arch, smoke=True)
    defaults = dict(compute_dtype="float32", moe_capacity_factor=8.0)
    defaults.update(overrides)
    return dataclasses.replace(cfg, **defaults)
