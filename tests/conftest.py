"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see 1 CPU device;
multi-device sharding tests spawn subprocesses that set the flag first."""

import dataclasses

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def smoke_cfg(arch: str, **overrides):
    from repro.models.registry import get_config

    cfg = get_config(arch, smoke=True)
    defaults = dict(compute_dtype="float32", moe_capacity_factor=8.0)
    defaults.update(overrides)
    return dataclasses.replace(cfg, **defaults)
