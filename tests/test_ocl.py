"""OCL substrate: metrics, streams, replay, admission baselines."""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.ocl import metrics
from repro.ocl.algorithms import OCLConfig, ReplayBuffer, mix_replay_into_stream
from repro.ocl.baselines import AdmissionPolicy, make_admission_mask
from repro.ocl.streams import StreamConfig, make_stream


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_agm_matches_eq18():
    # agm = log(exp(oacc_A - oacc_B) / (M_A / M_B))
    val = metrics.agm(0.8, 0.5, 2.0, 1.0)
    assert val == pytest.approx((0.8 - 0.5) - math.log(2.0))


def test_agm_baseline_is_zero():
    assert metrics.agm(0.5, 0.5, 3.0, 3.0) == pytest.approx(0.0)


def test_adaptation_rate_discounts_delay_and_drops():
    r = metrics.adaptation_rate_empirical([0.0, 1.0, np.inf], c=1.0)
    assert r == pytest.approx((1.0 + math.exp(-1.0) + 0.0) / 3)


# ---------------------------------------------------------------------------
# streams
# ---------------------------------------------------------------------------


def test_token_stream_shapes_and_determinism():
    cfg = StreamConfig(kind="drift", modality="tokens", length=16, batch=2, vocab=32, seq=8)
    s1, s2 = make_stream(cfg), make_stream(cfg)
    assert s1["tokens"].shape == (16, 2, 8)
    np.testing.assert_array_equal(s1["tokens"], s2["tokens"])
    assert s1["tokens"].max() < 32


def test_split_stream_partitions_classes():
    cfg = StreamConfig(kind="split", modality="vectors", length=100, batch=1,
                       num_classes=10, num_tasks=5)
    s = make_stream(cfg)
    first = set(np.unique(s["labels"][:20]))
    last = set(np.unique(s["labels"][-20:]))
    assert first.isdisjoint(last)


def test_drift_stream_rotates_distribution():
    cfg = StreamConfig(kind="drift", modality="vectors", length=400, batch=4,
                       drift_rate=0.02, noise=0.01)
    s = make_stream(cfg)
    # class-0 mean early vs late should differ (prototypes rotated)
    m0 = s["x"][:50][s["labels"][:50] == 0].mean(0)
    m1 = s["x"][-50:][s["labels"][-50:] == 0].mean(0)
    assert np.linalg.norm(m0 - m1) > 0.05


# ---------------------------------------------------------------------------
# replay buffer
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(cap=st.integers(2, 32), n=st.integers(1, 200))
def test_reservoir_capacity_and_coverage(cap, n):
    buf = ReplayBuffer(cap, seed=0)
    for i in range(n):
        buf.add({"x": np.asarray([i])})
    assert len(buf) == min(cap, n)
    assert buf.seen == n


def test_mix_replay_marks_new_rows():
    stream = {
        "tokens": np.zeros((10, 2, 4), np.int32),
        "labels": np.zeros((10, 2, 4), np.int32),
    }
    mixed = mix_replay_into_stream(stream, OCLConfig(method="er", replay_batch=3))
    assert mixed["tokens"].shape == (10, 5, 4)
    np.testing.assert_array_equal(mixed["new_mask"][:, :2], 1.0)
    np.testing.assert_array_equal(mixed["new_mask"][:, 2:], 0.0)


# ---------------------------------------------------------------------------
# admission baselines
# ---------------------------------------------------------------------------


def test_oracle_admits_everything_with_zero_delay():
    tr = make_admission_mask(AdmissionPolicy("oracle"), 20, t_d=1.0, t_train=5.0)
    assert tr.admitted.all()
    np.testing.assert_array_equal(tr.delays, 0.0)


def test_one_skip_drops_items_when_training_is_slow():
    # t_train = 3 t_d  → roughly 1/3 of items admitted
    tr = make_admission_mask(AdmissionPolicy("one_skip"), 30, t_d=1.0, t_train=3.0)
    assert 8 <= tr.admitted.sum() <= 12
    # no two trainings overlap
    done = tr.trained_at[np.isfinite(tr.trained_at)]
    assert np.all(np.diff(np.sort(done)) >= 3.0 - 1e-9)


def test_one_skip_admits_everything_when_fast():
    tr = make_admission_mask(AdmissionPolicy("one_skip"), 30, t_d=1.0, t_train=0.5)
    assert tr.admitted.all()


def test_last_n_prefers_recent():
    tr = make_admission_mask(AdmissionPolicy("last_n", buffer=8, select=2), 40, 1.0, 2.0)
    admitted = np.where(tr.admitted)[0]
    assert len(admitted) > 0
    # buffered policies never train more than the arrival rate allows
    assert len(admitted) <= 40


def test_camel_selects_diverse_coreset():
    rng = np.random.default_rng(0)
    # two tight clusters: k-center should pick from both
    feats = np.concatenate([rng.normal(0, 0.01, (20, 4)), rng.normal(5, 0.01, (20, 4))])
    order = rng.permutation(40)
    feats = feats[order]
    tr = make_admission_mask(
        AdmissionPolicy("camel", buffer=40, select=2), 40, t_d=1.0, t_train=10.0,
        features=feats,
    )
    sel = np.where(tr.admitted)[0]
    if len(sel) >= 2:
        norms = np.linalg.norm(feats[sel] - feats[sel][0], axis=1)
        assert norms.max() > 2.0  # spans both clusters
