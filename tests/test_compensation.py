"""Compensation validation on a quadratic testbed where everything is exact.

L(θ) = ½ θᵀHθ − bᵀθ with diagonal H: ∇L(θ_new) = ∇L(θ_old) + H·Δθ exactly,
so a *perfect* compensator recovers the fresh gradient. Iter-Fisher's proxy
λ·g⊙g ≈ H is checked to (a) beat the no-compensation baseline and (b) λ
auto-tuning to reduce the error further.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import compensation as comp


def _quad(n=64, seed=0):
    rng = np.random.default_rng(seed)
    H = jnp.asarray(np.diag(rng.uniform(0.5, 2.0, size=n)), jnp.float32)
    b = jnp.asarray(rng.normal(size=n), jnp.float32)
    return H, b


def test_fisher_compensation_beats_stale_on_quadratic():
    """Validity regime of Eq. 7 (FIM ≈ Hessian): |g_i| = √H_ii, where
    λ·g⊙g = diag(H) exactly and one A_I application recovers ∇L(θ_new)
    to higher order. Constructed: θ_old = θ* + H^{-1/2}·1."""
    H, _ = _quad()
    n = H.shape[0]
    rng = np.random.default_rng(1)
    theta_star = jnp.asarray(rng.normal(size=n), jnp.float32)
    b = H @ theta_star  # makes θ* the optimum
    h_diag = jnp.diag(H)
    theta_old = theta_star + 1.0 / jnp.sqrt(h_diag)  # g_i = +√H_ii
    deltas = jnp.asarray(rng.normal(size=(3, n)) * 1e-2, jnp.float32)
    theta_new = theta_old + deltas.sum(0)

    g_stale = comp.quadratic_true_gradient(H, theta_old, b)
    g_true = comp.quadratic_true_gradient(H, theta_new, b)
    np.testing.assert_allclose(np.asarray(g_stale), np.asarray(jnp.sqrt(h_diag)), rtol=1e-5)

    cfg = dataclasses.replace(
        comp.CompensationConfig(), method="iter_fisher", eta_lambda=0.0, lam0=1.0
    )
    state = comp.init_state(g_stale, cfg)
    err_stale = float(jnp.linalg.norm(g_true - g_stale))
    _, g_comp = comp.compensate(cfg, state, g_stale, deltas)
    err_comp = float(jnp.linalg.norm(g_true - g_comp))
    assert err_comp < 0.2 * err_stale  # near-exact in the validity regime


def test_lambda_autotuning_reduces_residual():
    """λ descent step follows the closed-form gradient of Eq. 10."""
    cfg = comp.CompensationConfig(
        method="iter_fisher", eta_lambda=1e-2, alpha=0.5, nu=0.0, lam0=0.0
    )
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.normal(size=32), jnp.float32)
    d = jnp.asarray(rng.normal(size=32) * 0.1, jnp.float32)
    state = comp.init_state(g, cfg)
    # Seed the EMAs so v_a ≠ 0, then verify one λ update matches closed form.
    state = dataclasses.replace(
        state,
        v_r=jnp.zeros_like(g),
        v_a=jnp.asarray(rng.normal(size=32), jnp.float32),
    )
    deltas = d[None]
    new_state, _ = comp.compensate(cfg, state, g, deltas)
    dv_r = (1 - cfg.alpha) * (g - state.v_r)
    grad_lam = -2 * jnp.sum(dv_r * state.v_a) + 2 * state.lam * jnp.sum(state.v_a**2)
    want = state.lam - cfg.eta_lambda * grad_lam
    np.testing.assert_allclose(float(new_state.lam), float(want), rtol=1e-5)


def test_step_aware_shrinks_with_staleness():
    cfg = comp.CompensationConfig(method="step_aware")
    g = jnp.ones(16)
    deltas = jnp.zeros((4, 16))
    state = comp.init_state(g, cfg)
    _, out = comp.compensate(cfg, state, g, deltas, tau=jnp.asarray(4.0))
    np.testing.assert_allclose(np.asarray(out), np.full(16, 1 / 5), rtol=1e-6)


def test_gap_aware_penalizes_moved_params():
    cfg = comp.CompensationConfig(method="gap_aware")
    g = jnp.ones(4)
    deltas = jnp.asarray([[0.0, 0.01, 0.1, 1.0]])
    state = comp.init_state(g, cfg)
    _, out = comp.compensate(cfg, state, g, deltas, lr=0.01)
    out = np.asarray(out)
    assert out[0] == 1.0 and np.all(np.diff(out) < 0)  # larger gap → smaller step


def test_none_and_zero_tau_are_identity():
    g = jnp.asarray(np.random.default_rng(0).normal(size=8), jnp.float32)
    for method in ("none", "iter_fisher", "fisher", "gap_aware"):
        cfg = comp.CompensationConfig(method=method, eta_lambda=0.0)
        state = comp.init_state(g, cfg)
        _, out = comp.compensate(cfg, state, g, jnp.zeros((0, 8)))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(g))


def test_iterative_matches_sequential_application():
    """Eq. 9: iterating A_I over per-step deltas == the kernel's scan."""
    rng = np.random.default_rng(3)
    g = jnp.asarray(rng.normal(size=16), jnp.float32)
    deltas = jnp.asarray(rng.normal(size=(3, 16)) * 0.05, jnp.float32)
    lam = 0.3
    manual = np.asarray(g, np.float64)
    for i in range(3):
        manual = manual + lam * manual * manual * np.asarray(deltas[i], np.float64)
    cfg = comp.CompensationConfig(method="iter_fisher", eta_lambda=0.0, lam0=lam)
    state = comp.init_state(g, cfg)
    _, out = comp.compensate(cfg, state, g, deltas)
    np.testing.assert_allclose(np.asarray(out), manual, rtol=1e-5, atol=1e-6)
