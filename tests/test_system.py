"""End-to-end behaviour tests for the full Ferret system."""

import dataclasses
import json
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from conftest import smoke_cfg
from repro.core.compensation import CompensationConfig
from repro.core.ferret import FerretConfig, FerretTrainer, sequential_oracle_run
from repro.models import transformer as T
from repro.ocl.streams import StreamConfig, make_stream


def _learnable_stream(vocab=32, length=150, seq=16, batch=2, seed=0):
    return make_stream(
        StreamConfig(kind="iid", modality="tokens", length=length, batch=batch,
                     vocab=vocab, seq=seq, seed=seed)
    )


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = smoke_cfg("h2o-danube-1.8b", num_layers=4, vocab_size=32)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    stream = _learnable_stream()
    return cfg, params, stream


def test_ferret_trainer_learns_and_respects_budget(tiny_setup):
    cfg, params, stream = tiny_setup
    fc = FerretConfig(
        budget_bytes=float("inf"), lr=5e-3, max_workers=3, max_stages=4,
        compensation=CompensationConfig(method="iter_fisher", eta_lambda=1e-4),
    )
    tr = FerretTrainer(cfg, fc, batch=2, seq=16)
    res = tr.run_stream(params, stream)
    assert np.isfinite(res.losses).all()
    # the model learns: mean loss over the last quarter < first quarter
    q = len(res.losses) // 4
    assert res.losses[-q:].mean() < res.losses[:q].mean()
    assert res.admitted_frac == 1.0

    # constrained run: planner memory within budget, rate not higher than M+
    budget = tr.plan.memory * 0.3
    fc2 = dataclasses.replace(fc, budget_bytes=budget)
    tr2 = FerretTrainer(cfg, fc2, batch=2, seq=16)
    assert tr2.plan.memory <= budget * (1 + 1e-9)
    assert tr2.plan.rate <= tr.plan.rate * (1 + 1e-9)


def test_ferret_tracks_oracle_on_stationary_stream(tiny_setup):
    """Ferret_M+ online accuracy should be within a few points of Oracle
    (paper Table 1's qualitative claim)."""
    cfg, params, stream = tiny_setup
    orc = sequential_oracle_run(cfg, params, stream, lr=5e-3)
    fc = FerretConfig(budget_bytes=float("inf"), lr=5e-3, max_workers=3, max_stages=4,
                      compensation=CompensationConfig(method="iter_fisher", eta_lambda=1e-4))
    res = FerretTrainer(cfg, fc, batch=2, seq=16).run_stream(params, stream)
    oacc_oracle = float(orc["acc"].mean())
    assert res.online_acc > 0.5 * oacc_oracle


def test_compensation_improves_async_accuracy(tiny_setup):
    """Iter-Fisher ≥ no-compensation on the same async pipeline (Table 4)."""
    cfg, params, _ = tiny_setup
    stream = _learnable_stream(length=240, seed=3)
    accs = {}
    for method in ("none", "iter_fisher"):
        fc = FerretConfig(
            budget_bytes=float("inf"), lr=1e-2, max_workers=2, max_stages=4,
            compensation=CompensationConfig(method=method, eta_lambda=0.0, lam0=0.2),
        )
        res = FerretTrainer(cfg, fc, batch=2, seq=16).run_stream(params, stream)
        accs[method] = res.online_acc
    # allow tiny noise, but compensation must not be significantly worse
    assert accs["iter_fisher"] >= accs["none"] - 0.01


SUBPROCESS_SHARDING = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, jax, jax.numpy as jnp, dataclasses
    from repro.models.registry import get_config
    from repro.models import transformer as T
    from repro.configs.common import InputShape, input_specs
    from repro.launch import shardings as sh
    from repro.launch.steps import make_train_step, make_decode_step
    from repro.optim.optimizers import adamw

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    maxes = dict(zip(mesh.axis_names, mesh.devices.shape))
    cfg = get_config("{arch}", smoke=True)
    shape = InputShape("t", "{kind}", 64, 8)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    pspecs = T.param_pspecs(cfg, maxes, data_axes=("data",))
    p_sh = sh.named(mesh, pspecs)
    batch_s = input_specs(cfg, shape)
    b_sh = sh.named(mesh, sh.batch_pspecs(cfg, shape, maxes, ("data",), "model"))
    with mesh:
        if "{kind}" == "train":
            opt = adamw(1e-3)
            opt_s = jax.eval_shape(opt.init, params)
            o_sh = sh.named(mesh, sh.opt_pspecs(pspecs, opt_s))
            step = make_train_step(cfg, opt, remat=False)
            c = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh)).lower(
                jax.eval_shape(lambda: params), opt_s, batch_s).compile()
        else:
            cache_s = jax.eval_shape(lambda: T.init_cache(cfg, shape.batch, shape.seq))
            c_specs = sh.cache_pspecs(cfg, cache_s, maxes, ("data",), "model")
            c_sh = sh.named(mesh, c_specs)
            step = make_decode_step(cfg)
            c = jax.jit(step, in_shardings=(p_sh, c_sh, b_sh)).lower(
                jax.eval_shape(lambda: params), cache_s, batch_s).compile()
    from repro.compat import cost_analysis_dict
    print(json.dumps({{"ok": True, "flops": cost_analysis_dict(c).get("flops", 0)}}))
    """
)


@pytest.mark.parametrize("arch,kind", [
    ("h2o-danube-1.8b", "train"),
    ("mamba2-780m", "train"),
    ("mixtral-8x22b", "train"),
    ("gemma3-12b", "decode"),
    ("hymba-1.5b", "decode"),
])
def test_sharded_lowering_on_8_device_mesh(arch, kind):
    """Multi-device GSPMD lowering of smoke configs (subprocess so the
    device-count flag never leaks into other tests)."""
    import os
    code = SUBPROCESS_SHARDING.format(arch=arch, kind=kind)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=300,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))), env=env,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["ok"]


def test_train_driver_plain_mode_smoke(tmp_path):
    """launch.train plain mode: runs, checkpoints, restarts."""
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    cmd = [
        sys.executable, "-m", "repro.launch.train", "--arch", "mamba2-780m",
        "--smoke", "--mode", "plain", "--steps", "6", "--batch", "2", "--seq", "16",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "2",
    ]
    p1 = subprocess.run(cmd, capture_output=True, text=True, timeout=600, cwd=root, env=env)
    assert p1.returncode == 0, p1.stderr[-2000:]
    # second run restores from the checkpoint and continues to 8
    cmd[cmd.index("6")] = "8"
    p2 = subprocess.run(cmd, capture_output=True, text=True, timeout=600, cwd=root, env=env)
    assert p2.returncode == 0, p2.stderr[-2000:]
    assert "restored from checkpoint" in p2.stdout
