"""Cost-model (Eq. 3/4, S1–S4) and planner (Alg. 2/3) properties."""

import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cost_model as cm
from repro.core import planner
from repro.core.profiler import LayerProfile, ModelProfile


def _profile(num_layers=8, t_f=1.0, t_b=2.0, w=100, a=50, a_int=30, batch=1, seq=8):
    layers = [LayerProfile(t_f, t_b, w, a, a_int) for _ in range(num_layers)]
    return ModelProfile(layers=layers, embed_bytes=0, batch=batch, seq=seq)


def _default_config(P, N=2):
    return cm.PipelineConfig(
        workers=[
            cm.WorkerConfig(delay=n, recompute=0, stages=[cm.StageKnobs() for _ in range(P)])
            for n in range(N)
        ]
    )


def test_memory_formula_matches_paper_counts():
    """Eq. 4 copies: stage i holds (1 + ⌈(P-i-1)/c^a⌉ - c^o) copies."""
    prof = _profile(num_layers=4)
    part = cm.Partition((0, 1, 2, 3, 4))
    stats = cm.stage_stats(prof, part)
    cfg = _default_config(4, N=1)
    mem = cm.memory_footprint(stats, cfg)
    per = stats.w[0] + stats.a[0]
    expected = sum((1 + (4 - i - 1)) * per for i in range(4))
    assert mem == pytest.approx(expected)


def test_s3_reduces_copies_to_one():
    prof = _profile(num_layers=4)
    stats = cm.stage_stats(prof, cm.Partition((0, 1, 2, 3, 4)))
    w = cm.WorkerConfig(0, 0, [cm.StageKnobs() for _ in range(4)])
    # exhaust T2 on stage 0 so S3 becomes eligible
    while cm.s2_accum_increment(4, 0, w.stages[0].accum) is not None:
        w.stages[0].accum += cm.s2_accum_increment(4, 0, w.stages[0].accum)
    r3 = cm.delta_s3(stats, w, 0)
    assert r3 is not None
    _, _, trial = r3
    assert trial.stages[0].omit == 3 and trial.stages[0].accum == 1
    assert cm._stage_copies(4, 0, trial.stages[0]) == 1


def test_deltas_equal_recompute_diffs():
    """Closed-form deltas (Eq. 19-22 semantics) = recompute diffs of Eq. 3/4."""
    prof = _profile(num_layers=6)
    stats = cm.stage_stats(prof, cm.Partition((0, 2, 4, 6)))
    w = cm.WorkerConfig(0, 0, [cm.StageKnobs() for _ in range(3)])
    for fn in (lambda: cm.delta_s1(stats, w), lambda: cm.delta_s2(stats, w, 0)):
        res = fn()
        assert res is not None
        dR, dM, trial = res
        assert dR == pytest.approx(
            cm.worker_rate(stats, w) - cm.worker_rate(stats, trial)
        )
        assert dM == pytest.approx(
            cm.worker_memory(stats, w) - cm.worker_memory(stats, trial)
        )


def test_recompute_trades_memory_for_rate():
    """S1 (T1): memory strictly drops, adaptation rate strictly drops."""
    prof = _profile(num_layers=6)
    stats = cm.stage_stats(prof, cm.Partition((0, 2, 4, 6)))
    w = cm.WorkerConfig(0, 0, [cm.StageKnobs() for _ in range(3)])
    dR, dM, _ = cm.delta_s1(stats, w)
    assert dM > 0 and dR > 0


def test_s4_requires_all_omitted():
    prof = _profile(num_layers=4)
    stats = cm.stage_stats(prof, cm.Partition((0, 2, 4)))
    w = cm.WorkerConfig(0, 0, [cm.StageKnobs() for _ in range(2)])
    assert cm.delta_s4(stats, w) is None
    w.stages[0].omit = 1
    r = cm.delta_s4(stats, w)
    assert r is not None
    assert r[1] == pytest.approx(cm.worker_memory(stats, w))


@settings(max_examples=25, deadline=None)
@given(
    L=st.integers(2, 12),
    budget_frac=st.floats(0.02, 1.0),
    tf=st.floats(0.5, 3.0),
    tb_ratio=st.floats(1.0, 3.0),
    c=st.floats(0.01, 2.0),
)
def test_planner_respects_budget(L, budget_frac, tf, tb_ratio, c):
    """Property: Alg. 3 output satisfies M_F ≤ M whenever marked feasible."""
    prof = _profile(num_layers=L, t_f=tf, t_b=tf * tb_ratio)
    t_d = planner.default_data_interval(prof)
    unconstrained = planner.plan(prof, t_d, budget=math.inf, c=c, max_workers=4)
    budget = unconstrained.memory * budget_frac
    p = planner.plan(prof, t_d, budget=budget, c=c, max_workers=4)
    if p.feasible:
        assert p.memory <= budget * (1 + 1e-9)
    assert p.rate <= unconstrained.rate * (1 + 1e-9)
    # partition is contiguous and covers all layers
    b = list(p.partition.bounds)
    assert b[0] == 0 and b[-1] == L and all(x < y for x, y in zip(b, b[1:]))


@settings(max_examples=10, deadline=None)
@given(L=st.integers(2, 8), seed=st.integers(0, 100))
def test_planner_rate_monotone_in_budget(L, seed):
    """More memory never hurts the planned adaptation rate."""
    rng = np.random.default_rng(seed)
    prof = _profile(num_layers=L, t_f=float(rng.uniform(0.5, 2)), t_b=float(rng.uniform(1, 4)))
    t_d = planner.default_data_interval(prof)
    m_plus = planner.plan(prof, t_d, budget=math.inf, max_workers=4)
    rates = []
    for frac in (0.1, 0.3, 0.6, 1.0):
        p = planner.plan(prof, t_d, budget=m_plus.memory * frac, max_workers=4)
        rates.append(p.rate)
    assert all(a <= b + 1e-9 for a, b in zip(rates, rates[1:]))


def test_itersearch_infeasible_flag():
    prof = _profile(num_layers=4)
    stats = cm.stage_stats(prof, cm.Partition((0, 1, 2, 3, 4)))
    cfg, rate, mem, ok = planner.itersearch(stats, t_d=1.0, c_r=0, budget=1.0)
    assert not ok or mem <= 1.0
    # with budget 1 byte everything must be removed -> rate 0 (still "searchable")
    assert rate >= 0.0


def test_lcm_tail():
    stages = [cm.StageKnobs(omit=o) for o in (1, 2, 0)]
    assert cm._lcm_tail(stages, 0) == math.lcm(2, 3, 1)
    assert cm._lcm_tail(stages, 2) == 1
