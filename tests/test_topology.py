"""DeviceTopology plane: discovery, budgets, meshes, sharded-engine parity.

In-process tests are device-count agnostic — they pass whether the host
exposes 1 device (bare ``pytest``) or 8 (``scripts/test.sh`` and the CI
multidevice job). Anything that *needs* a guaranteed multi-device world
(sharded parity vs single-device, shrink-on-device-loss) runs in a
subprocess that sets ``XLA_FLAGS`` before jax init — the
``test_stage_parallel.py`` idiom.
"""

import dataclasses
import json
import math
import os
import subprocess
import sys
import textwrap

import pytest
from jax.sharding import PartitionSpec as P

from repro.core.compensation import CompensationConfig
from repro.core.ferret import FerretConfig
from repro.core.planner import default_data_interval, plan
from repro.core.profiler import analytic_profile
from repro.core.stage_parallel import mesh_for_topology
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import stream_batch_pspec
from repro.models.registry import get_config
from repro.models.shard_hints import ShardHints
from repro.models.shard_hints import for_topology as hints_for_topology
from repro.profile.bridge import for_topology
from repro.runtime import ElasticStreamTrainer
from repro.runtime.topology import DeviceTopology, as_topology


def _cfg():
    return dataclasses.replace(
        get_config("h2o-danube-1.8b", smoke=True),
        compute_dtype="float32", num_layers=4, vocab_size=32,
    )


def _ferret_cfg():
    return FerretConfig(
        budget_bytes=math.inf, lr=5e-3,
        compensation=CompensationConfig(method="iter_fisher", eta_lambda=1e-4),
        max_workers=3, max_stages=4,
    )


# ---------------------------------------------------------------------------
# DeviceTopology units (no jax device state needed beyond what's visible)
# ---------------------------------------------------------------------------


def test_trivial_and_validation():
    t = DeviceTopology.trivial()
    assert t.is_trivial and t.data_parallel == 1 and t.model_parallel == 1
    assert t.is_main()
    with pytest.raises(ValueError):
        DeviceTopology(device_count=4, mesh_shape=(2, 1))


def test_discover_reads_the_jax_world():
    import jax

    n = len(jax.devices())
    t = DeviceTopology.discover()
    assert t.device_count == n and t.mesh_shape == (n, 1)
    assert t.device_kind == str(jax.devices()[0].device_kind)
    assert t.process_count == 1 and t.is_main()
    one = DeviceTopology.discover(max_devices=1)
    assert one.is_trivial
    with pytest.raises(ValueError):
        DeviceTopology.discover(model_axis=n + 1)


def test_shrink_keeps_model_axis_only_when_divisible():
    t = DeviceTopology(device_count=8, mesh_shape=(4, 2))
    assert t.shrink(2).mesh_shape == (3, 2)  # 6 % 2 == 0: model axis survives
    assert t.shrink(1).mesh_shape == (7, 1)  # 7 % 2 != 0: collapses to data
    assert t.shrink(7).mesh_shape == (1, 1)
    with pytest.raises(ValueError):
        t.shrink(8)


def test_plan_budget_scales_with_model_axis_not_data():
    mem = 100
    tp = DeviceTopology(device_count=8, mesh_shape=(4, 2), memory_per_device=mem)
    dp = DeviceTopology(device_count=8, mesh_shape=(8, 1), memory_per_device=mem)
    assert tp.plan_budget(memory_fraction=0.5) == 0.5 * mem * 2
    # data-parallel replicas hold the full footprint: no extra budget
    assert dp.plan_budget(memory_fraction=1.0) == mem
    assert dp.total_memory_bytes == 8 * mem


def test_fingerprint_and_describe_are_stable():
    t = DeviceTopology(device_count=8, mesh_shape=(4, 2))
    assert t.fingerprint() == ("topo", 8, "cpu", 1, (4, 2))
    assert t.fingerprint() == dataclasses.replace(t).fingerprint()
    d = t.describe()
    assert d["device_count"] == 8 and d["mesh_shape"] == [4, 2]
    json.dumps(d)  # JSON-ready for bench payloads / manifests


def test_as_topology_normalization():
    t = DeviceTopology.trivial()
    assert as_topology(None) is None
    assert as_topology(t) is t
    assert isinstance(as_topology("discover"), DeviceTopology)
    with pytest.raises(TypeError):
        as_topology(42)


def test_memory_per_device_overrides(monkeypatch):
    monkeypatch.setenv("REPRO_DEVICE_MEM_BYTES", "12345")
    assert DeviceTopology.discover().memory_per_device == 12345
    # explicit argument beats the env
    assert DeviceTopology.discover(memory_per_device=777).memory_per_device == 777


# ---------------------------------------------------------------------------
# mesh construction
# ---------------------------------------------------------------------------


def test_make_production_mesh_derives_from_topology():
    import jax

    mesh = make_production_mesh()
    assert mesh.devices.size == len(jax.devices())
    assert mesh.axis_names == ("data", "model")
    one = make_production_mesh(DeviceTopology.discover(max_devices=1))
    assert one.devices.size == 1


def test_make_production_mesh_preset_errors_clearly():
    import jax

    if len(jax.devices()) >= 256:  # pragma: no cover — not a CI shape
        pytest.skip("host actually has a pod's worth of devices")
    with pytest.raises(ValueError, match="256 devices"):
        make_production_mesh(preset="pod")
    with pytest.raises(ValueError, match="512 devices"):
        make_production_mesh(multi_pod=True)
    with pytest.raises(ValueError, match="unknown mesh preset"):
        make_production_mesh(preset="nope")


def test_mesh_for_topology_requires_matching_stage_axis():
    t = DeviceTopology(device_count=4, mesh_shape=(2, 2))
    with pytest.raises(ValueError, match="model_axis"):
        mesh_for_topology(t, num_stages=4)


# ---------------------------------------------------------------------------
# planner / profile / sharding integration
# ---------------------------------------------------------------------------


def test_plan_caps_budget_and_stamps_topology_fingerprint():
    cfg = _cfg()
    profile = analytic_profile(cfg, 2, 16)
    t_d = default_data_interval(profile)
    topo = DeviceTopology(
        device_count=2, mesh_shape=(2, 1), memory_per_device=64 * 2**20
    )
    p = plan(profile, t_d, budget=math.inf, max_workers=3, topology=topo)
    assert p.topology == topo.fingerprint()
    assert p.memory <= topo.plan_budget() * (1 + 1e-9)
    legacy = plan(profile, t_d, budget=math.inf, max_workers=3)
    assert legacy.topology is None


def test_profile_for_topology_scales_time_not_weights():
    cfg = _cfg()
    prof = analytic_profile(cfg, 2, 16)
    topo = DeviceTopology(device_count=4, mesh_shape=(4, 1))
    eff = for_topology(prof, topo)
    for raw, scaled in zip(prof.layers, eff.layers):
        assert scaled.t_fwd == pytest.approx(raw.t_fwd / 4)
        assert scaled.t_bwd == pytest.approx(raw.t_bwd / 4)
        assert scaled.a_bytes == raw.a_bytes // 4
        # weights replicate across data-parallel devices: bytes unchanged
        assert scaled.w_bytes == raw.w_bytes
    assert eff.embed_bytes == prof.embed_bytes
    # no topology / no data axis: the exact same object, no rescale
    assert for_topology(prof, None) is prof
    assert for_topology(prof, DeviceTopology.trivial()) is prof


def test_stream_batch_pspec_shards_batch_dim_when_divisible():
    axes = {"data": 2, "model": 1}
    assert stream_batch_pspec((40,), axes) == P()  # rank<2: replicate
    assert stream_batch_pspec((40, 4, 16), axes) == P(None, "data", None)
    # indivisible batch: replicate rather than crash
    assert stream_batch_pspec((40, 3, 16), axes) == P(None, None, None)


def test_shard_hints_for_topology():
    assert hints_for_topology(None) == ShardHints()
    assert hints_for_topology(DeviceTopology.trivial()) == ShardHints()
    h = hints_for_topology(DeviceTopology(device_count=2, mesh_shape=(2, 1)))
    assert h.logits == P("data", None, None)
    assert h.activations == P("data", None, None)


def test_trainer_cache_scope_gains_topology_fingerprint():
    cfg, fc = _cfg(), _ferret_cfg()
    legacy = ElasticStreamTrainer(cfg, fc, batch=2, seq=16)
    topo = ElasticStreamTrainer(
        cfg, fc, batch=2, seq=16, topology=DeviceTopology.trivial()
    )
    # legacy trainers keep byte-identical cache keys (serve-layer sharing);
    # topology-aware trainers append the fingerprint so a shrink re-keys
    assert topo._cache_scope[:-1] == legacy._cache_scope
    assert topo._cache_scope[-1] == DeviceTopology.trivial().fingerprint()
    with pytest.raises(RuntimeError, match="request_budget"):
        legacy.request_shrink()


# ---------------------------------------------------------------------------
# sharded-engine parity (subprocess: guaranteed 8 fake devices)
# ---------------------------------------------------------------------------

PARITY_CODE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json, math
    import jax, numpy as np
    from repro.core.compensation import CompensationConfig
    from repro.core.ferret import FerretConfig, FerretTrainer
    from repro.models import transformer as T
    from repro.models.registry import get_config
    from repro.ocl.streams import StreamConfig, make_stream
    from repro.runtime.topology import DeviceTopology

    cfg = dataclasses.replace(get_config("h2o-danube-1.8b", smoke=True),
                              compute_dtype="float32", num_layers=4, vocab_size=32)
    fc = FerretConfig(budget_bytes=math.inf, lr=5e-3,
                      compensation=CompensationConfig(method="iter_fisher",
                                                      eta_lambda=1e-4),
                      max_workers=3, max_stages=4)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    stream = make_stream(StreamConfig(kind="drift", modality="tokens",
                                      length=16, batch=4, vocab=32, seq=16))

    base = FerretTrainer(cfg, fc, batch=4, seq=16).run_stream(params, stream)

    # a trivial topology degenerates to the legacy path: bit-identical
    triv = FerretTrainer(cfg, fc, batch=4, seq=16,
                         topology=DeviceTopology.trivial()
                         ).run_stream(params, stream)
    np.testing.assert_array_equal(np.asarray(base.losses),
                                  np.asarray(triv.losses))

    # 4-way data-parallel over the fake devices: same math, different
    # reduction geometry -> numerical tolerance, not bit-exactness
    topo = DeviceTopology.discover(max_devices=4)
    assert topo.mesh_shape == (4, 1), topo
    shard = FerretTrainer(cfg, fc, batch=4, seq=16,
                          topology=topo).run_stream(params, stream)
    np.testing.assert_allclose(np.asarray(base.losses),
                               np.asarray(shard.losses),
                               rtol=1e-5, atol=1e-6)
    assert shard.online_acc == base.online_acc
    print(json.dumps({"ok": True}))
    """
)


def _run_sub(code: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)  # the subprocess pins its own device count
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=600, cwd=root, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_sharded_engine_matches_single_device():
    assert _run_sub(PARITY_CODE)["ok"]
