"""``repro.api`` session layer.

Covers the acceptance criteria of the API redesign:
(a) one call signature: ``FerretSession(...).run(runner)`` executes the
    same stream through all four runners and all five registered
    algorithms, all returning the unified ``StreamResult``;
(b) pipelined and elastic runs match exactly under a constant budget;
(c) the registry is open: a custom ``OCLAlgorithm`` registered from
    outside ``repro.ocl`` runs through the pipelined and sequential
    runners, and an unknown name raises an error listing what exists;
(d) ``StreamSource`` semantics: exactly-once consumption, generator-backed
    and unbounded sources, coercions.
"""

import dataclasses
import itertools
import math

import numpy as np
import pytest

from repro.api import (
    ArrayStreamSource,
    FerretSession,
    IterableStreamSource,
    OCLAlgorithm,
    StreamResult,
    as_stream_source,
    available_algorithms,
    available_runners,
    get_algorithm,
    register_algorithm,
)
from repro.core.pipeline import StagedModel
from repro.models.registry import get_config
from repro.ocl.algorithms import OCLConfig
from repro.ocl.streams import StreamConfig, make_stream

R_STREAM = 10
RUNNERS = ["pipelined", "elastic", "sequential", "baseline"]
ALGOS = ["vanilla", "er", "mir", "lwf", "mas"]


def _cfg():
    return dataclasses.replace(
        get_config("h2o-danube-1.8b", smoke=True),
        compute_dtype="float32", num_layers=2, d_model=32, num_heads=2,
        num_kv_heads=1, d_ff=64, vocab_size=16,
    )


def _stream(length=R_STREAM, seed=0):
    return make_stream(StreamConfig(
        kind="drift", modality="tokens", length=length, batch=2, vocab=16,
        seq=8, seed=seed,
    ))


def _session(cfg, stream, algo="vanilla", **over):
    ocl = OCLConfig(replay_batch=2, replay_size=32, mir_candidates=4, refresh_every=4)
    over.setdefault("max_workers", 2)
    over.setdefault("max_stages", 2)
    return FerretSession(cfg, math.inf, algo, stream, ocl=ocl, **over)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    from repro.models import transformer as T
    import jax

    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params, _stream()


# ---------------------------------------------------------------------------
# (a) one signature across every (runner × algorithm) pair
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algo", ALGOS)
def test_every_runner_runs_every_algorithm(setup, algo):
    cfg, params, stream = setup
    session = _session(cfg, stream, algo, params=params)
    for runner in RUNNERS:
        res = session.run(runner)
        assert isinstance(res, StreamResult)
        assert res.runner in available_runners()
        assert res.algorithm == algo
        assert res.rounds == R_STREAM
        assert res.losses.shape == (R_STREAM,)
        assert res.online_acc_curve.shape == (R_STREAM,)
        assert np.isfinite(res.losses).all(), (runner, algo)
        assert 0.0 <= res.online_acc <= 1.0
        assert res.final_params is not None


def test_registries_list_the_builtins():
    assert set(ALGOS) <= set(available_algorithms())
    assert set(RUNNERS) <= set(available_runners())
    assert "oracle" in available_runners()  # alias of sequential


# ---------------------------------------------------------------------------
# (b) pipelined == elastic under a constant budget
# ---------------------------------------------------------------------------


def test_pipelined_matches_elastic_under_constant_budget(setup):
    cfg, params, _ = setup
    session = _session(cfg, _stream(length=16), "er", params=params)
    a = session.run("pipelined")
    b = session.run("elastic")
    np.testing.assert_array_equal(a.losses, b.losses)
    np.testing.assert_array_equal(a.online_acc_curve, b.online_acc_curve)
    assert a.online_acc == b.online_acc
    assert a.admitted_frac == b.admitted_frac
    assert b.num_replans == 0 and len(b.segments) == 1


# ---------------------------------------------------------------------------
# (c) open registry
# ---------------------------------------------------------------------------


@register_algorithm
class _LossScaled(OCLAlgorithm):
    """Test-only algorithm defined outside repro.ocl: 2× the staged loss."""

    name = "test-loss-scaled"

    def wrap_staged(self, staged: StagedModel) -> StagedModel:
        base = staged.loss

        def loss(logits, batch):
            ce, metrics = base(logits, batch)
            return 2.0 * ce, metrics

        return StagedModel(staged.num_stages, staged.forward_stage, loss)


def test_custom_algorithm_runs_through_pipelined_and_sequential(setup):
    cfg, params, stream = setup
    assert "test-loss-scaled" in available_algorithms()
    session = _session(cfg, stream, "test-loss-scaled", params=params)
    res_p = session.run("pipelined")
    res_s = session.run("sequential")
    assert np.isfinite(res_p.losses).all() and np.isfinite(res_s.losses).all()
    assert res_p.algorithm == res_s.algorithm == "test-loss-scaled"
    # the custom loss wrapper is live: the pipelined trajectory differs
    # from vanilla on identical data/params
    van = _session(cfg, stream, "vanilla", params=params).run("pipelined")
    assert not np.allclose(res_p.losses, van.losses)


def test_unknown_algorithm_error_lists_registered():
    with pytest.raises(ValueError) as exc:
        get_algorithm("definitely-not-registered")
    msg = str(exc.value)
    for name in ALGOS:
        assert name in msg
    assert "register_algorithm" in msg


def test_unknown_runner_error_lists_registered(setup):
    cfg, params, stream = setup
    session = _session(cfg, stream, params=params)
    with pytest.raises(ValueError) as exc:
        session.run("definitely-not-a-runner")
    msg = str(exc.value)
    for name in RUNNERS:
        assert name in msg


# ---------------------------------------------------------------------------
# (d) StreamSource semantics
# ---------------------------------------------------------------------------


def test_array_source_exactly_once():
    src = ArrayStreamSource(_stream(length=7))
    assert src.length == 7 and src.remaining == 7
    first = src.take(4)
    assert first["tokens"].shape[0] == 4 and src.remaining == 3
    rest = src.materialize()
    assert rest["tokens"].shape[0] == 3  # never re-serves consumed rounds
    assert src.take(1) is None


def test_array_source_seek_for_resume():
    arrays = _stream(length=6)
    src = ArrayStreamSource(arrays)
    src.seek(4)
    got = src.materialize()
    np.testing.assert_array_equal(got["tokens"], arrays["tokens"][4:])


def test_generator_source_and_unbounded_guard():
    def rounds():
        m = 0
        while True:  # unbounded live feed
            yield {
                "tokens": np.full((2, 8), m % 16, np.int32),
                "labels": np.full((2, 8), (m + 1) % 16, np.int32),
            }
            m += 1

    src = IterableStreamSource(rounds())
    assert src.length is None
    with pytest.raises(ValueError, match="max_rounds"):
        src.materialize()
    got = src.materialize(max_rounds=5)
    assert got["tokens"].shape == (5, 2, 8)
    # consumption continues where the previous window stopped
    nxt = src.take(1)
    assert int(nxt["tokens"][0, 0, 0]) == 5


def test_unbounded_source_through_session_sequential(setup):
    cfg, params, _ = setup
    base = _stream(length=64)

    def rounds():
        m = 0
        while True:
            yield {k: v[m % 64] for k, v in base.items()}
            m += 1

    session = _session(cfg, None, "vanilla", params=params)
    res = session.run("sequential", stream=rounds(), max_rounds=6)
    assert res.rounds == 6
    assert np.isfinite(res.losses).all()


def test_as_stream_source_coercions():
    arrays = _stream(length=3)
    assert isinstance(as_stream_source(arrays), ArrayStreamSource)
    src = as_stream_source(arrays)
    assert as_stream_source(src) is src
    cfg_src = as_stream_source(StreamConfig(modality="tokens", length=4, batch=1))
    assert cfg_src.length == 4
    it_src = as_stream_source(iter([{"tokens": np.zeros((1, 4), np.int32)}]))
    assert isinstance(it_src, IterableStreamSource)
    with pytest.raises(TypeError, match="StreamSource"):
        as_stream_source(123)


def test_inconsistent_stream_fields_rejected():
    with pytest.raises(ValueError, match="inconsistent"):
        ArrayStreamSource({
            "tokens": np.zeros((4, 2, 8), np.int32),
            "labels": np.zeros((3, 2, 8), np.int32),
        })


# ---------------------------------------------------------------------------
# session ergonomics
# ---------------------------------------------------------------------------


def test_session_infers_batch_seq_and_plans(setup):
    cfg, params, stream = setup
    session = _session(cfg, stream, params=params)
    session.run("sequential")
    assert (session.batch, session.seq) == (2, 8)
    plan = session.plan
    assert plan.partition.num_stages >= 1


def test_session_requires_a_stream(setup):
    cfg, params, _ = setup
    session = _session(cfg, None, params=params)
    with pytest.raises(ValueError, match="stream"):
        session.run("sequential")


def test_misspelled_runner_option_raises(setup):
    cfg, params, stream = setup
    session = _session(cfg, stream, params=params)
    with pytest.raises(TypeError):
        session.run("elastic", schedules=[])  # typo for schedule=
    with pytest.raises(TypeError):
        session.run("baseline", polcy="last_n")  # typo for policy=


def test_algorithm_resolves_from_ocl_when_not_explicit(setup):
    cfg, params, stream = setup
    session = FerretSession(
        cfg, stream=stream, ocl=OCLConfig(method="er", replay_batch=2),
        params=params, max_workers=2, max_stages=2,
    )
    assert session.algorithm.name == "er"
    assert session.ferret_cfg.ocl.method == "er"


def test_session_cache_slices_and_guards(setup):
    cfg, params, _ = setup
    # bounded session stream: cached in full, max_rounds slices a prefix
    session = _session(cfg, _stream(length=8), "vanilla", params=params)
    full = session.run("sequential")
    part = session.run("sequential", max_rounds=3)
    np.testing.assert_array_equal(full.losses[:3], part.losses)
    clamped = session.run("sequential", max_rounds=99)  # "at most" semantics
    assert clamped.rounds == 8
    # unbounded session stream: never cached — every run consumes fresh
    # rounds, continuing exactly where the previous run's window stopped
    base = _stream(length=16)
    seen = []

    def rounds():
        m = 0
        while True:
            seen.append(m)
            yield {k: v[m % 16] for k, v in base.items()}
            m += 1

    live = FerretSession(
        cfg, stream=as_stream_source(rounds()), params=params,
        max_workers=2, max_stages=2,
    )
    first = live.run("sequential", max_rounds=4)
    assert first.rounds == 4
    again = live.run("sequential", max_rounds=8)
    assert again.rounds == 8
    # exactly-once across runs: rounds 0-3 then 4-11, nothing re-served
    assert seen == list(range(12))


def test_runner_algorithm_grid_is_complete():
    """The acceptance grid: 4 runners × 5 algorithms resolve cleanly."""
    for runner, algo in itertools.product(RUNNERS, ALGOS):
        from repro.api import get_runner

        assert get_runner(runner).name in available_runners()
        assert get_algorithm(algo).name == algo
