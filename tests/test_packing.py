"""Flat-packed Iter-Fisher megakernels vs the per-leaf reference.

The packed path must be (a) equivalent to the per-leaf reference within
1e-5 (fp32) on ragged pytrees — including odd-sized leaves the old
``size % 128 == 0`` gate excluded from the Pallas path — across dtypes,
staleness depths, and fixed-λ mode; and (b) exactly **one** kernel launch
per compensation/statistics step regardless of leaf count.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import compensation as comp
from repro.kernels import ops, packing

# Ragged leaf-shape sets: odd sizes, 128-multiples, scalars, bf16 mixes.
RAGGED_TREES = [
    {"w": (33, 17), "b": (5,), "scale": ()},
    {"w1": (128,), "w2": (64, 2), "b": (127,), "n": (129,)},
    {"a": (3, 5, 7), "b": (1,), "c": (256,), "d": (4097,)},
]


def _make_tree(shapes, dtype, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return {
        k: jnp.asarray(rng.normal(size=s) * scale, jnp.dtype(dtype))
        for k, s in shapes.items()
    }


def _deltas_for(tree, tau, seed):
    rng = np.random.default_rng(seed)
    return jax.tree.map(
        lambda p: jnp.asarray(rng.normal(size=(tau, *p.shape)) * 0.01, p.dtype), tree
    )


# ---------------------------------------------------------------------------
# pack / unpack layout
# ---------------------------------------------------------------------------


def test_pack_roundtrip_and_alignment():
    tree = _make_tree(RAGGED_TREES[1], "float32", 0)
    tree["h"] = jnp.asarray(np.arange(6).reshape(2, 3), jnp.bfloat16)
    spec = packing.pack_spec(tree)
    assert spec.total % packing.BLOCK == 0
    assert all(off % packing.ALIGN == 0 for off in spec.offsets)
    flat = packing.pack(spec, tree)
    assert flat.dtype == jnp.float32 and flat.shape == (spec.total,)
    out = packing.unpack(spec, flat)
    for k in tree:
        assert out[k].dtype == tree[k].dtype
        np.testing.assert_allclose(
            np.asarray(out[k], np.float32), np.asarray(tree[k], np.float32),
            rtol=0, atol=0,
        )
    # gaps between leaves are zero (padding must be inert)
    mask = np.zeros(spec.total, bool)
    for off, size in zip(spec.offsets, spec.sizes):
        mask[off : off + size] = True
    np.testing.assert_array_equal(np.asarray(flat)[~mask], 0.0)


def test_pack_spec_is_cached_per_structure():
    t1 = _make_tree(RAGGED_TREES[0], "float32", 0)
    t2 = _make_tree(RAGGED_TREES[0], "float32", 1)  # same structure, new values
    assert packing.pack_spec(t1) is packing.pack_spec(t2)


# ---------------------------------------------------------------------------
# packed vs per-leaf reference equivalence
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    tree_idx=st.integers(0, len(RAGGED_TREES) - 1),
    tau=st.sampled_from([1, 4]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    seed=st.integers(0, 2**16),
)
def test_packed_compensate_matches_per_leaf(tree_idx, tau, dtype, seed):
    tree = _make_tree(RAGGED_TREES[tree_idx], dtype, seed)
    deltas = _deltas_for(tree, tau, seed + 1)
    lam = jnp.asarray(0.3, jnp.float32)
    got = ops.iter_fisher_compensate_tree(tree, deltas, lam, packed=True)
    want = ops.iter_fisher_compensate_tree(tree, deltas, lam, packed=False)
    tol = 1e-5 if dtype == "float32" else 3e-2
    for k in tree:
        assert got[k].dtype == tree[k].dtype
        np.testing.assert_allclose(
            np.asarray(got[k], np.float32), np.asarray(want[k], np.float32),
            rtol=tol, atol=tol,
        )


@settings(max_examples=15, deadline=None)
@given(
    tree_idx=st.integers(0, len(RAGGED_TREES) - 1),
    alpha=st.floats(0.5, 0.99),
    seed=st.integers(0, 2**16),
)
def test_packed_stats_match_per_leaf(tree_idx, alpha, seed):
    g = _make_tree(RAGGED_TREES[tree_idx], "float32", seed)
    d = _make_tree(RAGGED_TREES[tree_idx], "float32", seed + 1, scale=0.01)
    vr = _make_tree(RAGGED_TREES[tree_idx], "float32", seed + 2)
    va = _make_tree(RAGGED_TREES[tree_idx], "float32", seed + 3)
    got = ops.iter_fisher_stats_tree(g, d, vr, va, alpha, packed=True)
    want = ops.iter_fisher_stats_tree(g, d, vr, va, alpha, packed=False)
    for t_got, t_want in zip(got[:2], want[:2]):
        for a, b in zip(jax.tree.leaves(t_got), jax.tree.leaves(t_want)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(got[2]), float(want[2]), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(got[3]), float(want[3]), rtol=1e-4, atol=1e-5)


def test_full_compensate_packed_vs_per_leaf_with_lambda_tuning():
    """comp.compensate end-to-end: λ update + compensation, packed == per-leaf."""
    cfg = comp.CompensationConfig(method="iter_fisher", eta_lambda=1e-3, alpha=0.8)
    tree = _make_tree(RAGGED_TREES[1], "float32", 7)
    deltas = _deltas_for(tree, 3, 8)
    state = comp.init_state(tree, cfg)
    # seed EMAs so the λ gradient is nonzero
    state = dataclasses.replace(
        state, v_a=_make_tree(RAGGED_TREES[1], "float32", 9)
    )
    results = {}
    for packed, env in ((True, "1"), (False, "0")):
        import os

        old = os.environ.get("REPRO_PACK")
        os.environ["REPRO_PACK"] = env
        try:
            results[packed] = comp.compensate(cfg, state, tree, deltas)
        finally:
            if old is None:
                os.environ.pop("REPRO_PACK", None)
            else:
                os.environ["REPRO_PACK"] = old
    s_p, g_p = results[True]
    s_r, g_r = results[False]
    np.testing.assert_allclose(float(s_p.lam), float(s_r.lam), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g_p), jax.tree.leaves(g_r)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)
    for a, b in zip(jax.tree.leaves(s_p.v_a), jax.tree.leaves(s_r.v_a)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_fixed_lambda_mode_packed():
    """η_λ = 0: empty EMA placeholders pass through, compensation still packed."""
    cfg = comp.CompensationConfig(method="iter_fisher", eta_lambda=0.0, lam0=0.4)
    tree = _make_tree(RAGGED_TREES[0], "float32", 3)
    deltas = _deltas_for(tree, 4, 4)
    state = comp.init_state(tree, cfg)
    new_state, out = comp.compensate(cfg, state, tree, deltas)
    want = ops.iter_fisher_compensate_tree(
        tree, deltas, jnp.asarray(0.4, jnp.float32), packed=False
    )
    for k in tree:
        np.testing.assert_allclose(
            np.asarray(out[k]), np.asarray(want[k]), rtol=1e-5, atol=1e-5
        )
    np.testing.assert_allclose(float(new_state.lam), 0.4, rtol=1e-6)


def test_tau_zero_is_identity():
    tree = _make_tree(RAGGED_TREES[0], "float32", 5)
    deltas = jax.tree.map(lambda p: jnp.zeros((0, *p.shape), p.dtype), tree)
    out = ops.iter_fisher_compensate_tree(tree, deltas, jnp.asarray(0.5), packed=True)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(tree[k]))


def test_zero_delta_is_identity_on_odd_leaves():
    """Zero Δθ (and zero padding) must be exactly the identity."""
    tree = _make_tree(RAGGED_TREES[2], "float32", 6)
    deltas = jax.tree.map(lambda p: jnp.zeros((3, *p.shape), p.dtype), tree)
    out = packing.compensate_tree(tree, deltas, jnp.asarray(0.7), use_pallas=True,
                                  interpret=True)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(tree[k]))


# ---------------------------------------------------------------------------
# one launch regardless of leaf count (the whole point)
# ---------------------------------------------------------------------------


def test_single_kernel_launch_per_step():
    tree = _make_tree(RAGGED_TREES[2], "float32", 11)  # 4 ragged leaves
    assert len(jax.tree.leaves(tree)) > 1
    deltas = _deltas_for(tree, 4, 12)
    d1 = jax.tree.map(lambda d: d[0], deltas)
    vr = jax.tree.map(jnp.zeros_like, tree)
    va = _make_tree(RAGGED_TREES[2], "float32", 13)
    lam = jnp.asarray(0.2, jnp.float32)

    n0 = packing.KERNEL_LAUNCHES
    packing.compensate_tree(tree, deltas, lam, use_pallas=True, interpret=True)
    assert packing.KERNEL_LAUNCHES - n0 == 1, "compensation must be 1 launch"
    packing.stats_tree(tree, d1, vr, va, 0.9, use_pallas=True, interpret=True)
    assert packing.KERNEL_LAUNCHES - n0 == 2, "λ-statistics must be 1 launch"


def test_packed_pallas_matches_reference_on_odd_sizes():
    """Interpret-mode Pallas over the packed buffer == per-leaf reference,
    on leaves the old ``% 128`` gate excluded."""
    tree = _make_tree(RAGGED_TREES[0], "float32", 21)  # 33×17, (5,), scalar
    deltas = _deltas_for(tree, 2, 22)
    lam = jnp.asarray(0.25, jnp.float32)
    got = packing.compensate_tree(tree, deltas, lam, use_pallas=True, interpret=True)
    want = ops.iter_fisher_compensate_tree(tree, deltas, lam, packed=False)
    for k in tree:
        np.testing.assert_allclose(
            np.asarray(got[k]), np.asarray(want[k]), rtol=1e-5, atol=1e-5
        )
