"""Deterministic fault injection + the recovery paths it exercises.

Unit coverage of the ``repro.faults`` plane itself (spec validation,
hit-count determinism, thread safety, recovery-latency records, seeded
storms), then one test per hardened layer:

- stream: injected stalls and transient take errors are absorbed by the
  feeder bit-exactly; a dead prefetch worker falls back to a synchronous
  pull — every round still delivered exactly once;
- checkpoint: a crash mid-write never clobbers the previous checkpoint, a
  corrupt/torn payload is detected by checksum, quarantined, and restore
  falls back to the previous good one;
- engine: a transient device error rewinds and re-runs the segment from
  the retained rows (bit-exact vs a clean run); a NaN-poisoned batch
  under a Supervisor rolls back and completes.

Serve-layer fault isolation (tenant crash, quarantine, drain→restore)
lives in ``tests/test_serve.py`` next to the other server tests.
"""

import os
import threading

import numpy as np
import pytest

from repro import faults
from repro.api.streams import ArrayStreamSource, BufferedStreamSource
from repro.checkpointing.checkpoint import (
    CheckpointCorruptError,
    CheckpointManager,
    latest_checkpoint,
    restore_checkpoint,
    restore_latest_good,
    save_checkpoint,
    verify_checkpoint,
)
from repro.faults import (
    FaultError,
    FaultInjector,
    FaultPlan,
    FaultSpec,
    TransientFaultError,
)

# ---------------------------------------------------------------------------
# the injection plane itself
# ---------------------------------------------------------------------------


def test_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec("no.such.point", "stall")
    with pytest.raises(ValueError):
        FaultSpec("stream.take", "no_such_kind")
    with pytest.raises(ValueError):
        FaultSpec("stream.take", "stall", times=0)


def test_injector_after_times_and_match():
    plan = FaultPlan(specs=(
        FaultSpec("stream.take", "error", after=2, times=2),
        FaultSpec("serve.step", "tenant_crash", match=(("tenant", "t1"),)),
    ))
    inj = FaultInjector(plan)
    # hits 0 and 1 are skipped, 2 and 3 fire, 4 is past the window
    fired = [inj.fire("stream.take") is not None for _ in range(5)]
    assert fired == [False, False, True, True, False]
    # context filter: only the matching tenant advances (and fires)
    assert inj.fire("serve.step", tenant="t0") is None
    assert inj.fire("serve.step", tenant="t1") is not None
    assert inj.fire("serve.step", tenant="t1") is None  # times=1 spent


def test_injector_thread_safe_hit_counts():
    plan = FaultPlan(specs=(FaultSpec("stream.take", "error", after=50, times=7),))
    inj = FaultInjector(plan)
    hits = []

    def hammer():
        for _ in range(25):
            hits.append(inj.fire("stream.take"))

    threads = [threading.Thread(target=hammer) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # 100 hits over a [50, 57) window: exactly 7 fired, regardless of schedule
    assert sum(1 for h in hits if h is not None) == 7
    assert inj.fired == 7


def test_records_and_resolved_latency():
    inj = FaultInjector(FaultPlan(specs=(FaultSpec("stream.take", "stall"),)))
    assert inj.resolved("stream.take") is None  # nothing outstanding: no-op
    assert inj.fire("stream.take", n=4) is not None
    assert [r.recovered for r in inj.records] == [False]
    rec = inj.resolved("stream.take")
    assert rec is not None and rec.recovery_latency_s >= 0.0
    assert not inj.unrecovered()
    s = inj.summary()
    assert s["fired"] == 1 and s["recovered"] == 1
    assert s["recovery_latency_max_s"] is not None
    assert s["records"][0]["ctx"] == {"n": "4"}


def test_storm_is_seed_deterministic():
    a, b = FaultPlan.storm(seed=7), FaultPlan.storm(seed=7)
    assert a.specs == b.specs and a.kinds() == b.kinds()
    assert FaultPlan.storm(seed=8).specs != a.specs
    # ≥ 4 distinct kinds across the 4 layers (the bench's storm contract)
    assert len(a.kinds()) >= 4
    assert not any(
        s.kind == "nan" for s in FaultPlan.storm(seed=7, supervised=False).specs
    )
    pinned = FaultPlan.storm(seed=7, tenant="x")
    assert all(
        s.match == (("tenant", "x"),)
        for s in pinned.specs if s.point == "serve.step"
    )


def test_inject_context_installs_and_clears():
    assert faults.fire("stream.take") is None  # nothing installed: no-op
    with faults.inject(FaultPlan(specs=(FaultSpec("stream.take", "error"),))) as chaos:
        assert faults.active() is chaos
        assert faults.fire("stream.take") is not None
    assert faults.active() is None
    assert chaos.fired == 1


# ---------------------------------------------------------------------------
# checkpoint layer: crash mid-write, corruption, fallback-to-previous-good
# ---------------------------------------------------------------------------


def _ckpt_state(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": rng.normal(size=(4, 8)).astype(np.float32)}


def test_crash_mid_write_preserves_previous_checkpoint(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _ckpt_state(1), extras={"cursor": 10})
    plan = FaultPlan(specs=(FaultSpec("checkpoint.write", "crash_mid_write"),))
    with faults.inject(plan):
        with pytest.raises(FaultError):
            save_checkpoint(d, 2, _ckpt_state(2), extras={"cursor": 20})
    # the torn write never renamed: previous checkpoint set is untouched
    assert latest_checkpoint(d).endswith("step_0000000001")
    _, step, extras = restore_checkpoint(d, _ckpt_state())
    assert step == 1 and extras["cursor"] == 10
    # the crash artifact (a .tmp dir with a torn shard) is left behind, and
    # the manager's gc clears it once a later save lands
    assert any(x.endswith(".tmp") for x in os.listdir(d))
    mgr = CheckpointManager(d, keep=3, every_steps=1)
    mgr.save_async(3, _ckpt_state(3))
    mgr.wait()
    assert not any(x.endswith(".tmp") for x in os.listdir(d))


def test_corrupt_payload_quarantined_and_fallback(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _ckpt_state(1), extras={"cursor": 10})
    plan = FaultPlan(specs=(FaultSpec("checkpoint.write", "corrupt_payload"),))
    with faults.inject(plan):
        save_checkpoint(d, 2, _ckpt_state(2), extras={"cursor": 20})
    # the corrupted latest fails its checksum...
    with pytest.raises(CheckpointCorruptError):
        verify_checkpoint(os.path.join(d, "step_0000000002"))
    # ...so restore falls back to the previous good one and quarantines it
    state, step, extras = restore_latest_good(d, _ckpt_state())
    assert step == 1 and extras["cursor"] == 10
    np.testing.assert_array_equal(state["w"], _ckpt_state(1)["w"])
    assert any(x.endswith(".corrupt") for x in os.listdir(d))
    assert latest_checkpoint(d).endswith("step_0000000001")


def test_torn_payload_detected_by_checksum(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 1, _ckpt_state(1))
    save_checkpoint(d, 2, _ckpt_state(2))
    shard = os.path.join(d, "step_0000000002", "shard_0.npz")
    size = os.path.getsize(shard)
    with open(shard, "r+b") as f:  # torn write: half the payload gone
        f.truncate(size // 2)
    state, step, _ = restore_checkpoint(d, _ckpt_state())
    assert step == 1
    np.testing.assert_array_equal(state["w"], _ckpt_state(1)["w"])


def test_manager_surfaces_injected_write_error_on_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, every_steps=1)
    plan = FaultPlan(specs=(FaultSpec("checkpoint.write", "crash_mid_write"),))
    with faults.inject(plan):
        mgr.save_async(1, _ckpt_state())
        with pytest.raises(FaultError):
            mgr.wait()


# ---------------------------------------------------------------------------
# stream layer: stalls, transient take errors, feeder death
# ---------------------------------------------------------------------------

_R = 8


def _rows(seed=0):
    rng = np.random.default_rng(seed)
    return {"tokens": rng.integers(0, 32, size=(_R, 2, 4)).astype(np.int32)}


def test_stream_stall_and_error_bit_exact_exactly_once():
    rows = _rows()
    clean = BufferedStreamSource(ArrayStreamSource(rows), prefetch=False)
    want = clean.take(_R)
    plan = FaultPlan(specs=(
        FaultSpec("stream.take", "stall", after=0, arg=0.01),
        FaultSpec("stream.take", "error", after=1),
    ))
    src = BufferedStreamSource(ArrayStreamSource(rows), prefetch=False)
    with faults.inject(plan) as chaos:
        got = [src.take(3), src.take(3), src.take(2)]
    cat = {k: np.concatenate([g[k] for g in got]) for k in got[0]}
    np.testing.assert_array_equal(cat["tokens"], want["tokens"])  # bit-exact
    assert src.take(1) is None  # nothing re-served: exactly-once
    assert chaos.fired == 2 and not chaos.unrecovered()
    assert src.take_wait_s >= 0.01  # the stall is visible, not hidden


def test_feeder_death_falls_back_to_sync_pull():
    rows = _rows(seed=3)
    plan = FaultPlan(specs=(FaultSpec("stream.prefetch", "feeder_death"),))
    src = BufferedStreamSource(ArrayStreamSource(rows), prefetch=True)
    with faults.inject(plan) as chaos:
        src.prefetch(4)
        first = src.take(4)  # syncs on the dead worker, re-pulls inline
        rest = src.take(_R)
    try:
        np.testing.assert_array_equal(first["tokens"], rows["tokens"][:4])
        np.testing.assert_array_equal(rest["tokens"], rows["tokens"][4:])
        assert src.take(1) is None
        assert chaos.fired == 1 and not chaos.unrecovered()
    finally:
        src.close()


def test_transient_take_error_escapes_after_retry():
    # two consecutive injected errors exhaust the feeder's single retry —
    # the error surfaces as the transient it is (callers rewind + re-take)
    plan = FaultPlan(specs=(FaultSpec("stream.take", "error", times=2),))
    src = BufferedStreamSource(ArrayStreamSource(_rows()), prefetch=False)
    with faults.inject(plan):
        with pytest.raises(TransientFaultError):
            src.take(2)
        got = src.take(2)  # next attempt is clean; nothing was consumed
    np.testing.assert_array_equal(got["tokens"], _rows()["tokens"][:2])


# ---------------------------------------------------------------------------
# engine layer: transient rewind/re-run (bit-exact), NaN under a Supervisor
# ---------------------------------------------------------------------------


def _tiny_session(stream_arrays, **over):
    import math as _math

    from repro.api import FerretSession
    from repro.core.compensation import CompensationConfig
    from repro.models.config import ModelConfig

    cfg = ModelConfig(
        name="faults-test-lm", family="dense", num_layers=2, d_model=32,
        num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=32,
        compute_dtype="float32",
    )
    kw = dict(
        batch=2, seq=16, lr=5e-3, seed=0,
        compensation=CompensationConfig(method="iter_fisher", eta_lambda=1e-4),
        max_workers=3, max_stages=4,
    )
    kw.update(over)
    return FerretSession(cfg, _math.inf, "er", stream_arrays, **kw)


def _lm_stream(length=8, seed=0):
    from repro.ocl.streams import StreamConfig, make_stream

    return make_stream(StreamConfig(
        kind="drift", modality="tokens", length=length, batch=2,
        vocab=32, seq=16, seed=seed,
    ))


def test_elastic_transient_rewind_bit_exact():
    from repro.core.ferret import EngineCache

    stream = _lm_stream()
    ref = _tiny_session(stream).run(
        "elastic", segment_rounds=4, engine_cache=EngineCache()
    )
    plan = FaultPlan(specs=(FaultSpec("engine.step", "transient", after=1),))
    with faults.inject(plan) as chaos:
        got = _tiny_session(stream).run(
            "elastic", segment_rounds=4, engine_cache=EngineCache()
        )
    # the faulted segment re-ran from the retained rows with unchanged
    # state: the whole run is bit-exact vs the clean one, nothing skipped
    np.testing.assert_array_equal(np.asarray(got.losses), np.asarray(ref.losses))
    np.testing.assert_array_equal(got.online_acc_curve, ref.online_acc_curve)
    assert got.rounds == ref.rounds == 8
    assert chaos.fired == 1 and not chaos.unrecovered()


def test_elastic_nan_under_supervisor_recovers(tmp_path):
    from repro.core.ferret import EngineCache
    from repro.runtime import SupervisorCfg

    plan = FaultPlan(specs=(
        FaultSpec("engine.step", "nan", match=(("supervised", True),)),
    ))
    sup = SupervisorCfg(
        checkpoint_dir=str(tmp_path), checkpoint_every=1, nan_check_every=1
    )
    with faults.inject(plan) as chaos:
        res = _tiny_session(_lm_stream(seed=2)).run(
            "elastic", segment_rounds=4, supervisor_cfg=sup,
            engine_cache=EngineCache(),
        )
    assert res.rounds == 8  # the poisoned segment rolled back and re-ran
    assert chaos.fired == 1 and not chaos.unrecovered()
    assert all(np.isfinite(np.asarray(res.losses)))
