"""Incremental elastic streaming: segment-by-segment ``take()`` with no
up-front materialization.

Covers the tentpole guarantees:
(a) an elastic run fed by an *unbounded* ``IterableStreamSource`` (no
    ``materialize``, no whole-stream device copy) is bit-identical to the
    materialized dict run on the same rounds — params, curves, cache
    counts — with peak stream residency O(segment_rounds), not O(R);
(b) ``length=None`` + a budget schedule + ``segment_rounds`` compose;
(c) a fault re-run replays the un-acked segment from the feeder's
    retained buffer: every source round is produced exactly once;
(d) per-chunk stream preparation (ER reservoir mixing, LwF teacher
    logits) chains bit-exactly with the whole-stream preparation;
plus the satellite regressions: resumed-run ``empirical_rate`` is no
longer diluted by the skipped prefix, ``fatal_handler`` works before the
first segment, a zero-round elastic run reports finite memory, and
``IterableStreamSource`` rejects inconsistent per-round dicts.
"""

import dataclasses
import math

import jax
import numpy as np
import pytest

from repro.api import FerretSession
from repro.api.streams import BufferedStreamSource, IterableStreamSource
from repro.core import compensation as comp_lib
from repro.core.compensation import CompensationConfig
from repro.core.ferret import FerretConfig
from repro.core.profiler import ModelProfile, analytic_profile
from repro.models import transformer as T
from repro.models.registry import get_config
from repro.ocl.algorithms import OCLConfig
from repro.ocl.streams import StreamConfig, make_stream
from repro.optim.optimizers import adamw
from repro.runtime import BudgetEvent, ElasticStreamTrainer, ResumeState

R_STREAM = 40


def _cfg():
    return dataclasses.replace(
        get_config("h2o-danube-1.8b", smoke=True),
        compute_dtype="float32", num_layers=4, vocab_size=32,
    )


def _ferret_cfg(**over):
    base = dict(
        budget_bytes=math.inf, lr=5e-3,
        compensation=CompensationConfig(method="iter_fisher", eta_lambda=1e-4),
        max_workers=3, max_stages=4,
    )
    base.update(over)
    return FerretConfig(**base)


def _stream(length=R_STREAM):
    return make_stream(StreamConfig(
        kind="drift", modality="tokens", length=length, batch=2, vocab=32, seq=16,
    ))


def _hetero_profile(cfg) -> ModelProfile:
    base = analytic_profile(cfg, 2, 16)
    layers = [
        dataclasses.replace(ly, t_fwd=ly.t_fwd * (1 + i), t_bwd=ly.t_bwd * (1 + i))
        for i, ly in enumerate(base.layers)
    ]
    return ModelProfile(layers=layers, embed_bytes=base.embed_bytes, batch=2, seq=16)


def _unbounded(arrays, counter=None):
    """A live-feed view of ``arrays``: per-round dicts, length undeclared."""

    def rounds():
        R = next(iter(arrays.values())).shape[0]
        for m in range(R):
            if counter is not None:
                counter.append(m)
            yield {k: v[m] for k, v in arrays.items()}

    return IterableStreamSource(rounds())  # length=None: unbounded to the trainer


# ---------------------------------------------------------------------------
# (a) incremental unbounded == materialized, residency O(segment)
# ---------------------------------------------------------------------------


def test_incremental_unbounded_matches_materialized(rng):
    cfg = _cfg()
    fc = _ferret_cfg()
    params = T.init_params(cfg, rng)
    arrays = _stream()

    base = ElasticStreamTrainer(cfg, fc, batch=2, seq=16).run_stream(
        params, arrays, segment_rounds=10
    )
    produced = []
    res = ElasticStreamTrainer(cfg, fc, batch=2, seq=16).run_stream(
        params, _unbounded(arrays, produced), segment_rounds=10
    )

    assert res.rounds == R_STREAM
    assert produced == list(range(R_STREAM))  # every round pulled exactly once
    np.testing.assert_array_equal(np.asarray(base.losses), np.asarray(res.losses))
    np.testing.assert_array_equal(base.online_acc_curve, res.online_acc_curve)
    assert [(s.start, s.end) for s in res.segments] == [
        (s.start, s.end) for s in base.segments
    ]
    assert (res.engine_cache_hits, res.engine_cache_misses) == (
        base.engine_cache_hits, base.engine_cache_misses
    )
    for a, b in zip(jax.tree.leaves(base.final_params), jax.tree.leaves(res.final_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # residency: one segment + the prefetch window, never the whole stream
    assert 0 < res.peak_buffered_rounds <= 2 * 10
    assert res.peak_buffered_rounds < R_STREAM


def test_capped_live_feed_still_runs_in_finite_segments(rng):
    """max_rounds makes the length known, but a live feed must never run
    as one O(R) segment — the residency bound is the whole point."""
    cfg = _cfg()
    params = T.init_params(cfg, rng)
    session = FerretSession(
        cfg, math.inf, "vanilla", _unbounded(_stream()),
        batch=2, seq=16, max_workers=3, max_stages=4, params=params,
        ferret=_ferret_cfg(),
    )
    res = session.run("elastic", max_rounds=R_STREAM)
    assert res.rounds == R_STREAM
    raw = res.extras["raw"]
    assert all(s.end - s.start <= 16 for s in raw.segments)
    assert res.extras["peak_buffered_rounds"] < R_STREAM


def test_unbounded_defaults_to_finite_segments(rng):
    """No segment cap + no known length must still produce finite segments."""
    cfg = _cfg()
    fc = _ferret_cfg()
    params = T.init_params(cfg, rng)
    res = ElasticStreamTrainer(cfg, fc, batch=2, seq=16).run_stream(
        params, _unbounded(_stream())
    )
    assert res.rounds == R_STREAM
    assert all(s.end - s.start <= 16 for s in res.segments)


# ---------------------------------------------------------------------------
# (b) length=None + budget schedule + segment_rounds compose
# ---------------------------------------------------------------------------


def test_unknown_length_budget_schedule_and_segment_cap_compose(rng):
    cfg = _cfg()
    fc = _ferret_cfg()
    profile = _hetero_profile(cfg)
    params = T.init_params(cfg, rng)
    arrays = _stream()
    et0 = ElasticStreamTrainer(cfg, fc, batch=2, seq=16, profile=profile)
    full = et0.plan_for(math.inf)
    events = [BudgetEvent(18, full.memory * 0.3)]

    base = et0.run_stream(params, arrays, schedule=events, segment_rounds=8)
    et1 = ElasticStreamTrainer(cfg, fc, batch=2, seq=16, profile=profile)
    res = et1.run_stream(
        params, _unbounded(arrays), schedule=events, segment_rounds=8
    )

    assert res.num_replans == base.num_replans == 1
    assert [(s.start, s.end) for s in res.segments] == [
        (s.start, s.end) for s in base.segments
    ]
    # the event cut the segment mid-cap on the unknown-length path too
    assert (18 in [s.start for s in res.segments])
    np.testing.assert_array_equal(np.asarray(base.losses), np.asarray(res.losses))
    for a, b in zip(jax.tree.leaves(base.final_params), jax.tree.leaves(res.final_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# (c) fault re-run replays the retained buffer: exactly-once without seek
# ---------------------------------------------------------------------------


def test_fault_rerun_replays_buffer_exactly_once(rng, tmp_path):
    from repro.runtime import SupervisorCfg

    cfg = _cfg()
    fc = _ferret_cfg()
    params = T.init_params(cfg, rng)
    arrays = _stream()
    produced = []
    sup = SupervisorCfg(
        checkpoint_dir=str(tmp_path), checkpoint_every=1, step_timeout_s=600.0,
    )

    res = ElasticStreamTrainer(cfg, fc, batch=2, seq=16).run_stream(
        params, _unbounded(arrays, produced),
        segment_rounds=R_STREAM // 2,
        supervisor_cfg=sup,
        fault_rounds=[R_STREAM // 2 + 2],
        fault_budget_scale=0.3,
    )
    assert res.num_faults == 1 and res.num_replans == 1
    # the generator produced every round exactly once even though the
    # faulted segment ran twice — the re-run came from the replay buffer
    assert produced == list(range(R_STREAM))
    assert res.rounds == R_STREAM
    assert [(s.start, s.end) for s in res.segments] == [
        (0, R_STREAM // 2), (R_STREAM // 2, R_STREAM)
    ]
    assert np.isfinite(res.losses).all()


# ---------------------------------------------------------------------------
# (d) per-chunk stream preparation chains bit-exactly (ER / LwF)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algo", ["er", "lwf"])
def test_segmented_prep_matches_whole_stream_prep(algo):
    """pipelined (whole-stream prep in the session) == elastic with ragged
    segments (per-chunk prep in the trainer) for prep-heavy algorithms."""
    cfg = dataclasses.replace(
        get_config("h2o-danube-1.8b", smoke=True),
        compute_dtype="float32", num_layers=2, d_model=32, num_heads=2,
        num_kv_heads=1, d_ff=64, vocab_size=16,
    )
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    stream = make_stream(StreamConfig(
        kind="drift", modality="tokens", length=21, batch=2, vocab=16, seq=8,
    ))
    session = FerretSession(
        cfg, math.inf, algo, stream,
        ocl=OCLConfig(replay_batch=2, replay_size=32, mir_candidates=4),
        max_workers=2, max_stages=2, params=params,
    )
    a = session.run("pipelined")
    b = session.run("elastic", segment_rounds=8)  # 8 + 8 + 5: ragged
    assert len(b.segments) == 3
    np.testing.assert_array_equal(a.losses, b.losses)
    np.testing.assert_array_equal(a.online_acc_curve, b.online_acc_curve)
    for x, y in zip(jax.tree.leaves(a.final_params), jax.tree.leaves(b.final_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# satellite regressions
# ---------------------------------------------------------------------------


def test_resumed_run_empirical_rate_not_diluted(rng):
    """A resumed run covers R - cursor rounds; the round-weighted rate must
    average over the rounds consumed, not the full stream length."""
    cfg = _cfg()
    fc = _ferret_cfg()
    et = ElasticStreamTrainer(cfg, fc, batch=2, seq=16)
    params = T.init_params(cfg, rng)
    stream = _stream()

    plan = et.plan_for(fc.budget_bytes)
    bounds = list(plan.partition.bounds)
    sp = T.split_stage_params(cfg, params, bounds)
    resume = ResumeState(
        stage_params=sp,
        opt_states=tuple(adamw(lr=fc.lr).init(p) for p in sp),
        comp_states=tuple(comp_lib.init_state(p, fc.compensation) for p in sp),
        bounds=bounds,
        cursor=R_STREAM // 2,
        budget_bytes=fc.budget_bytes,
    )
    res = et.run_stream(params, stream, resume=resume)
    assert res.rounds == R_STREAM // 2
    # one segment → the run rate IS the segment rate; the old code halved
    # it by dividing the round-weighted sum by the full stream length
    assert len(res.segments) == 1
    seg_rate = res.segments[0].result.empirical_rate
    assert res.empirical_rate == pytest.approx(seg_rate, rel=1e-12)
    assert seg_rate > 0


def test_fatal_handler_usable_before_first_segment():
    """A Supervisor wired before run_stream must be able to escalate."""
    cfg = _cfg()
    et = ElasticStreamTrainer(cfg, _ferret_cfg(), batch=2, seq=16)
    handler = et.fatal_handler(0.5)
    handler(RuntimeError("device loss before any segment"))  # no AttributeError
    assert et._pending_budget is not None
    assert math.isfinite(et._pending_budget) and et._pending_budget > 0


def test_zero_round_stream_reports_finite_memory(rng):
    cfg = _cfg()
    params = T.init_params(cfg, rng)
    session = FerretSession(
        cfg, math.inf, "vanilla", IterableStreamSource(iter(())),
        batch=2, seq=16, max_workers=3, max_stages=4, params=params,
    )
    res = session.run("elastic")
    assert res.rounds == 0
    assert math.isfinite(res.memory_bytes) and res.memory_bytes > 0


def test_iterable_source_rejects_inconsistent_round_dicts():
    rows = [
        {"tokens": np.zeros((2, 8), np.int32), "labels": np.zeros((2, 8), np.int32)},
        {"tokens": np.zeros((2, 8), np.int32)},  # 'labels' vanished
    ]
    src = IterableStreamSource(iter(rows))
    with pytest.raises(ValueError, match="inconsistent stream fields"):
        src.take(2)
    extra = [
        {"tokens": np.zeros((2, 8), np.int32)},
        {"tokens": np.zeros((2, 8), np.int32), "mask": np.ones((2, 8), np.float32)},
    ]
    with pytest.raises(ValueError, match="inconsistent stream fields"):
        IterableStreamSource(iter(extra)).take(2)


# ---------------------------------------------------------------------------
# BufferedStreamSource semantics
# ---------------------------------------------------------------------------


def _counting_source(R=12, calls=None):
    def rounds():
        for m in range(R):
            if calls is not None:
                calls.append(m)
            yield {"x": np.full((2,), m, np.int32)}

    return IterableStreamSource(rounds())


def test_buffered_take_ack_rewind_exactly_once():
    feeder = BufferedStreamSource(_counting_source())
    first = feeder.take(5)
    assert first["x"].shape[0] == 5 and int(first["x"][0, 0]) == 0
    feeder.rewind()  # fault: replay the same rounds
    replay = feeder.take(5)
    np.testing.assert_array_equal(first["x"], replay["x"])
    feeder.ack()
    nxt = feeder.take(5)
    assert int(nxt["x"][0, 0]) == 5  # continues after the acked rounds
    feeder.ack()
    tail = feeder.take(5)
    assert tail["x"].shape[0] == 2  # source ends: short final take
    assert feeder.take(1) is None


def test_buffered_transform_applied_exactly_once_in_order():
    seen = []

    def transform(chunk):
        seen.extend(chunk["x"][:, 0].tolist())
        out = dict(chunk)
        out["doubled"] = chunk["x"] * 2
        return out

    feeder = BufferedStreamSource(_counting_source(), transform=transform)
    a = feeder.take(4)
    feeder.rewind()
    b = feeder.take(4)  # replayed rows are NOT re-transformed
    np.testing.assert_array_equal(a["doubled"], b["doubled"])
    feeder.ack()
    feeder.take(8)
    assert seen == list(range(12))  # each round transformed once, in order


def test_buffered_prefetch_overlaps_and_loses_nothing():
    calls = []
    feeder = BufferedStreamSource(_counting_source(calls=calls))
    got = feeder.take(4)
    feeder.ack()
    feeder.prefetch(4)
    feeder.close()  # drains the in-flight prefetch into the buffer
    nxt = feeder.take(8)  # 4 prefetched + 4 pulled now
    assert int(got["x"][0, 0]) == 0 and int(nxt["x"][0, 0]) == 4
    assert nxt["x"].shape[0] == 8
    feeder.ack()
    assert feeder.take(4) is None  # all 12 rounds consumed
    assert calls == list(range(12))


def test_buffered_peek_does_not_consume():
    feeder = BufferedStreamSource(_counting_source())
    first = feeder.peek(2)
    assert first["x"].shape[0] == 2 and int(first["x"][0, 0]) == 0
    taken = feeder.take(3)
    assert int(taken["x"][0, 0]) == 0  # peeked rounds served first
    assert feeder.peak_buffered_rounds >= 3
