"""Pallas kernel validation: hypothesis shape/dtype sweeps vs ref.py oracles.

Kernels execute under interpret=True on CPU (the TPU path is the same body).
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.iter_fisher import (
    iter_fisher_compensate_pallas,
    iter_fisher_leaf_stats_pallas,
)
from repro.kernels.ssd_scan import ssd_scan_pallas

# ---------------------------------------------------------------------------
# iter_fisher
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(3, 4500),
    tau=st.integers(1, 6),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    seed=st.integers(0, 2**16),
)
def test_iter_fisher_compensate_matches_ref(n, tau, dtype, seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(n,)), jnp.dtype(dtype))
    d = jnp.asarray(rng.normal(size=(tau, n)) * 0.01, jnp.dtype(dtype))
    lam = jnp.asarray(0.2, jnp.float32)
    want = ref.iter_fisher_compensate_ref(g, d, lam)
    got = iter_fisher_compensate_pallas(g, d, lam, interpret=True)
    tol = 1e-6 if dtype == "float32" else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


@settings(max_examples=15, deadline=None)
@given(
    shape=st.sampled_from([(128,), (513,), (32, 33), (4, 8, 130)]),
    alpha=st.floats(0.5, 0.99),
    seed=st.integers(0, 2**16),
)
def test_iter_fisher_stats_matches_ref(shape, alpha, seed):
    rng = np.random.default_rng(seed)
    def mk():
        return jnp.asarray(rng.normal(size=shape), jnp.float32)

    g, d, vr, va = mk(), mk(), mk(), mk()
    want = ref.iter_fisher_leaf_stats_ref(g, d, vr, va, alpha)
    got = iter_fisher_leaf_stats_pallas(g, d, vr, va, alpha, interpret=True)
    for a, b in zip(want, got):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=1e-4, atol=1e-4)


def test_iter_fisher_zero_delta_is_identity():
    g = jnp.asarray(np.random.default_rng(0).normal(size=(300,)), jnp.float32)
    d = jnp.zeros((4, 300), jnp.float32)
    out = iter_fisher_compensate_pallas(g, d, jnp.asarray(0.5), interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g), rtol=0, atol=0)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 3),
    nc=st.integers(1, 4),
    h=st.integers(1, 4),
    p=st.sampled_from([8, 16, 64]),
    n=st.sampled_from([8, 16, 128]),
    Q=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**16),
)
def test_ssd_kernel_matches_ref(b, nc, h, p, n, Q, seed):
    slen = nc * Q
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, slen, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(b, slen, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, slen, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, slen, n)), jnp.float32)
    s0 = jnp.asarray(rng.normal(size=(b, h, p, n)) * 0.1, jnp.float32)
    y_ref, s_ref = ref.ssd_scan_ref(x, dt, A, B, C, Q, s0)
    y_k, s_k = ssd_scan_pallas(x, dt, A, B, C, Q, s0, interpret=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref), rtol=3e-5, atol=3e-5)


def test_ssd_matches_sequential_recurrence():
    """Chunked kernel == exact token-by-token recurrence (ground truth)."""
    b, slen, h, p, n, Q = 2, 32, 3, 8, 16, 8
    rng = np.random.default_rng(1)
    x = rng.normal(size=(b, slen, h, p))
    dt = rng.uniform(0.001, 0.2, size=(b, slen, h))
    A = -rng.uniform(0.5, 2.0, size=(h,))
    B = rng.normal(size=(b, slen, n))
    C = rng.normal(size=(b, slen, n))
    y_k, s_k = ssd_scan_pallas(
        *(jnp.asarray(a, jnp.float32) for a in (x, dt, A, B, C)), Q, None, interpret=True
    )
    s = np.zeros((b, h, p, n))
    ys = np.zeros((b, slen, h, p))
    for t in range(slen):
        dA = np.exp(dt[:, t] * A)
        s = s * dA[:, :, None, None] + np.einsum("bh,bhp,bn->bhpn", dt[:, t], x[:, t], B[:, t])
        ys[:, t] = np.einsum("bhpn,bn->bhp", s, C[:, t])
    np.testing.assert_allclose(np.asarray(y_k), ys, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_k), s, rtol=1e-4, atol=1e-4)


def test_ssd_decode_step_continues_scan():
    """Prefill final state + decode step == scan over s+1 tokens."""
    b, slen, h, p, n, Q = 1, 16, 2, 8, 8, 8
    rng = np.random.default_rng(2)
    def mk(*s):
        return jnp.asarray(rng.normal(size=s), jnp.float32)

    x, B, C = mk(b, slen + 1, h, p), mk(b, slen + 1, n), mk(b, slen + 1, n)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, size=(b, slen + 1, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 1.5, size=(h,)), jnp.float32)
    y_all, s_all = ref.ssd_scan_ref(x, dt, A, B, C, chunk=slen + 1)
    _, s_pre = ref.ssd_scan_ref(x[:, :slen], dt[:, :slen], A, B[:, :slen], C[:, :slen], chunk=Q)
    y_dec, s_dec = ref.ssd_decode_step_ref(
        x[:, slen], dt[:, slen], A, B[:, slen], C[:, slen], s_pre
    )
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_all[:, slen]), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_dec), np.asarray(s_all), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# flash attention (custom VJP) — values AND gradients vs dense oracle
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 2),
    s=st.sampled_from([32, 64, 96]),
    heads=st.sampled_from([(4, 2), (4, 4), (8, 2)]),
    d=st.sampled_from([8, 16]),
    window=st.sampled_from([None, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_flash_attention_fwd_bwd_matches_dense(b, s, heads, d, window, seed):
    from repro.models.flash import flash_gqa_attention
    from repro.models.layers import causal_mask_bias, gqa_scores_softmax_value

    h, kv = heads
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, kv, d)), jnp.float32)
    weff = jnp.asarray(window if window else s + 100, jnp.int32)
    probe = jnp.cos(jnp.arange(d, dtype=jnp.float32))

    def f_flash(q, k, v):
        return jnp.sum(flash_gqa_attention(q, k, v, weff, 32) * probe)

    def f_dense(q, k, v):
        return jnp.sum(gqa_scores_softmax_value(q, k, v, causal_mask_bias(s, window)) * probe)

    np.testing.assert_allclose(float(f_flash(q, k, v)), float(f_dense(q, k, v)), rtol=1e-4)
    gf = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    gd = jax.grad(f_dense, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gf, gd):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=5e-4, atol=5e-4)
