"""Pipeline engine: schedule correctness + learning-dynamics equivalences."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import smoke_cfg
from repro.core import compensation as comp
from repro.core import pipeline as pl
from repro.core import schedule as sch
from repro.core.cost_model import PipelineConfig, StageKnobs, WorkerConfig
from repro.models import transformer as T
from repro.optim.optimizers import sgd


def _stream(cfg, rng, R, b=2, s=8):
    toks = jax.random.randint(rng, (R, b, s + 1), 0, cfg.vocab_size)
    return {"tokens": toks[:, :, :-1], "labels": toks[:, :, 1:]}


def _pcfg(P, N=1, accum=1, omit=0, removed=()):
    ws = []
    for n in range(N):
        ws.append(
            WorkerConfig(
                delay=-1 if n in removed else n,
                recompute=0,
                stages=[StageKnobs(accum=accum, omit=omit) for _ in range(P)],
            )
        )
    return PipelineConfig(workers=ws)


# ---------------------------------------------------------------------------
# schedule properties
# ---------------------------------------------------------------------------


def test_schedule_staleness_matches_pipeline_depth():
    s = sch.build_schedule(_pcfg(4), 4, 40)
    pops = s.pop_slot >= 0
    # stage P-1 updates fresh, stage 0 is (P-1) stale at steady state
    for j in range(4):
        taus = s.tau[pops[:, j], j]
        if len(taus) > 2:
            assert taus.max() <= 4 - 1 - j
            assert taus[2:].min() >= 0


def test_schedule_continuation_warmup_slice_pad_agree():
    """The three continuation primitives are one semantics: warmup ==
    rows of one big build == slice_schedule of it; pad rounds are inert."""
    cfgp = PipelineConfig(workers=[
        WorkerConfig(0, 0, [StageKnobs(accum=2), StageKnobs()]),
        WorkerConfig(1, 0, [StageKnobs(), StageKnobs(omit=1)]),
    ])
    fields = ("process", "backward", "push_slot", "push_reset", "pop_slot",
              "pop_scale", "delta_mask", "delta_push_slot", "tau")
    big = sch.build_schedule(cfgp, 2, 30)
    for cut in (7, 13):
        warm = sch.build_schedule(cfgp, 2, 30 - cut, warmup=cut)
        sliced = sch.slice_schedule(big, cut)
        for f in fields:
            np.testing.assert_array_equal(getattr(warm, f), getattr(big, f)[cut:])
            np.testing.assert_array_equal(getattr(sliced, f), getattr(big, f)[cut:])
    window = sch.slice_schedule(big, 7, 13)
    assert window.num_rounds == 6
    np.testing.assert_array_equal(window.tau, big.tau[7:13])
    padded = sch.pad_schedule(sch.build_schedule(cfgp, 2, 10), 16)
    assert padded.num_rounds == 16
    assert not padded.process[10:].any()
    assert (padded.push_slot[10:] == -1).all() and (padded.pop_slot[10:] == -1).all()
    assert (padded.delta_push_slot[10:] == -1).all()
    np.testing.assert_array_equal(padded.delta_mask[10:], 0.0)


def test_schedule_accumulation_reduces_updates():
    s1 = sch.build_schedule(_pcfg(2), 2, 40)
    s2 = sch.build_schedule(_pcfg(2, accum=4), 2, 40)
    assert (s2.pop_slot >= 0).sum() < (s1.pop_slot >= 0).sum()


def test_schedule_omission_skips_backward():
    s = sch.build_schedule(_pcfg(2, omit=1), 2, 40)
    # with c_o=1, half the items skip backward at each stage
    assert s.backward[:, 0].sum() == 20


def test_schedule_worker_removal_drops_items():
    s = sch.build_schedule(_pcfg(2, N=2, removed=(1,)), 2, 40)
    assert s.process.sum() == 20
    assert s.stats()["admitted"] == 20


def test_delta_ring_order_is_oldest_first():
    """Ground truth: replay the schedule and check gathered Δ ordering."""
    P, R = 3, 30
    s = sch.build_schedule(_pcfg(P), P, R)
    K = s.delta_ring
    # simulate: each update u of stage j writes value u at slot u%K
    upd = [0] * P
    for m in range(R):
        for j in range(P):
            if s.pop_slot[m, j] >= 0:
                slot = s.delta_push_slot[m, j]
                assert slot == upd[j] % K
                # engine gathers (slot + i) % K as oldest→newest
                tau = s.tau[m, j]
                assert tau <= K
                upd[j] += 1


# ---------------------------------------------------------------------------
# engine equivalences
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny():
    cfg = smoke_cfg("h2o-danube-1.8b", num_layers=4)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_p1_sync_equals_sequential_sgd(tiny, rng):
    cfg, params = tiny
    R = 10
    stream = _stream(cfg, rng, R)

    opt = sgd(lr=1e-2)
    p_ref, st = params, sgd(lr=1e-2).init(params)
    for m in range(R):
        batch = {k: v[m] for k, v in stream.items()}
        g = jax.grad(lambda p: T.loss_fn(cfg, p, batch)[0])(p_ref)
        p_ref, st = opt.update(p_ref, g, st)

    boundaries = [0, cfg.num_layers]
    staged = pl.staged_from_transformer(cfg, boundaries)
    schedule = sch.build_schedule(_pcfg(1), 1, R, sync_period=1)
    eng = pl.FerretEngine(staged, schedule, sgd(lr=1e-2), comp.CompensationConfig(method="none"))
    state = eng.init_state(T.split_stage_params(cfg, params, boundaries))
    final, ys = eng.run(state, stream)
    p_eng = T.merge_stage_params(cfg, list(final[0]))
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_eng)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_engine_sync_period_k_equals_accumulated_sgd(tiny, rng):
    """DAPPLE-style flush: update every K items with the mean gradient,
    all grads evaluated at the group-start parameters."""
    cfg, params = tiny
    R, K = 8, 4
    stream = _stream(cfg, rng, R)

    opt = sgd(lr=1e-2)
    p_ref, st = params, opt.init(params)
    for g0 in range(0, R, K):
        acc = None
        for m in range(g0, g0 + K):
            batch = {k: v[m] for k, v in stream.items()}
            g = jax.grad(lambda p: T.loss_fn(cfg, p, batch)[0])(p_ref)
            acc = g if acc is None else jax.tree.map(jnp.add, acc, g)
        acc = jax.tree.map(lambda a: a / K, acc)
        p_ref, st = opt.update(p_ref, acc, st)

    boundaries = [0, cfg.num_layers]
    staged = pl.staged_from_transformer(cfg, boundaries)
    schedule = sch.build_schedule(_pcfg(1), 1, R, sync_period=K)
    eng = pl.FerretEngine(staged, schedule, sgd(lr=1e-2), comp.CompensationConfig(method="none"))
    state = eng.init_state(T.split_stage_params(cfg, params, boundaries))
    final, _ = eng.run(state, stream)
    p_eng = T.merge_stage_params(cfg, list(final[0]))
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_eng)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_engine_async_applies_stale_gradients(tiny, rng):
    """Async P=2: stage 0's update at round m uses the gradient from round
    m-1 (τ=1). Verified against a hand-rolled replay."""
    cfg, params = tiny
    R = 6
    stream = _stream(cfg, rng, R)
    boundaries = [0, 2, 4]
    staged = pl.staged_from_transformer(cfg, boundaries)
    schedule = sch.build_schedule(_pcfg(2), 2, R)
    eng = pl.FerretEngine(staged, schedule, sgd(lr=1e-2), comp.CompensationConfig(method="none"))
    stages0 = T.split_stage_params(cfg, params, boundaries)
    state = eng.init_state(stages0)
    final, ys = eng.run(state, stream)

    # manual replay
    opt = sgd(lr=1e-2)
    stages = list(stages0)
    opt_states = [opt.init(sp) for sp in stages]
    pending = {0: [], 1: []}  # stage -> queue of grads

    def loss_of(stages_t, batch):
        x = None
        for j in range(2):
            x = staged.forward_stage(j, stages_t[j], x, batch)
        return staged.loss(x, batch)[0]

    for m in range(R):
        batch = {k: v[m] for k, v in stream.items()}
        grads = jax.grad(lambda st_: loss_of(st_, batch))(tuple(stages))
        # stage 1: fresh (τ=0); stage 0: delayed by 1 round
        pending[0].append(grads[0])
        stages[1], opt_states[1] = opt.update(stages[1], grads[1], opt_states[1])
        if m >= 1:
            g0 = pending[0].pop(0)
            stages[0], opt_states[0] = opt.update(stages[0], g0, opt_states[0])

    for a, b in zip(jax.tree.leaves(tuple(stages)), jax.tree.leaves(final[0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_engine_worker_removal_freezes_updates(tiny, rng):
    cfg, params = tiny
    R = 6
    stream = _stream(cfg, rng, R)
    boundaries = [0, cfg.num_layers]
    staged = pl.staged_from_transformer(cfg, boundaries)
    schedule = sch.build_schedule(_pcfg(1, N=1, removed=(0,)), 1, R)
    eng = pl.FerretEngine(staged, schedule, sgd(lr=1e-2), comp.CompensationConfig(method="none"))
    state = eng.init_state(T.split_stage_params(cfg, params, boundaries))
    final, ys = eng.run(state, stream)
    assert float(np.asarray(ys["admitted"]).sum()) == 0
    for a, b in zip(jax.tree.leaves(state[0]), jax.tree.leaves(final[0])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
