"""Multi-tenant serving: shared engine cache, admission, pool, scheduler.

Covers the serving tentpole's guarantees:
(a) two *threads* driving separately-constructed same-geometry sessions
    against one ``EngineCache`` compile exactly one engine and do not
    cross-talk — each concurrent result is bit-identical to its solo run;
(b) the server consumes every tenant's stream exactly once (counting
    sources + a push-fed ``TenantFeed``), with engine compiles < tenants;
(c) pool-rebalance parity: a tenant that was admitted alongside others
    who then left runs identically to the same tenant admitted alone;
plus the satellite units: ``TenantFeed`` admission policies, ``MemoryPool``
share math, scheduler fairness, the single deprecating raw-dict stream
entry point, and the typed ``StreamResult`` accessors.
"""

import math
import threading

import numpy as np
import pytest

from repro.api import FerretSession
from repro.api.results import StreamResult
from repro.api.streams import ArrayStreamSource, StreamSource, coerce_trainer_stream
from repro.core.compensation import CompensationConfig
from repro.core.ferret import EngineCache
from repro.models.config import ModelConfig
from repro.ocl.streams import StreamConfig, make_stream
from repro.serve import (
    DeficitRoundRobinScheduler,
    FerretServer,
    MemoryPool,
    RoundRobinScheduler,
    TenantFeed,
)

BATCH, SEQ, VOCAB = 2, 16, 32
R_STREAM = 8
SEGMENT = 4


def _model() -> ModelConfig:
    return ModelConfig(
        name="serve-test-lm", family="dense", num_layers=2, d_model=32,
        num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=VOCAB,
        compute_dtype="float32",
    )


def _stream(length=R_STREAM, seed=0):
    return make_stream(StreamConfig(
        kind="drift", modality="tokens", length=length, batch=BATCH,
        vocab=VOCAB, seq=SEQ, seed=seed,
    ))


def _session(cfg, stream, budget=math.inf, **over):
    kw = dict(
        batch=BATCH, seq=SEQ, lr=5e-3, seed=0,
        compensation=CompensationConfig(method="iter_fisher", eta_lambda=1e-4),
        max_workers=3, max_stages=4,
    )
    kw.update(over)
    return FerretSession(cfg, budget, "er", stream, **kw)


class CountingSource(StreamSource):
    """Delegating source that counts every round handed out."""

    def __init__(self, arrays):
        self.inner = ArrayStreamSource(arrays)
        self.rounds_out = 0

    @property
    def length(self):
        return self.inner.length

    @property
    def remaining(self):
        return self.inner.remaining

    def take(self, n):
        got = self.inner.take(n)
        if got is not None:
            self.rounds_out += next(iter(got.values())).shape[0]
        return got


# ---------------------------------------------------------------------------
# (a) concurrent same-geometry sessions share one compiled engine
# ---------------------------------------------------------------------------


def test_shared_cache_two_threads_one_compile_no_crosstalk():
    cfg = _model()
    streams = {0: _stream(seed=0), 1: _stream(seed=1)}

    # solo references, each with a private cache
    solo = {}
    for i in (0, 1):
        solo[i] = _session(cfg, streams[i]).run(
            "elastic", segment_rounds=SEGMENT, engine_cache=EngineCache()
        )

    shared = EngineCache()
    out, errs = {}, []

    def drive(i):
        try:
            # a separately *constructed* (not shared) session: engine reuse
            # must come from structural keys, not object identity
            out[i] = _session(cfg, streams[i]).run(
                "elastic", segment_rounds=SEGMENT, engine_cache=shared
            )
        except Exception as e:  # pragma: no cover - surfaced via errs
            errs.append(e)

    threads = [threading.Thread(target=drive, args=(i,)) for i in (0, 1)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs, errs

    # one geometry -> one compile across both threads
    assert shared.misses == 1, shared.stats()
    assert shared.hits == 2 * (R_STREAM // SEGMENT) - 1, shared.stats()
    # no cross-talk: concurrent results bit-identical to the solo runs
    for i in (0, 1):
        np.testing.assert_array_equal(
            np.asarray(out[i].losses), np.asarray(solo[i].losses)
        )
        np.testing.assert_array_equal(
            out[i].online_acc_curve, solo[i].online_acc_curve
        )
        assert out[i].rounds == R_STREAM


# ---------------------------------------------------------------------------
# (b) the server: exactly-once consumption + engine sharing + latency
# ---------------------------------------------------------------------------


def test_server_exactly_once_sharing_and_latency():
    cfg = _model()
    server = FerretServer(budget_bytes=2 * 2**30, segment_rounds=SEGMENT)

    counters = {}
    for i in ("a", "b"):
        counters[i] = CountingSource(_stream(seed=ord(i)))
        server.admit(cfg, "er", counters[i], name=i, batch=BATCH, seq=SEQ,
                     max_workers=3, max_stages=4)
    # a third, push-fed tenant of the same geometry
    c = server.admit(cfg, "er", None, name="c", batch=BATCH, seq=SEQ,
                     max_workers=3, max_stages=4)
    rows = _stream(seed=7)
    assert c.push_many(rows) == R_STREAM
    c.close_feed()

    results = server.serve(timeout_s=600)
    assert set(results) == {"a", "b", "c"}
    for i in ("a", "b"):
        # every round left the source exactly once and was trained
        assert counters[i].rounds_out == R_STREAM
        assert results[i].rounds == R_STREAM
    assert results["c"].rounds == R_STREAM
    # same geometry: strictly fewer compiles than tenants (here: one)
    assert server.compile_count < 3, server.engine_cache.stats()
    # push-fed tenant: one arrival->completion latency per served round
    assert len(c.round_latencies_s) == R_STREAM
    assert all(lat > 0 for lat in c.round_latencies_s)
    assert not server.active_tenants
    # results carry the unified typed surface
    assert results["c"].metrics()["runner"] == "serve"


def test_server_supervised_tenant_namespaced_checkpoints(tmp_path):
    from repro.runtime import SupervisorCfg

    cfg = _model()
    server = FerretServer(segment_rounds=SEGMENT)
    sup = SupervisorCfg(checkpoint_dir=str(tmp_path), checkpoint_every=1)
    server.admit(cfg, "er", _stream(seed=5), name="s", batch=BATCH, seq=SEQ,
                 max_workers=3, max_stages=4, supervisor_cfg=sup)
    res = server.serve()["s"]
    assert res.rounds == R_STREAM
    # checkpoints landed in the tenant's own namespace, not the shared dir
    assert (tmp_path / "tenant_s").is_dir()
    assert any((tmp_path / "tenant_s").iterdir())


def test_server_leave_midway_keeps_consumed_accounting():
    cfg = _model()
    server = FerretServer(segment_rounds=SEGMENT)
    server.admit(cfg, "er", _stream(length=4 * SEGMENT), name="x",
                 batch=BATCH, seq=SEQ, max_workers=3, max_stages=4)
    first = server.step()
    assert first is not None and first.tenant == "x"
    res = server.leave("x")
    # stopped at a segment boundary: exactly the served rounds accounted
    assert res.rounds == first.report.end - first.report.start
    assert not server.active_tenants
    assert server.results()["x"] is res


# ---------------------------------------------------------------------------
# (c) pool-rebalance parity on join/leave
# ---------------------------------------------------------------------------


def test_join_leave_rebalance_parity():
    cfg = _model()
    budget = 2 * 2**30
    stream = _stream(seed=3)

    alone = FerretServer(budget, segment_rounds=SEGMENT)
    alone.admit(cfg, "er", stream, name="t", batch=BATCH, seq=SEQ,
                max_workers=3, max_stages=4)
    ref = alone.serve()["t"]

    crowded = FerretServer(budget, segment_rounds=SEGMENT)
    crowded.admit(cfg, "er", stream, name="t", batch=BATCH, seq=SEQ,
                  max_workers=3, max_stages=4)
    other = crowded.admit(cfg, "er", None, name="other", weight=3.0,
                          batch=BATCH, seq=SEQ, max_workers=3, max_stages=4)
    # while `other` holds 3/4 of the pool, `t` plans under a quarter share
    assert crowded.pool.share("t") == pytest.approx(budget / 4)
    assert crowded.pool.share("other") == pytest.approx(3 * budget / 4)
    other.close_feed()  # empty feed: `other` finishes with zero rounds
    results = crowded.serve()
    assert results["other"].rounds == 0

    # after the others left, the tenant ran exactly as it would have alone
    assert crowded.pool.tenants == []
    got = results["t"]
    np.testing.assert_array_equal(np.asarray(got.losses), np.asarray(ref.losses))
    assert got.rounds == ref.rounds == R_STREAM
    assert got.memory_bytes <= budget


# ---------------------------------------------------------------------------
# satellite units (no device work)
# ---------------------------------------------------------------------------


def _row(v=0):
    return {"tokens": np.full((BATCH, SEQ), v, np.int32)}


def test_tenant_feed_reject_policy():
    feed = TenantFeed(max_rounds=2, policy="reject")
    assert feed.push(_row(0)) and feed.push(_row(1))
    assert not feed.push(_row(2))  # full: rejected, producer backs off
    assert feed.dropped == 1 and feed.pushed == 2
    assert feed.available_rounds() == 2
    got = feed.take(8)
    assert got["tokens"].shape[0] == 2  # what is available, never blocks
    assert [int(t[0, 0]) for t in got["tokens"]] == [0, 1]
    assert len(feed.pop_consumed_arrivals(2)) == 2
    feed.close()
    assert feed.take(1) is None and feed.remaining == 0
    with pytest.raises(RuntimeError):
        feed.push(_row(3))


def test_tenant_feed_drop_policies():
    old = TenantFeed(max_rounds=2, policy="drop_oldest")
    assert old.push(_row(0)) and old.push(_row(1))
    assert old.push(_row(2))  # evicts round 0; the new round got in
    assert [int(t[0, 0]) for t in old.take(4)["tokens"]] == [1, 2]

    new = TenantFeed(max_rounds=2, policy="drop_newest")
    new.push(_row(0)), new.push(_row(1))
    assert not new.push(_row(2))  # incoming dropped, backlog kept
    assert [int(t[0, 0]) for t in new.take(4)["tokens"]] == [0, 1]

    with pytest.raises(ValueError):
        TenantFeed(policy="nope")


def test_memory_pool_shares():
    pool = MemoryPool(100.0)
    assert pool.join("a") == pytest.approx(100.0)
    assert pool.join("b", weight=3.0) == pytest.approx(75.0)
    assert pool.share("a") == pytest.approx(25.0)
    pool.leave("b")
    assert pool.shares() == {"a": pytest.approx(100.0)}
    with pytest.raises(ValueError):
        pool.join("a")  # duplicate
    assert math.isinf(MemoryPool().join("x"))


def test_schedulers():
    rr = RoundRobinScheduler()
    picks = [rr.select(["a", "b", "c"], {}) for _ in range(4)]
    assert picks == ["a", "b", "c", "a"]
    assert rr.select(["b", "c"], {}) == "b"  # last=a gone: restart cleanly

    drr = DeficitRoundRobinScheduler(quantum=4.0)
    weights = {"heavy": 3.0, "light": 1.0}
    served = {"heavy": 0, "light": 0}
    for _ in range(20):
        pick = drr.select(["heavy", "light"], weights)
        served[pick] += 1
        drr.charge(pick, 4)
    # 3:1 weights -> ~3:1 service, and the light tenant is never starved
    assert served["heavy"] == 15 and served["light"] == 5
    # a late joiner starts at the current virtual time, not at zero: it
    # does not monopolize the device to "catch up" on service it missed
    assert drr.select(["heavy", "light", "late"], weights | {"late": 1.0}) != "late"
    drr.forget("heavy")
    assert "heavy" not in drr._service


def test_raw_dict_stream_deprecation_single_entry_point():
    arrays = {"tokens": np.zeros((4, BATCH, SEQ), np.int32)}
    with pytest.warns(DeprecationWarning, match="FerretTrainer.run_stream"):
        src = coerce_trainer_stream(arrays, "FerretTrainer.run_stream")
    assert isinstance(src, ArrayStreamSource)
    # already a StreamSource: passes through silently, identity preserved
    import warnings as W

    with W.catch_warnings():
        W.simplefilter("error")
        assert coerce_trainer_stream(src, "x") is src


def test_stream_result_typed_accessors():
    res = StreamResult(
        runner="elastic", algorithm="er", online_acc=0.5,
        online_acc_curve=np.ones(3), losses=np.ones(3), rounds=3,
        admitted_frac=1.0, memory_bytes=1024.0, empirical_rate=0.9,
        final_params=None, engine_cache_hits=2, engine_cache_misses=1,
        extras={"peak_buffered_rounds": 5, "stream_wait_s": 0.25,
                "lam_curve": [0.1, 0.2]},
    )
    assert res.peak_buffered_rounds == 5
    assert res.stream_wait_s == 0.25
    np.testing.assert_allclose(res.lam_curve, [0.1, 0.2])
    assert res.cache_counts == {"hits": 2, "misses": 1}
    m = res.metrics()
    assert m["peak_buffered_rounds"] == 5 and m["rounds"] == 3
    # absent extras read as empty, not KeyError (the point of the accessors)
    empty = StreamResult(
        runner="serve", algorithm="vanilla", online_acc=0.0,
        online_acc_curve=np.zeros(0), losses=np.zeros(0), rounds=0,
        admitted_frac=0.0, memory_bytes=0.0, empirical_rate=0.0,
        final_params=None,
    )
    assert empty.peak_buffered_rounds == 0
    assert empty.lam_curve.size == 0


# ---------------------------------------------------------------------------
# fault isolation: crash containment, quarantine, graceful drain -> restore
# ---------------------------------------------------------------------------


def _chaos_server(streams, **over):
    import repro.serve as _serve

    kw = dict(segment_rounds=SEGMENT)
    kw.update(over)
    resume = kw.pop("_resume", {})
    server = _serve.FerretServer(**kw)
    for name, s in streams.items():
        server.admit(_model(), "er", s, name=name, batch=BATCH, seq=SEQ,
                     max_workers=3, max_stages=4,
                     resume_from=resume.get(name))
    return server


def test_tenant_crash_retried_no_crosstalk():
    """A transient tenant crash (< max_tenant_crashes) is retried at a
    later scheduling decision: the injected crash fires before the step
    consumed anything, so the tenant — and its siblings — finish all
    rounds bit-identically to an uninjected server."""
    from repro import faults
    from repro.faults import FaultPlan, FaultSpec

    streams = {"a": _stream(seed=0), "b": _stream(seed=1)}
    ref = _chaos_server(streams).serve(timeout_s=600)

    plan = FaultPlan(specs=(
        FaultSpec("serve.step", "tenant_crash", after=1, match=(("tenant", "a"),)),
    ))
    server = _chaos_server(streams)
    with faults.inject(plan) as chaos:
        got = server.serve(timeout_s=600)

    assert chaos.fired == 1 and not chaos.unrecovered()
    assert not server.quarantined_tenants
    for n in ("a", "b"):
        assert got[n].rounds == ref[n].rounds == R_STREAM
        np.testing.assert_array_equal(
            np.asarray(got[n].losses), np.asarray(ref[n].losses)
        )
        np.testing.assert_array_equal(
            got[n].online_acc_curve, ref[n].online_acc_curve
        )


def test_tenant_quarantine_isolates_siblings():
    """A persistently crashing tenant is quarantined after
    ``max_tenant_crashes`` consecutive failures; the sibling sharing the
    server (and ``EngineCache``) is untouched and bit-exact vs solo."""
    from repro import faults
    from repro.faults import FaultPlan, FaultSpec

    ok_stream = _stream(seed=2)
    solo = _chaos_server({"ok": ok_stream}).serve(timeout_s=600)["ok"]

    plan = FaultPlan(specs=(
        FaultSpec("serve.step", "tenant_crash", times=99, match=(("tenant", "bad"),)),
    ))
    server = _chaos_server(
        {"ok": ok_stream, "bad": _stream(seed=9)}, max_tenant_crashes=2
    )
    with faults.inject(plan) as chaos:
        results = server.serve(timeout_s=600)

    assert list(server.quarantined_tenants) == ["bad"]
    assert "TenantCrashError" in server.quarantined_tenants["bad"]
    assert chaos.fired == 2  # one retry, then quarantine
    assert results["bad"].rounds == 0  # crashed before consuming anything
    assert results["ok"].rounds == R_STREAM
    np.testing.assert_array_equal(
        np.asarray(results["ok"].losses), np.asarray(solo.losses)
    )
    np.testing.assert_array_equal(
        results["ok"].online_acc_curve, solo.online_acc_curve
    )


def test_injected_drain_then_restore_loses_zero_rounds(tmp_path):
    """An injected SIGTERM-style drain stops serving at a segment
    boundary; ``drain()`` checkpoints every tenant; a fresh server
    re-admits with ``resume_from`` and finishes — per tenant, rounds
    served before + after the restart sum to exactly the stream length
    (nothing lost, nothing re-trained)."""
    from repro import faults
    from repro.faults import FaultPlan, FaultSpec
    from repro.serve import FerretServer

    streams = {"a": _stream(seed=3), "b": _stream(seed=4)}
    ckpt = str(tmp_path / "drainpoint")

    plan = FaultPlan(specs=(FaultSpec("serve.loop", "drain", after=2),))
    server = _chaos_server(streams)
    with faults.inject(plan) as chaos:
        finished = server.serve(timeout_s=600)
        assert not finished  # nobody finished: the drain stopped the loop
        assert server.draining
        manifest = server.drain(ckpt)
    assert chaos.fired == 1 and not chaos.unrecovered()
    partial = server.results()  # drain finalized every tenant's partial run

    served_pre = sum(e["rounds_served"] for e in manifest.values())
    assert 0 < served_pre < 2 * R_STREAM  # genuinely mid-flight
    for name, entry in manifest.items():
        assert entry["cursor"] == entry["rounds_served"]
        assert partial[name].rounds == entry["rounds_served"]
        if entry["rounds_served"]:
            assert entry["checkpoint"] is not None

    # restart: a brand-new server over fresh (seekable) copies of the
    # same streams, positioned by the drain manifest
    reloaded = FerretServer.load_drain_manifest(ckpt)
    assert reloaded == manifest
    server2 = _chaos_server(
        streams, _resume={n: e["checkpoint"] for n, e in reloaded.items()}
    )
    final = server2.serve(timeout_s=600)
    for name, entry in reloaded.items():
        # exactly-once across the restart: pre + post == stream length
        assert entry["rounds_served"] + final[name].rounds == R_STREAM


def test_drain_restore_is_bit_exact_for_stateless_algorithm(tmp_path):
    """Drain → restart reproduces the uninterrupted run bit for bit.

    Schema-2 drain checkpoints carry the in-flight accumulation/Δθ rings
    and the schedule origin, so the restarted engine re-enters the same
    schedule with identical state. The tenant runs "vanilla" because a
    replay-buffer algorithm's host-side reservoir legitimately resets
    across a process restart — the engine state itself is what this test
    pins down."""
    from repro.serve import FerretServer

    length = 4 * SEGMENT
    stream = _stream(length=length, seed=11)

    solo = FerretServer(segment_rounds=SEGMENT)
    solo.admit(_model(), "vanilla", stream, name="v", batch=BATCH, seq=SEQ,
               max_workers=3, max_stages=4)
    ref = solo.serve(timeout_s=600)["v"]
    assert ref.rounds == length

    server = FerretServer(segment_rounds=SEGMENT)
    server.admit(_model(), "vanilla", stream, name="v", batch=BATCH, seq=SEQ,
                 max_workers=3, max_stages=4)
    assert server.step() is not None and server.step() is not None
    manifest = server.drain(str(tmp_path / "drainpoint"))
    partial = server.results()["v"]
    assert partial.rounds == 2 * SEGMENT
    assert manifest["v"]["checkpoint"] is not None
    assert manifest["v"]["cursor"] == 2 * SEGMENT

    server2 = FerretServer(segment_rounds=SEGMENT)
    server2.admit(_model(), "vanilla", stream, name="v", batch=BATCH, seq=SEQ,
                  max_workers=3, max_stages=4,
                  resume_from=manifest["v"]["checkpoint"])
    final = server2.serve(timeout_s=600)["v"]
    assert partial.rounds + final.rounds == length
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(partial.losses), np.asarray(final.losses)]),
        np.asarray(ref.losses),
    )


def test_v1_drain_checkpoint_migrates_with_warning(tmp_path):
    """A pre-ring (schema-1) drain checkpoint still loads: forward
    migration fills ``rings=None`` with a warning naming the re-warm, and
    the resumed run keeps exactly-once round accounting."""
    import json
    import os

    import jax

    from repro.checkpointing.checkpoint import save_checkpoint
    from repro.core.ferret import FerretConfig
    from repro.models import transformer as T
    from repro.runtime import ElasticStreamTrainer

    cfg = _model()
    fc = FerretConfig(
        budget_bytes=math.inf, lr=5e-3,
        compensation=CompensationConfig(method="iter_fisher", eta_lambda=1e-4),
        max_workers=3, max_stages=4,
    )
    length = 4 * SEGMENT
    stream = _stream(length=length, seed=13)
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    et = ElasticStreamTrainer(cfg, fc, batch=BATCH, seq=SEQ)
    run = et.open_stream(params, stream, segment_rounds=SEGMENT)
    run.step()
    run.step()
    part1 = run.stop()
    rs = et.live_resume_state()
    assert rs is not None and rs.rings is not None

    # forge the old on-disk format: 3-tuple payload (no rings), no ring
    # extras, and no "schema" key in the manifest (implicit schema 1)
    d1 = str(tmp_path / "v1_drain")
    path = save_checkpoint(
        d1, rs.cursor,
        (list(rs.stage_params), tuple(rs.opt_states), tuple(rs.comp_states)),
        {"bounds": [int(b) for b in rs.bounds], "cursor": int(rs.cursor),
         "budget_bytes": "inf"},
    )
    mpath = os.path.join(path, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    del manifest["schema"]
    with open(mpath, "w") as f:
        json.dump(manifest, f)

    et2 = ElasticStreamTrainer(cfg, fc, batch=BATCH, seq=SEQ)
    with pytest.warns(UserWarning, match="re-warms"):
        resume = et2.load_drain_state(params, d1)
    assert resume.rings is None and resume.cursor == 2 * SEGMENT
    part2 = et2.run_stream(params, stream, resume=resume, segment_rounds=SEGMENT)
    assert part1.rounds + part2.rounds == length


def test_sigterm_handler_requests_drain():
    import os
    import signal
    import time as _time

    from repro.serve import FerretServer

    server = FerretServer()
    prev = signal.getsignal(signal.SIGTERM)
    server.install_signal_handler()
    try:
        os.kill(os.getpid(), signal.SIGTERM)
        for _ in range(200):
            if server.draining:
                break
            _time.sleep(0.005)
        assert server.draining
    finally:
        signal.signal(signal.SIGTERM, prev)
