"""Streaming-native pipelined runner + engine parameter-penalty hook.

Covers the tentpole guarantees:
(a) ``FerretTrainer.run_stream`` fed by an *unbounded* source (no
    materialization, no whole-stream device copy) is bit-identical to the
    dict run on the same rounds for vanilla/ER/LwF/MAS — losses, curves,
    final params — with peak stream residency O(segment_rounds);
(b) MAS on the pipeline path applies the Ω-weighted penalty through the
    ``FerretEngine`` hook: it matches the sequential runner on a
    degenerate (P=1, N=1, no-compensation) plan, and it is *live* — no
    silent Vanilla fallback remains;
plus the satellite regressions: a zero-round stream reports 0.0 instead
of a NaN ``online_acc`` (pipelined and sequential), the feeder's prefetch
pool winds down when the consumer dies mid-segment, background ``take``
exceptions re-raise with the original traceback at the next sync point,
and the pipelined runner reports consumed-rounds/residency like the
elastic runner does.
"""

import dataclasses
import math
import threading
import warnings

import jax
import numpy as np
import pytest

from repro.api import FerretSession, IterableStreamSource, get_runner
from repro.api.streams import ArrayStreamSource, BufferedStreamSource, StreamSource
from repro.core.compensation import CompensationConfig
from repro.core.ferret import FerretConfig, FerretTrainer
from repro.models import transformer as T
from repro.models.registry import get_config
from repro.ocl.algorithms import OCLConfig, mas_penalty
from repro.ocl.registry import OCLAlgorithm
from repro.ocl.streams import StreamConfig, make_stream

R_STREAM = 24


def _cfg():
    return dataclasses.replace(
        get_config("h2o-danube-1.8b", smoke=True),
        compute_dtype="float32", num_layers=2, d_model=32, num_heads=2,
        num_kv_heads=1, d_ff=64, vocab_size=16,
    )


def _ferret_cfg(**over):
    base = dict(
        budget_bytes=math.inf, lr=5e-3,
        compensation=CompensationConfig(method="iter_fisher", eta_lambda=1e-4),
        max_workers=2, max_stages=2,
        ocl=OCLConfig(replay_batch=2, replay_size=32, mir_candidates=4),
    )
    base.update(over)
    return FerretConfig(**base)


def _stream(length=R_STREAM, seed=0):
    return make_stream(StreamConfig(
        kind="drift", modality="tokens", length=length, batch=2, vocab=16,
        seq=8, seed=seed,
    ))


def _unbounded(arrays, counter=None):
    """A live-feed view of ``arrays``: per-round dicts, length undeclared."""

    def rounds():
        R = next(iter(arrays.values())).shape[0]
        for m in range(R):
            if counter is not None:
                counter.append(m)
            yield {k: v[m] for k, v in arrays.items()}

    return IterableStreamSource(rounds())


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params, _stream()


# ---------------------------------------------------------------------------
# (a) incremental unbounded == materialized, residency O(segment)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("algo", ["vanilla", "er", "lwf", "mas"])
def test_pipelined_unbounded_matches_materialized(setup, algo):
    cfg, params, arrays = setup
    fc = _ferret_cfg()

    t_base = FerretTrainer(cfg, fc, batch=2, seq=8, algorithm=algo)
    base = t_base.run_stream(params, arrays, segment_rounds=8)
    produced = []
    t_incr = FerretTrainer(cfg, fc, batch=2, seq=8, algorithm=algo)
    res = t_incr.run_stream(
        params, _unbounded(arrays, produced), segment_rounds=8
    )

    assert res.rounds == R_STREAM
    assert produced == list(range(R_STREAM))  # every round pulled exactly once
    np.testing.assert_array_equal(np.asarray(base.losses), np.asarray(res.losses))
    np.testing.assert_array_equal(base.online_acc_curve, res.online_acc_curve)
    np.testing.assert_array_equal(base.lam_curve, res.lam_curve)
    for a, b in zip(
        jax.tree.leaves(t_base.final_params), jax.tree.leaves(t_incr.final_params)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # residency: one segment + the prefetch window, never the whole stream
    assert 0 < res.peak_buffered_rounds <= 2 * 8
    assert res.peak_buffered_rounds < R_STREAM


@pytest.mark.parametrize("algo", ["vanilla", "mas"])
def test_pipelined_chunked_matches_single_scan_params(setup, algo):
    """The chunked run carries the engine rings across slices: final
    weights equal the one-big-scan run bit for bit."""
    cfg, params, arrays = setup
    fc = _ferret_cfg()
    t_one = FerretTrainer(cfg, fc, batch=2, seq=8, algorithm=algo)
    one = t_one.run_stream(params, arrays, segment_rounds=R_STREAM)
    t_chunk = FerretTrainer(cfg, fc, batch=2, seq=8, algorithm=algo)
    chunk = t_chunk.run_stream(params, arrays, segment_rounds=7)  # ragged
    np.testing.assert_array_equal(np.asarray(one.losses), np.asarray(chunk.losses))
    for a, b in zip(
        jax.tree.leaves(t_one.final_params), jax.tree.leaves(t_chunk.final_params)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pipelined_runner_consumes_source_with_rounds_accounting(setup):
    cfg, params, arrays = setup
    session = FerretSession(
        cfg, math.inf, "vanilla", _unbounded(arrays),
        batch=2, seq=8, max_workers=2, max_stages=2, params=params,
    )
    res = session.run("pipelined", max_rounds=12, segment_rounds=4)
    # consumed-rounds semantics (PR 4), not len(losses)-of-whatever-ran
    assert res.rounds == 12
    assert res.losses.shape == (12,)
    assert res.extras["lam_curve"].shape == (12,)
    assert 0 < res.extras["peak_buffered_rounds"] <= 8
    assert res.extras["stream_wait_s"] >= 0.0
    # the rest of the feed is untouched: the next run continues at round 12
    nxt = session.run("pipelined", max_rounds=4, segment_rounds=4)
    assert nxt.rounds == 4


def test_session_probe_does_not_retain_the_stream(setup):
    """With batch/seq inferred from a live feed, the session's pass-through
    views (shape probe + cross-run live view) must not keep a replay copy
    of every round the trainer pulls through them — retention is the
    consuming trainer's feeder's job, once."""
    cfg, params, arrays = setup
    session = FerretSession(
        cfg, math.inf, "vanilla", _unbounded(arrays),
        max_workers=2, max_stages=2, params=params,  # no batch/seq: probed
    )
    res = session.run("pipelined", segment_rounds=8)
    assert res.rounds == R_STREAM
    assert (session.batch, session.seq) == (2, 8)
    # the shared live view handed out every round exactly once and holds
    # none of them afterwards — host residency stays O(segment)
    assert session._live_stream._inflight == []
    assert res.extras["peak_buffered_rounds"] < R_STREAM


# ---------------------------------------------------------------------------
# (b) MAS: engine penalty hook — exact, live, parity with sequential
# ---------------------------------------------------------------------------


def test_mas_penalty_is_live_on_pipeline_path(setup):
    """No silent Vanilla fallback: MAS and vanilla trajectories diverge on
    identical data/params as soon as θ moves off the reference."""
    cfg, params, arrays = setup
    fc = _ferret_cfg(ocl=OCLConfig(method="mas", mas_weight=10.0))
    mas = FerretTrainer(cfg, fc, batch=2, seq=8, algorithm="mas").run_stream(
        params, arrays, segment_rounds=8
    )
    van = FerretTrainer(cfg, fc, batch=2, seq=8, algorithm="vanilla").run_stream(
        params, arrays, segment_rounds=8
    )
    # round 0: θ == θ_ref, the penalty is exactly 0 → identical loss
    assert mas.losses[0] == van.losses[0]
    assert not np.allclose(mas.losses[1:], van.losses[1:])
    assert np.isfinite(mas.losses).all()


def test_mas_pipeline_matches_sequential_parity():
    """On a degenerate plan (P=1, N=1, no compensation, no periodic
    refresh) the pipeline engine's per-round update equals the sequential
    runner's — penalty value and final params within tolerance."""
    # a 1-layer model is the smallest profile the planner partitions into
    # a single stage (τ=0: the pipeline update is as fresh as sequential)
    cfg = dataclasses.replace(_cfg(), num_layers=1)
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    arrays = _stream(length=12, seed=3)
    ocl = OCLConfig(method="mas", mas_weight=5.0, refresh_every=0)
    fc = FerretConfig(
        budget_bytes=math.inf, lr=5e-3,
        compensation=CompensationConfig(method="none"),
        max_workers=1, max_stages=1, ocl=ocl,
    )

    def _session():
        return FerretSession(
            cfg, algorithm="mas", stream=arrays, batch=2, seq=8,
            params=params, ferret=fc, max_workers=1, max_stages=1, ocl=ocl,
        )

    s_pipe = _session()
    pipe = s_pipe.run("pipelined")
    assert pipe.plan.partition.num_stages == 1
    assert pipe.admitted_frac == 1.0
    s_seq = _session()
    seq = s_seq.run("sequential")

    # both paths anchored Ω/θ* at stream entry from the first round
    a_pipe, a_seq = s_pipe.algorithm, s_seq.algorithm
    for x, y in zip(jax.tree.leaves(a_pipe.omega), jax.tree.leaves(a_seq.omega)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-5)

    # the Ω-weighted pull on the final weights agrees across paths
    p_pipe = float(mas_penalty(pipe.final_params, a_pipe.ref, a_pipe.omega))
    p_seq = float(mas_penalty(seq.final_params, a_seq.ref, a_seq.omega))
    assert p_pipe > 0.0  # the penalty actually engaged
    assert p_pipe == pytest.approx(p_seq, rel=1e-3)

    np.testing.assert_allclose(pipe.losses, seq.losses, rtol=1e-4, atol=1e-5)
    for x, y in zip(
        jax.tree.leaves(pipe.final_params), jax.tree.leaves(seq.final_params)
    ):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-4, atol=1e-6)


def test_mas_elastic_replan_refreshes_omega(setup):
    """At a re-plan boundary the Ω anchor moves to the live weights
    (segment_refresh), and the run stays finite/penalized throughout."""
    from repro.runtime import BudgetEvent, ElasticStreamTrainer

    cfg, params, arrays = setup
    fc = _ferret_cfg(ocl=OCLConfig(method="mas", mas_weight=5.0))
    et = ElasticStreamTrainer(cfg, fc, batch=2, seq=8, algorithm="mas")
    full = et.plan_for(math.inf)
    events = [BudgetEvent(12, full.memory * 0.3)]
    res = et.run_stream(params, arrays, schedule=events, segment_rounds=6)
    assert res.num_replans == 1
    assert np.isfinite(res.losses).all()
    algo = et.algorithm
    assert algo.omega is not None
    # after the refresh the reference is the replan-boundary weights, not
    # the stream-entry weights
    entry_leaf = jax.tree.leaves(params)[0]
    ref_leaf = jax.tree.leaves(algo.ref)[0]
    assert not np.array_equal(np.asarray(entry_leaf), np.asarray(ref_leaf))


# ---------------------------------------------------------------------------
# satellite: zero-round streams report 0.0, not NaN (pipelined + sequential)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("runner", ["pipelined", "sequential"])
def test_zero_round_stream_reports_zero_not_nan(setup, runner):
    cfg, params, arrays = setup
    empty = {k: v[:0] for k, v in arrays.items()}
    session = FerretSession(
        cfg, math.inf, "vanilla", None,
        batch=2, seq=8, max_workers=2, max_stages=2, params=params,
    )
    r = get_runner(runner)
    stream = ArrayStreamSource(empty) if r.consumes_source else empty
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # the empty-mean RuntimeWarning fails
        res = r.run(session, params, stream)
    assert res.rounds == 0
    assert res.online_acc == 0.0
    assert not math.isnan(res.empirical_rate)
    assert res.losses.shape == (0,)
    assert math.isfinite(res.memory_bytes)


# ---------------------------------------------------------------------------
# satellite: feeder prefetch-pool lifecycle under consumer faults
# ---------------------------------------------------------------------------


def _prefetch_threads():
    return [
        t for t in threading.enumerate()
        if t.name.startswith("stream-prefetch") and t.is_alive()
    ]


class _BoomPrep(OCLAlgorithm):
    """Test-only algorithm whose stream prep dies on the second chunk —
    a consumer fault *mid-stream*, with a prefetch already in flight."""

    name = "test-boom-prep"

    def reset(self):
        self.calls = 0

    def prepare_stream(self, stream, ctx=None):
        self.calls += 1
        if self.calls >= 2:
            raise RuntimeError("boom mid-segment")
        return stream


def test_feeder_pool_winds_down_when_trainer_dies_mid_segment(setup):
    cfg, params, arrays = setup
    fc = _ferret_cfg()
    trainer = FerretTrainer(cfg, fc, batch=2, seq=8, algorithm=_BoomPrep())
    with pytest.raises(RuntimeError, match="boom mid-segment"):
        trainer.run_stream(params, _unbounded(arrays), segment_rounds=8)
    # the try/finally close() shut the worker down — no leaked non-daemon
    # thread left blocked on the feed
    assert _prefetch_threads() == []


class _ExplodingSource(StreamSource):
    """A feed whose ``take`` raises — e.g. a dead upstream socket."""

    @property
    def length(self):
        return None

    @property
    def remaining(self):
        return None

    def take(self, n):
        raise ConnectionError("upstream feed died")


def test_background_take_exception_rethrows_with_traceback_then_closes():
    feeder = BufferedStreamSource(_ExplodingSource())
    feeder.prefetch(4)
    with pytest.raises(ConnectionError, match="upstream feed died") as exc:
        feeder.take(4)  # the sync point: the background error surfaces here
    # the original traceback is attached: the failing frame is the
    # source's take, not an opaque future internals frame
    frames = []
    tb = exc.value.__traceback__
    while tb is not None:
        frames.append(tb.tb_frame.f_code.co_name)
        tb = tb.tb_next
    assert frames[-1] == "take"  # innermost frame: the source's take
    # close() during unwind must not raise and must stop the worker, even
    # with another failed prefetch in flight
    feeder.prefetch(4)
    feeder.close()
    assert _prefetch_threads() == []
