"""Budget-elastic streaming trainer: live replan + state remap.

Covers the tentpole guarantees:
(a) a no-op budget schedule reproduces ``FerretTrainer.run_stream`` exactly;
(b) a mid-stream budget shrink replans to a different partition, remaps
    live state without shape errors, and keeps training — loss finite,
    cursor monotone/contiguous, no stream item lost or double-consumed;
(c) optimizer moments and Iter-Fisher statistics survive the remap
    (merge → re-split round-trips);
(d) a simulated device loss escalates through ``Supervisor.on_fatal`` into
    a shrink-replan instead of killing the run.
"""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compensation import CompensationConfig, CompensationState, init_state
from repro.core.ferret import FerretConfig, FerretTrainer
from repro.core.profiler import ModelProfile, analytic_profile
from repro.models import transformer as T
from repro.models.registry import get_config
from repro.ocl.streams import StreamConfig, make_stream
from repro.optim.optimizers import AdamWState, adamw
from repro.runtime import (
    BudgetEvent,
    ElasticStreamTrainer,
    SupervisorCfg,
)
from repro.state import (
    remap_comp_states,
    remap_opt_states,
    remap_stage_params,
)

R_STREAM = 40


def _cfg():
    return dataclasses.replace(
        get_config("h2o-danube-1.8b", smoke=True),
        compute_dtype="float32", num_layers=4, vocab_size=32,
    )


def _ferret_cfg(**over):
    base = dict(
        budget_bytes=math.inf, lr=5e-3,
        compensation=CompensationConfig(method="iter_fisher", eta_lambda=1e-4),
        max_workers=3, max_stages=4,
    )
    base.update(over)
    return FerretConfig(**base)


def _stream(length=R_STREAM):
    return make_stream(StreamConfig(
        kind="drift", modality="tokens", length=length, batch=2, vocab=32, seq=16,
    ))


def _hetero_profile(cfg) -> ModelProfile:
    """Per-layer times scaled 1×..4× so budget changes move the partition."""
    base = analytic_profile(cfg, 2, 16)
    layers = [
        dataclasses.replace(ly, t_fwd=ly.t_fwd * (1 + i), t_bwd=ly.t_bwd * (1 + i))
        for i, ly in enumerate(base.layers)
    ]
    return ModelProfile(layers=layers, embed_bytes=base.embed_bytes, batch=2, seq=16)


# ---------------------------------------------------------------------------
# (a) no-op schedule == FerretTrainer.run_stream
# ---------------------------------------------------------------------------


def test_noop_schedule_matches_run_stream(rng):
    cfg = _cfg()
    fc = _ferret_cfg()
    params = T.init_params(cfg, rng)
    stream = _stream()
    base = FerretTrainer(cfg, fc, batch=2, seq=16).run_stream(params, stream)
    res = ElasticStreamTrainer(cfg, fc, batch=2, seq=16).run_stream(
        params, stream, schedule=[]
    )
    assert len(res.segments) == 1 and not res.segments[0].replanned
    np.testing.assert_array_equal(np.asarray(base.losses), np.asarray(res.losses))
    np.testing.assert_array_equal(base.online_acc_curve, res.online_acc_curve)
    assert res.online_acc == base.online_acc
    assert res.admitted_frac == base.admitted_frac
    assert res.rounds == R_STREAM


# ---------------------------------------------------------------------------
# (b) mid-stream shrink: replan + remap + seamless continuation
# ---------------------------------------------------------------------------


def test_midstream_shrink_replans_and_continues(rng):
    cfg = _cfg()
    fc = _ferret_cfg()
    profile = _hetero_profile(cfg)
    et = ElasticStreamTrainer(cfg, fc, batch=2, seq=16, profile=profile)
    full = et.plan_for(math.inf)
    params = T.init_params(cfg, rng)
    stream = _stream()

    events = [BudgetEvent(R_STREAM // 2, full.memory * 0.3)]
    res = et.run_stream(params, stream, schedule=events)

    assert len(res.segments) == 2
    first, second = res.segments
    assert (first.start, first.end) == (0, R_STREAM // 2)
    assert (second.start, second.end) == (R_STREAM // 2, R_STREAM)
    assert second.replanned and res.num_replans == 1
    # the shrink genuinely moved the partition (fewer stages here) and the
    # new plan fits the budget
    b_old = tuple(first.result.plan.partition.bounds)
    b_new = tuple(second.result.plan.partition.bounds)
    assert b_new != b_old
    assert second.result.plan.partition.num_stages < first.result.plan.partition.num_stages
    assert second.result.memory_bytes <= events[0].budget_bytes * (1 + 1e-9)
    # training continued: finite losses, exactly-once stream consumption
    assert np.isfinite(res.losses).all()
    assert res.rounds == R_STREAM and res.losses.shape == (R_STREAM,)
    assert res.online_acc_curve.shape == (R_STREAM,)
    assert res.num_faults == 0


def test_callable_schedule_and_segment_cap(rng):
    cfg = _cfg()
    fc = _ferret_cfg()
    params = T.init_params(cfg, rng)
    stream = _stream()

    calls = []

    def budget_fn(cursor):
        calls.append(cursor)
        return None  # never change — just verify polling + chunking

    res = ElasticStreamTrainer(cfg, fc, batch=2, seq=16).run_stream(
        params, stream, schedule=budget_fn, segment_rounds=10
    )
    assert [s.start for s in res.segments] == [0, 10, 20, 30]
    assert sorted(set(calls)) == [0, 10, 20, 30]  # polled at every boundary
    assert res.rounds == R_STREAM and res.num_replans == 0


# ---------------------------------------------------------------------------
# (c) remap round-trips
# ---------------------------------------------------------------------------

OLD_BOUNDS = [0, 1, 2, 3, 4]
NEW_BOUNDS = [0, 3, 4]


def _merged(cfg, stage_trees):
    return T.merge_stage_params(cfg, list(stage_trees))


def test_remap_params_roundtrip(rng):
    cfg = _cfg()
    params = T.init_params(cfg, rng)
    old = T.split_stage_params(cfg, params, OLD_BOUNDS)
    new = remap_stage_params(cfg, old, NEW_BOUNDS)
    assert len(new) == len(NEW_BOUNDS) - 1
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(_merged(cfg, new))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_remap_opt_moments_preserved(rng):
    cfg = _cfg()
    opt = adamw(lr=1e-3)
    params = T.init_params(cfg, rng)
    old_sp = T.split_stage_params(cfg, params, OLD_BOUNDS)
    # distinct per-stage moments and counts to catch mis-slicing
    old_states = []
    for j, sp in enumerate(old_sp):
        st = opt.init(sp)
        mu = jax.tree.map(lambda p, j=j: jnp.full_like(p, 1.0 + j, dtype=jnp.float32), sp)
        nu = jax.tree.map(lambda p, j=j: jnp.full_like(p, 10.0 + j, dtype=jnp.float32), sp)
        old_states.append(AdamWState(mu=mu, nu=nu, count=jnp.asarray(5 + j, jnp.int32)))
    new_sp = T.split_stage_params(cfg, params, NEW_BOUNDS)
    new_states = remap_opt_states(cfg, old_states, OLD_BOUNDS, NEW_BOUNDS, opt, new_sp)

    merged_mu_old = _merged(cfg, [s.mu for s in old_states])
    merged_mu_new = _merged(cfg, [s.mu for s in new_states])
    for a, b in zip(jax.tree.leaves(merged_mu_old), jax.tree.leaves(merged_mu_new)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # structure matches the new stage params exactly
    for st, sp in zip(new_states, new_sp):
        assert jax.tree.structure(st.mu) == jax.tree.structure(sp)
        for m, p in zip(jax.tree.leaves(st.mu), jax.tree.leaves(sp)):
            assert m.shape == p.shape
    # count: conservative min over overlapping old stages
    assert int(new_states[0].count) == 5  # covers old stages 0,1,2 → min(5,6,7)
    assert int(new_states[1].count) == 8  # covers old stage 3 only


def test_remap_comp_lambda_overlap_weighted(rng):
    cfg = _cfg()
    params = T.init_params(cfg, rng)
    old_sp = T.split_stage_params(cfg, params, OLD_BOUNDS)
    ccfg = CompensationConfig(method="iter_fisher", eta_lambda=1e-4)
    old = []
    for j, sp in enumerate(old_sp):
        st = init_state(sp, ccfg)
        old.append(CompensationState(
            lam=jnp.asarray(0.1 * (j + 1), jnp.float32),
            v_r=st.v_r, v_a=st.v_a, steps=jnp.asarray(j, jnp.int32),
        ))
    new = remap_comp_states(cfg, old, OLD_BOUNDS, NEW_BOUNDS)
    assert len(new) == 2
    # new stage 0 covers layers 0-2 (one layer from each of stages 0,1,2)
    assert float(new[0].lam) == pytest.approx((0.1 + 0.2 + 0.3) / 3, rel=1e-5)
    assert float(new[1].lam) == pytest.approx(0.4, rel=1e-5)
    assert int(new[0].steps) == 2 and int(new[1].steps) == 3


# ---------------------------------------------------------------------------
# (d) device loss escalates through Supervisor.on_fatal
# ---------------------------------------------------------------------------


def test_device_loss_escalates_to_shrink_replan(rng, tmp_path):
    cfg = _cfg()
    fc = _ferret_cfg()
    params = T.init_params(cfg, rng)
    stream = _stream()
    et = ElasticStreamTrainer(cfg, fc, batch=2, seq=16)
    res = et.run_stream(
        params, stream,
        segment_rounds=R_STREAM // 2,
        supervisor_cfg=SupervisorCfg(
            checkpoint_dir=str(tmp_path), checkpoint_every=1, step_timeout_s=600.0,
        ),
        fault_rounds=[R_STREAM // 2 + 2],
        fault_budget_scale=0.3,
    )
    assert res.num_faults == 1 and res.num_replans == 1
    # the failed segment re-ran from its own cursor: nothing lost, nothing twice
    assert res.rounds == R_STREAM
    starts_ends = [(s.start, s.end) for s in res.segments]
    assert starts_ends == [(0, R_STREAM // 2), (R_STREAM // 2, R_STREAM)]
    # post-fault budget is finite and the plan respects it
    post = res.segments[-1]
    assert math.isfinite(post.budget_bytes)
    assert post.result.memory_bytes <= post.budget_bytes * (1 + 1e-9)
    assert np.isfinite(res.losses).all()
    # the supervised segments checkpointed into per-segment dirs (state
    # shapes are partition-dependent) with plan + end-cursor extras
    import json

    ckpts = sorted(tmp_path.glob("seg_*/step_*/manifest.json"))
    assert ckpts, "supervised segments must leave a checkpoint behind"
    extras = json.loads(ckpts[-1].read_text())["extras"]
    assert extras["cursor"] == R_STREAM  # end-of-segment state → end cursor
    assert "bounds" in extras and math.isfinite(float(extras["budget_bytes"]))


# ---------------------------------------------------------------------------
# (e) crash → restore → remap: resume from a checkpoint taken under a
# *different* partition, every stream item consumed exactly once
# ---------------------------------------------------------------------------


def test_crash_restore_remap_consumes_stream_exactly_once(rng, tmp_path):
    cfg = _cfg()
    fc = _ferret_cfg()
    profile = _hetero_profile(cfg)
    params = T.init_params(cfg, rng)
    stream = _stream()  # R_STREAM = 40 rounds
    crash_at = 20

    # --- run 1: budget ∞ (partition A), checkpointing every segment; the
    # process "crashes" after consuming [0, crash_at) ---
    et1 = ElasticStreamTrainer(cfg, fc, batch=2, seq=16, profile=profile)
    part = {k: v[:crash_at] for k, v in stream.items()}
    res1 = et1.run_stream(
        params, part, segment_rounds=10,
        supervisor_cfg=SupervisorCfg(
            checkpoint_dir=str(tmp_path), checkpoint_every=1, step_timeout_s=600.0,
        ),
    )
    assert res1.rounds == crash_at
    bounds_a = tuple(res1.segments[-1].result.plan.partition.bounds)

    # --- restart under a 0.3× budget: the restart plans a *different*
    # partition, so the restored state must be remapped ---
    full = et1.plan_for(math.inf)
    fc2 = dataclasses.replace(fc, budget_bytes=full.memory * 0.3)
    et2 = ElasticStreamTrainer(cfg, fc2, batch=2, seq=16, profile=profile)
    template = T.init_params(cfg, jax.random.split(rng)[0])  # shapes only
    resume = et2.load_resume_state(template, str(tmp_path))
    assert resume.cursor == crash_at
    assert tuple(resume.bounds) == bounds_a
    bounds_b = tuple(et2.plan_for(fc2.budget_bytes).partition.bounds)
    assert bounds_b != bounds_a, "restart budget must move the partition"

    res2 = et2.run_stream(params, stream, resume=resume)
    assert tuple(res2.segments[0].result.plan.partition.bounds) == bounds_b

    # exactly-once: run 1 consumed [0, crash_at), the resumed run consumed
    # [crash_at, R) — disjoint, complete, nothing twice
    spans = [(s.start, s.end) for s in res1.segments] + [
        (s.start, s.end) for s in res2.segments
    ]
    assert spans == sorted(spans)
    covered = []
    for start, end in spans:
        covered.extend(range(start, end))
    assert covered == list(range(R_STREAM)), "items lost or double-consumed"
    assert res1.rounds + res2.rounds == R_STREAM
    assert len(res1.losses) + len(res2.losses) == R_STREAM
    assert np.isfinite(res2.losses).all()

    # the restored weights actually carried over: resuming from the
    # checkpoint differs from cold-starting the tail at init params
    cold = ElasticStreamTrainer(cfg, fc2, batch=2, seq=16, profile=profile)
    tail = {k: v[crash_at:] for k, v in stream.items()}
    res_cold = cold.run_stream(params, tail, schedule=[])
    assert not np.allclose(res2.losses, res_cold.losses)


# ---------------------------------------------------------------------------
# (f) compile-once hot path: engine cache + bucketed segment lengths
# ---------------------------------------------------------------------------


def test_aba_budget_schedule_compiles_exactly_two_engines(rng):
    """A→B→A compiles 2 engines (A and B); the return to A is a cache hit."""
    cfg = _cfg()
    fc = _ferret_cfg()
    profile = _hetero_profile(cfg)
    et = ElasticStreamTrainer(cfg, fc, batch=2, seq=16, profile=profile)
    full = et.plan_for(math.inf)
    params = T.init_params(cfg, rng)
    stream = _stream(length=60)  # equal 20-round segments → one bucket

    events = [
        BudgetEvent(20, full.memory * 0.3),  # A → B
        BudgetEvent(40, math.inf),  # B → A
    ]
    res = et.run_stream(params, stream, schedule=events)
    assert len(res.segments) == 3 and res.num_replans == 2
    bounds = [tuple(s.result.plan.partition.bounds) for s in res.segments]
    assert bounds[0] == bounds[2] != bounds[1], "A→B→A must move and return"
    assert res.engine_cache_misses == 2
    assert res.engine_cache_hits == 1
    assert [s.cache_hit for s in res.segments] == [False, False, True]
    # bucketing padded all three segments onto one compiled length
    assert len({s.rounds_compiled for s in res.segments}) <= 2
    assert np.isfinite(res.losses).all() and res.rounds == 60


def test_cache_disabled_compiles_every_segment(rng):
    from repro.runtime import EngineCache

    cfg = _cfg()
    fc = _ferret_cfg()
    params = T.init_params(cfg, rng)
    stream = _stream()
    et = ElasticStreamTrainer(
        cfg, fc, batch=2, seq=16, engine_cache=EngineCache(enabled=False)
    )
    res = et.run_stream(params, stream, segment_rounds=10)
    assert res.engine_cache_hits == 0
    assert res.engine_cache_misses == len(res.segments) == 4
    # disabled cache does not bucket: segments ran at their true length
    assert all(s.rounds_compiled == 10 for s in res.segments)


def test_segmented_run_matches_single_run_exactly(rng):
    """Same-structure segment boundaries carry the in-flight accumulation
    and Δθ rings (continued schedule via warmup), so a chunked run equals
    the unchunked run — gradients, λ statistics, losses, weights."""
    cfg = _cfg()
    fc = _ferret_cfg()
    params = T.init_params(cfg, rng)
    stream = _stream()
    ft = FerretTrainer(cfg, fc, batch=2, seq=16)
    base = ft.run_stream(params, stream)
    et = ElasticStreamTrainer(cfg, fc, batch=2, seq=16)
    res = et.run_stream(params, stream, segment_rounds=7)  # ragged segments
    assert len(res.segments) == 6
    assert res.engine_cache_hits >= 1  # equal-length chunks share a bucket
    np.testing.assert_allclose(
        np.asarray(res.losses), np.asarray(base.losses), rtol=1e-6, atol=1e-7
    )
    np.testing.assert_allclose(
        res.online_acc_curve, base.online_acc_curve, rtol=1e-6, atol=1e-7
    )
    for a, b in zip(jax.tree.leaves(ft.final_params), jax.tree.leaves(res.final_params)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6
        )


def test_bucketed_segment_is_exact(rng):
    """Padding a segment to a bucket length (inert schedule rounds) must
    not change any per-round output or the final state."""
    cfg = _cfg()
    fc = _ferret_cfg()
    params = T.init_params(cfg, rng)
    stream = _stream(length=37)  # prime-ish: buckets to 64
    base = FerretTrainer(cfg, fc, batch=2, seq=16).run_stream(params, stream)
    res = ElasticStreamTrainer(cfg, fc, batch=2, seq=16).run_stream(
        params, stream, schedule=[]
    )
    assert res.segments[0].rounds_compiled == 64
    np.testing.assert_array_equal(np.asarray(base.losses), np.asarray(res.losses))
    assert res.rounds == 37 and res.losses.shape == (37,)


# ---------------------------------------------------------------------------
# (g) lossless switches: the unified state plane (repro.state)
# ---------------------------------------------------------------------------


def test_deprecated_remap_aliases_warn(rng):
    """The old elastic_trainer entrypoints delegate to repro.state and
    warn; remap_engine_state additionally names its lossless replacement
    (the old silent ring drop is now an explicit, reported choice)."""
    from repro.runtime import elastic_trainer as et_mod

    cfg = _cfg()
    params = T.init_params(cfg, rng)
    old = T.split_stage_params(cfg, params, OLD_BOUNDS)
    with pytest.warns(DeprecationWarning, match="moved to repro.state"):
        new = et_mod.remap_stage_params(cfg, old, NEW_BOUNDS)
    assert len(new) == len(NEW_BOUNDS) - 1

    opt = adamw(lr=1e-3)
    ccfg = CompensationConfig(method="iter_fisher", eta_lambda=1e-4)
    opts = tuple(opt.init(sp) for sp in old)
    comps = tuple(init_state(sp, ccfg) for sp in old)
    state = (list(old), None, None, opts, comps)
    with pytest.warns(DeprecationWarning, match="StateRemapper"):
        sp2, opts2, comps2 = et_mod.remap_engine_state(
            cfg, state, OLD_BOUNDS, NEW_BOUNDS, opt
        )
    assert len(sp2) == len(opts2) == len(comps2) == len(NEW_BOUNDS) - 1


def test_plan_equal_budget_switch_is_bit_exact(rng):
    """A budget event that plans the *same* partition and config is a
    same-schedule switch: the rings carry, rounds_lost is 0, and the run
    is bit-identical to one with no schedule at all."""
    cfg = _cfg()
    fc = _ferret_cfg()
    profile = _hetero_profile(cfg)
    et = ElasticStreamTrainer(cfg, fc, batch=2, seq=16, profile=profile)
    full = et.plan_for(math.inf)
    params = T.init_params(cfg, rng)
    stream = _stream()

    base = ElasticStreamTrainer(
        cfg, fc, batch=2, seq=16, profile=profile
    ).run_stream(params, stream, segment_rounds=R_STREAM // 2)

    # finite budget, same resulting plan: replan fires, partition doesn't move
    events = [BudgetEvent(R_STREAM // 2, full.memory)]
    res = et.run_stream(params, stream, schedule=events)
    assert res.num_replans == 1
    assert (
        tuple(res.segments[0].result.plan.partition.bounds)
        == tuple(res.segments[1].result.plan.partition.bounds)
    )
    assert res.rounds_lost_per_switch == 0
    np.testing.assert_array_equal(np.asarray(base.losses), np.asarray(res.losses))
    np.testing.assert_array_equal(base.online_acc_curve, res.online_acc_curve)


def test_cross_partition_switch_lossless_vs_carry_rings_escape_hatch(rng):
    """A schedule-restarting shrink is lossless by default (in-flight
    groups flushed; rounds_lost == 0). carry_rings=False is the explicit
    escape hatch: the same switch drops the rings and *reports* it."""
    cfg = _cfg()
    fc = _ferret_cfg()
    profile = _hetero_profile(cfg)
    params = T.init_params(cfg, rng)
    stream = _stream()
    def events_for(et):
        return [BudgetEvent(R_STREAM // 2, et.plan_for(math.inf).memory * 0.3)]

    et = ElasticStreamTrainer(cfg, fc, batch=2, seq=16, profile=profile)
    res = et.run_stream(params, stream, schedule=events_for(et))
    assert res.num_replans == 1
    assert (
        res.segments[0].result.plan.partition.num_stages
        != res.segments[1].result.plan.partition.num_stages
    )
    assert res.rounds_lost_per_switch == 0
    assert all(s.rounds_lost == 0 for s in res.segments)

    et_drop = ElasticStreamTrainer(
        cfg, fc, batch=2, seq=16, profile=profile, carry_rings=False
    )
    res_drop = et_drop.run_stream(params, stream, schedule=events_for(et_drop))
    assert res_drop.num_replans == 1
    # the async pipeline always has accumulation in flight mid-stream
    assert res_drop.rounds_lost_per_switch > 0
    assert res_drop.segments[1].rounds_lost == res_drop.rounds_lost_per_switch
    # dropping in-flight gradients changes the trajectory
    tail = slice(R_STREAM // 2, None)
    assert not np.array_equal(res.losses[tail], res_drop.losses[tail])


def test_drain_restore_is_bit_exact(rng, tmp_path):
    """Stopping at a segment boundary, draining to a checkpoint, and
    resuming on a fresh trainer reproduces the uninterrupted run bit for
    bit — the rings travel through the drain (schema-2 checkpoints)."""
    cfg = _cfg()
    fc = _ferret_cfg()
    params = T.init_params(cfg, rng)
    stream = _stream()

    base = ElasticStreamTrainer(cfg, fc, batch=2, seq=16).run_stream(
        params, stream, segment_rounds=10
    )

    et1 = ElasticStreamTrainer(cfg, fc, batch=2, seq=16)
    run = et1.open_stream(params, stream, segment_rounds=10)
    run.step()
    run.step()
    part1 = run.stop()
    assert part1.rounds == 20
    path = et1.save_live_checkpoint(str(tmp_path))
    assert path is not None

    et2 = ElasticStreamTrainer(cfg, fc, batch=2, seq=16)
    template = T.init_params(cfg, jax.random.split(rng)[0])  # shapes only
    resume = et2.load_drain_state(template, str(tmp_path))
    assert resume.cursor == 20
    assert resume.rings is not None and resume.sched_origin == 0
    part2 = et2.run_stream(params, stream, resume=resume, segment_rounds=10)
    assert part2.rounds == R_STREAM - 20

    np.testing.assert_array_equal(
        np.concatenate([np.asarray(part1.losses), np.asarray(part2.losses)]),
        np.asarray(base.losses),
    )
    for a, b in zip(
        jax.tree.leaves(base.final_params), jax.tree.leaves(part2.final_params)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# (g) topology shrink: an injected device loss mid-stream re-meshes over the
# survivors, replans, and keeps the stream exactly-once with zero rounds
# lost. Runs in a subprocess so the topology is guaranteed 8 fake devices
# regardless of the parent process's XLA_FLAGS.
# ---------------------------------------------------------------------------

import json  # noqa: E402
import os  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import textwrap  # noqa: E402

SHRINK_CODE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json, math
    import jax, numpy as np
    from repro import faults
    from repro.core.compensation import CompensationConfig
    from repro.core.ferret import FerretConfig
    from repro.faults import FaultPlan, FaultSpec
    from repro.models import transformer as T
    from repro.models.registry import get_config
    from repro.ocl.streams import StreamConfig, make_stream
    from repro.runtime import ElasticStreamTrainer
    from repro.runtime.topology import DeviceTopology

    R = 16
    cfg = dataclasses.replace(get_config("h2o-danube-1.8b", smoke=True),
                              compute_dtype="float32", num_layers=4, vocab_size=32)
    fc = FerretConfig(budget_bytes=math.inf, lr=5e-3,
                      compensation=CompensationConfig(method="iter_fisher",
                                                      eta_lambda=1e-4),
                      max_workers=3, max_stages=4)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    stream = make_stream(StreamConfig(kind="drift", modality="tokens",
                                      length=R, batch=4, vocab=32, seq=16))

    topo = DeviceTopology.discover(max_devices=4)
    assert topo.mesh_shape == (4, 1), topo
    et = ElasticStreamTrainer(cfg, fc, batch=4, seq=16, topology=topo)
    scope_before = et._cache_scope

    # lose one device at the second segment's first engine step
    plan = FaultPlan(specs=(
        FaultSpec("engine.step", "device_loss", match=(("cursor", R // 2),)),
    ))
    with faults.inject(plan) as chaos:
        res = et.run_stream(params, stream, segment_rounds=R // 2)

    assert chaos.summary()["fired"] == 1
    assert not chaos.unrecovered(), chaos.summary()
    assert res.num_faults == 1 and res.num_replans == 1

    # the survivors' world replaced the lost one
    assert et.topology.device_count == 3
    assert et.topology.mesh_shape == (3, 1)
    assert et._mesh.devices.size == 3
    assert et._cache_scope != scope_before  # shrink re-keys the engine cache

    # exactly-once stream consumption, zero rounds lost through the remap
    assert res.rounds == R
    assert [(s.start, s.end) for s in res.segments] == [(0, R // 2), (R // 2, R)]
    assert res.rounds_lost_per_switch == 0
    assert all(s.rounds_lost == 0 for s in res.segments)
    assert np.isfinite(res.losses).all()
    print(json.dumps({"ok": True, "topology": et.topology.describe()}))
    """
)


def test_device_loss_shrinks_topology_exactly_once():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    proc = subprocess.run(
        [sys.executable, "-c", SHRINK_CODE], capture_output=True, text=True,
        timeout=600, cwd=root, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["topology"]["device_count"] == 3
