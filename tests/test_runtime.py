"""Checkpointing + supervisor fault tolerance + elastic replanning."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing.checkpoint import (
    CheckpointManager,
    latest_checkpoint,
    restore_checkpoint,
    save_checkpoint,
)
from repro.runtime.elastic import ClusterSpec, ElasticPlanner
from repro.runtime.supervisor import Supervisor, SupervisorCfg


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32),
        "opt": {"m": jnp.zeros((4, 8)), "count": jnp.asarray(3, jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 7, state, extras={"cursor": 42})
    restored, step, extras = restore_checkpoint(str(tmp_path), jax.tree.map(jnp.zeros_like, state))
    assert step == 7 and extras["cursor"] == 42
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    save_checkpoint(str(tmp_path), 1, _state())
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))
    assert latest_checkpoint(str(tmp_path)).endswith("step_0000000001")


def test_manager_retention_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, every_steps=1)
    for s in range(1, 5):
        mgr.save_async(s, _state(s))
    mgr.wait()
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 2 and kept[-1] == "step_0000000004"


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, _state())
    bad = {"w": jnp.zeros((2, 2)), "opt": {"m": jnp.zeros((4, 8)), "count": jnp.asarray(0)}}
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), bad)


def test_supervisor_nan_rollback(tmp_path):
    """A poisoned batch must trigger restore from the last checkpoint."""
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        w = state["w"] + batch["delta"]
        loss = jnp.sum(w)
        return {"w": w}, {"loss": loss}

    sup = Supervisor(
        SupervisorCfg(checkpoint_dir=str(tmp_path), checkpoint_every=1, nan_check_every=1),
        step_fn,
        {"w": jnp.ones(4)},
    )
    r1 = sup.run_step({"delta": jnp.ones(4)})
    sup.manager.wait()
    assert r1.step == 1 and not r1.restarted
    # poison: NaN loss -> rollback to step 1 and retry (same batch) succeeds
    # only if retried batch is clean; feed NaN then rely on retries failing
    with pytest.raises(FloatingPointError):
        sup.run_step({"delta": jnp.full(4, jnp.nan)})
    # state was rolled back to the last checkpoint (step 1)
    np.testing.assert_array_equal(np.asarray(sup.state["w"]), np.full(4, 2.0))
    assert sup.step == 1


def test_supervisor_recovers_and_continues(tmp_path):
    flaky = {"fail_next": False}

    def step_fn(state, batch):
        if flaky["fail_next"]:
            flaky["fail_next"] = False
            return state, {"loss": jnp.asarray(float("nan"))}
        return {"w": state["w"] + 1}, {"loss": jnp.sum(state["w"])}

    sup = Supervisor(
        SupervisorCfg(checkpoint_dir=str(tmp_path), checkpoint_every=1, nan_check_every=1),
        step_fn,
        {"w": jnp.zeros(2)},
    )
    sup.run_step({})
    sup.manager.wait()
    flaky["fail_next"] = True
    rep = sup.run_step({})  # fails once, rolls back, retries, succeeds
    assert rep.restarted and rep.step == 2
    np.testing.assert_array_equal(np.asarray(sup.state["w"]), np.full(2, 2.0))


def test_elastic_replan_degrades_gracefully():
    from repro.models.registry import get_config

    cfg = get_config("h2o-danube-1.8b")
    ep = ElasticPlanner(cfg, batch=8, seq=512, max_workers=4)
    full = ep.replan(ClusterSpec(chips=256))
    shrunk = ep.replan(ClusterSpec(chips=128))
    assert full.feasible and shrunk.feasible
    deg = ep.degradation(full, shrunk)
    assert 0.0 <= deg < 1.0
    # less memory budget -> planned memory within the shrunken budget
    assert shrunk.memory <= 0.9 * ClusterSpec(chips=128).total_hbm * (1 + 1e-9)


def test_data_pipeline_exactly_once_cursor(tmp_path):
    from repro.data.pipeline import PipelineCfg, TokenStreamSource

    cfg = PipelineCfg(batch=2, seq=8)
    s1 = TokenStreamSource(64, cfg)
    batches = [s1.next_batch() for _ in range(5)]
    # resume from cursor 3 reproduces batch 3 exactly
    s2 = TokenStreamSource(64, cfg)
    s2.seek(3)
    b3 = s2.next_batch()
    np.testing.assert_array_equal(b3["tokens"], batches[3]["tokens"])
