"""Checkpointing + supervisor fault tolerance + elastic replanning."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing.checkpoint import (
    CheckpointManager,
    latest_checkpoint,
    restore_checkpoint,
    save_checkpoint,
)
from repro.runtime.elastic import ClusterSpec, ElasticPlanner
from repro.runtime.supervisor import Supervisor, SupervisorCfg


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32),
        "opt": {"m": jnp.zeros((4, 8)), "count": jnp.asarray(3, jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 7, state, extras={"cursor": 42})
    restored, step, extras = restore_checkpoint(str(tmp_path), jax.tree.map(jnp.zeros_like, state))
    assert step == 7 and extras["cursor"] == 42
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    save_checkpoint(str(tmp_path), 1, _state())
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))
    assert latest_checkpoint(str(tmp_path)).endswith("step_0000000001")


def test_manager_retention_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, every_steps=1)
    for s in range(1, 5):
        mgr.save_async(s, _state(s))
    mgr.wait()
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 2 and kept[-1] == "step_0000000004"


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, _state())
    bad = {"w": jnp.zeros((2, 2)), "opt": {"m": jnp.zeros((4, 8)), "count": jnp.asarray(0)}}
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), bad)


def test_supervisor_nan_rollback(tmp_path):
    """A poisoned batch must trigger restore from the last checkpoint."""
    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        w = state["w"] + batch["delta"]
        loss = jnp.sum(w)
        return {"w": w}, {"loss": loss}

    sup = Supervisor(
        SupervisorCfg(checkpoint_dir=str(tmp_path), checkpoint_every=1, nan_check_every=1),
        step_fn,
        {"w": jnp.ones(4)},
    )
    r1 = sup.run_step({"delta": jnp.ones(4)})
    sup.manager.wait()
    assert r1.step == 1 and not r1.restarted
    # poison: NaN loss -> rollback to step 1 and retry (same batch) succeeds
    # only if retried batch is clean; feed NaN then rely on retries failing
    with pytest.raises(FloatingPointError):
        sup.run_step({"delta": jnp.full(4, jnp.nan)})
    # state was rolled back to the last checkpoint (step 1)
    np.testing.assert_array_equal(np.asarray(sup.state["w"]), np.full(4, 2.0))
    assert sup.step == 1


def test_supervisor_recovers_and_continues(tmp_path):
    flaky = {"fail_next": False}

    def step_fn(state, batch):
        if flaky["fail_next"]:
            flaky["fail_next"] = False
            return state, {"loss": jnp.asarray(float("nan"))}
        return {"w": state["w"] + 1}, {"loss": jnp.sum(state["w"])}

    sup = Supervisor(
        SupervisorCfg(checkpoint_dir=str(tmp_path), checkpoint_every=1, nan_check_every=1),
        step_fn,
        {"w": jnp.zeros(2)},
    )
    sup.run_step({})
    sup.manager.wait()
    flaky["fail_next"] = True
    rep = sup.run_step({})  # fails once, rolls back, retries, succeeds
    assert rep.restarted and rep.step == 2
    np.testing.assert_array_equal(np.asarray(sup.state["w"]), np.full(2, 2.0))


def test_elastic_replan_degrades_gracefully():
    from repro.models.registry import get_config

    cfg = get_config("h2o-danube-1.8b")
    ep = ElasticPlanner(cfg, batch=8, seq=512, max_workers=4)
    full = ep.replan(ClusterSpec(chips=256))
    shrunk = ep.replan(ClusterSpec(chips=128))
    assert full.feasible and shrunk.feasible
    deg = ep.degradation(full, shrunk)
    assert 0.0 <= deg < 1.0
    # less memory budget -> planned memory within the shrunken budget
    assert shrunk.memory <= 0.9 * ClusterSpec(chips=128).total_hbm * (1 + 1e-9)


def test_data_pipeline_exactly_once_cursor(tmp_path):
    from repro.data.pipeline import PipelineCfg, TokenStreamSource

    cfg = PipelineCfg(batch=2, seq=8)
    s1 = TokenStreamSource(64, cfg)
    batches = [s1.next_batch() for _ in range(5)]
    # resume from cursor 3 reproduces batch 3 exactly
    s2 = TokenStreamSource(64, cfg)
    s2.seek(3)
    b3 = s2.next_batch()
    np.testing.assert_array_equal(b3["tokens"], batches[3]["tokens"])


def test_supervisor_retry_deadline_is_per_attempt(tmp_path):
    """Regression: the step deadline must reset on every retry.

    Each attempt takes ~0.07s against a 0.1s timeout. The first attempt
    fails with a poisoned loss; with a *cumulative* timer (the old bug,
    ``t0`` set once outside the attempt loop) the clean retry would
    inherit the failed attempt's elapsed time and spuriously time out.
    """
    import time as _time

    flaky = {"fail_next": False}

    def step_fn(state, batch):
        _time.sleep(0.07)
        if flaky["fail_next"]:
            flaky["fail_next"] = False
            return state, {"loss": jnp.asarray(float("nan"))}
        return {"w": state["w"] + 1}, {"loss": jnp.sum(state["w"])}

    sup = Supervisor(
        SupervisorCfg(
            checkpoint_dir=str(tmp_path), checkpoint_every=1,
            nan_check_every=1, step_timeout_s=0.1,
        ),
        step_fn,
        {"w": jnp.zeros(2)},
    )
    sup.run_step({})
    sup.manager.wait()
    flaky["fail_next"] = True
    rep = sup.run_step({})  # NaN, rollback, retry — must NOT TimeoutError
    assert rep.restarted and rep.step == 2


def test_supervisor_rollback_restores_extras(tmp_path):
    """Regression: a mid-run rollback must hand checkpoint extras (stream
    cursor, replay state) back through the same hook as ``try_restore`` —
    dropping them silently double-trains rounds after the rollback."""
    seen = {}
    flaky = {"fail_next": False}

    def step_fn(state, batch):
        if flaky["fail_next"]:
            flaky["fail_next"] = False
            return state, {"loss": jnp.asarray(float("nan"))}
        return {"w": state["w"] + 1}, {"loss": jnp.sum(state["w"])}

    sup = Supervisor(
        SupervisorCfg(checkpoint_dir=str(tmp_path), checkpoint_every=1, nan_check_every=1),
        step_fn,
        {"w": jnp.zeros(2)},
        extras_hook=seen.update,
    )
    sup.run_step({}, extras={"cursor": 4})
    sup.manager.wait()
    flaky["fail_next"] = True
    rep = sup.run_step({}, extras={"cursor": 5})
    assert rep.restarted
    assert seen["cursor"] == 4  # the rolled-back-to checkpoint's extras


def test_supervisor_persistent_error_not_retried(tmp_path):
    """A non-transient exception is a bug: surface it immediately, do not
    burn the retry budget re-running something retries cannot fix."""
    calls = {"n": 0}
    fatals = []

    def step_fn(state, batch):
        calls["n"] += 1
        raise ValueError("shape mismatch: a bug, not a fault")

    sup = Supervisor(
        SupervisorCfg(checkpoint_dir=str(tmp_path), max_retries=3),
        step_fn,
        {"w": jnp.zeros(2)},
        on_fatal=fatals.append,
    )
    with pytest.raises(ValueError):
        sup.run_step({})
    assert calls["n"] == 1  # exactly one attempt
    assert len(fatals) == 1 and isinstance(fatals[0], ValueError)


def test_supervisor_transient_fault_retried_in_place(tmp_path):
    """``TransientFaultError`` is raised before any side effect, so the
    supervisor re-attempts without rolling back (state stays current)."""
    from repro.faults import TransientFaultError

    calls = {"n": 0}

    def step_fn(state, batch):
        calls["n"] += 1
        if calls["n"] == 2:
            raise TransientFaultError("injected device hiccup")
        return {"w": state["w"] + 1}, {"loss": jnp.sum(state["w"])}

    sup = Supervisor(
        SupervisorCfg(
            checkpoint_dir=str(tmp_path), checkpoint_every=1,
            nan_check_every=1, backoff_base_s=0.001, backoff_cap_s=0.01,
        ),
        step_fn,
        {"w": jnp.zeros(2)},
    )
    sup.run_step({})
    sup.manager.wait()
    rep = sup.run_step({})  # transient on attempt 1, clean on attempt 2
    assert rep.restarted and rep.step == 2 and calls["n"] == 3
    # no rollback happened: state advanced past the checkpointed step 1
    np.testing.assert_array_equal(np.asarray(sup.state["w"]), np.full(2, 2.0))
