"""Per-architecture smoke tests + decode/prefill consistency (deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import smoke_cfg
from repro.compat import tree_flatten_with_path
from repro.models import transformer as T
from repro.models.registry import ARCHITECTURES, get_config

ALL_ARCHS = sorted(ARCHITECTURES)


def _batch(cfg, rng, b=2, s=16, extra_tok=0):
    if cfg.embed_inputs:
        toks = jax.random.randint(rng, (b, s + extra_tok), 0, cfg.vocab_size)
        out = {"tokens": toks[:, : s + extra_tok]}
    else:
        out = {
            "embeds": jax.random.normal(
                rng, (b, s + extra_tok, cfg.d_model), dtype=jnp.dtype(cfg.compute_dtype)
            )
        }
    out["labels"] = jax.random.randint(rng, (b, s + extra_tok), 0, cfg.vocab_size)
    return out


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch, rng):
    """Reduced config: one forward + one train step, shapes + finiteness."""
    cfg = smoke_cfg(arch)
    params = T.init_params(cfg, rng)
    b, s = 2, 16
    batch = _batch(cfg, rng, b, s)
    logits, aux = T.forward(cfg, params, batch)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    from repro.optim.optimizers import adamw

    opt = adamw(lr=1e-3)
    state = opt.init(params)
    loss, metrics = T.loss_fn(cfg, params, batch)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: T.loss_fn(cfg, p, batch)[0])(params)
    new_params, _ = opt.update(params, grads, state)
    # parameters actually moved and stayed finite
    moved = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(a - b_))), params, new_params)
    assert max(jax.tree.leaves(moved)) > 0
    finite = jax.tree.map(lambda a: bool(jnp.isfinite(a).all()), new_params)
    assert all(jax.tree.leaves(finite))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_matches_forward(arch, rng):
    """prefill(s) + decode(1) must equal forward(s+1) — exercises every cache
    type (full KV, ring SWA, grouped local:global, SSM conv+state)."""
    cfg = smoke_cfg(arch)
    params = T.init_params(cfg, rng)
    b, s = 2, 12
    full = _batch(cfg, rng, b, s, extra_tok=1)
    if cfg.embed_inputs:
        pre = {"tokens": full["tokens"][:, :s]}
        dec = {"tokens": full["tokens"][:, s : s + 1]}
    else:
        pre = {"embeds": full["embeds"][:, :s]}
        dec = {"embeds": full["embeds"][:, s : s + 1]}
    logits_full, _ = T.forward(cfg, params, full)
    logits_pre, cache = T.prefill(cfg, params, pre, max_len=s + 8)
    np.testing.assert_allclose(
        np.asarray(logits_full[:, :s]), np.asarray(logits_pre), rtol=3e-4, atol=3e-4
    )
    logits_dec, cache = T.decode_step(cfg, params, cache, dec)
    np.testing.assert_allclose(
        np.asarray(logits_full[:, s]), np.asarray(logits_dec), rtol=3e-4, atol=3e-4
    )
    assert int(cache["pos"]) == s + 1


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_specs_match_shapes(arch):
    """The PartitionSpec tree must mirror the parameter tree exactly and only
    shard divisible dims (GSPMD padding never needed)."""
    cfg = get_config(arch)  # FULL config: this is what the dry-run shards
    shapes = T.param_shapes(cfg)
    mesh_axes = {"pod": 2, "data": 16, "model": 16}
    specs = T.param_pspecs(cfg, mesh_axes, data_axes=("pod", "data"))
    flat_shapes = tree_flatten_with_path(shapes, is_leaf=lambda s: isinstance(s, tuple))[0]
    sh_map = {tuple(p): v for p, v in flat_shapes}
    sp_flat = tree_flatten_with_path(
        specs, is_leaf=lambda s: s.__class__.__name__ == "PartitionSpec"
    )[0]
    assert len(sh_map) == len(sp_flat)
    for path, spec in sp_flat:
        shape = sh_map[tuple(path)]
        assert len(spec) <= len(shape), (path, spec, shape)
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            size = 1
            for a in axes:
                size *= mesh_axes[a]
            assert shape[dim] % size == 0, (path, spec, shape, dim)


def test_param_count_matches_init():
    for arch in ALL_ARCHS:
        cfg = smoke_cfg(arch)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        n = sum(int(np.prod(leaf.shape)) for leaf in jax.tree.leaves(params))
        assert n == cfg.param_count(), arch


def test_stage_split_merge_roundtrip(rng):
    cfg = smoke_cfg("h2o-danube-1.8b", num_layers=4)
    params = T.init_params(cfg, rng)
    stages = T.split_stage_params(cfg, params, [0, 1, 3, 4])
    merged = T.merge_stage_params(cfg, stages)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(merged)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_stage_forward_composes_to_forward(rng):
    cfg = smoke_cfg("h2o-danube-1.8b", num_layers=4)
    params = T.init_params(cfg, rng)
    batch = _batch(cfg, rng, 2, 8)
    bounds = [0, 2, 4]
    stages = T.split_stage_params(cfg, params, bounds)
    x = None
    for j in range(2):
        x, _ = T.stage_forward(cfg, stages[j], x, j, 2, bounds, batch)
    ref, _ = T.forward(cfg, params, batch)
    np.testing.assert_allclose(np.asarray(x), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_moe_dispatch_drops_only_over_capacity():
    from repro.models.layers import moe_dispatch

    ids = jnp.asarray([[0], [0], [0], [1], [1], [2], [0], [0]], dtype=jnp.int32)
    dest, keep, order = moe_dispatch(ids, num_experts=4, capacity=3)
    # expert 0 got 5 tokens, capacity 3 -> exactly 2 dropped
    assert int(keep.sum()) == 6
    kept_dest = dest[keep]
    assert int(jnp.max(kept_dest)) < 4 * 3
    # destinations unique for kept tokens
    assert len(set(np.asarray(kept_dest).tolist())) == 6


def test_long_500k_applicability_flags():
    from repro.configs.common import SHAPES, shape_applicable

    runnable = {a for a in ALL_ARCHS if shape_applicable(get_config(a), SHAPES["long_500k"])}
    assert runnable == {
        "mamba2-780m", "h2o-danube-1.8b", "gemma3-12b", "hymba-1.5b", "mixtral-8x22b"
    }
