from repro.data.pipeline import DataPipeline, PipelineCfg, TokenStreamSource

__all__ = ["DataPipeline", "PipelineCfg", "TokenStreamSource"]
