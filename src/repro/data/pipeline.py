"""Host data pipeline: sources → shard-aware batching → background prefetch.

OCL semantics drive the design: items arrive continuously; the pipeline
never blocks the training loop (a bounded queue + drop-oldest policy is the
data-plane half of the paper's admission control), and every emitted batch
carries its arrival timestamp so the trainer can compute per-item delays
r^t for the adaptation-rate metric.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineCfg:
    batch: int
    seq: int
    prefetch: int = 4  # bounded queue depth
    drop_policy: str = "oldest"  # oldest | newest | block
    shard_index: int = 0  # this host's data shard
    num_shards: int = 1
    seed: int = 0


class TokenStreamSource:
    """Deterministic synthetic token source (shard-aware, resumable).

    Produces drifting-Markov token sequences (see repro.ocl.streams for the
    generator used by benchmarks); resumable via an integer cursor so
    checkpoint/restart replays exactly-once.
    """

    def __init__(self, vocab: int, cfg: PipelineCfg, drift_rate: float = 0.0):
        self.vocab = vocab
        self.cfg = cfg
        self.drift_rate = drift_rate
        self.cursor = 0

    def seek(self, cursor: int) -> None:
        self.cursor = cursor

    def next_batch(self) -> Dict[str, np.ndarray]:
        c = self.cfg
        # fold the cursor + shard into the seed: reproducible & disjoint
        rng = np.random.default_rng(
            (c.seed * 1_000_003 + self.cursor) * c.num_shards + c.shard_index
        )
        toks = rng.integers(0, self.vocab, size=(c.batch, c.seq + 1), dtype=np.int64)
        # simple drifting bias so later cursors have shifted distribution
        if self.drift_rate:
            shift = int(self.cursor * self.drift_rate) % self.vocab
            toks = (toks + shift) % self.vocab
        self.cursor += 1
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
            "_cursor": np.asarray(self.cursor - 1, np.int64),
            "_arrival": np.asarray(time.time(), np.float64),
        }


class DataPipeline:
    """Background-thread prefetcher with bounded queue + admission policy."""

    def __init__(self, source, cfg: PipelineCfg):
        self.source = source
        self.cfg = cfg
        self._q: "queue.Queue" = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._dropped = 0
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle ---------------------------------------------------------
    def start(self) -> "DataPipeline":
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5)

    # -- worker --------------------------------------------------------------
    def _worker(self) -> None:
        while not self._stop.is_set():
            batch = self.source.next_batch()
            if self.cfg.drop_policy == "block":
                while not self._stop.is_set():
                    try:
                        self._q.put(batch, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                continue
            try:
                self._q.put_nowait(batch)
            except queue.Full:
                self._dropped += 1
                if self.cfg.drop_policy == "oldest":
                    try:
                        self._q.get_nowait()  # discard stalest
                        self._q.put_nowait(batch)
                    except (queue.Empty, queue.Full):
                        pass
                # 'newest': drop the incoming batch (already counted)

    # -- consumer ------------------------------------------------------------
    def get(self, timeout: float = 10.0) -> Dict[str, np.ndarray]:
        return self._q.get(timeout=timeout)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.get()

    @property
    def dropped(self) -> int:
        return self._dropped
