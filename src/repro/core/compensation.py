"""Gradient compensation for stale gradients (paper §5.1.2, Alg. 1).

The flagship algorithm is **Iter-Fisher**: iterative first-order Taylor
compensation with a diagonal-Fisher Hessian proxy and an online-optimized
global λ (Eq. 8–12). Baselines from Table 4 are included:

- ``none``        : use the stale gradient as-is (zero-order)
- ``step_aware``  : shrink the step by 1/(τ+1)            [33, 41]
- ``gap_aware``   : per-parameter penalty by the weight gap [7]
- ``fisher``      : one-shot Fisher compensation with the *total* Δθ [14, 85]
- ``iter_fisher`` : Alg. 1 (ours)

All functions operate on parameter pytrees; the elementwise hot loops are
Pallas kernels on TPU (``repro.kernels``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops

Pytree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CompensationState:
    """λ and its EMA statistics (paper: v_r, v_a; space 2·Σ|w|)."""

    lam: jax.Array  # scalar float32
    v_r: Pytree  # EMA of gradients       (E_k ∇L)
    v_a: Pytree  # EMA of g⊙g⊙Δθ          (the λ-feature F)
    steps: jax.Array  # scalar int32


@dataclasses.dataclass(frozen=True)
class CompensationConfig:
    method: str = "iter_fisher"  # none|step_aware|gap_aware|fisher|iter_fisher
    lam0: float = 0.2  # paper §12: λ = 0.2
    alpha: float = 0.9  # EMA coefficient
    eta_lambda: float = 1e-3  # λ learning rate (0 disables auto-tuning: fixed λ)
    nu: float = 2e-6  # ℓ2 regularizer on λ (paper's μ)


def init_state(params: Pytree, cfg: CompensationConfig) -> CompensationState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    if cfg.eta_lambda == 0.0:
        # Fixed-λ mode (paper: η_λ = 0 frees v_r/v_a) — keep empty pytrees.
        zeros = jax.tree.map(lambda p: jnp.zeros((0,), dtype=jnp.float32), params)
    return CompensationState(
        lam=jnp.asarray(cfg.lam0, jnp.float32),
        v_r=zeros,
        v_a=jax.tree.map(jnp.copy, zeros),
        steps=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Iter-Fisher (Alg. 1)
# ---------------------------------------------------------------------------


def _update_lambda(
    state: CompensationState, grad: Pytree, first_delta: Pytree, cfg: CompensationConfig
) -> CompensationState:
    """Alg. 1 lines 3–7: one λ-descent step + EMA updates (global λ).

    The whole pytree goes through one packed statistics pass
    (``repro.kernels.packing``); s1/s2 accumulate as on-device scalars on
    every path — no per-leaf host round-trips.
    """
    new_vr, new_va, s1_total, s2_total = ops.iter_fisher_stats_tree(
        grad, first_delta, state.v_r, state.v_a, cfg.alpha
    )
    grad_lam = -2.0 * s1_total + 2.0 * state.lam * s2_total + 2.0 * cfg.nu * state.lam
    new_lam = state.lam - cfg.eta_lambda * grad_lam
    return CompensationState(
        lam=new_lam,
        v_r=new_vr,
        v_a=new_va,
        steps=state.steps + 1,
    )


def compensate(
    cfg: CompensationConfig,
    state: CompensationState,
    grad: Pytree,
    deltas: Pytree,  # stacked (K, ...) per leaf: θ^{t+i} − θ^{t+i-1}, oldest first
    lr: float = 1e-3,
    tau: Optional[jax.Array] = None,  # traced staleness; default: K (static)
) -> Tuple[CompensationState, Pytree]:
    """Compensate a gradient that is ≤ K versions stale.

    The stacked ``deltas`` axis is oldest→newest; entries beyond the true
    staleness must be zero (a zero Δθ is the identity for every method
    except step_aware, which takes ``tau`` explicitly).
    Returns (new_state, compensated_grad). K = 0 is a no-op.
    """
    method = cfg.method
    K = jax.tree.leaves(deltas)[0].shape[0] if jax.tree.leaves(deltas) else 0

    if method == "none" or K == 0:
        return state, grad

    if tau is None:
        tau = jnp.asarray(float(K), jnp.float32)

    if method == "step_aware":
        scale = 1.0 / (1.0 + tau.astype(jnp.float32))
        return state, jax.tree.map(lambda g: (g * scale).astype(g.dtype), grad)

    if method == "gap_aware":
        # Barkai et al.: divide by the per-parameter gap 1 + |Δθ_total| / η.
        def leaf(g, d):
            total = jnp.sum(d.astype(jnp.float32), axis=0)
            gap = 1.0 + jnp.abs(total) / jnp.maximum(lr, 1e-12)
            return (g.astype(jnp.float32) / gap).astype(g.dtype)

        return state, jax.tree.map(leaf, grad, deltas)

    if method == "fisher":
        # One-shot: g + λ g⊙g⊙(θ^{t+τ} − θ^t); fixed λ, no iteration, no tuning.
        def leaf(g, d):
            total = jnp.sum(d.astype(jnp.float32), axis=0)
            g32 = g.astype(jnp.float32)
            return (g32 + cfg.lam0 * g32 * g32 * total).astype(g.dtype)

        return state, jax.tree.map(leaf, grad, deltas)

    if method == "iter_fisher":
        if cfg.eta_lambda > 0.0:
            # Alg. 1 lines 3–7 use the most recent version step (θ^t − θ^{t-1}).
            last_delta = jax.tree.map(lambda d: d[-1], deltas)
            state = _update_lambda(state, grad, last_delta, cfg)
        # One flat-packed pass for the whole pytree (1 kernel launch on the
        # Pallas path regardless of leaf count).
        comp = ops.iter_fisher_compensate_tree(grad, deltas, state.lam)
        return state, comp

    raise ValueError(f"unknown compensation method {method!r}")


# ---------------------------------------------------------------------------
# Reference check utility (used by tests): exact gradient on quadratic loss
# ---------------------------------------------------------------------------


def quadratic_true_gradient(H: jax.Array, theta: jax.Array, b: jax.Array) -> jax.Array:
    """∇L for L(θ) = ½ θᵀHθ − bᵀθ, the closed-form testbed for compensation."""
    return H @ theta - b
