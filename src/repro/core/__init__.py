"""The paper's primary contribution — Ferret's core systems.

- profiler:       per-layer t^f/t^b/|w|/|a| profile (analytic TPU roofline)
- cost_model:     Eq. 3 (adaptation rate R_F), Eq. 4 (memory M_F), Eq. 19-22 deltas
- planner:        Alg. 2 iterative configuration search + Alg. 3 brute-force planning
- compensation:   Alg. 1 Iter-Fisher (+ Step-Aware / Gap-Aware / Fisher baselines)
- pipeline:       fine-grained asynchronous 1F1B engine with T1-T4 semantics
- ferret:         the top-level trainer tying everything together
"""
