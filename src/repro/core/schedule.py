"""Static schedule construction for the fine-grained pipeline engine.

The paper's asynchronous 1F1B pipeline has a *deterministic* schedule once
(L, C) are fixed: which arriving item is admitted (worker interleave /
removal, T4), which stages back-propagate it (omission, T3), when each
stage's (possibly accumulated, T2) gradient is applied, and how stale —
in stage-update counts — that gradient is at application time.

We precompute all of it here as numpy arrays. The jit'd engine
(`repro.core.pipeline`) then consumes the arrays as `lax.scan` xs: control
flow never depends on traced values, and the learning dynamics exactly
follow the paper's staleness model (∇L(D^t;θ^t) applied at θ^{t+τ},
Fig. 9, with τ_j = P-1-j for stage j, scaled by the worker interleave).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.cost_model import PipelineConfig

RING = "ring"  # sentinel docs


@dataclasses.dataclass(frozen=True)
class RingGeometry:
    """Per-stage ring depths a schedule's engine state is shaped for.

    Depends only on the pipeline config and stage count — *not* on the
    partition bounds or the number of rounds — which is what makes
    cross-partition ring remapping well-defined: two plans with equal
    ``(config, num_stages)`` share one geometry (and one schedule), so
    ring contents can move between their partitions slot-for-slot
    (``repro.state.StateRemapper``).
    """

    ring_size: int  # gradient-accumulation ring slots per stage
    delta_ring: int  # Δθ ring depth per stage (max staleness window)


def ring_geometry(
    config: PipelineConfig, num_stages: int, sync_period: Optional[int] = None
) -> RingGeometry:
    """Ring geometry for ``(config, num_stages)`` — the single source of
    truth ``build_schedule`` (and every remap/checkpoint/drain path)
    shapes ring arrays from."""
    if sync_period is not None:
        return RingGeometry(ring_size=1, delta_ring=1)
    P = num_stages
    tau_max = P - 1  # τ_j = P-1-j, maximized at stage 0
    max_accum = max(
        (s.accum for w in config.workers for s in w.stages), default=1
    )
    # gradient stays in its ring slot for ≤ N·(c_a-1) rounds of filling plus
    # N·τ_j rounds of delay; slots are recycled round-robin per stage.
    ring_size = int(2 + (tau_max if P > 1 else 0) + max_accum)
    delta_ring = int(max(tau_max + 1, 1))
    return RingGeometry(ring_size=ring_size, delta_ring=delta_ring)


@dataclasses.dataclass
class EngineSchedule:
    """All arrays indexed [round] or [round, stage]."""

    num_rounds: int
    num_stages: int
    ring_size: int  # gradient-accumulation ring slots per stage
    delta_ring: int  # Δθ ring depth per stage (max staleness)

    process: np.ndarray  # (R,) bool   — item admitted (worker not removed)
    backward: np.ndarray  # (R, P) bool — stage back-propagates this item (T3)
    push_slot: np.ndarray  # (R, P) int  — grad ring slot to accumulate into (-1: none)
    push_reset: np.ndarray  # (R, P) bool — first grad of its accumulation group
    pop_slot: np.ndarray  # (R, P) int  — grad ring slot to apply (-1: none)
    pop_scale: np.ndarray  # (R, P) f32  — 1/c^a normalization at apply time
    delta_mask: np.ndarray  # (R, P, K) f32 — which stacked Δθ entries are "live"
    delta_push_slot: np.ndarray  # (R, P) int — Δθ ring slot written on apply (-1: none)
    tau: np.ndarray  # (R, P) int — staleness (stage updates) at apply
    # (R,) bool — False only for bucket-padding rounds (pad_schedule): the
    # engine skips the forward/backward entirely, not just the masked
    # apply. None means all-true (every real schedule).
    compute: Optional[np.ndarray] = None

    def stats(self) -> dict:
        return {
            "admitted": int(self.process.sum()),
            "updates": int((self.pop_slot >= 0).sum()),
            "mean_tau": float(self.tau[self.pop_slot >= 0].mean())
            if (self.pop_slot >= 0).any()
            else 0.0,
        }


def build_schedule(
    config: PipelineConfig,
    num_stages: int,
    num_rounds: int,
    sync_period: Optional[int] = None,
    phase: int = 0,
    warmup: int = 0,
) -> EngineSchedule:
    """Builds the engine schedule for a pipeline configuration.

    sync_period: if set, emulate a *synchronous* pipeline instead — every
    stage accumulates `sync_period` items and applies a fresh (τ=0) update
    at the group boundary (DAPPLE/GPipe-style flushes). Ferret's async
    schedule is `sync_period=None`.

    phase: global round index of this schedule's first round. A segmented
    run (runtime/elastic_trainer.py) passes the stream cursor so the worker
    interleave — and hence the T4 admission pattern — continues seamlessly
    across segment boundaries instead of restarting at worker 0.

    warmup: number of rounds to *simulate* before the ``num_rounds``
    emitted rounds (``phase`` then addresses the first simulated round).
    The result equals rows ``[warmup:warmup+num_rounds)`` of one big
    build, so in-flight accumulation groups, ring slots, staleness
    counters and pending pops continue exactly across a segment boundary —
    provided the engine's gradient/Δθ rings are carried over too
    (runtime/elastic_trainer.py does this for same-structure segments).
    O(warmup) extra host work.
    """
    if warmup:
        full = build_schedule(
            config, num_stages, warmup + num_rounds,
            sync_period=sync_period, phase=phase,
        )
        return slice_schedule(full, warmup)
    P = num_stages
    R = num_rounds
    workers = config.workers
    N = max(len(workers), 1)

    taus = np.array([P - 1 - j for j in range(P)], dtype=np.int64)

    process = np.zeros(R, dtype=bool)
    backward = np.zeros((R, P), dtype=bool)
    push_slot = -np.ones((R, P), dtype=np.int32)
    push_reset = np.zeros((R, P), dtype=bool)
    pop_slot = -np.ones((R, P), dtype=np.int32)
    pop_scale = np.zeros((R, P), dtype=np.float32)
    tau_arr = np.zeros((R, P), dtype=np.int32)
    delta_push_slot = -np.ones((R, P), dtype=np.int32)

    if sync_period is not None:
        K = max(int(sync_period), 1)
        geom = ring_geometry(config, P, sync_period)
        ring_size, delta_ring = geom.ring_size, geom.delta_ring
        for m in range(R):
            process[m] = True
            backward[m, :] = True
            push_slot[m, :] = 0
            push_reset[m, :] = (m % K) == 0
            if (m % K) == K - 1:
                pop_slot[m, :] = 0
                pop_scale[m, :] = 1.0 / K
                delta_push_slot[m, :] = 0
        delta_mask = np.zeros((R, P, delta_ring), dtype=np.float32)
        return EngineSchedule(
            R, P, ring_size, delta_ring, process, backward, push_slot, push_reset,
            pop_slot, pop_scale, delta_mask, delta_push_slot, tau_arr,
        )

    # ---- asynchronous fine-grained schedule (Ferret) ----
    geom = ring_geometry(config, P)
    ring_size, delta_ring = geom.ring_size, geom.delta_ring

    # Per-(worker, stage) running state during construction.
    seen = np.zeros((N, P), dtype=np.int64)  # worker-local item count
    grp_count = np.zeros((N, P), dtype=np.int64)  # grads accumulated in open group
    grp_slot = -np.ones((N, P), dtype=np.int64)  # open group's ring slot
    next_slot = np.zeros(P, dtype=np.int64)  # per-stage round-robin slot counter

    upd_count = np.zeros(P, dtype=np.int64)  # total updates applied per stage
    # pending pops: list per round of (stage, slot, scale, upd_count_at_enqueue)
    pending = [[] for _ in range(R)]

    for m in range(R):
        w = (m + phase) % N
        worker = workers[w]
        if worker.removed:
            continue
        process[m] = True
        for j in range(P):
            knobs = worker.stages[j]
            k_local = seen[w, j]
            seen[w, j] += 1
            if k_local % (knobs.omit + 1) != 0:
                continue  # T3: omitted backward
            backward[m, j] = True
            if grp_count[w, j] == 0:
                grp_slot[w, j] = next_slot[j] % ring_size
                next_slot[j] += 1
                push_reset[m, j] = True
            push_slot[m, j] = grp_slot[w, j]
            grp_count[w, j] += 1
            if grp_count[w, j] >= knobs.accum:
                # group complete: schedule the apply after the pipeline delay
                pop_round = m + int(N * taus[j])
                if pop_round < R:
                    pending[pop_round].append(
                        (j, int(grp_slot[w, j]), 1.0 / knobs.accum, m)
                    )
                grp_count[w, j] = 0
                grp_slot[w, j] = -1

        # apply any pops scheduled for this round (computed below via second loop)

    # Second pass: walk rounds again to resolve pops in order and track
    # per-stage update counts for staleness + Δθ ring slots.
    upd_at_round = np.zeros((R + 1, P), dtype=np.int64)
    delta_mask = np.zeros((R, P, delta_ring), dtype=np.float32)
    upd_count[:] = 0
    # Record at push-completion time the stage's update count; staleness at
    # pop = upd_count_then − upd_count_at_push.
    for m in range(R):
        for (j, slot, scale, m_push) in pending[m]:
            if pop_slot[m, j] >= 0:
                # Two groups of the same stage landing on one round cannot
                # happen: group completions per worker are ≥ N·c_a apart and
                # delays are worker-uniform. Guard anyway.
                raise RuntimeError("schedule conflict: two pops in one round")
            pop_slot[m, j] = slot
            pop_scale[m, j] = scale
            tau = int(upd_count[j] - upd_at_round[m_push, j])
            tau = min(tau, delta_ring)
            tau_arr[m, j] = tau
            # stacked Δθ given to the compensator is ordered oldest→newest in
            # the last `delta_ring` updates; mask the most recent `tau`.
            if tau > 0:
                delta_mask[m, j, delta_ring - tau :] = 1.0
            delta_push_slot[m, j] = int(upd_count[j] % delta_ring)
            upd_count[j] += 1
        upd_at_round[m + 1] = upd_count
    return EngineSchedule(
        R, P, ring_size, delta_ring, process, backward, push_slot, push_reset,
        pop_slot, pop_scale, delta_mask, delta_push_slot, tau_arr,
    )


def slice_schedule(
    s: EngineSchedule, start: int, end: Optional[int] = None
) -> EngineSchedule:
    """Rows ``[start:end)`` of a schedule (ring geometry unchanged).

    Construction is causal, so slicing one big build is exactly the
    continuation semantics: pushes before ``start`` whose pops land inside
    the window fire here (the engine's carried rings hold their partial
    groups), and pops landing beyond ``end`` fire in a later slice.
    """
    end = s.num_rounds if end is None else end
    return EngineSchedule(
        num_rounds=end - start,
        num_stages=s.num_stages,
        ring_size=s.ring_size,
        delta_ring=s.delta_ring,
        process=s.process[start:end],
        backward=s.backward[start:end],
        push_slot=s.push_slot[start:end],
        push_reset=s.push_reset[start:end],
        pop_slot=s.pop_slot[start:end],
        pop_scale=s.pop_scale[start:end],
        delta_mask=s.delta_mask[start:end],
        delta_push_slot=s.delta_push_slot[start:end],
        tau=s.tau[start:end],
        compute=None if s.compute is None else s.compute[start:end],
    )


def pad_schedule(s: EngineSchedule, num_rounds: int) -> EngineSchedule:
    """Extend to ``num_rounds`` with inert rounds (nothing admitted, no
    push, no pop), which are the identity on engine state.

    This is what lets the elastic trainer pad segment lengths up to a
    small bucket set and reuse one compiled scan for many segment lengths:
    the first ``s.num_rounds`` rows are untouched, the padded tail leaves
    the carry unchanged, and per-round outputs for padded rounds are
    sliced off by the caller. Padded rounds carry ``compute=False``, so
    the engine skips their forward/backward entirely — bucket padding
    costs one ``lax.cond`` branch per round, not redundant model compute.
    """
    pad = num_rounds - s.num_rounds
    if pad <= 0:
        return s
    P, K = s.num_stages, s.delta_ring

    def cat(a, fill):
        ext = np.full((pad,) + a.shape[1:], fill, dtype=a.dtype)
        return np.concatenate([np.asarray(a), ext], axis=0)

    compute = s.compute if s.compute is not None else np.ones(s.num_rounds, bool)
    return EngineSchedule(
        num_rounds=num_rounds,
        num_stages=P,
        ring_size=s.ring_size,
        delta_ring=K,
        process=cat(s.process, False),
        backward=cat(s.backward, False),
        push_slot=cat(s.push_slot, -1),
        push_reset=cat(s.push_reset, False),
        pop_slot=cat(s.pop_slot, -1),
        pop_scale=cat(s.pop_scale, 0.0),
        delta_mask=cat(s.delta_mask, 0.0),
        delta_push_slot=cat(s.delta_push_slot, -1),
        tau=cat(s.tau, 0),
        compute=cat(compute, False),
    )
