"""Layer profiling: per-layer forward/backward time, weight and activation sizes.

The paper profiles wall-clock per layer on the target GPU (appendix Alg. 3,
``profile(θ)``). This container has no TPU, so the default profile is
*analytic*: per-layer FLOPs and bytes are derived from the architecture
config and converted to time with the TPU-v5e roofline
(t = max(flops / (util · peak), bytes / hbm_bw)). A measured profile
(timing real CPU executions of single blocks) is also provided for the
small benchmark models and can override the analytic one.
"""

from __future__ import annotations

import dataclasses
from typing import List

from repro.models.config import ModelConfig

# TPU v5e hardware constants (per chip) — also used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
DEFAULT_UTILIZATION = 0.55  # achievable fraction of peak for dense matmul


@dataclasses.dataclass(frozen=True)
class LayerProfile:
    """One model layer (block) as seen by the planner."""

    t_fwd: float  # seconds, forward
    t_bwd: float  # seconds, backward
    w_bytes: int  # parameter bytes |ŵ_i|
    a_bytes: int  # boundary activation bytes |â_i| (stage input/output)
    a_internal_bytes: int  # intra-layer activations recomputable under T1


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    layers: List[LayerProfile]
    embed_bytes: int  # embedding + head parameter bytes (stage 0 / last stage)
    batch: int
    seq: int
    # where the numbers came from: "analytic" (roofline), "measured"
    # (harness wall-clock), or "online" (measured + segment feedback)
    provenance: str = "analytic"

    @property
    def num_layers(self) -> int:
        return len(self.layers)

    def total_w(self) -> int:
        return sum(ly.w_bytes for ly in self.layers)


def _block_flops_per_token(cfg: ModelConfig, seq: int) -> float:
    """Forward FLOPs per token for one block (matmul-dominated, 2·m·n·k)."""
    d, ff = cfg.d_model, cfg.d_ff
    hd = cfg.resolved_head_dim
    f = 0.0
    if cfg.uses_attention:
        q, kv = cfg.num_heads * hd, cfg.num_kv_heads * hd
        f += 2.0 * d * (q + 2 * kv + q)  # qkv + out projections (wq,wk,wv,wo)
        # score/value matmuls against effective context length
        kinds = cfg.layer_kinds()
        w0 = cfg.window_for_kind(kinds[0])
        ctx = min(seq, w0) if w0 is not None else seq
        f += 2.0 * 2.0 * cfg.num_heads * hd * (ctx / 2.0)  # causal: avg ctx/2
    if cfg.uses_ssm:
        di, n, nh, ph = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
        f += 2.0 * d * (2 * di + 2 * n + nh)  # z/x/B/C/dt projections
        f += 2.0 * di * d  # out projection
        # SSD: intra-chunk (Q per token) + state update (n per channel)
        Q = cfg.ssm_chunk
        f += 2.0 * nh * ph * Q  # C·B^T ⊙ L intra-chunk (amortized per token)
        f += 4.0 * di * n  # state update + output contraction
    if ff > 0:
        active = cfg.experts_per_token if cfg.uses_moe else 1
        f += 2.0 * 3.0 * d * ff * active
        if cfg.uses_moe:
            f += 2.0 * d * cfg.num_experts  # router
    return f


def _block_w_bytes(cfg: ModelConfig, dtype_bytes: int = 4) -> int:
    total = cfg.param_count()
    embed = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    per_layer = (total - embed - cfg.d_model) // cfg.num_layers
    return per_layer * dtype_bytes


def _block_a_bytes(cfg: ModelConfig, batch: int, seq: int, dtype_bytes: int = 2) -> int:
    """Boundary activation bytes per microbatch: (b, s, d)."""
    return batch * seq * cfg.d_model * dtype_bytes


def _block_a_internal_bytes(cfg: ModelConfig, batch: int, seq: int, dtype_bytes: int = 2) -> int:
    """Intra-block activations that T1 recomputation avoids storing."""
    d, ff = cfg.d_model, cfg.d_ff
    hd = cfg.resolved_head_dim
    per_token = 0
    if cfg.uses_attention:
        per_token += cfg.num_heads * hd + 2 * cfg.num_kv_heads * hd  # q, k, v
        per_token += cfg.num_heads * hd  # attn out pre-proj
    if cfg.uses_ssm:
        per_token += 2 * cfg.d_inner + 2 * cfg.ssm_state + cfg.ssm_heads
        per_token += cfg.d_inner
    if ff > 0:
        active = cfg.experts_per_token if cfg.uses_moe else 1
        per_token += 2 * ff * active + d
    return batch * seq * per_token * dtype_bytes


def analytic_profile(
    cfg: ModelConfig,
    batch: int,
    seq: int,
    utilization: float = DEFAULT_UTILIZATION,
    chips: int = 1,
    param_dtype_bytes: int = 4,
    act_dtype_bytes: int = 2,
) -> ModelProfile:
    """Roofline-derived per-layer profile for a microbatch of (batch, seq)."""
    tokens = batch * seq
    f_fwd = _block_flops_per_token(cfg, seq) * tokens / chips
    w_b = _block_w_bytes(cfg, param_dtype_bytes) // chips
    a_b = _block_a_bytes(cfg, batch, seq, act_dtype_bytes) // chips
    a_int = _block_a_internal_bytes(cfg, batch, seq, act_dtype_bytes) // chips

    def t_of(flops, bytes_moved):
        return max(flops / (utilization * PEAK_FLOPS_BF16), bytes_moved / HBM_BW)

    t_f = t_of(f_fwd, w_b + a_b + a_int)
    t_b = t_of(2.0 * f_fwd, 2 * (w_b + a_b + a_int))
    layers = [LayerProfile(t_f, t_b, w_b, a_b, a_int) for _ in range(cfg.num_layers)]
    embed_bytes = cfg.vocab_size * cfg.d_model * param_dtype_bytes
    if not cfg.tie_embeddings:
        embed_bytes *= 2
    return ModelProfile(layers=layers, embed_bytes=embed_bytes // chips, batch=batch, seq=seq)


def measured_profile(
    cfg: ModelConfig,
    batch: int,
    seq: int,
    repeats: int = 3,
    rng_seed: int = 0,
) -> ModelProfile:
    """Wall-clock profile of a single block on the local backend (paper-style).

    Delegates to the ``repro.profile`` measurement harness — there is one
    timed-execution code path in the repo. Does not read or write the
    profile store; use ``profile_for(..., prefer="measured")`` for the
    cached store-backed resolution.
    """
    from repro.profile.harness import measure_model_profile

    profile, _ = measure_model_profile(
        cfg, batch, seq, repeats=repeats, rng_seed=rng_seed
    )
    return profile


def profile_for(
    cfg: ModelConfig,
    batch: int,
    seq: int,
    prefer: str = "auto",
    chips: int = 1,
) -> ModelProfile:
    """The planner's profile resolution (paper Alg. 3 ``profile(θ)``).

    ``prefer="auto"``: a stored on-device measurement for this (backend,
    model, dtype, geometry) if one exists, else the analytic roofline —
    never measures. ``"measured"``: store hit, else measure-and-persist.
    ``"analytic"``: the roofline unconditionally. The returned profile's
    ``provenance`` records which one happened.
    """
    from repro.profile.bridge import resolve_profile

    return resolve_profile(cfg, batch, seq, prefer=prefer, chips=chips)
