"""Spatial pipeline parallelism: stages mapped onto a mesh axis.

The emulation engine (repro.core.pipeline) reproduces Ferret's *learning
dynamics*; this module executes the pipeline *spatially* the TPU-native
way: each device group along a mesh axis holds one stage's weights, and
activations travel stage→stage with `lax.ppermute` inside a scan over
schedule ticks — the classic GPipe wavefront with P−1 bubble ticks.

Differentiating through the scan gives the reverse wavefront for free
(ppermute's transpose is the reverse permute), so `jax.grad` over
``spatial_pipeline_loss`` IS a spatially-pipelined backward pass; XLA
overlaps the ppermute transfers of tick t+1 with the block compute of
tick t (compute/comm overlap — the same latency-hiding the paper gets
from asynchrony, here inside one SPMD step).

Used by tests/test_stage_parallel.py (8 host devices) and available to the
serving driver for stage-sharded scoring at pod scale.
"""

from __future__ import annotations

from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import pvary, shard_map
from repro.models.config import ModelConfig


def mesh_for_topology(topology, num_stages: int):
    """A 1-D ``("stage",)`` mesh over the topology's model axis.

    The wavefront needs one device group per stage: the topology's model
    axis must span ``num_stages`` devices (build the topology with
    ``discover(model_axis=num_stages)``). This is the model-axis execution
    path of the topology plane — `FerretEngine`'s scan covers the data
    axis, this mesh covers the stage dimension.
    """
    if topology.model_parallel != num_stages:
        raise ValueError(
            f"topology model axis spans {topology.model_parallel} devices "
            f"but the pipeline has {num_stages} stages — discover the "
            f"topology with model_axis={num_stages}"
        )
    import numpy as np

    devices = jax.devices()
    if len(devices) < topology.device_count:
        raise RuntimeError(
            f"topology wants {topology.device_count} devices but only "
            f"{len(devices)} are visible"
        )
    # stage axis varies fastest in the (data, model) mesh layout, so the
    # first `num_stages` devices are exactly data-row 0's stage groups
    arr = np.array(devices[: topology.device_count]).reshape(
        topology.mesh_shape
    )[0]
    return jax.sharding.Mesh(arr, ("stage",))


def stack_stage_blocks(cfg: ModelConfig, params: Dict, num_stages: int) -> Dict:
    """(L, ...) stacked block params -> (P, L/P, ...) stage-stacked."""
    L = cfg.num_layers
    assert L % num_stages == 0, (L, num_stages)
    per = L // num_stages
    return jax.tree.map(
        lambda a: a.reshape(num_stages, per, *a.shape[1:]), params["blocks"]
    )


def _stage_apply(cfg: ModelConfig, stage_blocks: Dict, x: jax.Array, positions) -> jax.Array:
    """Run this device's block slice ((L/P, ...) leading dim) over x."""
    from repro.models.transformer import _block_train

    def body(x, p):
        x, _ = _block_train(cfg, p, x, jnp.int32(cfg.layer_kinds()[0]), positions)
        return x, None

    x, _ = jax.lax.scan(body, x, stage_blocks)
    return x


def spatial_pipeline_logits(
    cfg: ModelConfig,
    params: Dict,
    batch: Dict,
    mesh,
    num_stages: int,
    axis: str = "stage",
) -> jax.Array:
    """Forward the microbatched batch through the spatial pipeline.

    batch['tokens']: (M, b, s) — M microbatches flow down the stage axis;
    the embedding/head run data-parallel outside the pipelined region.
    Returns logits (M, b, s, V).
    """
    from repro.models.layers import embed_tokens, lm_head_logits, rms_norm

    M, b, s = batch["tokens"].shape
    cd = jnp.dtype(cfg.compute_dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x_all = embed_tokens(params["embed"], batch["tokens"], cd)  # (M, b, s, d)
    stage_blocks = stack_stage_blocks(cfg, params, num_stages)

    T = M + num_stages - 1  # wavefront ticks

    @partial(
        shard_map,
        mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(axis), stage_blocks), P(None)),
        out_specs=P(None),
    )
    def run(blocks_local, x_feed):
        # blocks_local leaves: (1, L/P, ...) — this device's stage
        blocks_local = jax.tree.map(lambda a: a[0], blocks_local)
        idx = jax.lax.axis_index(axis)
        last = num_stages - 1
        zero = pvary(jnp.zeros((b, s, cfg.d_model), cd), (axis,))

        def tick(carry, t):
            buf = carry  # activation held by this stage
            # stage 0 injects microbatch t (if in range); others use buf
            feed = jnp.where(t < M, x_feed[jnp.minimum(t, M - 1)], zero)
            x_in = jnp.where(idx == 0, feed, buf)
            y = _stage_apply(cfg, blocks_local, x_in, positions)
            # last stage's finished microbatch index at tick t is t - (P-1)
            out = jnp.where(idx == last, y, zero)
            # pass activations down the pipe (ring; last->0 output is unused)
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % num_stages) for i in range(num_stages)]
            )
            return nxt, out

        _, outs = jax.lax.scan(tick, zero, jnp.arange(T))  # (T, b, s, d)
        # collect the last stage's valid outputs: microbatch m done at tick m+P-1
        outs = jax.lax.psum(outs, axis)  # only the last stage contributed
        return outs[num_stages - 1 :]

    acts = run(stage_blocks, x_all)  # (M, b, s, d)
    acts = rms_norm(acts, params["final_norm"], cfg.norm_eps)
    return lm_head_logits(cfg, params, acts)


def spatial_pipeline_loss(
    cfg: ModelConfig, params: Dict, batch: Dict, mesh, num_stages: int, axis: str = "stage"
) -> jax.Array:
    """Mean CE over all microbatches — differentiable end-to-end; its grad
    is the spatially-pipelined backward wavefront."""
    from repro.models.layers import cross_entropy_loss

    logits = spatial_pipeline_logits(cfg, params, batch, mesh, num_stages, axis)
    return cross_entropy_loss(
        logits.reshape(-1, *logits.shape[2:]),
        batch["labels"].reshape(-1, batch["labels"].shape[-1]),
    )
