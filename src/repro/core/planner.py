"""Model partitioning and pipeline planning (paper §5.2, Alg. 2 + Alg. 3).

Bi-level optimization:
  outer (Alg. 3)  — enumerate stage-time caps t^c from the profile, greedily
                    group consecutive layers into stages, and keep the
                    partition whose inner solution maximizes R_F^T;
  inner (Alg. 2)  — given a partition, progressively deploy T1–T4 by the
                    best ΔM/ΔR ratio until M_F ≤ M.

Both run once, on the host, before the pipeline starts (the paper reports
O(N·P²) for Alg. 2 and O(L̂³) for Alg. 3).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

from repro.core import cost_model as cm
from repro.core.profiler import ModelProfile


@dataclasses.dataclass
class Plan:
    partition: cm.Partition
    config: cm.PipelineConfig
    rate: float
    memory: float
    stats: cm.StageStats
    t_c: float  # chosen stage-time cap
    feasible: bool
    # provenance of the profile this plan was derived from
    # ("analytic" | "measured" | "online")
    profile_provenance: str = "analytic"
    # fingerprint of the DeviceTopology the plan was bounded by (None for
    # the legacy scalar-budget path) — see DeviceTopology.fingerprint()
    topology: Optional[Tuple] = None


# ---------------------------------------------------------------------------
# Alg. 2 — iterative configuration search
# ---------------------------------------------------------------------------


def _initial_config(
    stats: cm.StageStats, t_d: float, c_r: int, max_workers: Optional[int] = None
) -> cm.PipelineConfig:
    """N = ⌈(t^f + t^b + c^r t^f)/t^d⌉ interleaved workers, c_n^d = n."""
    P = len(stats.w)
    step = stats.t_f + stats.t_b + c_r * stats.t_f
    N = max(1, math.ceil(step / t_d))
    if max_workers is not None:
        N = min(N, max_workers)
    workers = [
        cm.WorkerConfig(delay=n, recompute=c_r, stages=[cm.StageKnobs() for _ in range(P)])
        for n in range(N)
    ]
    return cm.PipelineConfig(workers=workers)


def itersearch(
    stats: cm.StageStats,
    t_d: float,
    c_r: int,
    budget: float,
    c: float = 1.0,
    V_D: float = 1.0,
    base_bytes: int = 0,
    max_workers: Optional[int] = None,
) -> Tuple[cm.PipelineConfig, float, float, bool]:
    """Alg. 2 ``itersearch``: greedy T2/T3/T4 deployment until M_F ≤ M.

    Returns (config, R_F, M_F, feasible).
    """
    config = _initial_config(stats, t_d, c_r, max_workers)
    P = len(stats.w)
    mem = cm.memory_footprint(stats, config, base_bytes)

    while mem > budget:
        best = None  # (ratio, n, trial_worker, dR, dM)
        for n, worker in enumerate(config.workers):
            if worker.removed:
                continue
            candidates = []
            for j in range(P):
                r2 = cm.delta_s2(stats, worker, j, c, V_D)
                if r2 is not None:
                    candidates.append(r2)
                r3 = cm.delta_s3(stats, worker, j, c, V_D)
                if r3 is not None:
                    candidates.append(r3)
            r4 = cm.delta_s4(stats, worker, c, V_D)
            if r4 is not None:
                candidates.append(r4)
            for dR, dM, trial in candidates:
                if dM <= 0:
                    continue  # no memory saved — useless move
                ratio = dM / max(dR, 1e-30)
                if best is None or ratio > best[0]:
                    best = (ratio, n, trial, dR, dM)
        if best is None:
            # Nothing else to deploy: infeasible under this budget.
            return config, cm.adaptation_rate(stats, config, c, V_D), mem, False
        _, n, trial, _, _ = best
        config.workers[n] = trial
        mem = cm.memory_footprint(stats, config, base_bytes)

    return config, cm.adaptation_rate(stats, config, c, V_D), mem, True


def search(
    stats: cm.StageStats,
    t_d: float,
    budget: float,
    c: float = 1.0,
    V_D: float = 1.0,
    base_bytes: int = 0,
    max_workers: Optional[int] = None,
) -> Tuple[cm.PipelineConfig, float, float, bool]:
    """Alg. 2 ``search``: S1 evaluated separately (c^r ∈ {0, 1}), keep best R."""
    results = []
    for c_r in (0, 1):
        cfg, rate, mem, ok = itersearch(
            stats, t_d, c_r, budget, c, V_D, base_bytes, max_workers
        )
        results.append((ok, rate, -mem, cfg, mem))
    # Prefer feasible; among those, higher rate; among equal, lower memory.
    results.sort(key=lambda r: (r[0], r[1], r[2]), reverse=True)
    ok, rate, _, cfg, mem = results[0]
    return cfg, rate, mem, ok


# ---------------------------------------------------------------------------
# Alg. 3 — brute-force planning
# ---------------------------------------------------------------------------


def _candidate_caps(profile: ModelProfile) -> List[float]:
    """All contiguous-range sums of (t^f_i + t^b_i) — candidate t^c values."""
    times = [ly.t_fwd + ly.t_bwd for ly in profile.layers]
    caps = set()
    for i in range(len(times)):
        acc = 0.0
        for j in range(i, len(times)):
            acc += times[j]
            caps.add(round(acc, 15))
    return sorted(caps)


def _partition_for_cap(profile: ModelProfile, t_c: float) -> Optional[cm.Partition]:
    """Greedy consecutive grouping (Alg. 3 lines 11–16)."""
    bounds = [0]
    acc = 0.0
    for i, ly in enumerate(profile.layers):
        t = ly.t_fwd + ly.t_bwd
        if t > t_c + 1e-18:
            return None  # single layer exceeds the cap
        if acc + t > t_c + 1e-18:
            bounds.append(i)
            acc = t
        else:
            acc += t
    bounds.append(len(profile.layers))
    if bounds[-2] == bounds[-1]:
        bounds.pop()
    return cm.Partition(tuple(bounds))


def plan(
    profile: ModelProfile,
    t_d: float,
    budget: float,
    c: float = 1.0,
    V_D: float = 1.0,
    include_base: bool = True,
    max_workers: Optional[int] = None,
    max_stages: Optional[int] = None,
    topology=None,
) -> Plan:
    """Alg. 3 ``plan``: enumerate t^c, inner-search each partition, keep best.

    ``topology`` (a ``repro.runtime.topology.DeviceTopology``) bounds the
    plan by what the hardware can actually hold: the effective budget is
    ``min(budget, topology.plan_budget())`` — per-device memory times the
    model-axis span, never the scalar cluster total — and the plan records
    the topology fingerprint it was derived under.
    """
    topo_fp = None
    if topology is not None:
        budget = min(budget, topology.plan_budget())
        topo_fp = topology.fingerprint()
    best: Optional[Plan] = None
    base = profile.embed_bytes if include_base else 0
    seen_partitions = set()
    for t_c in _candidate_caps(profile):
        part = _partition_for_cap(profile, t_c)
        if part is None or tuple(part.bounds) in seen_partitions:
            continue
        seen_partitions.add(tuple(part.bounds))
        if max_stages is not None and part.num_stages > max_stages:
            continue
        stats = cm.stage_stats(profile, part)
        config, rate, mem, ok = search(
            stats, t_d, budget, c, V_D, base_bytes=base, max_workers=max_workers
        )
        cand = Plan(
            part, config, rate, mem, stats, t_c, ok,
            profile_provenance=getattr(profile, "provenance", "analytic"),
            topology=topo_fp,
        )
        if best is None:
            best = cand
            continue
        # feasible beats infeasible; then higher rate; then lower memory
        key = (cand.feasible, cand.rate, -cand.memory)
        best_key = (best.feasible, best.rate, -best.memory)
        if key > best_key:
            best = cand
    assert best is not None, "no candidate partitions (empty profile?)"
    return best


def default_data_interval(profile: ModelProfile) -> float:
    """Paper §12: t^d = max_i t̂_i^f (one layer-forward per arrival)."""
    return max(ly.t_fwd for ly in profile.layers)
