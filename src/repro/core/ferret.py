"""Ferret trainer: plan → schedule → pipeline-execute an OCL stream.

This is the user-facing composition of the paper's three contributions:

    profile = analytic/measured per-layer profile
    plan    = Alg. 3 ∘ Alg. 2  (partition L*, config C* s.t. M_F ≤ M)
    engine  = fine-grained async pipeline with Iter-Fisher compensation

``FerretTrainer.run_stream`` executes a stream and reports online accuracy,
the empirical adaptation rate (Def. 4.1), and the planned memory footprint
(for agm/tagm comparisons). It consumes a ``StreamSource`` incrementally —
segment-by-segment ``take()`` through a ``BufferedStreamSource`` feeder
with background prefetch, per-chunk stream preparation, and O(segment)
peak stream residency; a dict of stacked arrays is wrapped for compat —
and is bit-exact with a single materialized scan (each segment runs a
slice of one causal schedule build with the engine rings carried across
slices). Algorithms with a parameter-space penalty (MAS) apply it inside
the engine via the ``penalty_fn`` hook.

Note: ``FerretTrainer`` / ``sequential_oracle_run`` are the internal
engines behind ``repro.api.FerretSession`` — prefer the session layer for
new code; these entrypoints stay importable for compatibility.
"""

from __future__ import annotations

import dataclasses
import math
import os
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core import compensation as comp_lib
from repro.core import planner as planner_lib
from repro.core import schedule as sched_lib
from repro.core.pipeline import FerretEngine, staged_from_transformer
from repro.core.profiler import ModelProfile, profile_for
from repro.models.config import ModelConfig
from repro.ocl.algorithms import OCLConfig
from repro.ocl.registry import OCLAlgorithm, PrepareContext, get_algorithm
from repro.optim.optimizers import Optimizer, adamw

Pytree = Any


@dataclasses.dataclass(frozen=True)
class FerretConfig:
    budget_bytes: float = math.inf  # M (Ferret_M+ := inf)
    decay_c: float = 1.0  # data-value decay rate c (Def. 4.1)
    data_value: float = 1.0  # V_D
    t_d: Optional[float] = None  # arrival interval; default max_i t̂_i^f (§12)
    lr: float = 1e-3
    max_workers: Optional[int] = 8
    max_stages: Optional[int] = None
    compensation: comp_lib.CompensationConfig = dataclasses.field(
        default_factory=comp_lib.CompensationConfig
    )
    ocl: OCLConfig = dataclasses.field(default_factory=OCLConfig)
    # Online profile refinement: feed observed segment wall-clock back
    # into the profile store (repro.profile.bridge.observe_segment) so
    # replans — and future runs — plan from real numbers. Host-side only;
    # never changes what the engine computes.
    profile_feedback: bool = False


# ---------------------------------------------------------------------------
# Engine compile cache (bucketed segment lengths)
# ---------------------------------------------------------------------------

# The pipelined (single-plan) runner's feeder chunk length: rounds are
# pulled from the stream source this many at a time, so peak stream
# residency is O(segment), and every slice pads to this length so the
# whole run reuses one compiled scan. Override per run with
# run_stream(segment_rounds=...).
DEFAULT_PIPELINE_SEGMENT_ROUNDS = 32

# Geometric bucket set for segment lengths: a segment of n rounds runs a
# compiled scan of the smallest bucket ≥ n (padded with inert schedule
# rounds, which are the identity on engine state), so repeated and A→B→A
# budget switches land on identical shapes and reuse compiled engines.
# Override with REPRO_SEGMENT_BUCKETS="8,16,..." or EngineCache(buckets=...).
DEFAULT_SEGMENT_BUCKETS = (8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096)


def _buckets_from_env() -> Tuple[int, ...]:
    """Bucket ladder precedence: REPRO_SEGMENT_BUCKETS env > the backend's
    autotune record (repro.profile.autotune) > the built-in geometric set."""
    raw = os.environ.get("REPRO_SEGMENT_BUCKETS", "").strip()
    if raw:
        return tuple(sorted(int(tok) for tok in raw.split(",") if tok.strip()))
    try:
        from repro.profile.autotune import tuned_defaults

        tuned = tuned_defaults()
        if tuned.segment_buckets:
            return tuple(sorted(tuned.segment_buckets))
    except Exception:
        pass
    return DEFAULT_SEGMENT_BUCKETS


class IdentityKey:
    """Hashable identity wrapper for cache keys.

    A bare ``id()`` in a long-lived shared cache can alias two objects if
    the first is garbage-collected and its address reused; holding the
    referent pins it for the cache's lifetime, so identity keys stay
    unambiguous.
    """

    __slots__ = ("obj",)

    def __init__(self, obj: Any):
        self.obj = obj

    def __hash__(self) -> int:
        return id(self.obj)

    def __eq__(self, other: Any) -> bool:
        return isinstance(other, IdentityKey) and other.obj is self.obj


class EngineCache:
    """Compiled-engine cache for segmented/elastic runs.

    One ``FerretEngine`` is kept per structure (``struct_key`` = trainer
    scope + stage boundaries); segments reuse it with ``set_schedule`` —
    schedule content is scan *data*, so a same-shape swap reuses the
    engine's compiled scan outright, and ``jax.jit`` keys further compiles
    on array shapes only. ``hits``/``misses`` count compiled-scan reuse at
    the shape level (``compile_key`` = struct_key + ring geometry +
    bucketed rounds + stream shape): the caller checks ``seen`` before a
    segment and ``record``s after it *succeeds*, so aborted segments never
    skew the perf accounting. An A→B→A budget schedule compiles 2 engines
    and hits once.

    Thread-safe: one cache may be shared by concurrent trainers (the
    multi-tenant server path). The internal lock covers the engine map and
    the compile bookkeeping; callers who need ``seen``/``record`` to stay
    truthful across a whole segment additionally serialize execution on
    the shared engine's ``exec_lock`` (see ``FerretEngine``), which also
    protects the engine's mutable schedule.
    """

    def __init__(self, buckets: Optional[Tuple[int, ...]] = None, enabled: bool = True):
        self.buckets = tuple(sorted(buckets)) if buckets else _buckets_from_env()
        self.enabled = enabled
        self._engines: Dict[Tuple, Any] = {}
        self._compiled: set = set()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0

    def bucket_len(self, n: int) -> int:
        """Smallest bucket ≥ n (multiples of the top bucket beyond it)."""
        if not self.enabled:
            return n
        for b in self.buckets:
            if n <= b:
                return b
        top = self.buckets[-1]
        return ((n + top - 1) // top) * top

    def engine_for(self, struct_key: Tuple, factory: Callable[[], Any]) -> Any:
        """The cached engine for ``struct_key`` (built by ``factory`` on
        first use; always fresh when the cache is disabled)."""
        if not self.enabled:
            return factory()
        with self._lock:
            engine = self._engines.get(struct_key)
            if engine is None:
                engine = factory()
                self._engines[struct_key] = engine
            return engine

    def seen(self, compile_key: Tuple) -> bool:
        """Was this shape already compiled (i.e. will the run be a hit)?"""
        with self._lock:
            return self.enabled and compile_key in self._compiled

    def record(self, compile_key: Tuple, hit: bool) -> None:
        """Account one *completed* segment run under ``compile_key``."""
        with self._lock:
            if hit:
                self.hits += 1
            else:
                self.misses += 1
                if self.enabled:
                    self._compiled.add(compile_key)

    @property
    def counts(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses}


@dataclasses.dataclass
class StreamResult:
    online_acc: float
    online_acc_curve: np.ndarray
    losses: np.ndarray
    admitted_frac: float
    memory_bytes: float
    planned_rate: float
    empirical_rate: float
    lam_curve: np.ndarray
    plan: planner_lib.Plan
    rounds: int = 0  # stream rounds consumed (exactly once)
    peak_buffered_rounds: int = 0  # max rounds resident in the feeder
    stream_wait_s: float = 0.0  # un-overlapped time blocked on the source


# ---------------------------------------------------------------------------
# Engine parameter-penalty adapters (shared by the pipelined and elastic
# trainers): an OCLAlgorithm's penalty operates on a params-shaped tree,
# the engine holds per-stage slices — these bridge the two.
# ---------------------------------------------------------------------------


def stage_penalty_fn(algorithm: OCLAlgorithm) -> Optional[Callable]:
    """``algorithm.engine_penalty`` lifted to the engine's per-stage weight
    tuple: evaluated on each stage's slice and summed (the hook's contract
    requires the penalty to decompose over parameter groups)."""
    fn = algorithm.engine_penalty()
    if fn is None:
        return None

    def stage_fn(stages, extras):
        total = jnp.zeros((), jnp.float32)
        for sp, ex in zip(stages, extras):
            total = total + fn(sp, ex)
        return total

    return stage_fn


def split_penalty_extras(
    algorithm: OCLAlgorithm, model_cfg: ModelConfig, bounds
) -> Tuple:
    """The algorithm's current penalty extras, split per pipeline stage.

    Called at every segment boundary — after ``prepare_stream`` /
    ``segment_refresh`` have run, so the extras reflect this segment's
    anchor. Raising (instead of silently running without the penalty) is
    the point: MAS-as-Vanilla was exactly that silent fallback.
    """
    from repro.models import transformer as T

    extras = algorithm.engine_penalty_extras()
    if extras is None:
        raise RuntimeError(
            f"algorithm {algorithm.name!r} declares engine_penalty() but "
            "engine_penalty_extras() is None at segment start — its "
            "prepare_stream/segment_refresh must populate the penalty "
            "state before the engine runs"
        )
    parts = {
        k: T.split_stage_params(model_cfg, v, bounds) for k, v in extras.items()
    }
    P = len(bounds) - 1
    return tuple({k: parts[k][j] for k in parts} for j in range(P))


def empirical_adaptation_rate(
    cfg: FerretConfig, plan: planner_lib.Plan, admitted: np.ndarray, R: int
) -> float:
    """Def. 4.1 empirically: admitted items complete after one full pipeline
    traversal; dropped items contribute 0 (r = ∞)."""
    active = plan.config.active_workers()
    cr = max(w.recompute for w in active) if active else 0
    traversal = plan.partition.num_stages * (
        plan.stats.t_f + plan.stats.t_b + cr * plan.stats.t_f
    )
    contrib = admitted * math.exp(-cfg.decay_c * traversal) * cfg.data_value
    return float(contrib.sum() / max(R, 1))


class FerretTrainer:
    def __init__(
        self,
        model_cfg: ModelConfig,
        ferret_cfg: FerretConfig,
        batch: int,
        seq: int,
        optimizer: Optional[Optimizer] = None,
        profile: Optional[ModelProfile] = None,
        algorithm: Optional[Union[str, OCLAlgorithm]] = None,
        topology=None,
    ):
        from repro.runtime.topology import as_topology

        self.model_cfg = model_cfg
        self.cfg = ferret_cfg
        self.batch = batch
        self.seq = seq
        # Topology-aware execution: a DeviceTopology (or "discover") makes
        # the planner budget per-device-bounded, scales the profile for the
        # data-parallel replicas, and runs the engine scan under the
        # topology's mesh. topology=None — and a trivial 1-device topology —
        # is the exact historical single-device path.
        self.topology = as_topology(topology)
        self.mesh = (
            None
            if self.topology is None or self.topology.is_trivial
            else self.topology.mesh()
        )
        from repro.models import shard_hints as shard_hints_lib

        self.shard_hints = shard_hints_lib.for_topology(self.topology)
        self.algorithm = (
            get_algorithm(algorithm, ferret_cfg.ocl)
            if algorithm is not None
            else get_algorithm(ferret_cfg.ocl)
        )
        # Default resolution is store-aware (Alg. 3 profile(θ)): a persisted
        # on-device measurement for this geometry wins, the analytic
        # roofline is the fallback — identical to the old default when no
        # measurement exists.
        self.profile = profile or profile_for(model_cfg, batch, seq)
        # self.profile stays single-device (so delegating to the elastic
        # trainer never double-scales); the plan sees the topology-scaled
        # view — data-parallel replicas divide times/activations, weights
        # replicate
        eff_profile = self.profile
        if self.topology is not None:
            from repro.profile.bridge import for_topology

            eff_profile = for_topology(self.profile, self.topology)
        t_d = ferret_cfg.t_d or planner_lib.default_data_interval(eff_profile)
        self.t_d = t_d
        self.plan = planner_lib.plan(
            eff_profile,
            t_d,
            ferret_cfg.budget_bytes,
            c=ferret_cfg.decay_c,
            V_D=ferret_cfg.data_value,
            max_workers=ferret_cfg.max_workers,
            max_stages=ferret_cfg.max_stages,
            topology=self.topology,
        )
        self.boundaries = list(self.plan.partition.bounds)
        staged = staged_from_transformer(model_cfg, self.boundaries)
        self.staged = self.algorithm.wrap_staged(staged)
        self.optimizer = optimizer or adamw(lr=ferret_cfg.lr)

    # ------------------------------------------------------------------
    def _prepare_rows(self, rows: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        """The feeder's one-shot transform: per-chunk stream preparation.

        Chunks arrive in stream order and are prepared exactly once, so a
        stateful preparation (ER reservoir mixing) chained over chunks is
        bit-identical to preparing the whole stream at once (PR 4's
        incremental-elastic guarantee, now shared by the pipelined path).
        """
        algo = self.algorithm
        if type(algo).prepare_stream is OCLAlgorithm.prepare_stream:
            return rows  # identity prep: skip the call entirely
        return algo.prepare_stream(rows, self._prep_ctx)

    def run_stream(
        self,
        params: Pytree,
        stream: Union[Dict[str, np.ndarray], "StreamSource"],
        *,
        segment_rounds: Optional[int] = None,
        prefetch: bool = True,
    ) -> StreamResult:
        """Execute a stream through the single-plan pipeline engine.

        stream: a ``StreamSource`` — consumed *incrementally*: rounds are
        pulled ``take(segment_rounds)`` at a time through a
        ``BufferedStreamSource`` feeder, so peak stream residency on host
        and device is O(segment_rounds), never O(R), and unbounded sources
        (``length=None``) run until the feed ends. A dict of ``(R, b,
        ...)`` arrays is accepted for compat (wrapped in an
        ``ArrayStreamSource``; still consumed per segment). Pass *raw*
        rounds — the algorithm's ``prepare_stream`` (replay mixing,
        teacher logits) is applied per pulled chunk, exactly once, in
        stream order, which is bit-identical to whole-stream preparation.

        Each segment runs a slice of one causal schedule build with the
        engine's gradient-accumulation/Δθ rings carried across slices, so
        the chunked run is bit-exact with the materialized single-scan
        run; segments pad to ``segment_rounds`` with inert rounds, so the
        whole run reuses one compiled scan. ``prefetch`` pulls segment
        k+1 on a background thread while segment k computes.

        Algorithms that declare an ``engine_penalty`` (MAS) have their
        parameter-space term applied *inside* the engine — no silent
        Vanilla fallback remains on the pipeline path.
        """
        from repro.api.streams import BufferedStreamSource, coerce_trainer_stream
        from repro.models import transformer as T

        source = coerce_trainer_stream(stream, "FerretTrainer.run_stream")
        seg = int(segment_rounds) if segment_rounds else DEFAULT_PIPELINE_SEGMENT_ROUNDS
        remaining = source.remaining
        R: Optional[int] = None if remaining is None else int(remaining)

        # stream prep anchors at the weights entering the stream, exactly
        # like the materialized whole-stream preparation did
        self._prep_ctx = PrepareContext(
            params=params,
            forward_fn=lambda p, b: T.forward(self.model_cfg, p, b)[0],
        )
        feeder = BufferedStreamSource(
            source, transform=self._prepare_rows, prefetch=prefetch
        )

        P = self.plan.partition.num_stages
        penalty_fn = stage_penalty_fn(self.algorithm)
        penalty = None  # split once after the first chunk anchors it
        engine: Optional[FerretEngine] = None
        full_sched: Optional[sched_lib.EngineSchedule] = None
        stages = T.split_stage_params(self.model_cfg, params, self.boundaries)
        rings = deltas = opt_states = comp_states = None
        cursor = 0
        seg_index = 0
        acc_all: list = []
        loss_all: list = []
        adm_all: list = []
        lam_all: list = []
        try:
            while R is None or cursor < R:
                want = seg if R is None else min(seg, R - cursor)
                rows = feeder.take(want)
                if rows is None:
                    break  # source exhausted
                seg_len = next(iter(rows.values())).shape[0]
                seg_end = cursor + seg_len
                if seg_len < want:
                    R = seg_end  # source ended early: true stream end found
                # one causal build; segments slice it. A bounded stream
                # builds straight to its end; an unknown end grows
                # geometrically — construction is causal, so a longer
                # rebuild is bit-identical on its prefix (the same
                # continuation ``build_schedule(warmup=)`` computes), and
                # doubling keeps host-side schedule work O(R) per run.
                if full_sched is None or full_sched.num_rounds < seg_end:
                    if R is not None:
                        build_len = max(R, seg_end)
                    else:
                        built = 0 if full_sched is None else full_sched.num_rounds
                        build_len = max(seg_end, 2 * built, 2 * seg)
                    full_sched = sched_lib.build_schedule(
                        self.plan.config, P, build_len
                    )
                # pad every slice to the segment length with inert rounds
                # (identity on engine state): one compiled scan serves the
                # whole run, ragged tail included
                engine_sched = sched_lib.pad_schedule(
                    sched_lib.slice_schedule(full_sched, cursor, seg_end), seg
                )
                if engine is None:
                    engine = FerretEngine(
                        self.staged, engine_sched, self.optimizer,
                        self.cfg.compensation, lr=self.cfg.lr,
                        penalty_fn=penalty_fn, mesh=self.mesh,
                        hints=self.shard_hints,
                    )
                else:
                    engine.set_schedule(engine_sched)
                state = engine.init_state(
                    stages, opt_states, comp_states, rings=rings, deltas=deltas,
                    bounds=self.boundaries, sched_origin=0,
                )
                # only this segment's rounds ever reach the device
                seg_stream = {k: jnp.asarray(v) for k, v in rows.items()}
                if seg > seg_len:
                    # padding rounds repeat the last item (never admitted)
                    seg_stream = {
                        k: jnp.concatenate(
                            [v, jnp.repeat(v[-1:], seg - seg_len, axis=0)]
                        )
                        for k, v in seg_stream.items()
                    }
                # overlap: pull segment k+1 on the host while k computes
                if R is None or seg_end < R:
                    feeder.prefetch(seg if R is None else min(seg, R - seg_end))
                if penalty_fn is not None and penalty is None:
                    # single-plan run: the anchor never refreshes after the
                    # first chunk sets it, so split Ω/θ* once and reuse the
                    # same pytree every segment (stable jit arguments, no
                    # per-segment re-split/re-upload of 2× model size)
                    penalty = split_penalty_extras(
                        self.algorithm, self.model_cfg, self.boundaries
                    )
                t0 = time.perf_counter()
                final_state, ys = engine.run(state, seg_stream, penalty)
                seg_wall = time.perf_counter() - t0
                feeder.ack()  # segment complete: retained rows consumed
                if self.cfg.profile_feedback and seg_index > 0 and seg_len > 0:
                    # skip segment 0: its wall-clock includes the compile.
                    # The single-plan run never replans, so the refinement
                    # lands in the store for future runs/replans.
                    from repro.profile.bridge import observe_segment

                    # the compiled scan executes `seg` rounds (inert padding
                    # included), so that is the wall-clock's denominator
                    refined = observe_segment(
                        self.model_cfg, self.batch, self.seq,
                        self.profile, self.plan, seg, seg_wall,
                    )
                    if refined is not None:
                        self.profile = refined[0]
                seg_index += 1
                ys = {k: v[:seg_len] for k, v in ys.items()}  # drop padding
                stages = list(final_state.stage_params)
                rings = tuple(final_state.rings)
                deltas = tuple(final_state.deltas)
                opt_states = tuple(final_state.opt_states)
                comp_states = tuple(final_state.comp_states)
                acc_all.append(np.asarray(ys["acc"], dtype=np.float64))
                loss_all.append(np.asarray(ys["loss"]))
                adm_all.append(np.asarray(ys["admitted"], dtype=np.float64))
                lam_all.append(np.asarray(ys["lam"]))
                cursor = seg_end
        finally:
            feeder.close()

        self.final_params = T.merge_stage_params(self.model_cfg, list(stages))
        rounds = cursor
        acc = np.concatenate(acc_all) if acc_all else np.zeros(0)
        admitted = np.concatenate(adm_all) if adm_all else np.zeros(0)
        empirical_rate = empirical_adaptation_rate(
            self.cfg, self.plan, admitted, rounds
        )
        return StreamResult(
            # a zero-round stream reports 0.0, not an empty-mean NaN (the
            # elastic path's twin guard landed in PR 4)
            online_acc=float(acc.mean()) if acc.size else 0.0,
            online_acc_curve=np.cumsum(acc) / np.arange(1, acc.size + 1),
            losses=np.concatenate(loss_all) if loss_all else np.zeros(0),
            admitted_frac=float(admitted.mean()) if admitted.size else 0.0,
            memory_bytes=self.plan.memory,
            planned_rate=self.plan.rate,
            empirical_rate=empirical_rate,
            lam_curve=np.concatenate(lam_all) if lam_all else np.zeros(0),
            plan=self.plan,
            rounds=rounds,
            peak_buffered_rounds=feeder.peak_buffered_rounds,
            stream_wait_s=feeder.take_wait_s,
        )

    # ------------------------------------------------------------------
    def run_stream_elastic(self, params: Pytree, stream: Dict[str, np.ndarray],
                           schedule=(), **kwargs):
        """Segmented run under a varying memory budget (Ferret_M live).

        Delegates to ``repro.runtime.elastic_trainer.ElasticStreamTrainer``:
        the stream executes in segments, re-planning and remapping live
        state at every budget change. ``schedule`` is a list of
        ``BudgetEvent`` or a ``round -> budget_bytes | None`` callable; see
        ``ElasticStreamTrainer.run_stream`` for the remaining kwargs.
        Returns an ``ElasticStreamResult`` with per-segment ``StreamResult``s
        and the stitched online-accuracy curve.
        """
        from repro.runtime.elastic_trainer import ElasticStreamTrainer

        et = ElasticStreamTrainer(
            self.model_cfg, self.cfg, batch=self.batch, seq=self.seq,
            optimizer=self.optimizer, profile=self.profile,
            algorithm=self.algorithm, topology=self.topology,
        )
        result = et.run_stream(params, stream, schedule, **kwargs)
        self.final_params = result.final_params
        return result


def sequential_oracle_run(
    model_cfg: ModelConfig,
    params: Pytree,
    stream: Dict[str, np.ndarray],
    lr: float = 1e-3,
    trained_mask: Optional[np.ndarray] = None,
    optimizer: Optional[Optimizer] = None,
) -> Dict[str, np.ndarray]:
    """Plain predict-then-train loop (Oracle / skip baselines).

    trained_mask: bool (R,) — items that actually get a gradient update
    (admission policies produce it). Prediction happens for every item."""
    from repro.core import schedule as sched_lib
    from repro.core.cost_model import PipelineConfig, StageKnobs, WorkerConfig
    from repro.models import transformer as T

    R = next(iter(stream.values())).shape[0]
    opt = optimizer or adamw(lr=lr)
    boundaries = [0, model_cfg.num_layers]
    staged = staged_from_transformer(model_cfg, boundaries)
    pcfg = PipelineConfig(workers=[WorkerConfig(0, 0, [StageKnobs()])])
    schedule = sched_lib.build_schedule(pcfg, 1, R, sync_period=1)
    if trained_mask is not None:
        schedule.process[:] = trained_mask
    engine = FerretEngine(
        staged, schedule, opt, comp_lib.CompensationConfig(method="none"), lr=lr
    )
    stages = T.split_stage_params(model_cfg, params, boundaries)
    state = engine.init_state(stages)
    final_state, ys = engine.run(state, {k: jnp.asarray(v) for k, v in stream.items()})
    return {
        "acc": np.asarray(ys["acc"]),
        "loss": np.asarray(ys["loss"]),
        "final_params": T.merge_stage_params(
            model_cfg, list(final_state.stage_params)
        ),
    }
