"""Ferret trainer: plan → schedule → pipeline-execute an OCL stream.

This is the user-facing composition of the paper's three contributions:

    profile = analytic/measured per-layer profile
    plan    = Alg. 3 ∘ Alg. 2  (partition L*, config C* s.t. M_F ≤ M)
    engine  = fine-grained async pipeline with Iter-Fisher compensation

``FerretTrainer.run_stream`` executes a stream and reports online accuracy,
the empirical adaptation rate (Def. 4.1), and the planned memory footprint
(for agm/tagm comparisons).

Note: ``FerretTrainer`` / ``sequential_oracle_run`` are the internal
engines behind ``repro.api.FerretSession`` — prefer the session layer for
new code; these entrypoints stay importable for compatibility.
"""

from __future__ import annotations

import dataclasses
import math
import os
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core import compensation as comp_lib
from repro.core import planner as planner_lib
from repro.core import schedule as sched_lib
from repro.core.pipeline import FerretEngine, staged_from_transformer
from repro.core.profiler import ModelProfile, analytic_profile
from repro.models.config import ModelConfig
from repro.ocl.algorithms import OCLConfig
from repro.ocl.registry import OCLAlgorithm, get_algorithm
from repro.optim.optimizers import Optimizer, adamw

Pytree = Any


@dataclasses.dataclass(frozen=True)
class FerretConfig:
    budget_bytes: float = math.inf  # M (Ferret_M+ := inf)
    decay_c: float = 1.0  # data-value decay rate c (Def. 4.1)
    data_value: float = 1.0  # V_D
    t_d: Optional[float] = None  # arrival interval; default max_i t̂_i^f (§12)
    lr: float = 1e-3
    max_workers: Optional[int] = 8
    max_stages: Optional[int] = None
    compensation: comp_lib.CompensationConfig = dataclasses.field(
        default_factory=comp_lib.CompensationConfig
    )
    ocl: OCLConfig = dataclasses.field(default_factory=OCLConfig)


# ---------------------------------------------------------------------------
# Engine compile cache (bucketed segment lengths)
# ---------------------------------------------------------------------------

# Geometric bucket set for segment lengths: a segment of n rounds runs a
# compiled scan of the smallest bucket ≥ n (padded with inert schedule
# rounds, which are the identity on engine state), so repeated and A→B→A
# budget switches land on identical shapes and reuse compiled engines.
# Override with REPRO_SEGMENT_BUCKETS="8,16,..." or EngineCache(buckets=...).
DEFAULT_SEGMENT_BUCKETS = (8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096)


def _buckets_from_env() -> Tuple[int, ...]:
    raw = os.environ.get("REPRO_SEGMENT_BUCKETS", "").strip()
    if not raw:
        return DEFAULT_SEGMENT_BUCKETS
    return tuple(sorted(int(tok) for tok in raw.split(",") if tok.strip()))


class IdentityKey:
    """Hashable identity wrapper for cache keys.

    A bare ``id()`` in a long-lived shared cache can alias two objects if
    the first is garbage-collected and its address reused; holding the
    referent pins it for the cache's lifetime, so identity keys stay
    unambiguous.
    """

    __slots__ = ("obj",)

    def __init__(self, obj: Any):
        self.obj = obj

    def __hash__(self) -> int:
        return id(self.obj)

    def __eq__(self, other: Any) -> bool:
        return isinstance(other, IdentityKey) and other.obj is self.obj


class EngineCache:
    """Compiled-engine cache for segmented/elastic runs.

    One ``FerretEngine`` is kept per structure (``struct_key`` = trainer
    scope + stage boundaries); segments reuse it with ``set_schedule`` —
    schedule content is scan *data*, so a same-shape swap reuses the
    engine's compiled scan outright, and ``jax.jit`` keys further compiles
    on array shapes only. ``hits``/``misses`` count compiled-scan reuse at
    the shape level (``compile_key`` = struct_key + ring geometry +
    bucketed rounds + stream shape): the caller checks ``seen`` before a
    segment and ``record``s after it *succeeds*, so aborted segments never
    skew the perf accounting. An A→B→A budget schedule compiles 2 engines
    and hits once.
    """

    def __init__(self, buckets: Optional[Tuple[int, ...]] = None, enabled: bool = True):
        self.buckets = tuple(sorted(buckets)) if buckets else _buckets_from_env()
        self.enabled = enabled
        self._engines: Dict[Tuple, Any] = {}
        self._compiled: set = set()
        self.hits = 0
        self.misses = 0

    def bucket_len(self, n: int) -> int:
        """Smallest bucket ≥ n (multiples of the top bucket beyond it)."""
        if not self.enabled:
            return n
        for b in self.buckets:
            if n <= b:
                return b
        top = self.buckets[-1]
        return ((n + top - 1) // top) * top

    def engine_for(self, struct_key: Tuple, factory: Callable[[], Any]) -> Any:
        """The cached engine for ``struct_key`` (built by ``factory`` on
        first use; always fresh when the cache is disabled)."""
        if not self.enabled:
            return factory()
        engine = self._engines.get(struct_key)
        if engine is None:
            engine = factory()
            self._engines[struct_key] = engine
        return engine

    def seen(self, compile_key: Tuple) -> bool:
        """Was this shape already compiled (i.e. will the run be a hit)?"""
        return self.enabled and compile_key in self._compiled

    def record(self, compile_key: Tuple, hit: bool) -> None:
        """Account one *completed* segment run under ``compile_key``."""
        if hit:
            self.hits += 1
        else:
            self.misses += 1
            if self.enabled:
                self._compiled.add(compile_key)

    @property
    def counts(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses}


@dataclasses.dataclass
class StreamResult:
    online_acc: float
    online_acc_curve: np.ndarray
    losses: np.ndarray
    admitted_frac: float
    memory_bytes: float
    planned_rate: float
    empirical_rate: float
    lam_curve: np.ndarray
    plan: planner_lib.Plan


def empirical_adaptation_rate(
    cfg: FerretConfig, plan: planner_lib.Plan, admitted: np.ndarray, R: int
) -> float:
    """Def. 4.1 empirically: admitted items complete after one full pipeline
    traversal; dropped items contribute 0 (r = ∞)."""
    active = plan.config.active_workers()
    cr = max(w.recompute for w in active) if active else 0
    traversal = plan.partition.num_stages * (
        plan.stats.t_f + plan.stats.t_b + cr * plan.stats.t_f
    )
    contrib = admitted * math.exp(-cfg.decay_c * traversal) * cfg.data_value
    return float(contrib.sum() / max(R, 1))


class FerretTrainer:
    def __init__(
        self,
        model_cfg: ModelConfig,
        ferret_cfg: FerretConfig,
        batch: int,
        seq: int,
        optimizer: Optional[Optimizer] = None,
        profile: Optional[ModelProfile] = None,
        algorithm: Optional[Union[str, OCLAlgorithm]] = None,
    ):
        self.model_cfg = model_cfg
        self.cfg = ferret_cfg
        self.batch = batch
        self.seq = seq
        self.algorithm = (
            get_algorithm(algorithm, ferret_cfg.ocl)
            if algorithm is not None
            else get_algorithm(ferret_cfg.ocl)
        )
        self.profile = profile or analytic_profile(model_cfg, batch, seq)
        t_d = ferret_cfg.t_d or planner_lib.default_data_interval(self.profile)
        self.t_d = t_d
        self.plan = planner_lib.plan(
            self.profile,
            t_d,
            ferret_cfg.budget_bytes,
            c=ferret_cfg.decay_c,
            V_D=ferret_cfg.data_value,
            max_workers=ferret_cfg.max_workers,
            max_stages=ferret_cfg.max_stages,
        )
        self.boundaries = list(self.plan.partition.bounds)
        staged = staged_from_transformer(model_cfg, self.boundaries)
        self.staged = self.algorithm.wrap_staged(staged)
        self.optimizer = optimizer or adamw(lr=ferret_cfg.lr)

    # ------------------------------------------------------------------
    def run_stream(self, params: Pytree, stream: Dict[str, np.ndarray]) -> StreamResult:
        from repro.models import transformer as T

        R = next(iter(stream.values())).shape[0]
        P = self.plan.partition.num_stages
        schedule = sched_lib.build_schedule(self.plan.config, P, R)
        engine = FerretEngine(
            self.staged, schedule, self.optimizer, self.cfg.compensation, lr=self.cfg.lr
        )
        stages = T.split_stage_params(self.model_cfg, params, self.boundaries)
        state = engine.init_state(stages)
        stream_j = {k: jnp.asarray(v) for k, v in stream.items()}
        final_state, ys = engine.run(state, stream_j)
        self.final_params = T.merge_stage_params(self.model_cfg, list(final_state[0]))

        acc = np.asarray(ys["acc"], dtype=np.float64)
        admitted = np.asarray(ys["admitted"], dtype=np.float64)
        empirical_rate = empirical_adaptation_rate(self.cfg, self.plan, admitted, R)

        return StreamResult(
            online_acc=float(acc.mean()),
            online_acc_curve=np.cumsum(acc) / np.arange(1, R + 1),
            losses=np.asarray(ys["loss"]),
            admitted_frac=float(admitted.mean()),
            memory_bytes=self.plan.memory,
            planned_rate=self.plan.rate,
            empirical_rate=empirical_rate,
            lam_curve=np.asarray(ys["lam"]),
            plan=self.plan,
        )

    # ------------------------------------------------------------------
    def run_stream_elastic(self, params: Pytree, stream: Dict[str, np.ndarray],
                           schedule=(), **kwargs):
        """Segmented run under a varying memory budget (Ferret_M live).

        Delegates to ``repro.runtime.elastic_trainer.ElasticStreamTrainer``:
        the stream executes in segments, re-planning and remapping live
        state at every budget change. ``schedule`` is a list of
        ``BudgetEvent`` or a ``round -> budget_bytes | None`` callable; see
        ``ElasticStreamTrainer.run_stream`` for the remaining kwargs.
        Returns an ``ElasticStreamResult`` with per-segment ``StreamResult``s
        and the stitched online-accuracy curve.
        """
        from repro.runtime.elastic_trainer import ElasticStreamTrainer

        et = ElasticStreamTrainer(
            self.model_cfg, self.cfg, batch=self.batch, seq=self.seq,
            optimizer=self.optimizer, profile=self.profile,
            algorithm=self.algorithm,
        )
        result = et.run_stream(params, stream, schedule, **kwargs)
        self.final_params = result.final_params
        return result


def sequential_oracle_run(
    model_cfg: ModelConfig,
    params: Pytree,
    stream: Dict[str, np.ndarray],
    lr: float = 1e-3,
    trained_mask: Optional[np.ndarray] = None,
    optimizer: Optional[Optimizer] = None,
) -> Dict[str, np.ndarray]:
    """Plain predict-then-train loop (Oracle / skip baselines).

    trained_mask: bool (R,) — items that actually get a gradient update
    (admission policies produce it). Prediction happens for every item."""
    from repro.core import schedule as sched_lib
    from repro.core.cost_model import PipelineConfig, StageKnobs, WorkerConfig
    from repro.models import transformer as T

    R = next(iter(stream.values())).shape[0]
    opt = optimizer or adamw(lr=lr)
    boundaries = [0, model_cfg.num_layers]
    staged = staged_from_transformer(model_cfg, boundaries)
    pcfg = PipelineConfig(workers=[WorkerConfig(0, 0, [StageKnobs()])])
    schedule = sched_lib.build_schedule(pcfg, 1, R, sync_period=1)
    if trained_mask is not None:
        schedule.process[:] = trained_mask
    engine = FerretEngine(
        staged, schedule, opt, comp_lib.CompensationConfig(method="none"), lr=lr
    )
    stages = T.split_stage_params(model_cfg, params, boundaries)
    state = engine.init_state(stages)
    final_state, ys = engine.run(state, {k: jnp.asarray(v) for k, v in stream.items()})
    return {
        "acc": np.asarray(ys["acc"]),
        "loss": np.asarray(ys["loss"]),
        "final_params": T.merge_stage_params(model_cfg, list(final_state[0])),
    }
