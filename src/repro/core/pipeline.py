"""Fine-grained asynchronous pipeline engine (paper §5.1.1).

Executes the *learning dynamics* of Ferret's async 1F1B pipeline — per-stage
gradient staleness τ_j = P-1-j, gradient accumulation (T2), back-prop
omission (T3), worker interleave/removal (T4) — as one jit'd ``lax.scan``
over arriving stream items, driven by the statically precomputed
``EngineSchedule`` (repro.core.schedule).

Hardware adaptation note (DESIGN.md §2): XLA/TPU is SPMD-synchronous, so
wall-clock asynchrony is replaced by an exact deterministic emulation of
the staleness pattern; stage j's gradient, computed against the version-m
weights, is applied once the stage has advanced τ versions, and Iter-Fisher
compensates it at application time — precisely the paper's Fig. 9 model.
Throughput/latency effects are captured by the analytic cost model
(Eq. 3/4) that the planner optimizes.

Synchronous baselines (DAPPLE/GPipe-style flushes) run through the same
engine with ``sync_period=P`` schedules (fresh gradients, delayed updates).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core import compensation as comp_lib
from repro.core.schedule import EngineSchedule, RingGeometry
from repro.optim.optimizers import Optimizer
from repro.state.engine_state import EngineState

Pytree = Any


@dataclasses.dataclass(frozen=True)
class StagedModel:
    """Model split into P sequential stages.

    forward_stage(j, stage_params, x, batch) -> activations (stage j<P-1)
                                                or logits  (stage P-1)
    loss(logits, batch) -> (scalar loss, metrics dict)
    """

    num_stages: int
    forward_stage: Callable
    loss: Callable


def staged_from_transformer(cfg, boundaries) -> StagedModel:
    """Adapter: repro.models.transformer -> StagedModel."""
    from repro.models import transformer as T
    from repro.models.layers import cross_entropy_loss

    P = len(boundaries) - 1

    def fwd(j, sp, x, batch):
        out, _aux = T.stage_forward(cfg, sp, x, j, P, boundaries, batch)
        return out

    def loss(logits, batch):
        ce = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
        preds = jnp.argmax(logits, axis=-1)
        acc = jnp.mean((preds == batch["labels"]).astype(jnp.float32))
        return ce, {"acc": acc}

    return StagedModel(P, fwd, loss)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


def _dyn_index(tree: Pytree, idx) -> Pytree:
    return jax.tree.map(lambda a: jax.lax.dynamic_index_in_dim(a, idx, 0, keepdims=False), tree)


def _dyn_update(tree: Pytree, val: Pytree, idx) -> Pytree:
    return jax.tree.map(
        lambda a, v: jax.lax.dynamic_update_index_in_dim(a, v.astype(a.dtype), idx, 0), tree, val
    )


class FerretEngine:
    """Builds and runs the scan. Construct once per (model, partition).

    The compiled scan is held by one persistent ``jax.jit`` wrapper, so
    repeated ``run`` calls — and schedule swaps via ``set_schedule`` that
    keep the array shapes — reuse the compiled executable instead of
    re-tracing. The *content* of the schedule is scan data (xs), not a
    trace constant; only its shapes (rounds, stages, ring depths) key the
    compile cache.

    ``penalty_fn(stage_params, penalty) -> scalar`` adds a
    *parameter-space* loss term (MAS/EWC-style pulls, weight decay against
    a reference) that the staged ``(logits, batch)`` loss cannot express:
    it sees the per-stage weight tuple directly and its gradient flows into
    the same backward as the data loss. ``penalty`` is the segment-constant
    state the term needs (e.g. Ω and the reference weights, split per
    stage) — it is passed through the jitted scan as an *argument*, not a
    closure constant, so refreshing it at a segment boundary reuses the
    compiled executable as long as shapes hold.
    """

    def __init__(
        self,
        staged: StagedModel,
        schedule: EngineSchedule,
        optimizer: Optimizer,
        comp_cfg: comp_lib.CompensationConfig,
        lr: float = 1e-3,
        penalty_fn: Optional[Callable] = None,
        mesh=None,
        hints=None,
    ):
        self.staged = staged
        self.sched = schedule
        self.opt = optimizer
        self.comp_cfg = comp_cfg
        self.lr = lr
        self.penalty_fn = penalty_fn
        # Optional jax Mesh (from DeviceTopology.mesh()): when set, run()
        # commits the stream's batch dim to the "data" axis and the engine
        # carry to full replication before the scan, and GSPMD partitions
        # the compiled executable across the mesh. mesh=None is the exact
        # historical single-device path — no array is ever re-placed.
        # ``hints`` (models.shard_hints.ShardHints, usually built with
        # shard_hints.for_topology) are installed around the sharded scan's
        # trace so the model's internal constraint points (logits, block
        # boundaries) pin their batch dim to the data axis.
        self.mesh = mesh
        self.hints = hints
        self._compiled = jax.jit(self._scan)
        # ``set_schedule`` mutates ``self.sched`` and ``run`` reads it —
        # callers sharing one engine across threads (a shared EngineCache,
        # the multi-tenant server) hold this across the whole
        # set_schedule → init_state → run span so one tenant's schedule
        # swap can never leak into another's in-flight scan
        self.exec_lock = threading.Lock()

    def set_schedule(self, schedule: EngineSchedule) -> None:
        """Swap the schedule. Same (rounds, stages, ring_size, delta_ring)
        → the already-compiled scan is reused; different shapes retrace."""
        self.sched = schedule

    @property
    def ring_geometry(self) -> RingGeometry:
        """Ring depths the live schedule shapes engine state for — what
        ``repro.state.StateRemapper`` re-time-indexes rings against."""
        return RingGeometry(
            ring_size=self.sched.ring_size, delta_ring=self.sched.delta_ring
        )

    # -- state ------------------------------------------------------------
    def init_state(
        self,
        stage_params: List[Pytree],
        opt_states=None,
        comp_states=None,
        rings=None,
        deltas=None,
        *,
        bounds=None,
        sched_origin=None,
    ) -> EngineState:
        """Typed ``EngineState`` for ``stage_params``.

        ``opt_states`` / ``comp_states`` carry per-stage optimizer and
        compensation state across a re-plan (runtime/elastic_trainer.py);
        when omitted they are freshly initialized. ``rings`` / ``deltas``
        carry in-flight gradient-accumulation groups and the Δθ history
        across segment boundaries — a cross-partition switch remaps them
        through ``repro.state.StateRemapper`` (they are zero-filled only
        when omitted, i.e. genuinely fresh). ``bounds`` / ``sched_origin``
        are recorded as state metadata for the remapper and checkpoints.
        """
        Rsz, K = self.sched.ring_size, self.sched.delta_ring
        f32 = jnp.float32
        if rings is None:
            rings = tuple(
                jax.tree.map(lambda p: jnp.zeros((Rsz, *p.shape), f32), sp)
                for sp in stage_params
            )
        if deltas is None:
            deltas = tuple(
                jax.tree.map(lambda p: jnp.zeros((K, *p.shape), f32), sp)
                for sp in stage_params
            )
        if opt_states is None:
            opt_states = tuple(self.opt.init(sp) for sp in stage_params)
        if comp_states is None:
            comp_states = tuple(
                comp_lib.init_state(sp, self.comp_cfg) for sp in stage_params
            )
        return EngineState(
            stage_params=tuple(stage_params),
            rings=tuple(rings),
            deltas=tuple(deltas),
            opt_states=tuple(opt_states),
            comp_states=tuple(comp_states),
            bounds=None if bounds is None else tuple(int(b) for b in bounds),
            geometry=self.ring_geometry,
            sched_origin=None if sched_origin is None else int(sched_origin),
        )

    # -- schedule arrays as scan xs ----------------------------------------
    def _schedule_xs(self) -> Dict[str, jnp.ndarray]:
        s = self.sched
        compute = (
            s.compute if s.compute is not None
            else jnp.ones(s.num_rounds, bool)
        )
        return {
            "process": jnp.asarray(s.process),
            "backward": jnp.asarray(s.backward),
            "push_slot": jnp.asarray(s.push_slot),
            "push_reset": jnp.asarray(s.push_reset),
            "pop_slot": jnp.asarray(s.pop_slot),
            "pop_scale": jnp.asarray(s.pop_scale),
            "delta_mask": jnp.asarray(s.delta_mask),
            "delta_push": jnp.asarray(s.delta_push_slot),
            "tau": jnp.asarray(s.tau),
            "compute": jnp.asarray(compute),
        }

    # -- one round ----------------------------------------------------------
    def _round(self, carry, xs, penalty):
        """One scan step. Bucket-padding rounds (``compute=False``, only
        ever emitted by ``pad_schedule``) skip the forward/backward through
        the cond — the carry passes through untouched and the per-round
        outputs are zeros, which the caller slices off."""

        def skip(carry, _xs):
            zero = jnp.zeros((), jnp.float32)
            ys = {
                "loss": zero, "acc": zero, "admitted": zero,
                "lam": zero, "tau_mean": zero,
            }
            return carry, ys

        def live(carry, xs):
            return self._live_round(carry, xs, penalty)

        return jax.lax.cond(xs["compute"], live, skip, carry, xs)

    def _live_round(self, carry, xs, penalty):
        stages, rings, deltas, opts, comps = carry
        batch = xs["batch"]
        P = self.staged.num_stages
        K = self.sched.delta_ring
        f32 = jnp.float32

        def full_loss(stages_t):
            x = None
            for j in range(P):
                x = self.staged.forward_stage(j, stages_t[j], x, batch)
            loss, metrics = self.staged.loss(x, batch)
            if self.penalty_fn is not None:
                loss = loss + self.penalty_fn(stages_t, penalty)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(full_loss, has_aux=True)(stages)
        pmask = xs["process"].astype(f32)

        new_stages, new_rings, new_deltas, new_opts, new_comps = [], [], [], [], []
        lam_sum = jnp.zeros((), f32)
        for j in range(P):
            bmask = pmask * xs["backward"][j].astype(f32)
            g_j = jax.tree.map(lambda g: g.astype(f32) * bmask, grads[j])

            # ---- push (accumulate into the gradient ring, T2) ----
            slot = jnp.maximum(xs["push_slot"][j], 0)

            def do_push(ring, g_j=g_j, slot=slot, reset=xs["push_reset"][j]):
                cur = _dyn_index(ring, slot)
                base = jax.tree.map(lambda c, g: jnp.where(reset, g, c + g), cur, g_j)
                return _dyn_update(ring, base, slot)

            ring_j = jax.lax.cond(xs["push_slot"][j] >= 0, do_push, lambda r: r, rings[j])

            # ---- pop (compensate + apply, Alg. 1) ----
            def do_pop(args, j=j):
                params, opt_s, comp_s, ring, dring = args
                pslot = jnp.maximum(xs["pop_slot"][j], 0)
                g = jax.tree.map(
                    lambda a: a * xs["pop_scale"][j], _dyn_index(ring, pslot)
                )
                order = (xs["delta_push"][j] + jnp.arange(K)) % K  # oldest→newest
                mask = xs["delta_mask"][j]
                dl = jax.tree.map(
                    lambda a: a[order] * mask.reshape((K,) + (1,) * (a.ndim - 1)), dring
                )
                comp_s, gc = comp_lib.compensate(
                    self.comp_cfg, comp_s, g, dl, lr=self.lr, tau=xs["tau"][j]
                )
                newp, new_opt = self.opt.update(params, gc, opt_s)
                dnew = jax.tree.map(
                    lambda a, b: a.astype(f32) - b.astype(f32), newp, params
                )
                dslot = jnp.maximum(xs["delta_push"][j], 0)
                dring = _dyn_update(dring, dnew, dslot)
                return (newp, new_opt, comp_s, ring, dring)

            operands = (stages[j], opts[j], comps[j], ring_j, deltas[j])
            st_j, opt_j, comp_j, ring_j, delta_j = jax.lax.cond(
                xs["pop_slot"][j] >= 0, do_pop, lambda a: a, operands
            )
            new_stages.append(st_j)
            new_rings.append(ring_j)
            new_deltas.append(delta_j)
            new_opts.append(opt_j)
            new_comps.append(comp_j)
            lam_sum = lam_sum + comp_j.lam

        ys = {
            "loss": loss,
            "acc": metrics["acc"],
            "admitted": xs["process"].astype(f32),
            "lam": lam_sum / P,
            "tau_mean": jnp.mean(xs["tau"].astype(f32)),
        }
        carry = (
            tuple(new_stages),
            tuple(new_rings),
            tuple(new_deltas),
            tuple(new_opts),
            tuple(new_comps),
        )
        return carry, ys

    # -- run ------------------------------------------------------------
    def _scan(self, state, xs, penalty):
        def round_fn(carry, x):
            return self._round(carry, x, penalty)

        return jax.lax.scan(round_fn, state, xs)

    def run(self, state, stream: Dict[str, jnp.ndarray], penalty: Pytree = None):
        """stream: dict of arrays stacked over rounds, e.g. tokens (R, b, s).

        ``penalty`` is the extras pytree for ``penalty_fn`` (required iff
        the engine was built with one); it rides through the jitted scan as
        an argument, so a same-shape refresh never retraces.

        ``state`` may be an ``EngineState`` (preferred — the returned final
        state keeps its bounds/geometry/schedule-origin metadata) or the
        legacy plain 5-tuple. Either way the *jitted scan* carries the
        plain tuple: the conversion happens here, outside the compiled
        function, so metadata changes (a new ``sched_origin`` every
        segment) never key the compile cache or force a retrace.

        Returns (final_state, ys dict of per-round metrics)."""
        if (self.penalty_fn is not None) and penalty is None:
            raise ValueError(
                "engine built with penalty_fn but run() got penalty=None — "
                "the algorithm must populate its penalty extras before the "
                "segment runs (see OCLAlgorithm.engine_penalty_extras)"
            )
        xs = dict(self._schedule_xs())
        xs["batch"] = stream
        meta = state if isinstance(state, EngineState) else None
        carry = state.as_tuple() if meta is not None else state
        if self.mesh is not None and self.mesh.devices.size > 1:
            from repro.launch import shardings as sh
            from repro.models import shard_hints as hints_lib

            # Commit placements at the jit boundary: batch dim of every
            # stream leaf over "data", carry replicated. device_put is a
            # no-op when the arrays already live there (steady state).
            xs["batch"] = jax.device_put(
                stream, sh.stream_shardings(self.mesh, stream)
            )
            carry = jax.device_put(carry, sh.state_shardings(self.mesh, carry))
            # The mesh context resolves the hints' PartitionSpecs inside
            # the traced scan (first call traces; later calls reuse the
            # executable, the context is then just a cheap no-op).
            with self.mesh, hints_lib.use_hints(
                self.hints if self.hints is not None else hints_lib.ShardHints()
            ):
                final, ys = self._compiled(carry, xs, penalty)
        else:
            final, ys = self._compiled(carry, xs, penalty)
        if meta is not None:
            final = EngineState.from_tuple(
                final, bounds=meta.bounds, geometry=meta.geometry,
                sched_origin=meta.sched_origin,
            )
        return final, ys


# ---------------------------------------------------------------------------
# Delta-ring ordering: update u writes slot (u mod K). At pop time,
# delta_push = U mod K (U updates applied so far), and slot (U mod K) still
# holds update U-K — the *oldest* of the last K. Hence
# order = (delta_push + arange(K)) % K walks updates U-K..U-1 oldest→newest,
# and delta_mask keeps the most recent τ of them (the live staleness window).
# Verified against a reference simulation in tests/test_pipeline.py.
# ---------------------------------------------------------------------------
