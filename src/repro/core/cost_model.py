"""Ferret's analytic cost model.

Implements, exactly as stated in the paper:
- Eq. 3  — adaptation rate R_F^T of the fine-grained pipeline
- Eq. 4  — memory footprint M_F
- Eq. 19 — S1 (activation recomputation) deltas
- Eq. 20 — S2 (gradient accumulation) deltas
- Eq. 21 — S3 (back-propagation omission) deltas
- Eq. 22 — S4 (worker removal) deltas

All quantities are host-side Python floats/ints (the planner runs once,
before training starts). Tests verify the closed-form deltas against
recompute-diffs of Eq. 3/4.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

from repro.core.profiler import ModelProfile

# ---------------------------------------------------------------------------
# Configuration structures (the paper's L and C)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StageKnobs:
    accum: int = 1  # c_{n,j}^a  >= 1
    omit: int = 0  # c_{n,j}^o  >= 0


@dataclasses.dataclass
class WorkerConfig:
    delay: int  # c_n^d  (>= 0; -1 means removed)
    recompute: int = 0  # c_n^r  (0/1)
    stages: List[StageKnobs] = dataclasses.field(default_factory=list)

    @property
    def removed(self) -> bool:
        return self.delay < 0


@dataclasses.dataclass
class PipelineConfig:
    workers: List[WorkerConfig]

    def active_workers(self) -> List[WorkerConfig]:
        return [w for w in self.workers if not w.removed]

    def clone(self) -> "PipelineConfig":
        return PipelineConfig(
            workers=[
                WorkerConfig(
                    delay=w.delay,
                    recompute=w.recompute,
                    stages=[StageKnobs(s.accum, s.omit) for s in w.stages],
                )
                for w in self.workers
            ]
        )


@dataclasses.dataclass(frozen=True)
class Partition:
    """Model partition scheme L: stage j covers layers [bounds[j], bounds[j+1])."""

    bounds: Sequence[int]  # P+1 increasing ints, bounds[0]=0, bounds[-1]=num_layers

    @property
    def num_stages(self) -> int:
        return len(self.bounds) - 1

    def stage_layers(self, j: int) -> range:
        return range(self.bounds[j], self.bounds[j + 1])


@dataclasses.dataclass(frozen=True)
class StageStats:
    """Aggregated per-stage quantities from the profile + partition."""

    w: List[int]  # |w_j| bytes
    a: List[int]  # |a_j| bytes (all activations of the stage's layers)
    a_recomputable: List[int]  # c_r-subtractable bytes: Σ_{l=L_j+1}^{L_{j+1}-1} |â_l|
    t_f: float  # max-stage forward time
    t_b: float  # max-stage backward time


def stage_stats(profile: ModelProfile, part: Partition) -> StageStats:
    w, a, a_rec = [], [], []
    tf_list, tb_list = [], []
    for j in range(part.num_stages):
        layers = [profile.layers[i] for i in part.stage_layers(j)]
        w.append(sum(ly.w_bytes for ly in layers))
        a.append(sum(ly.a_bytes + ly.a_internal_bytes for ly in layers))
        # Eq. 4: T1 drops Σ_{l=L_i+1}^{L_{i+1}-1} |â_l| — everything except the
        # first layer's activations (the stage input survives for recompute).
        a_rec.append(sum(ly.a_bytes + ly.a_internal_bytes for ly in layers[1:]))
        tf_list.append(sum(ly.t_fwd for ly in layers))
        tb_list.append(sum(ly.t_bwd for ly in layers))
    return StageStats(w=w, a=a, a_recomputable=a_rec, t_f=max(tf_list), t_b=max(tb_list))


# ---------------------------------------------------------------------------
# Eq. 3 — adaptation rate
# ---------------------------------------------------------------------------


def _lcm_tail(stages: List[StageKnobs], i: int) -> int:
    """LCM({c^o_{n,k} + 1 | k ∈ [i, P-1]})."""
    out = 1
    for k in range(i, len(stages)):
        out = math.lcm(out, stages[k].omit + 1)
    return out


def _A_term(
    i: int,
    j: int,
    P: int,
    t_f: float,
    t_b: float,
    c_r: int,
    lcm: int,
    c: float,
    V_D: float,
) -> float:
    """A_{i,j} of Eq. 3."""
    expo = -c * ((P + j) * t_f + (P - i + j) * t_b + c_r * (P - i + j) * t_f)
    denom = lcm * (t_f + t_b + c_r * t_f)
    return math.exp(expo) * V_D / denom


def worker_rate(
    stats: StageStats, worker: WorkerConfig, c: float = 1.0, V_D: float = 1.0
) -> float:
    """Inner double sum of Eq. 3 for one worker."""
    if worker.removed:
        return 0.0
    P = len(stats.w)
    w_total = float(sum(stats.w))
    total = 0.0
    for i in range(P):
        knobs = worker.stages[i]
        lcm = _lcm_tail(worker.stages, i)
        inner = sum(
            _A_term(i, j, P, stats.t_f, stats.t_b, worker.recompute, lcm, c, V_D)
            for j in range(knobs.accum)
        )
        total += (stats.w[i] / w_total) * inner / knobs.accum
    return total


def adaptation_rate(
    stats: StageStats, config: PipelineConfig, c: float = 1.0, V_D: float = 1.0
) -> float:
    """Eq. 3: R_F^T."""
    return sum(worker_rate(stats, w, c, V_D) for w in config.workers)


def expected_round_seconds(stats: StageStats, config: PipelineConfig) -> float:
    """Steady-state wall-clock the plan predicts per stream round.

    The pipeline admits one round per max-stage traversal: t_f + t_b plus
    the recompute forward where any active worker enables T1. This is the
    baseline the online-refinement feedback compares observed segment
    wall-clock against (``repro.profile.bridge.observe_segment``).
    """
    active = config.active_workers()
    cr = max((w.recompute for w in active), default=0)
    return stats.t_f + stats.t_b + cr * stats.t_f


# ---------------------------------------------------------------------------
# Eq. 4 — memory footprint
# ---------------------------------------------------------------------------


def _stage_copies(P: int, i: int, knobs: StageKnobs) -> int:
    """(1 + ⌈(P-i-1)/c^a⌉ - c^o) — number of live (weights+activations) copies."""
    return 1 + math.ceil((P - i - 1) / knobs.accum) - knobs.omit


def worker_memory(stats: StageStats, worker: WorkerConfig) -> float:
    if worker.removed:
        return 0.0
    P = len(stats.w)
    total = 0.0
    for i in range(P):
        copies = _stage_copies(P, i, worker.stages[i])
        footprint = stats.w[i] + stats.a[i] - worker.recompute * stats.a_recomputable[i]
        total += max(copies, 0) * footprint
    return total


def memory_footprint(
    stats: StageStats, config: PipelineConfig, base_bytes: int = 0
) -> float:
    """Eq. 4: M_F (+ optional per-worker base bytes for embed/head)."""
    active = config.active_workers()
    return sum(worker_memory(stats, w) for w in active) + base_bytes * len(active)


# ---------------------------------------------------------------------------
# Eq. 19–22 — closed-form deltas for S1–S4
# (ΔR and ΔM are the *reductions*, i.e. old − new; positive = decrease.)
# ---------------------------------------------------------------------------


def delta_s1(stats: StageStats, worker: WorkerConfig, c: float = 1.0, V_D: float = 1.0):
    """Eq. 19: enable T1 (c_r 0→1) for this worker."""
    if worker.removed or worker.recompute == 1:
        return None
    before_r = worker_rate(stats, worker, c, V_D)
    before_m = worker_memory(stats, worker)
    trial = WorkerConfig(worker.delay, 1, [StageKnobs(s.accum, s.omit) for s in worker.stages])
    dR = before_r - worker_rate(stats, trial, c, V_D)
    dM = before_m - worker_memory(stats, trial)
    return dR, dM, trial


def s2_accum_increment(P: int, j: int, c_a: int) -> Optional[int]:
    """Δc^a of Eq. 20 — chosen so the ceiling actually drops; None = +∞."""
    k = math.ceil((P - j - 1) / c_a)
    if k <= 1:
        return None  # Δc^a = +∞: T2 exhausted for this stage (S3 takes over)
    return math.ceil((P - j - 1) / (k - 1)) - c_a


def delta_s2(
    stats: StageStats, worker: WorkerConfig, j: int, c: float = 1.0, V_D: float = 1.0
):
    """Eq. 20: increase c^a_{n,j} by Δc^a (requires c^o_{n,j} = 0)."""
    if worker.removed or worker.stages[j].omit != 0:
        return None
    P = len(stats.w)
    inc = s2_accum_increment(P, j, worker.stages[j].accum)
    if inc is None or inc <= 0:
        return None
    trial = WorkerConfig(worker.delay, worker.recompute,
                         [StageKnobs(s.accum, s.omit) for s in worker.stages])
    trial.stages[j].accum += inc
    dR = worker_rate(stats, worker, c, V_D) - worker_rate(stats, trial, c, V_D)
    dM = worker_memory(stats, worker) - worker_memory(stats, trial)
    return dR, dM, trial


def delta_s3(
    stats: StageStats, worker: WorkerConfig, j: int, c: float = 1.0, V_D: float = 1.0
):
    """Eq. 21: c^a_{n,j} → 1, c^o_{n,j} → P-1-j (requires T2 exhausted)."""
    if worker.removed:
        return None
    P = len(stats.w)
    if j >= P - 1:
        return None  # no staleness at the last stage; omission is a no-op
    if worker.stages[j].omit != 0:
        return None
    if s2_accum_increment(P, j, worker.stages[j].accum) is not None:
        return None  # S3 only once Δc^a = +∞
    trial = WorkerConfig(worker.delay, worker.recompute,
                         [StageKnobs(s.accum, s.omit) for s in worker.stages])
    trial.stages[j].accum = 1
    trial.stages[j].omit = P - 1 - j
    dR = worker_rate(stats, worker, c, V_D) - worker_rate(stats, trial, c, V_D)
    dM = worker_memory(stats, worker) - worker_memory(stats, trial)
    return dR, dM, trial


def delta_s4(stats: StageStats, worker: WorkerConfig, c: float = 1.0, V_D: float = 1.0):
    """Eq. 22: remove the worker (requires c^o ≠ 0 on all non-final stages)."""
    if worker.removed:
        return None
    P = len(stats.w)
    if any(worker.stages[j].omit == 0 for j in range(P - 1)):
        return None
    trial = WorkerConfig(-1, worker.recompute,
                         [StageKnobs(s.accum, s.omit) for s in worker.stages])
    dR = worker_rate(stats, worker, c, V_D)
    dM = worker_memory(stats, worker)
    return dR, dM, trial
