"""hymba-1.5b [hybrid] — parallel attention + mamba heads. [arXiv:2411.13676; hf]

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Attention branch uses a sliding window (1024) so long_500k runs bounded.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    window=1024,
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_conv=4,
    ssm_chunk=256,
)

SMOKE_CONFIG = ModelConfig(
    name="hymba-1.5b-smoke",
    family="hybrid",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    window=8,
    ssm_state=8,
    ssm_expand=2,
    ssm_headdim=16,
    ssm_conv=4,
    ssm_chunk=8,
)
