"""Assigned architecture configs (one module per arch) + input shapes."""

from repro.configs.common import SHAPES, InputShape, input_specs, shape_applicable

__all__ = ["SHAPES", "InputShape", "input_specs", "shape_applicable"]
