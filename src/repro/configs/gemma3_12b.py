"""gemma3-12b [dense] — 5:1 local:global attention, 128k. [hf:google/gemma-3-1b-pt; unverified]

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144, head_dim=256,
local window 1024, every 6th layer global.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    d_ff=15360,
    vocab_size=262144,
    head_dim=256,
    local_global_ratio=5,
    local_window=1024,
    rope_theta=1_000_000.0,
    # bf16 weights + fp32 Adam moments: halves FSDP all-gather wire
    # (EXPERIMENTS.md §Perf iteration 9)
    param_dtype="bfloat16",
)

SMOKE_CONFIG = ModelConfig(
    name="gemma3-12b-smoke",
    family="dense",
    num_layers=6,  # one full 5:1 local:global group
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    local_global_ratio=5,
    local_window=8,
)
