"""qwen1.5-4b [dense] — QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]

40L d_model=2560 20H (GQA kv=20) d_ff=6912 vocab=151936.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    # 32k-token MHA/GQA cache exceeds 16 GB/chip in bf16 — int8 KV cache
    # (per-position/head scales) halves it (EXPERIMENTS.md §Perf iteration 7)
    kv_cache_dtype="int8",
    # bf16 weights + fp32 Adam moments: halves FSDP all-gather wire
    # (EXPERIMENTS.md §Perf iteration 9)
    param_dtype="bfloat16",
)

SMOKE_CONFIG = ModelConfig(
    name="qwen1.5-4b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    qkv_bias=True,
)
