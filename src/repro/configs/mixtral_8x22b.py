"""mixtral-8x22b [moe] — 8 experts top-2, SWA. [arXiv:2401.04088; hf]

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    window=4096,
    num_experts=8,
    experts_per_token=2,
    # ≥70B total params: bf16 weights + fp32 optimizer moments (memory fit,
    # standard mixed-precision recipe; see EXPERIMENTS.md §Perf iteration 4)
    param_dtype="bfloat16",
)

SMOKE_CONFIG = ModelConfig(
    name="mixtral-8x22b-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    window=8,
    num_experts=4,
    experts_per_token=2,
)
