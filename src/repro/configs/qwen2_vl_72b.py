"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution. [arXiv:2409.12191; hf]

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.

Backbone only: the vision frontend is a stub — ``input_specs`` provides
precomputed patch embeddings (b, s, d_model) plus (3, b, s) M-RoPE positions.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    mrope_sections=(16, 24, 24),  # sums to head_dim/2 = 64
    embed_inputs=False,
    frontend="vision",
    # ≥70B total params: bf16 weights + fp32 optimizer moments (memory fit,
    # standard mixed-precision recipe; see EXPERIMENTS.md §Perf iteration 4)
    param_dtype="bfloat16",
    # 32k-token MHA/GQA cache exceeds 16 GB/chip in bf16 — int8 KV cache
    # (per-position/head scales) halves it (EXPERIMENTS.md §Perf iteration 7)
    kv_cache_dtype="int8",
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-vl-72b-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    qkv_bias=True,
    mrope_sections=(4, 2, 2),  # sums to head_dim/2 = 8
    embed_inputs=False,
    frontend="vision",
)
