"""Input-shape definitions shared by every architecture.

The assigned benchmark cells are (arch × shape) with:

    train_4k     seq=4096    global_batch=256   -> lowers train_step
    prefill_32k  seq=32768   global_batch=32    -> lowers serve prefill
    decode_32k   seq=32768   global_batch=128   -> lowers serve decode (1 new token,
                                                    KV cache of seq)
    long_500k    seq=524288  global_batch=1     -> decode; sub-quadratic archs only

``input_specs`` returns ShapeDtypeStruct stand-ins (no allocation) for every
model input of a given (cfg, shape) cell — the dry-run lowers against these.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str  # 'train' | 'prefill' | 'decode'
    seq: int
    batch: int


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32768, 128),
    "long_500k": InputShape("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> bool:
    """long_500k requires sub-quadratic attention (see DESIGN.md)."""
    if shape.name == "long_500k" and cfg.full_attention_only:
        return False
    return True


def _token_or_embed_spec(cfg: ModelConfig, batch: int, seq: int) -> Dict:
    cd = jnp.dtype(cfg.compute_dtype)
    if cfg.embed_inputs:
        return {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    # Frontend stub: precomputed patch/frame embeddings.
    return {"embeds": jax.ShapeDtypeStruct((batch, seq, cfg.d_model), cd)}


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict:
    """ShapeDtypeStruct stand-ins for the *batch* argument of the lowered fn."""
    if shape.kind == "train":
        specs = _token_or_embed_spec(cfg, shape.batch, shape.seq)
        specs["labels"] = jax.ShapeDtypeStruct((shape.batch, shape.seq), jnp.int32)
        if cfg.mrope_sections is not None:
            specs["positions"] = jax.ShapeDtypeStruct((3, shape.batch, shape.seq), jnp.int32)
        return specs
    if shape.kind == "prefill":
        specs = _token_or_embed_spec(cfg, shape.batch, shape.seq)
        if cfg.mrope_sections is not None:
            specs["positions"] = jax.ShapeDtypeStruct((3, shape.batch, shape.seq), jnp.int32)
        return specs
    # decode: one new token against a cache of length shape.seq
    specs = _token_or_embed_spec(cfg, shape.batch, 1)
    if cfg.mrope_sections is not None:
        specs["positions"] = jax.ShapeDtypeStruct((3, shape.batch, 1), jnp.int32)
    return specs
