"""musicgen-medium [audio] — decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

48L d_model=1536 24H (GQA kv=24) d_ff=6144 vocab=2048.

Backbone only: the EnCodec tokenizer is the stubbed frontend — inputs are
already EnCodec codebook token ids.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    frontend="audio",
    # 32k-token MHA/GQA cache exceeds 16 GB/chip in bf16 — int8 KV cache
    # (per-position/head scales) halves it (EXPERIMENTS.md §Perf iteration 7)
    kv_cache_dtype="int8",
)

SMOKE_CONFIG = ModelConfig(
    name="musicgen-medium-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    frontend="audio",
)
