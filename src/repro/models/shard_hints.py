"""Optional sharding hints for model internals.

Models stay mesh-agnostic; launchers install hints (PartitionSpecs for the
few internal tensors whose sharding GSPMD gets wrong at 256+ chips: logits,
MoE dispatch buffers) via the context manager. ``None`` hints are no-ops,
so tests and small runs never touch jax sharding machinery.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_local = threading.local()


@dataclasses.dataclass(frozen=True)
class ShardHints:
    logits: Optional[P] = None  # (b, s, V)
    moe_buffer: Optional[P] = None  # (E*C+1, d) dispatch buffer
    activations: Optional[P] = None  # (b, s, d) block boundaries


def current() -> ShardHints:
    return getattr(_local, "hints", None) or ShardHints()


@contextlib.contextmanager
def use_hints(hints: ShardHints):
    prev = getattr(_local, "hints", None)
    _local.hints = hints
    try:
        yield
    finally:
        _local.hints = prev


def for_topology(topology) -> ShardHints:
    """Hints matching a discovered ``DeviceTopology``: batch dim over the
    "data" axis for logits/activations when the topology actually has a
    data axis, otherwise the all-``None`` no-op hints."""
    if topology is None or topology.data_parallel <= 1:
        return ShardHints()
    return ShardHints(
        logits=P("data", None, None),
        activations=P("data", None, None),
    )


def constrain(x: jax.Array, spec: Optional[P]) -> jax.Array:
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def constrain_batch_dim(x: jax.Array) -> jax.Array:
    """Pin only the leading (batch) dim to the data axes of the active hints.

    Used for tensors whose trailing dims vary (MoE dispatch buffers): the
    scatter/gather ops lose GSPMD's batch-dim propagation and would
    otherwise replicate multi-GB buffers per device."""
    act = current().activations
    if act is None or len(act) == 0:
        return x
    spec = P(act[0], *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)
