"""Unified decoder stack covering all assigned families.

dense / moe / vlm / audio : attention (+SwiGLU or MoE FFN)
ssm (mamba2)              : SSD mixer only (d_ff = 0)
hybrid (hymba)            : parallel attention ∥ SSD heads (+FFN)

Blocks are homogeneous and scanned. Architectures with a local:global
attention pattern (gemma3) use a *grouped* scan for serving so that the two
cache geometries (ring-window vs. full) stay separately allocated.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import tree_flatten_with_path
from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig
from repro.models.layers import (
    attention_decode,
    attention_train,
    cross_entropy_loss,
    embed_tokens,
    lm_head_logits,
    moe_block,
    rms_norm,
    swiglu_mlp,
)

# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------


def _block_param_shapes(cfg: ModelConfig) -> Dict:
    d, ff = cfg.d_model, cfg.d_ff
    hd = cfg.resolved_head_dim
    q, kv = cfg.num_heads * hd, cfg.num_kv_heads * hd
    shapes: Dict = {"pre_norm": (d,)}
    if cfg.uses_attention:
        shapes.update({"wq": (d, q), "wk": (d, kv), "wv": (d, kv), "wo": (q, d)})
        if cfg.qkv_bias:
            shapes.update({"bq": (q,), "bk": (kv,), "bv": (kv,)})
    if cfg.uses_ssm:
        shapes["ssm"] = ssm_lib.ssm_param_shapes(cfg)
    if ff > 0:
        shapes["mlp_norm"] = (d,)
        if cfg.uses_moe:
            shapes.update(
                {
                    "router": (d, cfg.num_experts),
                    "we_gate": (cfg.num_experts, d, ff),
                    "we_up": (cfg.num_experts, d, ff),
                    "we_down": (cfg.num_experts, ff, d),
                }
            )
        else:
            shapes.update({"w_gate": (d, ff), "w_up": (d, ff), "w_down": (ff, d)})
    return shapes


def param_shapes(cfg: ModelConfig) -> Dict:
    """Full parameter pytree of shapes (blocks stacked over num_layers)."""
    L = cfg.num_layers
    blocks = jax.tree.map(
        lambda s: (L, *s), _block_param_shapes(cfg), is_leaf=lambda s: isinstance(s, tuple)
    )
    shapes: Dict = {
        "embed": (cfg.vocab_size, cfg.d_model),
        "blocks": blocks,
        "final_norm": (cfg.d_model,),
    }
    if not cfg.tie_embeddings:
        shapes["lm_head"] = (cfg.d_model, cfg.vocab_size)
    return shapes


def _init_leaf(key, path: str, shape, dtype):
    """Fan-in scaled normal init; norms zero; special-cased SSM scalars."""
    name = path.split("/")[-1]
    if "norm" in name or name in ("bq", "bk", "bv", "conv_bx", "conv_bB", "conv_bC", "dt_bias"):
        return jnp.zeros(shape, dtype=dtype)
    if name == "A_log":
        # A in [1, 16) as in Mamba-2.
        return jnp.log(
            jax.random.uniform(key, shape, minval=1.0, maxval=16.0, dtype=jnp.float32)
        ).astype(dtype)
    if name == "D":
        return jnp.ones(shape, dtype=dtype)
    if name == "embed":
        return (jax.random.normal(key, shape, dtype=jnp.float32) * 0.02).astype(dtype)
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


def init_params(cfg: ModelConfig, rng: jax.Array) -> Dict:
    shapes = param_shapes(cfg)
    dtype = jnp.dtype(cfg.param_dtype)
    flat, treedef = tree_flatten_with_path(shapes, is_leaf=lambda s: isinstance(s, tuple))
    leaves = []
    for i, (path, shape) in enumerate(flat):
        pathstr = "/".join(str(p.key) for p in path)
        leaves.append(_init_leaf(jax.random.fold_in(rng, i), pathstr, shape, dtype))
    return jax.tree.unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Partition specs (2-D sharding: FSDP over data axes ⊗ TP over model axis)
# ---------------------------------------------------------------------------


def _axis_size(mesh_axes: Dict[str, int], axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh_axes.get(axes, 1)
    n = 1
    for a in axes:
        n *= mesh_axes.get(a, 1)
    return n


def param_pspecs(
    cfg: ModelConfig,
    mesh_axes: Dict[str, int],
    data_axes=("data",),
    model_axis: str = "model",
) -> Dict:
    """PartitionSpec pytree matching ``param_shapes``. A dim is sharded only

    when evenly divisible by the axis-product (GSPMD would pad otherwise)."""
    dsz = _axis_size(mesh_axes, data_axes)
    msz = _axis_size(mesh_axes, model_axis) if model_axis else 1
    da = tuple(data_axes) if not isinstance(data_axes, str) else (data_axes,)
    da_spec = da if len(da) > 1 else da[0]

    def rule(pathstr: str, shape) -> P:
        name = pathstr.split("/")[-1]

        def d_ok(dim):
            return shape[dim] % dsz == 0

        def m_ok(dim):
            # model_axis=None: pure-FSDP variant — never TP-shard anything
            return model_axis is not None and shape[dim] % msz == 0

        if "norm" in name or name in ("A_log", "D", "dt_bias", "conv_bx", "conv_bB", "conv_bC"):
            return P()
        if name == "embed":  # (V, d)
            return P(model_axis if m_ok(0) else None, da_spec if d_ok(1) else None)
        if name == "lm_head":  # (d, V)
            return P(da_spec if d_ok(0) else None, model_axis if m_ok(1) else None)
        if name in ("wq", "wk", "wv", "w_gate", "w_up", "in_z", "in_x", "in_B", "in_C", "in_dt"):
            # (L, in, out): FSDP on in, TP on out
            return P(None, da_spec if d_ok(1) else None, model_axis if m_ok(2) else None)
        if name in ("wo", "w_down", "out_proj"):
            # (L, in, out): TP on in, FSDP on out
            return P(None, model_axis if m_ok(1) else None, da_spec if d_ok(2) else None)
        if name in ("bq", "bk", "bv"):
            return P(None, model_axis if m_ok(1) else None)
        if name == "router":  # (L, d, E)
            return P(None, da_spec if d_ok(1) else None, None)
        if name in ("we_gate", "we_up"):  # (L, E, d, ff)
            return P(None, None, da_spec if d_ok(2) else None, model_axis if m_ok(3) else None)
        if name == "we_down":  # (L, E, ff, d)
            return P(None, None, model_axis if m_ok(2) else None, da_spec if d_ok(3) else None)
        if name in ("conv_x", "conv_B", "conv_C"):  # (L, K, ch)
            return P(None, None, model_axis if m_ok(2) else None)
        return P()

    shapes = param_shapes(cfg)
    flat, treedef = tree_flatten_with_path(shapes, is_leaf=lambda s: isinstance(s, tuple))
    specs = []
    for path, shape in flat:
        pathstr = "/".join(str(p.key) for p in path)
        specs.append(rule(pathstr, shape))
    return jax.tree.unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# Forward (training / scoring)
# ---------------------------------------------------------------------------

from repro.models.scan_util import scan_or_unroll as _layer_scan  # noqa: E402


def _embed_input(cfg: ModelConfig, params: Dict, batch: Dict) -> jax.Array:
    from repro.models import shard_hints

    cd = jnp.dtype(cfg.compute_dtype)
    if cfg.embed_inputs:
        x = embed_tokens(params["embed"], batch["tokens"], cd)
    else:
        x = batch["embeds"].astype(cd)
    # Without this, the vocab-sharded gather can emit a replicated (b, s, d)
    # and every scan residual downstream stays replicated (≈ L × b × s × d
    # per device). See EXPERIMENTS.md §Perf iteration 1.
    return shard_hints.constrain(x, shard_hints.current().activations)


def _positions(cfg: ModelConfig, batch: Dict, b: int, s: int, offset=0) -> jax.Array:
    if "positions" in batch:
        return batch["positions"]
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :] + offset, (b, s))
    if cfg.mrope_sections is not None:
        return jnp.broadcast_to(pos[None], (3, b, s))
    return pos


def _block_train(cfg: ModelConfig, p: Dict, x, kind, positions):
    from repro.models import shard_hints

    x = shard_hints.constrain(x, shard_hints.current().activations)
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["pre_norm"], cfg.norm_eps)
    if cfg.family == "hybrid":
        a = attention_train(cfg, p, h, kind, positions)
        s = ssm_lib.ssm_mixer_train(cfg, p["ssm"], h)
        x = x + 0.5 * (a + s)
    elif cfg.family == "ssm":
        x = x + ssm_lib.ssm_mixer_train(cfg, p["ssm"], h)
    else:
        x = x + attention_train(cfg, p, h, kind, positions)
    if cfg.d_ff > 0:
        h = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
        if cfg.uses_moe:
            y, aux = moe_block(cfg, p, h)
        else:
            y = swiglu_mlp(p, h)
        x = x + y
    return x, aux


def forward(
    cfg: ModelConfig, params: Dict, batch: Dict, remat: bool = False
) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits (b, s, V), moe_aux_loss)."""
    x = _embed_input(cfg, params, batch)
    b, s = x.shape[0], x.shape[1]
    positions = _positions(cfg, batch, b, s)
    kinds = jnp.asarray(cfg.layer_kinds(), dtype=jnp.int32)

    block = _block_train
    if remat:
        block = jax.checkpoint(_block_train, static_argnums=(0,), prevent_cse=False)

    def body(carry, xs):
        x, aux = carry
        p, kind = xs
        x, a = block(cfg, p, x, kind, positions)
        return (x, aux + a), None

    (x, aux), _ = _layer_scan(body, (x, jnp.zeros((), jnp.float32)), (params["blocks"], kinds))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return lm_head_logits(cfg, params, x), aux


MOE_AUX_WEIGHT = 0.01


def loss_fn(
    cfg: ModelConfig, params: Dict, batch: Dict, remat: bool = False
) -> Tuple[jax.Array, Dict]:
    logits, aux = forward(cfg, params, batch, remat=remat)
    ce = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
    loss = ce + MOE_AUX_WEIGHT * aux
    preds = jnp.argmax(logits, axis=-1)
    acc = jnp.mean((preds == batch["labels"]).astype(jnp.float32))
    return loss, {"ce": ce, "moe_aux": aux, "acc": acc}


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------


def _attn_cache_len(cfg: ModelConfig, kind: int, max_len: int) -> int:
    w = cfg.window_for_kind(kind)
    return min(w, max_len) if w is not None else max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    """Decode cache pytree. Layout depends on the family / attention pattern."""
    cd = jnp.dtype(cfg.compute_dtype)
    hd, kvh, L = cfg.resolved_head_dim, cfg.num_kv_heads, cfg.num_layers
    cache: Dict = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.family == "ssm":
        cache["ssm"] = ssm_lib.init_ssm_cache(cfg, L, batch, cd)
        return cache
    if cfg.local_global_ratio > 0:
        r = cfg.local_global_ratio
        G = r + 1
        n_groups = L // G
        W = _attn_cache_len(cfg, 1, max_len)
        S = _attn_cache_len(cfg, 0, max_len)
        cache["k_local"] = jnp.zeros((n_groups, r, batch, W, kvh, hd), dtype=cd)
        cache["v_local"] = jnp.zeros((n_groups, r, batch, W, kvh, hd), dtype=cd)
        cache["k_global"] = jnp.zeros((n_groups, batch, S, kvh, hd), dtype=cd)
        cache["v_global"] = jnp.zeros((n_groups, batch, S, kvh, hd), dtype=cd)
        return cache
    S = _attn_cache_len(cfg, cfg.layer_kinds()[0], max_len)
    if cfg.kv_cache_dtype == "int8":
        cache["k"] = jnp.zeros((L, batch, S, kvh, hd), dtype=jnp.int8)
        cache["v"] = jnp.zeros((L, batch, S, kvh, hd), dtype=jnp.int8)
        cache["k_scale"] = jnp.zeros((L, batch, S, kvh), dtype=jnp.float32)
        cache["v_scale"] = jnp.zeros((L, batch, S, kvh), dtype=jnp.float32)
    else:
        cache["k"] = jnp.zeros((L, batch, S, kvh, hd), dtype=cd)
        cache["v"] = jnp.zeros((L, batch, S, kvh, hd), dtype=cd)
    if cfg.family == "hybrid":
        cache["ssm"] = ssm_lib.init_ssm_cache(cfg, L, batch, cd)
    return cache


def _ring(cfg: ModelConfig) -> bool:
    # Uniform-cache archs: ring iff every layer is windowed.
    return cfg.window is not None and cfg.local_global_ratio == 0


def _block_decode(cfg: ModelConfig, p: Dict, x, c: Dict, pos, positions, ring: bool):
    """One block, one token. c holds this layer's cache slices."""
    newc: Dict = {}
    h = rms_norm(x, p["pre_norm"], cfg.norm_eps)
    attn_keys = ("k", "v", "k_scale", "v_scale")
    attn_c = {k: c[k] for k in attn_keys if k in c}
    if cfg.family == "hybrid":
        a, attn_new = attention_decode(cfg, p, h, attn_c, pos, positions, 0, ring)
        s_out, nssm = ssm_lib.ssm_mixer_decode(cfg, p["ssm"], h, c["ssm"])
        x = x + 0.5 * (a + s_out)
        newc.update(attn_new)
        newc["ssm"] = nssm
    elif cfg.family == "ssm":
        s_out, nssm = ssm_lib.ssm_mixer_decode(cfg, p["ssm"], h, c["ssm"])
        x = x + s_out
        newc["ssm"] = nssm
    else:
        a, attn_new = attention_decode(cfg, p, h, attn_c, pos, positions, 0, ring)
        x = x + a
        newc.update(attn_new)
    if cfg.d_ff > 0:
        h = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
        if cfg.uses_moe:
            y, _ = moe_block(cfg, p, h)
        else:
            y = swiglu_mlp(p, h)
        x = x + y
    return x, newc


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict, batch: Dict):
    """One-token decode. batch: {'tokens': (b,1)} or {'embeds': (b,1,d)}.

    Returns (logits (b, V), new_cache)."""
    x = _embed_input(cfg, params, batch)
    b = x.shape[0]
    pos = cache["pos"]
    positions = _positions(cfg, batch, b, 1, offset=pos)

    if cfg.local_global_ratio > 0:
        x, new_cache = _decode_grouped(cfg, params, cache, x, pos, positions)
    else:
        layer_cache = {k: v for k, v in cache.items() if k != "pos"}

        def body(x, xs):
            p, c = xs
            x, newc = _block_decode(cfg, p, x, c, pos, positions, _ring(cfg))
            return x, newc

        x, new_layer_cache = _layer_scan(body, x, (params["blocks"], layer_cache))
        new_cache = dict(new_layer_cache)

    new_cache["pos"] = pos + 1
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_head_logits(cfg, params, x)
    return logits[:, 0, :], new_cache


def _decode_grouped(cfg: ModelConfig, params: Dict, cache: Dict, x, pos, positions):
    """Grouped scan for local:global archs (two cache geometries)."""
    r = cfg.local_global_ratio
    G = r + 1
    n_groups = cfg.num_layers // G
    grouped = jax.tree.map(lambda a: a.reshape(n_groups, G, *a.shape[1:]), params["blocks"])

    def body(x, xs):
        p_g, kl, vl, kg, vg = xs
        new_kl, new_vl = [], []
        for i in range(r):
            p_i = jax.tree.map(lambda a: a[i], p_g)
            xi, ci = _block_decode(
                cfg, p_i, x, {"k": kl[i], "v": vl[i]}, pos, positions, ring=True
            )
            x = xi
            new_kl.append(ci["k"])
            new_vl.append(ci["v"])
        p_glob = jax.tree.map(lambda a: a[r], p_g)
        x, cg = _block_decode(cfg, p_glob, x, {"k": kg, "v": vg}, pos, positions, ring=False)
        return x, (jnp.stack(new_kl), jnp.stack(new_vl), cg["k"], cg["v"])

    x, (kl, vl, kg, vg) = _layer_scan(
        body, x, (grouped, cache["k_local"], cache["v_local"], cache["k_global"], cache["v_global"])
    )
    new_cache = {"k_local": kl, "v_local": vl, "k_global": kg, "v_global": vg}
    return x, new_cache


# ---------------------------------------------------------------------------
# Prefill (forward + cache construction)
# ---------------------------------------------------------------------------


def _ring_place(k: jax.Array, W: int) -> jax.Array:
    """Place the last W entries of k (b, s, ...) at slots (pos % W)."""
    s = k.shape[1]
    if s < W:
        pad = jnp.zeros((k.shape[0], W - s, *k.shape[2:]), dtype=k.dtype)
        return jnp.concatenate([k, pad], axis=1)
    tail = k[:, s - W :]
    slots = (np.arange(s - W, s) % W).astype(np.int32)
    inv = np.argsort(slots)
    return tail[:, inv]


def _full_place(k: jax.Array, S: int) -> jax.Array:
    s = k.shape[1]
    if s >= S:
        return k[:, :S]
    pad = jnp.zeros((k.shape[0], S - s, *k.shape[2:]), dtype=k.dtype)
    return jnp.concatenate([k, pad], axis=1)


def _attn_train_with_kv(cfg, p, x, kind, positions):
    """attention_train that also returns post-rope K/V for cache building."""
    from repro.models.layers import (
        BLOCKED_ATTN_THRESHOLD,
        _window_eff,
        apply_mrope,
        apply_rope,
        causal_mask_bias,
        gqa_scores_softmax_value,
    )

    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    h, kvh = cfg.num_heads, cfg.num_kv_heads
    cd = x.dtype
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"].astype(cd))
    if cfg.qkv_bias:
        q, k, v = q + p["bq"].astype(cd), k + p["bk"].astype(cd), v + p["bv"].astype(cd)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kvh, hd)
    v = v.reshape(b, s, kvh, hd)
    if cfg.mrope_sections is not None:
        q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if s >= BLOCKED_ATTN_THRESHOLD:
        from repro.models.flash import flash_gqa_attention

        out = flash_gqa_attention(q, k, v, _window_eff(cfg, kind, s), 0)
    else:
        full_bias = causal_mask_bias(s, cfg.window_for_kind(0))
        if cfg.local_global_ratio > 0 or cfg.window is not None:
            local_bias = causal_mask_bias(s, cfg.window_for_kind(1))
            bias = jnp.where(kind == 1, local_bias, full_bias)
        else:
            bias = full_bias
        out = gqa_scores_softmax_value(q, k, v, bias)
    out = out.reshape(b, s, h * hd)
    return jnp.einsum("bsq,qd->bsd", out, p["wo"].astype(cd)), k, v


def _block_prefill(cfg: ModelConfig, p: Dict, x, kind_static: int, positions, max_len: int):
    newc: Dict = {}
    h = rms_norm(x, p["pre_norm"], cfg.norm_eps)
    if cfg.family == "hybrid":
        a, k, v = _attn_train_with_kv(cfg, p, h, jnp.int32(kind_static), positions)
        s_out, ssm_c = ssm_lib.ssm_mixer_prefill(cfg, p["ssm"], h)
        x = x + 0.5 * (a + s_out)
        newc["ssm"] = ssm_c
    elif cfg.family == "ssm":
        s_out, ssm_c = ssm_lib.ssm_mixer_prefill(cfg, p["ssm"], h)
        x = x + s_out
        newc["ssm"] = ssm_c
        k = v = None
    else:
        a, k, v = _attn_train_with_kv(cfg, p, h, jnp.int32(kind_static), positions)
        x = x + a
    if k is not None:
        C = _attn_cache_len(cfg, kind_static, max_len)
        w = cfg.window_for_kind(kind_static)
        place = _ring_place if (w is not None and C == w) else _full_place
        if cfg.kv_cache_dtype == "int8" and cfg.local_global_ratio == 0:
            from repro.models.layers import quantize_kv

            kq, ks = quantize_kv(k)
            vq, vs = quantize_kv(v)
            newc["k"], newc["v"] = place(kq, C), place(vq, C)
            newc["k_scale"], newc["v_scale"] = place(ks, C), place(vs, C)
        else:
            newc["k"], newc["v"] = place(k, C), place(v, C)
    if cfg.d_ff > 0:
        hh = rms_norm(x, p["mlp_norm"], cfg.norm_eps)
        y = moe_block(cfg, p, hh)[0] if cfg.uses_moe else swiglu_mlp(p, hh)
        x = x + y
    return x, newc


def prefill(cfg: ModelConfig, params: Dict, batch: Dict, max_len: int):
    """Run the full prompt, return (logits (b, s, V), decode cache)."""
    x = _embed_input(cfg, params, batch)
    b, s = x.shape[0], x.shape[1]
    positions = _positions(cfg, batch, b, s)

    if cfg.local_global_ratio > 0:
        r = cfg.local_global_ratio
        G = r + 1
        n_groups = cfg.num_layers // G
        grouped = jax.tree.map(lambda a: a.reshape(n_groups, G, *a.shape[1:]), params["blocks"])

        def body(x, p_g):
            kl, vl = [], []
            for i in range(r):
                p_i = jax.tree.map(lambda a: a[i], p_g)
                x, c = _block_prefill(cfg, p_i, x, 1, positions, max_len)
                kl.append(c["k"])
                vl.append(c["v"])
            p_glob = jax.tree.map(lambda a: a[r], p_g)
            x, cg = _block_prefill(cfg, p_glob, x, 0, positions, max_len)
            return x, (jnp.stack(kl), jnp.stack(vl), cg["k"], cg["v"])

        x, (kl, vl, kg, vg) = _layer_scan(body, x, grouped)
        cache = {"k_local": kl, "v_local": vl, "k_global": kg, "v_global": vg}
    else:
        kind = cfg.layer_kinds()[0]

        def body(x, p):
            x, c = _block_prefill(cfg, p, x, kind, positions, max_len)
            return x, c

        x, cache = _layer_scan(body, x, params["blocks"])
        cache = dict(cache)

    cache["pos"] = jnp.asarray(s, jnp.int32)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return lm_head_logits(cfg, params, x), cache


# ---------------------------------------------------------------------------
# Stage partitioning (consumed by the Ferret pipeline engine)
# ---------------------------------------------------------------------------


def split_stage_params(cfg: ModelConfig, params: Dict, boundaries) -> list:
    """Split into P stage subtrees. boundaries = partition scheme L (P+1 ints).

    Stage 0 owns the embedding; the last stage owns final_norm (+ lm_head).
    """
    P_ = len(boundaries) - 1
    stages = []
    for j in range(P_):
        lo, hi = boundaries[j], boundaries[j + 1]
        sp: Dict = {"blocks": jax.tree.map(lambda a: a[lo:hi], params["blocks"])}
        if j == 0:
            sp["embed"] = params["embed"]
        if j == P_ - 1:
            sp["final_norm"] = params["final_norm"]
            if not cfg.tie_embeddings:
                sp["lm_head"] = params["lm_head"]
        stages.append(sp)
    return stages


def merge_stage_params(cfg: ModelConfig, stages: list) -> Dict:
    """Inverse of split_stage_params."""
    blocks = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *[s["blocks"] for s in stages])
    params = {"embed": stages[0]["embed"], "blocks": blocks, "final_norm": stages[-1]["final_norm"]}
    if "lm_head" in stages[-1]:
        params["lm_head"] = stages[-1]["lm_head"]
    return params


def stage_forward(
    cfg: ModelConfig,
    stage_params: Dict,
    x_or_batch,
    stage_idx: int,
    num_stages: int,
    boundaries,
    batch: Dict,
    remat: bool = False,
):
    """Forward one pipeline stage. Stage 0 receives the batch (embeds);

    later stages receive activations. The last stage returns logits."""
    lo, hi = boundaries[stage_idx], boundaries[stage_idx + 1]
    if stage_idx == 0:
        x = _embed_input(
            cfg, {"embed": stage_params.get("embed")} if cfg.embed_inputs else {}, batch
        )
    else:
        x = x_or_batch
    b, s = x.shape[0], x.shape[1]
    positions = _positions(cfg, batch, b, s)
    kinds = jnp.asarray(cfg.layer_kinds()[lo:hi], dtype=jnp.int32)

    block = _block_train
    if remat:
        block = jax.checkpoint(_block_train, static_argnums=(0,), prevent_cse=False)

    def body(carry, xs):
        x, aux = carry
        p, kind = xs
        x, a = block(cfg, p, x, kind, positions)
        return (x, aux + a), None

    (x, aux), _ = _layer_scan(
        body, (x, jnp.zeros((), jnp.float32)), (stage_params["blocks"], kinds)
    )
    if stage_idx == num_stages - 1:
        x = rms_norm(x, stage_params["final_norm"], cfg.norm_eps)
        logits = lm_head_logits(cfg, stage_params, x)
        return logits, aux
    return x, aux
