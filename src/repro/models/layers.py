"""Shared neural-net layers (pure functions over pytrees).

Conventions
-----------
- Activations travel in ``cfg.compute_dtype`` (bf16 by default); softmax,
  norms and router math accumulate in float32.
- Attention tensors are laid out ``(batch, seq, heads, head_dim)``.
- All layers are shape-polymorphic and jit/scan-friendly (no Python control
  flow on traced values).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + weight.astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE and M-RoPE)
# ---------------------------------------------------------------------------


def _rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,)."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponent)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Standard RoPE.

    x: (b, s, h, d); positions: (b, s) int32.
    """
    head_dim = x.shape[-1]
    freqs = _rope_freqs(head_dim, theta)  # (d/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (b, s, d/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,
    sections: Tuple[int, int, int],
    theta: float,
) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): three position streams (t, h, w) own

    disjoint sections of the frequency spectrum.

    x: (b, s, h, d); positions: (3, b, s) int32; sum(sections) == d // 2.
    """
    head_dim = x.shape[-1]
    freqs = _rope_freqs(head_dim, theta)  # (d/2,)
    sec = jnp.concatenate(
        [jnp.full((n,), i, dtype=jnp.int32) for i, n in enumerate(sections)]
    )  # (d/2,) section id per frequency
    # Select, per frequency, the matching position stream.
    pos = positions.astype(jnp.float32)  # (3, b, s)
    pos_per_freq = jnp.take(pos, sec, axis=0)  # (d/2, b, s)
    angles = jnp.transpose(pos_per_freq, (1, 2, 0)) * freqs  # (b, s, d/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

_NEG_INF = -1e30


def causal_mask_bias(seq: int, window: Optional[int]) -> jax.Array:
    """(1, 1, seq, seq) additive float32 bias; window=None -> plain causal."""
    q_pos = jnp.arange(seq)[:, None]
    k_pos = jnp.arange(seq)[None, :]
    allowed = k_pos <= q_pos
    if window is not None:
        allowed &= k_pos > q_pos - window
    return jnp.where(allowed, 0.0, _NEG_INF).astype(jnp.float32)[None, None]


def gqa_scores_softmax_value(
    q: jax.Array,  # (b, s_q, h, d)
    k: jax.Array,  # (b, s_k, kv, d)
    v: jax.Array,  # (b, s_k, kv, d)
    bias: Optional[jax.Array],  # broadcastable to (b, h, s_q, s_k) or None
) -> jax.Array:
    """Grouped-query attention core. Returns (b, s_q, h, d)."""
    b, s_q, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, s_q, kv, g, d)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k, preferred_element_type=jnp.float32)
    scores = scores * scale
    if bias is not None:
        # bias (1/b, 1/h, s_q, s_k) -> (b, kv, g, s_q, s_k)
        bias_ = jnp.broadcast_to(bias, (b, h, s_q, scores.shape[-1])).reshape(
            b, kv, g, s_q, scores.shape[-1]
        )
        scores = scores + bias_
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, s_q, h, d)


# Sequences at or above this length use the blocked (flash-style) path:
# O(s·KB) live scores instead of the O(s²) dense materialization.
BLOCKED_ATTN_THRESHOLD = 2048
BLOCKED_ATTN_KV_BLOCK = 512


def blocked_gqa_attention(
    q: jax.Array,  # (b, s, h, d)
    k: jax.Array,  # (b, s, kv, d)
    v: jax.Array,  # (b, s, kv, d)
    window_eff: jax.Array,  # traced scalar: effective window (≥ s+KB ⇒ full causal)
    kv_block: int = BLOCKED_ATTN_KV_BLOCK,
) -> jax.Array:
    """Flash-style causal attention: scan over KV blocks with online softmax.

    Never materializes the (s × s) score matrix — the live working set is
    (b, kv, g, s, KB). Masked positions get probability exactly 0, so the
    result matches the dense path bit-for-bit up to fp accumulation order.
    """
    b, s, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    KB = min(kv_block, s)
    pad = (-s) % KB
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = k.shape[1] // KB
    kb = jnp.moveaxis(k.reshape(b, nb, KB, kvh, d), 1, 0)  # (nb, b, KB, kv, d)
    vb = jnp.moveaxis(v.reshape(b, nb, KB, kvh, d), 1, 0)

    qg = q.reshape(b, s, kvh, g, d)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    qpos = jnp.arange(s)

    m0 = jnp.full((b, kvh, g, s), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, s), jnp.float32)
    a0 = jnp.zeros((b, s, kvh, g, d), jnp.float32)

    def body(carry, inp):
        m, l, acc = carry
        kblk, vblk, idx = inp
        scores = (
            jnp.einsum("bqkgd,bskd->bkgqs", qg, kblk, preferred_element_type=jnp.float32)
            * scale
        )  # (b, kv, g, s, KB)
        kpos = idx * KB + jnp.arange(KB)
        allowed = (kpos[None, :] <= qpos[:, None]) & (
            kpos[None, :] > qpos[:, None] - window_eff
        )  # (s, KB)
        scores = jnp.where(allowed[None, None, None], scores, -1e30)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        p = jnp.exp(scores - m_new[..., None]) * allowed[None, None, None]
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(vblk.dtype), vblk).astype(jnp.float32)
        acc_new = acc * jnp.moveaxis(corr, 3, 1)[..., None] + pv
        return (m_new, l_new, acc_new), None

    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, jnp.arange(nb)))
    out = acc / jnp.maximum(jnp.moveaxis(l, 3, 1), 1e-30)[..., None]
    return out.reshape(b, s, h, d).astype(q.dtype)


def _window_eff(cfg: ModelConfig, kind: jax.Array, s: int) -> jax.Array:
    """Traced effective window: local layers use their window, full layers s+∞."""
    full_w = cfg.window_for_kind(0)
    local_w = cfg.window_for_kind(1)
    big = jnp.asarray(s + BLOCKED_ATTN_KV_BLOCK + 1, jnp.int32)
    w0 = jnp.asarray(full_w, jnp.int32) if full_w is not None else big
    w1 = jnp.asarray(local_w, jnp.int32) if local_w is not None else big
    return jnp.where(kind == 1, w1, w0)


def attention_train(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,  # (b, s, d_model)
    kind: jax.Array,  # scalar int: 0 full/global, 1 local
    positions: jax.Array,  # (b, s) or (3, b, s) for mrope
) -> jax.Array:
    """Full-sequence causal attention for training / prefill."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    h, kvh = cfg.num_heads, cfg.num_kv_heads
    cd = x.dtype

    q = jnp.einsum("bsd,dq->bsq", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kvh, hd)
    v = v.reshape(b, s, kvh, hd)

    if cfg.mrope_sections is not None:
        q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if s >= BLOCKED_ATTN_THRESHOLD:
        from repro.models.flash import flash_gqa_attention

        out = flash_gqa_attention(q, k, v, _window_eff(cfg, kind, s), 0)
    else:
        # Additive bias: full-causal and windowed variants selected by `kind`.
        full_bias = causal_mask_bias(s, cfg.window_for_kind(0))
        if cfg.local_global_ratio > 0 or cfg.window is not None:
            local_bias = causal_mask_bias(s, cfg.window_for_kind(1))
            bias = jnp.where(kind == 1, local_bias, full_bias)
        else:
            bias = full_bias
        out = gqa_scores_softmax_value(q, k, v, bias)
    out = out.reshape(b, s, h * hd)
    return jnp.einsum("bsq,qd->bsd", out, p["wo"].astype(cd))


def quantize_kv(x: jax.Array):
    """Per-(…, head) int8 quantization over the trailing head_dim.

    x: (..., hd) -> (q int8 (..., hd), scale f32 (...,))."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32), axis=-1), 1e-8) / 127.0
    q = jnp.clip(jnp.round(x32 / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)).astype(dtype)


def attention_decode(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,  # (b, 1, d_model)
    cache: dict,  # {'k','v'[, 'k_scale','v_scale']} — int8 cache carries scales
    cache_len: jax.Array,  # scalar int32: number of valid entries
    position: jax.Array,  # (b, 1) absolute position (or (3, b, 1) for mrope)
    kind: jax.Array,  # scalar int (unused in decode; validity via cache_len)
    ring: bool,
) -> Tuple[jax.Array, dict]:
    """One decode step against a (possibly ring-buffered, possibly int8-

    quantized) KV cache. Returns (out (b,1,d_model), new_cache)."""
    b, _, _ = x.shape
    hd = cfg.resolved_head_dim
    h, kvh = cfg.num_heads, cfg.num_kv_heads
    cd = x.dtype
    S = cache["k"].shape[1]

    q = jnp.einsum("bsd,dq->bsq", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    q = q.reshape(b, 1, h, hd)
    k = k.reshape(b, 1, kvh, hd)
    v = v.reshape(b, 1, kvh, hd)

    if cfg.mrope_sections is not None:
        q = apply_mrope(q, position, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, position, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, position, cfg.rope_theta)
        k = apply_rope(k, position, cfg.rope_theta)

    slot = jnp.where(ring, cache_len % S, jnp.minimum(cache_len, S - 1))
    def dus(buf, new):
        return jax.lax.dynamic_update_slice_in_dim(
            buf, new.astype(buf.dtype), slot, axis=1
        )

    newc = dict(cache)
    if "k_scale" in cache:  # int8 path
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        newc["k"] = dus(cache["k"], kq)
        newc["v"] = dus(cache["v"], vq)
        newc["k_scale"] = dus(cache["k_scale"], ks)
        newc["v_scale"] = dus(cache["v_scale"], vs)
        k_full = dequantize_kv(newc["k"], newc["k_scale"], cd)
        v_full = dequantize_kv(newc["v"], newc["v_scale"], cd)
    else:
        newc["k"] = dus(cache["k"], k)
        newc["v"] = dus(cache["v"], v)
        k_full = newc["k"].astype(cd)
        v_full = newc["v"].astype(cd)

    valid = jnp.arange(S) < jnp.minimum(cache_len + 1, S)  # (S,)
    bias = jnp.where(valid, 0.0, _NEG_INF).astype(jnp.float32)[None, None, None, :]
    out = gqa_scores_softmax_value(q, k_full, v_full, bias)
    out = out.reshape(b, 1, h * hd)
    return jnp.einsum("bsq,qd->bsd", out, p["wo"].astype(cd)), newc


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------


def swiglu_mlp(p: dict, x: jax.Array) -> jax.Array:
    cd = x.dtype
    gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(cd))
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(cd))
    act = jax.nn.silu(gate.astype(jnp.float32)).astype(cd) * up
    return jnp.einsum("bsf,fd->bsd", act, p["w_down"].astype(cd))


# ---------------------------------------------------------------------------
# MoE: sort-based capacity dispatch (dropless up to the capacity factor)
# ---------------------------------------------------------------------------


def moe_dispatch(
    expert_ids: jax.Array,  # (T, k) int32
    num_experts: int,
    capacity: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Compute scatter destinations for sorted token->expert dispatch.

    Returns (dest (T*k,), keep (T*k,), order (T*k,)) where ``dest`` indexes a
    flattened (E * C + 1) buffer (the final slot is the drop bin), for tokens
    in *sorted* order, and ``order`` is the sort permutation over the
    flattened (T*k,) routed copies.
    """
    flat = expert_ids.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat, stable=True)
    sorted_ids = flat[order]
    counts = jnp.bincount(flat, length=num_experts)  # (E,)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(flat.shape[0]) - starts[sorted_ids]
    keep = rank < capacity
    dest = jnp.where(keep, sorted_ids * capacity + rank, num_experts * capacity)
    return dest, keep, order


def moe_block(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,  # (b, s, d)
) -> Tuple[jax.Array, jax.Array]:
    """Top-k routed MoE FFN, GShard-style *grouped* dispatch.

    Each batch row is its own dispatch group (capacity = cf·s·k/E per row),
    so every sort/scatter/gather carries the batch dim — the data-parallel
    sharding of `b` survives through the whole block and no (tokens, d)
    tensor is ever replicated (see EXPERIMENTS.md §Perf iteration 3).
    Returns (output, aux_load_balance_loss)."""
    b, s, d = x.shape
    cd = x.dtype
    E, k = cfg.num_experts, cfg.experts_per_token
    capacity = int(cfg.moe_capacity_factor * s * k / E)
    capacity = max(4, min(capacity, s))

    router_logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(router_logits, axis=-1)  # (b, s, E)
    top_w, top_ids = jax.lax.top_k(probs, k)  # (b, s, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    # --- per-row dispatch bookkeeping (every op carries the leading b) ---
    flat_ids = top_ids.reshape(b, s * k)
    order = jnp.argsort(flat_ids, axis=-1, stable=True)  # (b, sk)
    sorted_ids = jnp.take_along_axis(flat_ids, order, axis=-1)
    counts = jnp.sum(jax.nn.one_hot(flat_ids, E, dtype=jnp.int32), axis=1)  # (b, E)
    starts = jnp.concatenate(
        [jnp.zeros((b, 1), jnp.int32), jnp.cumsum(counts, axis=-1)[:, :-1]], axis=-1
    )
    rank = jnp.arange(s * k)[None, :] - jnp.take_along_axis(starts, sorted_ids, axis=-1)
    keep = rank < capacity
    dest = jnp.where(keep, sorted_ids * capacity + rank, E * capacity)  # (b, sk)
    token_of_copy = order // k  # (b, sk)

    # --- scatter into per-row (E·C [+1 drop]) buffers ---
    from repro.models import shard_hints

    xk = jnp.take_along_axis(x.astype(cd), token_of_copy[..., None], axis=1)  # (b, sk, d)
    xk = shard_hints.constrain_batch_dim(xk)
    buf = jnp.zeros((b, E * capacity + 1, d), dtype=cd)
    buf = jax.vmap(lambda bb, dd, xx: bb.at[dd].set(xx))(buf, dest, xk)
    buf = shard_hints.constrain_batch_dim(buf)
    expert_in = buf[:, : E * capacity].reshape(b, E, capacity, d)
    expert_in = shard_hints.constrain_batch_dim(expert_in)

    # One expert at a time (lax.scan): bounds the FSDP-gathered weight
    # liveness to a single expert's (d, ff) tiles in fwd AND bwd — without
    # this the scheduler keeps several full (E, d, ff) gathers alive and
    # 141B-class MoE trains blow the 16 GB/chip budget.
    from repro.models.scan_util import scan_or_unroll

    def _one_expert(_, xs):
        wg, wu, wd, xin = xs  # (d,ff), (d,ff), (ff,d), (b, C, d)
        g = jnp.einsum("bcd,df->bcf", xin, wg.astype(cd))
        u = jnp.einsum("bcd,df->bcf", xin, wu.astype(cd))
        a = jax.nn.silu(g.astype(jnp.float32)).astype(cd) * u
        return None, jnp.einsum("bcf,fd->bcd", a, wd.astype(cd))

    _, expert_out = scan_or_unroll(
        _one_expert,
        None,
        (p["we_gate"], p["we_up"], p["we_down"], jnp.moveaxis(expert_in, 1, 0)),
    )
    expert_out = jnp.moveaxis(expert_out, 0, 1)  # (b, E, C, d)
    expert_out = shard_hints.constrain_batch_dim(expert_out)

    flat_out = jnp.concatenate(
        [expert_out.reshape(b, E * capacity, d), jnp.zeros((b, 1, d), dtype=cd)], axis=1
    )
    y_copies = jnp.take_along_axis(flat_out, dest[..., None], axis=1)
    y_copies = shard_hints.constrain_batch_dim(y_copies) * keep[..., None].astype(cd)
    w_copies = jnp.take_along_axis(top_w.reshape(b, s * k), order, axis=-1).astype(cd)
    y = jnp.zeros((b, s, d), dtype=jnp.float32)
    y = jax.vmap(lambda yy, tt, vv: yy.at[tt].add(vv))(
        y, token_of_copy, (y_copies * w_copies[..., None]).astype(jnp.float32)
    )
    y = shard_hints.constrain_batch_dim(y)

    # Switch-style load-balance aux loss.
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    ce = jnp.mean(jax.nn.one_hot(top_ids[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    return y.astype(cd), aux


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed_tokens(embed: jax.Array, tokens: jax.Array, compute_dtype) -> jax.Array:
    return jnp.take(embed, tokens, axis=0).astype(compute_dtype)


def lm_head_logits(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    from repro.models import shard_hints

    cd = x.dtype
    if cfg.tie_embeddings:
        w = params["embed"].astype(cd)  # (V, d)
        logits = jnp.einsum("...d,vd->...v", x, w)
    else:
        logits = jnp.einsum("...d,dv->...v", x, params["lm_head"].astype(cd))
    hint = shard_hints.current().logits
    if hint is not None and logits.ndim != len(hint):
        hint = None  # spatial-pipeline path: (M, b, s, V) — let GSPMD decide
    return shard_hints.constrain(logits, hint)


def cross_entropy_loss(
    logits: jax.Array,  # (b, s, V)
    labels: jax.Array,  # (b, s) int32
    mask: Optional[jax.Array] = None,  # (b, s) float/bool
) -> jax.Array:
    logits32 = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits32, axis=-1)
    gold = jnp.take_along_axis(logits32, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
