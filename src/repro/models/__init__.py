"""Model zoo: pure-pytree JAX decoder LMs (dense / MoE / SSM / hybrid / VLM / audio).

Every architecture exposes the same functional API:

    params = init_params(cfg, rng)                  # pytree of jnp arrays
    pspecs = param_pspecs(cfg)                      # matching pytree of PartitionSpec
    logits = forward(cfg, params, batch)            # training forward
    loss, aux = loss_fn(cfg, params, batch)
    cache  = init_cache(cfg, batch, max_len)        # decode caches (KV / ring / SSM state)
    logits, cache = decode_step(cfg, params, cache, batch)

Blocks are homogeneous and scanned (``jax.lax.scan`` over stacked per-layer
parameters) so that the lowered HLO stays compact even for 80-layer models.
"""

from repro.models.config import ModelConfig
from repro.models.registry import get_config, build_model, ARCHITECTURES

__all__ = ["ModelConfig", "get_config", "build_model", "ARCHITECTURES"]
