"""Mamba-2 (SSD) mixer used by the `ssm` and `hybrid` families.

The projection is de-fused relative to the reference implementation (separate
z/x/B/C/dt projections instead of one fused ``in_proj``) — mathematically
identical, but every weight then has TPU-friendly, mesh-divisible dims.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def rms_norm_gated(y: jax.Array, z: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    """Mamba-2 gated RMSNorm: norm(y * silu(z)) * (1 + w)."""
    dtype = y.dtype
    y32 = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y32), axis=-1, keepdims=True)
    out = y32 * jax.lax.rsqrt(var + eps) * (1.0 + weight.astype(jnp.float32))
    return out.astype(dtype)


def causal_depthwise_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (batch, seq, ch); w: (K, ch); b: (ch,). Causal depthwise conv1d."""
    K, ch = w.shape
    lhs = jnp.moveaxis(x, 1, 2)  # (batch, ch, seq)
    rhs = jnp.moveaxis(w, 0, 1)[:, None, :]  # (ch, 1, K)
    out = jax.lax.conv_general_dilated(
        lhs.astype(jnp.float32),
        rhs.astype(jnp.float32),
        window_strides=(1,),
        padding=[(K - 1, 0)],
        feature_group_count=ch,
    )
    out = jnp.moveaxis(out, 2, 1) + b.astype(jnp.float32)
    return out.astype(x.dtype)


def _project(cfg: ModelConfig, p: dict, x: jax.Array):
    """Common z/x/B/C/dt projection. x: (b, s, d)."""
    cd = x.dtype
    z = jnp.einsum("bsd,de->bse", x, p["in_z"].astype(cd))
    xs = jnp.einsum("bsd,de->bse", x, p["in_x"].astype(cd))
    B = jnp.einsum("bsd,dn->bsn", x, p["in_B"].astype(cd))
    C = jnp.einsum("bsd,dn->bsn", x, p["in_C"].astype(cd))
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["in_dt"].astype(cd))
    return z, xs, B, C, dt_raw


def ssm_mixer_train(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    """Full-sequence SSD mixer. x: (b, s, d_model) -> (b, s, d_model)."""
    from repro.kernels import ops  # local import: avoids cycle at module load

    b, s, _ = x.shape
    di, n, nh, ph = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    cd = x.dtype

    z, xs, B, C, dt_raw = _project(cfg, p, x)
    xs = jax.nn.silu(
        causal_depthwise_conv(xs, p["conv_x"], p["conv_bx"]).astype(jnp.float32)
    ).astype(cd)
    B = jax.nn.silu(
        causal_depthwise_conv(B, p["conv_B"], p["conv_bB"]).astype(jnp.float32)
    ).astype(cd)
    C = jax.nn.silu(
        causal_depthwise_conv(C, p["conv_C"], p["conv_bC"]).astype(jnp.float32)
    ).astype(cd)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (nh,)

    xh = xs.reshape(b, s, nh, ph)
    y, _ = ops.ssd_scan(xh, dt, A, B, C, cfg.ssm_chunk)
    y = y + p["D"].astype(cd)[None, None, :, None] * xh
    y = y.reshape(b, s, di)

    y = rms_norm_gated(y, z, p["gate_norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cd))


def ssm_mixer_prefill(
    cfg: ModelConfig, p: dict, x: jax.Array
) -> Tuple[jax.Array, dict]:
    """Like train, but also returns the decode cache (conv tails + final state)."""
    from repro.kernels import ops

    b, s, _ = x.shape
    di, n, nh, ph = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    K = cfg.ssm_conv
    cd = x.dtype

    z, xs_raw, B_raw, C_raw, dt_raw = _project(cfg, p, x)
    xs = jax.nn.silu(
        causal_depthwise_conv(xs_raw, p["conv_x"], p["conv_bx"]).astype(jnp.float32)
    ).astype(cd)
    B = jax.nn.silu(
        causal_depthwise_conv(B_raw, p["conv_B"], p["conv_bB"]).astype(jnp.float32)
    ).astype(cd)
    C = jax.nn.silu(
        causal_depthwise_conv(C_raw, p["conv_C"], p["conv_bC"]).astype(jnp.float32)
    ).astype(cd)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    xh = xs.reshape(b, s, nh, ph)
    y, final_state = ops.ssd_scan(xh, dt, A, B, C, cfg.ssm_chunk)
    y = y + p["D"].astype(cd)[None, None, :, None] * xh
    y = y.reshape(b, s, di)
    y = rms_norm_gated(y, z, p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cd))

    cache = {
        "conv_x": xs_raw[:, -K:, :].astype(cd),
        "conv_B": B_raw[:, -K:, :].astype(cd),
        "conv_C": C_raw[:, -K:, :].astype(cd),
        "state": final_state.astype(jnp.float32),
    }
    return out, cache


def _conv_step(buf: jax.Array, new: jax.Array, w: jax.Array, b: jax.Array):
    """buf: (batch, K, ch) raw inputs; new: (batch, 1, ch). Returns (out (batch, ch), new_buf)."""
    buf = jnp.concatenate([buf[:, 1:, :], new], axis=1)  # shift-in
    out = jnp.einsum("bkc,kc->bc", buf.astype(jnp.float32), w.astype(jnp.float32))
    return (out + b.astype(jnp.float32)).astype(new.dtype), buf


def ssm_mixer_decode(
    cfg: ModelConfig, p: dict, x: jax.Array, cache: dict
) -> Tuple[jax.Array, dict]:
    """One-token decode. x: (b, 1, d_model); cache from ``ssm_mixer_prefill``."""
    from repro.kernels import ops

    b = x.shape[0]
    di, n, nh, ph = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    cd = x.dtype

    z, xs_raw, B_raw, C_raw, dt_raw = _project(cfg, p, x)
    xs_c, conv_x = _conv_step(cache["conv_x"], xs_raw, p["conv_x"], p["conv_bx"])
    B_c, conv_B = _conv_step(cache["conv_B"], B_raw, p["conv_B"], p["conv_bB"])
    C_c, conv_C = _conv_step(cache["conv_C"], C_raw, p["conv_C"], p["conv_bC"])
    xs = jax.nn.silu(xs_c.astype(jnp.float32)).astype(cd)
    B = jax.nn.silu(B_c.astype(jnp.float32)).astype(cd)
    C = jax.nn.silu(C_c.astype(jnp.float32)).astype(cd)

    dt = jax.nn.softplus(dt_raw[:, 0, :].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    xh = xs.reshape(b, nh, ph)
    y, new_state = ops.ssd_decode_step(xh, dt, A, B, C, cache["state"])
    y = y + p["D"].astype(cd)[None, :, None] * xh
    y = y.reshape(b, 1, di)
    y = rms_norm_gated(y, z, p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cd))

    new_cache = {"conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C, "state": new_state}
    return out, new_cache


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------


def ssm_param_shapes(cfg: ModelConfig) -> dict:
    """Shapes for one layer (callers stack a leading L dim)."""
    d, di, n, nh, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv
    return {
        "in_z": (d, di),
        "in_x": (d, di),
        "in_B": (d, n),
        "in_C": (d, n),
        "in_dt": (d, nh),
        "conv_x": (K, di),
        "conv_bx": (di,),
        "conv_B": (K, n),
        "conv_bB": (n,),
        "conv_C": (K, n),
        "conv_bC": (n,),
        "dt_bias": (nh,),
        "A_log": (nh,),
        "D": (nh,),
        "gate_norm": (di,),
        "out_proj": (di, d),
    }


def init_ssm_cache(cfg: ModelConfig, num_layers: int, batch: int, dtype) -> dict:
    di, n, nh, ph, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_conv
    return {
        "conv_x": jnp.zeros((num_layers, batch, K, di), dtype=dtype),
        "conv_B": jnp.zeros((num_layers, batch, K, n), dtype=dtype),
        "conv_C": jnp.zeros((num_layers, batch, K, n), dtype=dtype),
        "state": jnp.zeros((num_layers, batch, nh, ph, n), dtype=jnp.float32),
    }
