"""Scan-or-unroll helper shared by the stack and the MoE block.

XLA's cost analysis counts a scan body once (trip count not folded in), so
the dry-run flips UNROLL to extrapolate exact per-layer costs from small-L
unrolled lowerings (see repro.launch.dryrun). Runtime always uses lax.scan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

UNROLL = False


def scan_or_unroll(body, carry, xs):
    if not UNROLL:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: a[i], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        ys = None
    return carry, ys
