"""Architecture configuration shared by the whole framework."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static description of one architecture.

    The same dataclass describes dense, MoE, SSM, hybrid, VLM and audio
    backbones; family-specific fields are simply unused by other families.
    """

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    # --- attention ---
    head_dim: Optional[int] = None  # default: d_model // num_heads
    qkv_bias: bool = False
    window: Optional[int] = None  # sliding-window size for *all* attn layers
    local_global_ratio: int = 0  # e.g. 5 -> 5 local : 1 global (gemma3)
    local_window: int = 1024
    rope_theta: float = 10_000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25

    # --- SSM (mamba2 / hymba) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 64

    # --- embedding / IO ---
    tie_embeddings: bool = False
    embed_inputs: bool = True  # False: batch provides pre-computed embeddings
    frontend: Optional[str] = None  # 'vision' | 'audio' | None (stubbed)

    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    kv_cache_dtype: str = "bfloat16"  # "int8": quantized KV cache (+f32 scales)
    norm_eps: float = 1e-6

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def uses_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def uses_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def uses_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def full_attention_only(self) -> bool:
        """True when *every* attention layer is unbounded full attention.

        Such architectures cannot run the 524k-token ``long_500k`` shape
        (quadratic/unbounded KV); see DESIGN.md §Arch-applicability.
        """
        if not self.uses_attention:
            return False
        if self.window is not None:
            return False
        if self.local_global_ratio > 0:
            return False  # mostly-windowed, global layers use sharded KV
        if self.family == "hybrid":
            return False
        return True

    def layer_kinds(self) -> Tuple[int, ...]:
        """Per-layer attention kind: 0 = full/global, 1 = local window.

        gemma3-style ``local_global_ratio = r`` yields the repeating pattern
        [local]*r + [global], aligned so the final layer is global.
        """
        if not self.uses_attention:
            return tuple(0 for _ in range(self.num_layers))
        if self.local_global_ratio <= 0:
            kind = 1 if self.window is not None else 0
            return tuple(kind for _ in range(self.num_layers))
        r = self.local_global_ratio
        return tuple(0 if (i % (r + 1)) == r else 1 for i in range(self.num_layers))

    def window_for_kind(self, kind: int) -> Optional[int]:
        if kind == 1:
            return self.local_window if self.local_global_ratio > 0 else self.window
        return self.window  # kind 0: full (None) unless global window set

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Total parameter count (all experts counted)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        n = 0
        n += V * d  # embed
        if not self.tie_embeddings:
            n += d * V  # lm head
        per_layer = d  # shared pre-norm (one per block for all families)
        if self.uses_attention:
            q = self.num_heads * hd
            kv = self.num_kv_heads * hd
            per_layer += d * q + 2 * d * kv + q * d  # wq wk wv wo
            if self.qkv_bias:
                per_layer += q + 2 * kv
        if self.uses_ssm:
            di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
            d_in_proj = 2 * di + 2 * ns + nh
            per_layer += d * d_in_proj
            per_layer += self.ssm_conv * (di + 2 * ns)  # conv kernels
            per_layer += di + 2 * ns  # conv biases
            per_layer += 3 * nh  # A_log, D, dt_bias
            per_layer += di * d  # out_proj
            per_layer += di  # gate norm
        if ff > 0:
            if self.uses_moe:
                per_layer += d * self.num_experts  # router
                per_layer += self.num_experts * 3 * d * ff
            else:
                per_layer += 3 * d * ff  # gate, up, down (SwiGLU)
            per_layer += d  # mlp norm
        n += self.num_layers * per_layer
        n += d  # final norm
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if not self.uses_moe:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        inactive = self.num_layers * (self.num_experts - self.experts_per_token) * 3 * d * ff
        return self.param_count() - inactive
