"""Architecture registry: ``--arch <id>`` → (full config, smoke config)."""

from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.models.config import ModelConfig

# arch id -> config module under repro.configs
ARCHITECTURES: Dict[str, str] = {
    "mamba2-780m": "mamba2_780m",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "qwen1.5-4b": "qwen1_5_4b",
    "stablelm-12b": "stablelm_12b",
    "gemma3-12b": "gemma3_12b",
    "hymba-1.5b": "hymba_1_5b",
    "mixtral-8x22b": "mixtral_8x22b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "musicgen-medium": "musicgen_medium",
}


def _module(arch: str):
    if arch not in ARCHITECTURES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHITECTURES)}")
    return importlib.import_module(f"repro.configs.{ARCHITECTURES[arch]}")


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = _module(arch)
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def build_model(arch: str, smoke: bool = False) -> Tuple[ModelConfig, object]:
    """Returns (cfg, module of model functions) — all archs share transformer.py."""
    from repro.models import transformer

    return get_config(arch, smoke=smoke), transformer
