"""Memory-efficient causal attention with a custom VJP (flash-attention).

Without this, differentiating the blocked-attention scan saves the
(b, h, s, KB) probability tiles for every KV block — O(s²) residuals per
layer, which is exactly the blow-up blocking the 16 GB/chip budget (see
EXPERIMENTS.md §Perf iteration 2). Here the forward saves only
(q, k, v, out, m, lse) — O(s·d) — and the backward recomputes each tile once:

  fwd:  online-softmax scan over KV blocks  →  out, m (row max), lse (row sum)
  bwd:  one more scan over KV blocks; per block recompute p, then
        dv += pᵀ·do,  ds = p∘(dp − D),  dq += ds·k,  dk += dsᵀ·q
        with D = rowsum(do ∘ out).

Supports GQA grouping and a *traced* sliding-window size (gemma3's
local:global pattern selects the window per layer inside one scan).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

DEFAULT_KV_BLOCK = 512
_NEG = -1e30


def _blocks(x: jax.Array, KB: int) -> jax.Array:
    """(b, s, kv, d) -> (nb, b, KB, kv, d), zero-padded."""
    b, s, kv, d = x.shape
    pad = (-s) % KB
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = x.shape[1] // KB
    return jnp.moveaxis(x.reshape(b, nb, KB, kv, d), 1, 0)


def _fwd_scan(qg, k, v, window_eff, KB):
    b, s, kvh, g, d = qg.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    qpos = jnp.arange(s)
    kb, vb = _blocks(k, KB), _blocks(v, KB)
    nb = kb.shape[0]

    m0 = jnp.full((b, kvh, g, s), _NEG, jnp.float32)
    lse0 = jnp.zeros((b, kvh, g, s), jnp.float32)
    a0 = jnp.zeros((b, s, kvh, g, d), jnp.float32)

    def body(carry, inp):
        m, lse, acc = carry
        kblk, vblk, idx = inp
        scores = (
            jnp.einsum("bqkgd,bskd->bkgqs", qg, kblk, preferred_element_type=jnp.float32)
            * scale
        )
        kpos = idx * KB + jnp.arange(KB)
        allowed = (kpos[None, :] <= qpos[:, None]) & (
            kpos[None, :] > qpos[:, None] - window_eff
        )
        scores = jnp.where(allowed[None, None, None], scores, _NEG)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        p = jnp.exp(scores - m_new[..., None]) * allowed[None, None, None]
        corr = jnp.exp(m - m_new)
        lse_new = lse * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(vblk.dtype), vblk).astype(jnp.float32)
        acc_new = acc * jnp.moveaxis(corr, 3, 1)[..., None] + pv
        return (m_new, lse_new, acc_new), None

    (m, lse, acc), _ = jax.lax.scan(body, (m0, lse0, a0), (kb, vb, jnp.arange(nb)))
    out = acc / jnp.maximum(jnp.moveaxis(lse, 3, 1), 1e-30)[..., None]  # (b,s,kv,g,d)
    return out, m, lse


@partial(jax.custom_vjp, nondiff_argnums=(4,))
def flash_gqa_attention(
    q: jax.Array,  # (b, s, h, d)
    k: jax.Array,  # (b, s, kv, d)
    v: jax.Array,  # (b, s, kv, d)
    window_eff: jax.Array,  # traced int scalar
    kv_block: int = 0,  # 0 -> module-level DEFAULT_KV_BLOCK (read at call time)
) -> jax.Array:
    if kv_block <= 0:
        kv_block = DEFAULT_KV_BLOCK
    b, s, h, d = q.shape
    kvh = k.shape[2]
    qg = q.reshape(b, s, kvh, h // kvh, d)
    out, _, _ = _fwd_scan(qg, k, v, window_eff, min(kv_block, s))
    return out.reshape(b, s, h, d).astype(q.dtype)


def _flash_fwd(q, k, v, window_eff, kv_block):
    if kv_block <= 0:
        kv_block = DEFAULT_KV_BLOCK
    b, s, h, d = q.shape
    kvh = k.shape[2]
    qg = q.reshape(b, s, kvh, h // kvh, d)
    out, m, lse = _fwd_scan(qg, k, v, window_eff, min(kv_block, s))
    # residual `out` in model dtype (bf16): halves the per-layer residual
    # footprint; D = rowsum(do∘out) tolerates the rounding (flash standard)
    res = (q, k, v, window_eff, out.astype(q.dtype), m, lse)
    return out.reshape(b, s, h, d).astype(q.dtype), res


def _flash_bwd(kv_block, res, dout):
    if kv_block <= 0:
        kv_block = DEFAULT_KV_BLOCK
    q, k, v, window_eff, out, m, lse = res
    b, s, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    KB = min(kv_block, s)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=jnp.float32))
    qpos = jnp.arange(s)

    qg = q.reshape(b, s, kvh, g, d).astype(jnp.float32)
    dog = dout.reshape(b, s, kvh, g, d).astype(jnp.float32)
    # D = rowsum(dout ∘ out): (b, kv, g, s)
    Drow = jnp.moveaxis(jnp.sum(dog * out.astype(jnp.float32), axis=-1), 1, 3)
    lse_safe = jnp.maximum(lse, 1e-30)

    kb, vb = _blocks(k, KB), _blocks(v, KB)
    nb = kb.shape[0]

    dq0 = jnp.zeros_like(qg)

    def body(dq, inp):
        kblk, vblk, idx = inp
        kf, vf = kblk.astype(jnp.float32), vblk.astype(jnp.float32)
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, kf, preferred_element_type=jnp.float32) * scale
        kpos = idx * KB + jnp.arange(KB)
        allowed = (kpos[None, :] <= qpos[:, None]) & (
            kpos[None, :] > qpos[:, None] - window_eff
        )
        p = jnp.exp(scores - m[..., None]) * allowed[None, None, None]
        pn = p / lse_safe[..., None]  # normalized probabilities (b,kv,g,s,KB)
        dv_b = jnp.einsum("bkgqs,bqkgd->bskd", pn, dog)
        dp = jnp.einsum("bqkgd,bskd->bkgqs", dog, vf, preferred_element_type=jnp.float32)
        ds = pn * (dp - Drow[..., None]) * scale
        dq = dq + jnp.einsum("bkgqs,bskd->bqkgd", ds, kf)
        dk_b = jnp.einsum("bkgqs,bqkgd->bskd", ds, qg)
        return dq, (dk_b, dv_b)

    dq, (dk_blocks, dv_blocks) = jax.lax.scan(body, dq0, (kb, vb, jnp.arange(nb)))
    dk = jnp.moveaxis(dk_blocks, 0, 1).reshape(b, nb * KB, kvh, d)[:, :s]
    dv = jnp.moveaxis(dv_blocks, 0, 1).reshape(b, nb * KB, kvh, d)[:, :s]
    dq = dq.reshape(b, s, h, d)
    zero_w = jnp.zeros((), dtype=jax.dtypes.float0)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype), zero_w


flash_gqa_attention.defvjp(_flash_fwd, _flash_bwd)
