import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402  — the two lines above MUST precede any jax-touching import.
"""Multi-pod dry-run: lower + compile every (architecture × input-shape × mesh).

For each cell this proves, without hardware:
  - the sharding config is coherent (compile succeeds, no GSPMD conflicts),
  - the program fits (memory_analysis of the full scanned program), and
  - the roofline terms. XLA's cost analysis counts scan bodies once, so
    FLOPs/bytes/collectives come from a 2-point extrapolation over *unrolled*
    small-L variants:  total = C(L1) + (L/G − 1)·(C(L2) − C(L1)),
    with G the layer-group size (6 for gemma3's 5:1 pattern, else 1),
    L1 = G, L2 = 2G. Exact for homogeneous stacks.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out results/dryrun.json
"""

import argparse
import dataclasses
from math import prod as np_prod
import json
import time
import traceback
from typing import Dict, Optional

import jax
from jax.sharding import PartitionSpec as P

from repro.compat import cost_analysis_dict
from repro.configs.common import SHAPES, InputShape, input_specs, shape_applicable
from repro.launch import shardings as sh
from repro.launch.hlo_analysis import (
    RooflineTerms,
    analytic_memory_bytes,
    parse_collectives,
)
from repro.launch.mesh import axis_sizes, data_axes, make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
from repro.models import shard_hints
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.registry import ARCHITECTURES, get_config
from repro.optim.optimizers import adamw

ACTIVATION_BUDGET_BYTES = 7 * 2**30  # per-device activation target (train)
ACT_BYTES_PER_TOKEN_LAYER = 6.5  # measured: ~3 bf16 copies of (tok, d) per layer


def _mem_analysis_dict(compiled) -> Optional[Dict]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    out = {}
    for k in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    out["total_hbm_bytes"] = (
        out.get("argument_size_in_bytes", 0)
        + out.get("output_size_in_bytes", 0)
        + out.get("temp_size_in_bytes", 0)
        - out.get("alias_size_in_bytes", 0)
    )
    return out


def _hints(dp, model_ax) -> shard_hints.ShardHints:
    dp_spec = dp if len(dp) > 1 else dp[0]
    return shard_hints.ShardHints(
        logits=P(dp_spec, None, model_ax),  # model_ax=None -> batch-only
        activations=P(dp_spec, None, None),
        moe_buffer=P(dp_spec, None),
    )


def _microbatch_for(cfg: ModelConfig, shape: InputShape, dp_size: int) -> int:
    """Pick the accumulation factor so per-device activations fit the budget.

    Activation bytes ≈ c · L · d_model · tokens_per_device / n, with c the
    measured ~6.5 B/(token·layer·d) (see EXPERIMENTS.md §Perf iteration 2);
    MoE blocks hold expert buffers too (≈ +2·k·ff/d relative)."""
    if shape.kind != "train":
        return 1
    per_dev_tokens = shape.batch * shape.seq // dp_size
    scale = ACT_BYTES_PER_TOKEN_LAYER
    if cfg.uses_moe:
        scale *= 1.0 + 2.0 * cfg.experts_per_token * cfg.d_ff / max(cfg.d_model, 1) / 3.0
    act = scale * cfg.num_layers * cfg.d_model * per_dev_tokens
    n = 1
    while act / n > ACTIVATION_BUDGET_BYTES and shape.batch % (2 * n) == 0:
        n *= 2
    return n


def _lower_compile(cfg, shape, mesh, remat: bool, microbatch: int, variant: str = "baseline"):
    """Build + lower + compile one program. Returns the compiled artifact.

    variant:
      baseline — batch over (pod, data); weights FSDP(data) ⊗ TP(model)
      fsdp     — batch AND weights over every axis (pod, data, model): no TP
    """
    maxes = axis_sizes(mesh)
    if variant == "fsdp":
        dp = tuple(a for a in ("pod", "data", "model") if a in maxes)
        model_ax = None
    else:
        dp = data_axes(mesh)
        model_ax = "model"
    params_s = jax.eval_shape(lambda: T.init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = T.param_pspecs(cfg, maxes, data_axes=dp, model_axis=model_ax)
    p_shard = sh.named(mesh, pspecs)
    batch_s = input_specs(cfg, shape)
    b_shard = sh.named(mesh, sh.batch_pspecs(cfg, shape, maxes, dp, model_ax))

    with mesh, shard_hints.use_hints(_hints(dp, model_ax)):
        if shape.kind == "train":
            opt = adamw(lr=1e-3)
            opt_s = jax.eval_shape(opt.init, params_s)
            o_shard = sh.named(mesh, sh.opt_pspecs(pspecs, opt_s))
            step = make_train_step(cfg, opt, remat=remat, microbatch=microbatch)
            lowered = jax.jit(
                step,
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
                donate_argnums=(0, 1),
            ).lower(params_s, opt_s, batch_s)
        elif shape.kind == "prefill":
            cache_s = jax.eval_shape(lambda: T.init_cache(cfg, shape.batch, shape.seq))
            c_specs = sh.cache_pspecs(cfg, cache_s, maxes, dp, model_ax)
            c_shard = sh.named(mesh, c_specs)
            step = make_prefill_step(cfg, max_len=shape.seq)
            lowered = jax.jit(
                step, in_shardings=(p_shard, b_shard), out_shardings=(None, c_shard)
            ).lower(params_s, batch_s)
        else:
            cache_s = jax.eval_shape(lambda: T.init_cache(cfg, shape.batch, shape.seq))
            c_specs = sh.cache_pspecs(cfg, cache_s, maxes, dp, model_ax)
            c_shard = sh.named(mesh, c_specs)
            step = make_decode_step(cfg)
            lowered = jax.jit(
                step,
                in_shardings=(p_shard, c_shard, b_shard),
                out_shardings=(None, c_shard),
                donate_argnums=(1,),
            ).lower(params_s, cache_s, batch_s)
        compiled = lowered.compile()
    return compiled


def _cost_point(cfg, shape, mesh, remat, num_layers, variant="baseline"):
    """Unrolled small-L lowering; returns (flops, bytes, coll_bytes, counts)."""
    from repro.models import scan_util

    small = dataclasses.replace(cfg, num_layers=num_layers)
    scan_util.UNROLL = True
    try:
        compiled = _lower_compile(small, shape, mesh, remat, microbatch=1, variant=variant)
    finally:
        scan_util.UNROLL = False
    ca = cost_analysis_dict(compiled)
    text = compiled.as_text()
    colls = parse_collectives(text, default_group=mesh.devices.size)
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0)), colls


def dryrun_cell(
    arch: str,
    shape: InputShape,
    multi_pod: bool,
    remat: bool = True,
    cost_points: bool = True,
    variant: str = "baseline",
) -> Dict:
    cfg = get_config(arch)
    rec: Dict = {
        "arch": arch,
        "shape": shape.name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "variant": variant,
    }
    if not shape_applicable(cfg, shape):
        rec["status"] = "skipped"
        rec["reason"] = "full-attention arch: long_500k requires sub-quadratic attention"
        return rec

    mesh = make_production_mesh(preset="multi_pod" if multi_pod else "pod")
    dp_size = 1
    for a in data_axes(mesh):
        dp_size *= axis_sizes(mesh)[a]
    microbatch = _microbatch_for(cfg, shape, dp_size)

    t0 = time.time()
    compiled = _lower_compile(cfg, shape, mesh, remat, microbatch, variant=variant)
    t_compile = time.time() - t0
    mem = _mem_analysis_dict(compiled)

    rec.update(
        {
            "status": "ok",
            "compile_s": round(t_compile, 2),
            "microbatch": microbatch,
            "memory_analysis": mem,
        }
    )

    if cost_points:
        G = cfg.local_global_ratio + 1 if cfg.local_global_ratio > 0 else 1
        L = cfg.num_layers
        f1, b1, c1 = _cost_point(cfg, shape, mesh, remat, G, variant)
        f2, b2, c2 = _cost_point(cfg, shape, mesh, remat, 2 * G, variant)
        groups = L // G
        flops = f1 + (groups - 1) * (f2 - f1)
        byts = b1 + (groups - 1) * (b2 - b1)
        coll = c1.total_bytes + (groups - 1) * (c2.total_bytes - c1.total_bytes)
        counts = {
            k: c1.counts[k] + (groups - 1) * (c2.counts[k] - c1.counts[k])
            for k in c1.counts
        }
        # microbatching multiplies per-step activation traffic & collectives
        # of the fwd/bwd but not the optimizer; the cost points run with
        # microbatch=1 over the full batch — equal total compute.
        terms = RooflineTerms(
            flops_per_device=flops,
            bytes_per_device=byts,
            collective_bytes_per_device=coll,
            chips=mesh.devices.size,
        )
        rec["cost_points"] = {
            "L1": {"flops": f1, "bytes": b1, "coll": c1.total_bytes},
            "L2": {"flops": f2, "bytes": b2, "coll": c2.total_bytes},
            "group_size": G,
        }
        rec["collectives"] = {
            "counts": counts,
            "wire_bytes": {
                k: c1.wire_bytes[k] + (groups - 1) * (c2.wire_bytes[k] - c1.wire_bytes[k])
                for k in c1.wire_bytes
            },
        }
        rd = terms.as_dict()
        # analytic HBM-traffic lower bound (see hlo_analysis.analytic_memory_bytes)
        model_shard = 16 if variant == "baseline" else 1
        cache_bytes = 0
        if shape.kind != "train":
            cache_s = jax.eval_shape(lambda: T.init_cache(cfg, shape.batch, shape.seq))
            cache_bytes = sum(
                int(np_prod(leaf.shape)) * leaf.dtype.itemsize for leaf in jax.tree.leaves(cache_s)
            )
        mem_model = analytic_memory_bytes(
            cfg, shape, mesh.devices.size, model_shard, microbatch, cache_bytes
        )
        rd["t_memory_model_s"] = mem_model / 819e9
        rd["bytes_model_per_device"] = mem_model
        rec["roofline"] = rd
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-cost", action="store_true", help="skip cost extrapolation points")
    ap.add_argument("--variant", default="baseline", choices=["baseline", "fsdp"])
    args = ap.parse_args()

    archs = list(ARCHITECTURES) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    records = []
    for arch in archs:
        for sname in shapes:
            for multi in meshes:
                tag = f"{arch} × {sname} × {'2x16x16' if multi else '16x16'}"
                t0 = time.time()
                try:
                    rec = dryrun_cell(
                        arch, SHAPES[sname], multi,
                        remat=not args.no_remat, cost_points=not args.no_cost,
                        variant=args.variant,
                    )
                except Exception as e:
                    rec = {
                        "arch": arch, "shape": sname,
                        "mesh": "2x16x16" if multi else "16x16",
                        "status": "error", "error": f"{type(e).__name__}: {e}",
                        "trace": traceback.format_exc()[-2000:],
                    }
                rec["wall_s"] = round(time.time() - t0, 1)
                records.append(rec)
                status = rec["status"]
                extra = ""
                if status == "ok" and "roofline" in rec:
                    r = rec["roofline"]
                    ma = rec.get("memory_analysis") or {}
                    extra = (
                        f" wall={rec['wall_s']}s"
                        f" hbm={ma.get('total_hbm_bytes', 0)/2**30:.2f}GiB"
                        f" tC={r['t_compute_s']:.4f} tM={r['t_memory_s']:.4f}"
                        f" tX={r['t_collective_s']:.4f} → {r['bottleneck']}"
                    )
                elif status == "error":
                    extra = " " + rec["error"][:200]
                print(f"[{status:7s}] {tag}{extra}", flush=True)
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                with open(args.out, "w") as f:
                    json.dump(records, f, indent=1)

    ok = sum(1 for r in records if r["status"] == "ok")
    sk = sum(1 for r in records if r["status"] == "skipped")
    err = sum(1 for r in records if r["status"] == "error")
    print(f"\ndone: {ok} ok, {sk} skipped, {err} errors → {args.out}")


if __name__ == "__main__":
    main()
