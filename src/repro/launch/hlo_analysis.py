"""Roofline-term extraction from compiled XLA artifacts.

``compiled.cost_analysis()`` gives HLO FLOPs and bytes, but collective
traffic is not in there — we parse the (post-SPMD-partitioning) HLO text
and sum wire bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, with ring-algorithm multipliers and
replica-group sizes taken from the instruction attributes.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (see repro.core.profiler).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.compat import cost_analysis_dict
from repro.core.profiler import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(shape_str: str) -> int:
    """Sum bytes over every 'dtype[dims]' group in a result-type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    # iota v2 format [num_groups, group_size]
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    wire_bytes: Dict[str, float]  # per-device bytes over ICI links

    @property
    def total_bytes(self) -> float:
        return sum(self.wire_bytes.values())


def parse_collectives(hlo_text: str, default_group: int) -> CollectiveStats:
    counts: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    wire: Dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "fused_computation" in stripped:
            continue
        for coll in _COLLECTIVES:
            # match op invocations, incl. async '-start' forms; skip '-done'
            if re.search(rf"= .* {coll}(-start)?\(", stripped) is None:
                continue
            # result type(s): between '=' and the op name
            m = re.search(rf"=\s*(.*?)\s*{coll}(-start)?\(", stripped)
            if not m:
                continue
            out_bytes = _shape_bytes(m.group(1))
            g = _group_size(stripped, default_group)
            if g <= 1:
                continue
            if coll == "all-reduce":
                # ring: reduce-scatter + all-gather ≈ 2·(g-1)/g · size
                b = 2.0 * (g - 1) / g * out_bytes
            elif coll == "all-gather":
                b = (g - 1) / g * out_bytes  # output is the gathered size
            elif coll == "reduce-scatter":
                b = (g - 1) * out_bytes  # output is the scattered shard
            elif coll == "all-to-all":
                b = (g - 1) / g * out_bytes
            else:  # collective-permute: point-to-point
                b = float(out_bytes)
            counts[coll] += 1
            wire[coll] += b
            break
    return CollectiveStats(counts=counts, wire_bytes=wire)


@dataclasses.dataclass
class RooflineTerms:
    """All terms in seconds, per the §Roofline definition."""

    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    chips: int

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Ideal-overlap roofline: the dominant term bounds the step."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
        }


def analytic_memory_bytes(
    cfg,
    shape,
    chips: int,
    model_shard: int,
    microbatch: int,
    cache_bytes: int = 0,
) -> float:
    """Per-device HBM-traffic *model* (lower bound).

    The compiled `bytes accessed` on the CPU backend sums every
    instruction's operands pre-fusion and overestimates TPU HBM traffic by
    10-30× (measured: danube train reports 2.75 TB/dev where weights+acts
    +optimizer round to ~70 GB). This model counts the unavoidable traffic:
      - optimizer: params+m+v read & write once per step,
      - weights: each fwd/remat/bwd pass streams the (TP-resident,
        FSDP-gathered) weights once (gather write + read ⇒ ×2),
      - activations: the ~6.5 B/(token·layer·d) residual stream written
        and read once,
      - decode/prefill: the KV/SSM cache read (+ write for decode).
    """
    pb = 2 if cfg.param_dtype == "bfloat16" else 4
    p_count = cfg.param_count()
    p_total = p_count * pb
    w_gathered = p_total / max(model_shard, 1)

    if shape.kind == "train":
        opt = p_count / chips * (pb + 8) * 2.0  # read+write of p, m, v
        passes = 3.0 * microbatch  # fwd + remat-fwd + bwd per microbatch
        weights = passes * w_gathered * 2.0
        tok_dev = shape.batch * shape.seq / max(chips / model_shard, 1)
        acts = 2.0 * 6.5 * cfg.num_layers * cfg.d_model * tok_dev
        return opt + weights + acts
    if shape.kind == "prefill":
        tok_dev = shape.batch * shape.seq / max(chips / model_shard, 1)
        acts = 2.0 * 2.0 * cfg.num_layers * cfg.d_model * tok_dev  # write+read, fwd only
        return w_gathered * 2.0 + acts + cache_bytes / chips
    # decode: weights once + cache r/w
    return w_gathered + 2.0 * cache_bytes / chips


def roofline_from_compiled(compiled, mesh, hlo_text: Optional[str] = None) -> RooflineTerms:
    chips = mesh.devices.size
    ca = cost_analysis_dict(compiled)
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    colls = parse_collectives(text, default_group=chips)
    return RooflineTerms(
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes_per_device=colls.total_bytes,
        chips=chips,
    ), colls
