"""Jittable train / serve step builders used by drivers and the dry-run."""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim.compression import CompressionConfig, compress_gradients
from repro.optim.optimizers import Optimizer

Pytree = Any


def _value_and_grad_microbatched(cfg, params, batch, remat, microbatch):
    """Gradient accumulation over `microbatch` splits of the global batch.

    The activation peak scales with the microbatch, not the global batch —
    the in-step analogue of Ferret's T2 (gradient accumulation) knob."""

    def loss_of(p, b):
        return T.loss_fn(cfg, p, b, remat=remat)

    if microbatch <= 1:
        return jax.value_and_grad(loss_of, has_aux=True)(params, batch)

    data_keys = [k for k in batch if k != "positions"]
    b_total = batch[data_keys[0]].shape[0]
    assert b_total % microbatch == 0, (b_total, microbatch)
    mb = b_total // microbatch

    def split(v, leading_batch_axis=0):
        return v.reshape(microbatch, mb, *v.shape[1:])

    sb = {}
    for k, v in batch.items():
        if k == "positions" and v.ndim >= 1 and v.shape[0] == 3:
            # mrope positions: (3, b, s) -> (micro, 3, mb, s)
            sb[k] = jnp.moveaxis(v.reshape(3, microbatch, mb, *v.shape[2:]), 1, 0)
        else:
            sb[k] = split(v)

    zero_grads = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(carry, micro):
        g_acc, loss_acc, acc_acc = carry
        (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(params, micro)
        g_acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), g_acc, grads)
        return (g_acc, loss_acc + loss, acc_acc + metrics["acc"]), None

    (grads, loss_sum, acc_sum), _ = jax.lax.scan(
        body, (zero_grads, jnp.zeros(()), jnp.zeros(())), sb
    )
    grads = jax.tree.map(lambda g: g / microbatch, grads)
    n = float(microbatch)
    metrics = {"ce": loss_sum / n, "acc": acc_sum / n, "moe_aux": jnp.zeros(())}
    return (loss_sum / n, metrics), grads


def make_train_step(
    cfg: ModelConfig,
    optimizer: Optimizer,
    remat: bool = True,
    compression: Optional[CompressionConfig] = None,
    microbatch: int = 1,
):
    """(params, opt_state, batch[, ef_residual]) -> (params, opt_state, metrics[, resid])."""

    if compression is None or compression.method == "none":

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = _value_and_grad_microbatched(
                cfg, params, batch, remat, microbatch
            )
            new_params, new_opt = optimizer.update(params, grads, opt_state)
            return new_params, new_opt, {"loss": loss, **metrics}

        return train_step

    def train_step_c(params, opt_state, batch, residual):
        (loss, metrics), grads = _value_and_grad_microbatched(
            cfg, params, batch, remat, microbatch
        )
        grads, residual = compress_gradients(compression, grads, residual)
        new_params, new_opt = optimizer.update(params, grads, opt_state)
        return new_params, new_opt, {"loss": loss, **metrics}, residual

    return train_step_c


def make_prefill_step(cfg: ModelConfig, max_len: int):
    """(params, batch) -> (next-token logits (b, V), cache)."""

    def prefill_step(params, batch):
        logits, cache = T.prefill(cfg, params, batch, max_len=max_len)
        return logits[:, -1, :], cache

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    """(params, cache, batch) -> (logits (b, V), cache)."""

    def decode_step(params, cache, batch):
        return T.decode_step(cfg, params, cache, batch)

    return decode_step
