"""Multi-tenant OCL serving CLI over ``repro.serve.FerretServer``.

Admits ``--tenants`` same-architecture sessions — each with its own
drifting token stream, OCL algorithm, and weighted share of one device
memory pool — and drives them to completion through the shared server:
one bucketed engine cache (compile count < tenant count proves the
same-geometry sharing), deficit-round-robin segment scheduling, live pool
re-division as tenants finish.

Examples:
  PYTHONPATH=src python -m repro.launch.serve --tenants 4 --rounds 64 \
      --arch h2o-danube-1.8b --smoke --budget-gb 4
  PYTHONPATH=src python -m repro.launch.serve --tenants 2 \
      --algorithm er --scheduler rr

The former ``repro.launch.serve`` (batched prefill + decode token
generation) lives at ``repro.launch.generate``; invocations using its
flags (``--gen`` / ``--prompt-len``) are forwarded there with a
``DeprecationWarning``.
"""

from __future__ import annotations

import argparse
import math
import sys
import time
import warnings

import numpy as np

_GENERATE_FLAGS = ("--gen", "--prompt-len", "--temperature")


def main(argv=None) -> None:
    argv = sys.argv[1:] if argv is None else list(argv)
    if any(flag in argv or any(a.startswith(flag + "=") for a in argv)
           for flag in _GENERATE_FLAGS):
        warnings.warn(
            "token generation moved from repro.launch.serve to "
            "repro.launch.generate — forwarding this invocation; switch to "
            "`python -m repro.launch.generate`",
            DeprecationWarning, stacklevel=2,
        )
        from repro.launch import generate

        sys.argv = [sys.argv[0], *argv]
        generate.main()
        return

    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--arch", default=None, help="registered architecture name "
                    "(default: a small built-in benchmark LM)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--rounds", type=int, default=48, help="stream rounds per tenant")
    ap.add_argument("--segment-rounds", type=int, default=8)
    ap.add_argument("--budget-gb", type=float, default=0.0,
                    help="global pool; 0 = unconstrained (every tenant M+)")
    ap.add_argument("--algorithm", default="vanilla")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=16)
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--scheduler", default="drr", choices=["drr", "rr"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.models.config import ModelConfig
    from repro.models.registry import get_config
    from repro.ocl.streams import StreamConfig, make_stream
    from repro.serve import FerretServer, RoundRobinScheduler

    if args.arch is not None:
        cfg = get_config(args.arch, smoke=args.smoke)
        vocab = min(cfg.vocab_size, 64)
    else:
        vocab = 32
        cfg = ModelConfig(
            name="serve-lm", family="dense", num_layers=4, d_model=64,
            num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=vocab,
            compute_dtype="float32",
        )

    budget = math.inf if args.budget_gb <= 0 else args.budget_gb * 2**30
    scheduler = RoundRobinScheduler() if args.scheduler == "rr" else None
    server = FerretServer(
        budget, scheduler=scheduler, segment_rounds=args.segment_rounds,
        smoke=True,
    )
    for i in range(args.tenants):
        stream = make_stream(StreamConfig(
            kind="drift", modality="tokens", length=args.rounds,
            batch=args.batch, vocab=vocab, seq=args.seq, seed=args.seed + i,
        ))
        for k in ("tokens", "labels"):
            stream[k] = stream[k] % cfg.vocab_size
        server.admit(
            cfg, args.algorithm, stream, name=f"tenant{i}",
            batch=args.batch, seq=args.seq, lr=args.lr,
            max_workers=3, max_stages=4, seed=args.seed + i,
        )
    print(f"admitted {args.tenants} tenants "
          f"(pool={'inf' if math.isinf(budget) else f'{args.budget_gb:g}GiB'}, "
          f"scheduler={args.scheduler})")

    t0 = time.time()
    results = server.serve()
    dt = time.time() - t0

    total_rounds = sum(r.rounds for r in results.values())
    for name in sorted(results):
        print(f"  {name}: {results[name].summary()}")
    print(
        f"{len(results)} tenants, {total_rounds} rounds in {dt:.1f}s "
        f"({total_rounds / dt:.1f} rounds/s sustained); engine compiles="
        f"{server.compile_count} (< {args.tenants} tenants: shared), "
        f"cache hits={server.engine_cache.hits}"
    )
    accs = np.array([r.online_acc for r in results.values()])
    print(f"online acc mean={accs.mean():.4f} min={accs.min():.4f} "
          f"max={accs.max():.4f}")


if __name__ == "__main__":
    main()
