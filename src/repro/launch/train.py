"""End-to-end OCL training driver.

Two modes:

- ``ferret`` (default): plan → fine-grained pipeline engine over a drifting
  token stream, with Iter-Fisher compensation (the paper's full system).
- ``plain``: supervised step loop with the fault-tolerant runtime
  (checkpoint/restart, NaN rollback, bounded-queue admission control) —
  the substrate a 1000-node deployment runs per host group.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch h2o-danube-1.8b --smoke \
      --steps 200 --mode ferret --budget-gb 2
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-780m --smoke \
      --steps 100 --mode plain --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import math
import time

import jax
import numpy as np

from repro.api import FerretSession
from repro.core.compensation import CompensationConfig
from repro.data.pipeline import DataPipeline, PipelineCfg, TokenStreamSource
from repro.launch.steps import make_train_step
from repro.models import transformer as T
from repro.models.registry import get_config
from repro.ocl.streams import StreamConfig, make_stream
from repro.optim.optimizers import adamw
from repro.runtime.supervisor import Supervisor, SupervisorCfg


def parse_budget_schedule(spec: str):
    """``"0:inf,120:2,180:0.5"`` → BudgetEvents (round : budget in GiB).

    ``inf`` (or ``0``) means unconstrained (Ferret_M+)."""
    from repro.runtime import BudgetEvent

    events = []
    for item in spec.split(","):
        try:
            r, v = item.split(":")
            gib = math.inf if v.strip() == "inf" else float(v)
            if gib == 0:  # 0 = unconstrained, same semantics as --budget-gb
                gib = math.inf
            budget = gib if gib == math.inf else gib * 2**30
            events.append(BudgetEvent(round=int(r), budget_bytes=budget))
        except ValueError:
            raise SystemExit(
                f"--budget-schedule: bad entry {item!r} — expected "
                "'round:GiB' items like '0:inf,120:2,180:0.5'"
            ) from None
    return events


def _incremental_feed(args, cfg):
    """The training stream as a lazy, unbounded-style feed.

    Chunks of the drifting stream are generated on demand (per-chunk
    seeds) and handed over one round at a time, so the driver never holds
    more than one chunk — the elastic runner pulls it segment by segment
    and peak stream residency stays O(segment_rounds), not O(steps).
    """
    from repro.api import IterableStreamSource

    def rounds():
        chunk_len, produced, chunk_idx = 64, 0, 0
        while produced < args.steps:
            n = min(chunk_len, args.steps - produced)
            arrays = make_stream(StreamConfig(
                kind=args.stream, modality="tokens", length=n, batch=args.batch,
                vocab=min(cfg.vocab_size, 64), seq=args.seq,
                seed=args.seed + chunk_idx,
            ))
            for k in ("tokens", "labels"):
                arrays[k] = arrays[k] % cfg.vocab_size
            for m in range(n):
                yield {k: v[m] for k, v in arrays.items()}
            produced += n
            chunk_idx += 1

    return IterableStreamSource(rounds())  # length undeclared: live-feed path


def run_ferret(args) -> None:
    cfg = get_config(args.arch, smoke=args.smoke)
    cfg = dataclasses.replace(cfg, compute_dtype="float32" if args.smoke else cfg.compute_dtype)
    if args.incremental:
        stream = _incremental_feed(args, cfg)
    else:
        stream = make_stream(
            StreamConfig(
                kind=args.stream, modality="tokens", length=args.steps,
                batch=args.batch, vocab=min(cfg.vocab_size, 64), seq=args.seq,
            )
        )
        # clamp token ids into the model vocab
        for k in ("tokens", "labels"):
            stream[k] = stream[k] % cfg.vocab_size
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    budget = math.inf if args.budget_gb <= 0 else args.budget_gb * 2**30
    session = FerretSession(
        cfg, budget, args.ocl, stream,
        batch=args.batch, seq=args.seq, lr=args.lr,
        compensation=CompensationConfig(method=args.compensation),
        max_workers=4, max_stages=8, params=params,
        profile=args.profile, profile_feedback=args.profile_feedback,
    )
    plan = session.plan
    print(
        f"plan: P={plan.partition.num_stages} N={len(plan.config.active_workers())} "
        f"R={plan.rate:.3f} M={plan.memory/2**20:.1f}MiB feasible={plan.feasible} "
        f"profile={plan.profile_provenance}"
    )
    t0 = time.time()
    if args.budget_schedule:
        res = session.run("elastic", schedule=parse_budget_schedule(args.budget_schedule))
        dt = time.time() - t0
        for s in res.segments:
            p = s.result.plan
            b = "inf" if math.isinf(s.budget_bytes) else f"{s.budget_bytes/2**30:.2f}GiB"
            tag = (f" replan={1e3*s.replan_s:.0f}ms remap={1e3*s.remap_s:.0f}ms"
                   if s.replanned else "")
            cache = "hit" if s.cache_hit else "compile"
            print(f"  seg [{s.start},{s.end}) budget={b} P={p.partition.num_stages} "
                  f"N={len(p.config.active_workers())} M={p.memory/2**20:.1f}MiB "
                  f"engine={cache}@{s.rounds_compiled} "
                  f"oacc={s.result.online_acc:.4f}{tag}")
        resident = ""
        if args.incremental:
            resident = (
                f" peak-stream-residency={res.peak_buffered_rounds} "
                f"rounds (of {res.rounds}; no materialization)"
            )
        print(
            f"oacc={res.online_acc:.4f} admitted={res.admitted_frac:.2f} "
            f"replans={res.num_replans} "
            f"engine-cache misses={res.engine_cache_misses} "
            f"hits={res.engine_cache_hits} "
            f"({res.rounds} items, exactly once, in {dt:.1f}s){resident}"
        )
        return
    # the pipelined runner is streaming-native: a lazy --incremental feed
    # is pulled segment by segment with prefetch, same as a materialized
    # stream — only the residency report differs
    res = session.run("pipelined")
    dt = time.time() - t0
    lam = res.lam_curve
    resident = ""
    if args.incremental:
        resident = (
            f" peak-stream-residency={res.peak_buffered_rounds} "
            f"rounds (of {res.rounds}; no materialization)"
        )
    print(
        f"oacc={res.online_acc:.4f} admitted={res.admitted_frac:.2f} "
        f"loss {res.losses[0]:.3f}→{res.losses[-1]:.3f} λ={lam[-1]:.4f} "
        f"({res.rounds} items in {dt:.1f}s){resident}"
    )


def run_plain(args) -> None:
    cfg = get_config(args.arch, smoke=args.smoke)
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = adamw(lr=args.lr)
    opt_state = opt.init(params)
    step_fn_raw = jax.jit(make_train_step(cfg, opt, remat=False))

    def step_fn(state, batch):
        params, opt_state = state
        b = {"tokens": batch["tokens"] % cfg.vocab_size,
             "labels": batch["labels"] % cfg.vocab_size}
        params, opt_state, metrics = step_fn_raw(params, opt_state, b)
        return (params, opt_state), metrics

    sup = Supervisor(
        SupervisorCfg(
            checkpoint_dir=args.ckpt_dir,
            checkpoint_every=args.ckpt_every,
            step_timeout_s=600.0,
            nan_check_every=1,
        ),
        step_fn,
        (params, opt_state),
    )
    source = TokenStreamSource(
        cfg.vocab_size, PipelineCfg(batch=args.batch, seq=args.seq, prefetch=4)
    )
    restored = sup.try_restore(extras_hook=lambda ex: source.seek(ex.get("cursor", 0)))
    if restored:
        print(f"restored from checkpoint @ step {sup.step}")
    pipe = DataPipeline(source, PipelineCfg(batch=args.batch, seq=args.seq, prefetch=4)).start()
    t0 = time.time()
    losses = []
    try:
        while sup.step < args.steps:
            batch = pipe.get()
            rep = sup.run_step(
                batch, extras={"cursor": int(batch["_cursor"])}, dropped=pipe.dropped
            )
            if not np.isnan(rep.loss):
                losses.append(rep.loss)
    finally:
        pipe.stop()
        sup.finalize(extras={"cursor": source.cursor})
    span = f"loss {losses[0]:.3f}→{losses[-1]:.3f}; " if losses else ""
    print(f"{sup.step} steps in {time.time()-t0:.1f}s; {span}dropped={pipe.dropped}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mode", default="ferret", choices=["ferret", "plain"])
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--budget-gb", type=float, default=0.0, help="0 = unconstrained (M+)")
    ap.add_argument(
        "--budget-schedule", default=None,
        help="mid-stream budget changes as 'round:GiB,...' e.g. '0:inf,120:2,180:0.5' "
             "(ferret mode; live replan + state remap, no restart)",
    )
    ap.add_argument(
        "--incremental", action="store_true",
        help="feed the runner from a lazy round generator instead of "
             "materializing the stream — segment-by-segment take() with "
             "prefetch, peak stream residency O(segment), not O(steps) "
             "(works on the default pipelined runner and, with "
             "--budget-schedule, the elastic runner)",
    )
    ap.add_argument(
        "--profile", default="auto", choices=["auto", "analytic", "measured"],
        help="planner profile source: 'auto' uses a stored on-device "
             "measurement when one exists (analytic roofline otherwise), "
             "'measured' measures-and-persists on a store miss, 'analytic' "
             "never touches the store (ferret mode)",
    )
    ap.add_argument(
        "--profile-feedback", action="store_true",
        help="refine the persisted profile from observed segment wall-clock "
             "(host-side; later replans use the refined numbers)",
    )
    ap.add_argument("--compensation", default="iter_fisher")
    ap.add_argument("--ocl", default="vanilla")
    ap.add_argument("--stream", default="drift", choices=["iid", "split", "drift"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()
    if args.mode == "ferret":
        run_ferret(args)
    else:
        run_plain(args)


if __name__ == "__main__":
    main()
