import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

# ruff: noqa: E402
"""Optimized dry-run: per-cell best sharding variant (EXPERIMENTS.md §Perf).

- train_4k  → 'fsdp'   (global batch 256 covers the chips; TP collectives
                         replaced by weight streaming — iteration 6)
- others    → 'baseline' (batch 32/128/1 < chips: FSDP would replicate)
plus every config-level optimization (bf16 weights, int8 KV, flash, grouped
MoE) already in the model configs.
"""

import json
import time
import traceback

from repro.configs.common import SHAPES
from repro.launch.dryrun import dryrun_cell
from repro.models.registry import ARCHITECTURES

OUT = "results/dryrun_v3.json"


def main() -> None:
    records = []
    for arch in ARCHITECTURES:
        for sname, shape in SHAPES.items():
            for multi in (False, True):
                variant = "fsdp" if sname == "train_4k" else "baseline"
                t0 = time.time()
                try:
                    rec = dryrun_cell(arch, shape, multi, variant=variant)
                except Exception as e:
                    rec = {
                        "arch": arch, "shape": sname,
                        "mesh": "2x16x16" if multi else "16x16",
                        "variant": variant, "status": "error",
                        "error": f"{type(e).__name__}: {e}",
                        "trace": traceback.format_exc()[-1500:],
                    }
                rec["wall_s"] = round(time.time() - t0, 1)
                records.append(rec)
                extra = ""
                if rec["status"] == "ok" and "roofline" in rec:
                    r = rec["roofline"]
                    ma = rec.get("memory_analysis") or {}
                    extra = (
                        f" hbm={ma.get('total_hbm_bytes', 0)/2**30:.2f}G"
                        f" tC={r['t_compute_s']:.3f} tMm={r.get('t_memory_model_s', 0):.3f}"
                        f" tX={r['t_collective_s']:.3f}"
                    )
                elif rec["status"] == "error":
                    extra = " " + rec["error"][:150]
                print(f"[{rec['status']:7s}] {arch} × {sname} × "
                      f"{'2x16x16' if multi else '16x16'} ({variant}){extra}", flush=True)
                os.makedirs("results", exist_ok=True)
                with open(OUT, "w") as f:
                    json.dump(records, f, indent=1)
    ok = sum(1 for r in records if r["status"] == "ok")
    sk = sum(1 for r in records if r["status"] == "skipped")
    err = sum(1 for r in records if r["status"] == "error")
    print(f"\ndone: {ok} ok, {sk} skipped, {err} errors → {OUT}")


if __name__ == "__main__":
    main()
