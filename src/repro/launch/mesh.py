"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
touches no jax device state — the dry-run sets XLA_FLAGS *before* first jax
init and only then calls these.
"""

from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 chips per pod; 2 pods for the multi-pod dry-run (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> Tuple[str, ...]:
    """Axes that shard the batch (pod ⊗ data when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
