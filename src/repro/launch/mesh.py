"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
touches no jax device state — the dry-run sets XLA_FLAGS *before* first jax
init and only then calls these.

The default mesh shape is derived from the discovered ``DeviceTopology``
(``repro.runtime.topology``), so `make_production_mesh()` works on any host
— the old behavior of unconditionally building 16×16 crashed on anything
under 256 devices. The historical 16×16-per-pod shapes survive as the
explicit dry-run ``preset`` (what ``launch/dryrun.py`` asks for under its
fake-device XLA_FLAGS).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax

from repro.runtime.topology import DeviceTopology


def make_production_mesh(
    topology: Optional[DeviceTopology] = None,
    *,
    multi_pod: bool = False,
    preset: Optional[str] = None,
):
    """Build the run's mesh.

    - default: shape ``(data, model)`` from ``topology`` (discovered when
      not given) — valid on any device count;
    - ``preset="pod"`` / ``preset="multi_pod"`` (or the legacy
      ``multi_pod=True`` flag): the 16×16-chips-per-pod dry-run shapes,
      which require 256 / 512 visible devices and raise a clear error
      otherwise instead of an opaque reshape failure.
    """
    if multi_pod and preset is None:
        preset = "multi_pod"
    if preset is not None:
        if preset not in ("pod", "multi_pod"):
            raise ValueError(f"unknown mesh preset {preset!r}")
        shape = (2, 16, 16) if preset == "multi_pod" else (16, 16)
        axes = ("pod", "data", "model") if preset == "multi_pod" else ("data", "model")
        need, have = math.prod(shape), len(jax.devices())
        if have < need:
            raise ValueError(
                f"mesh preset {preset!r} needs {need} devices but only {have} "
                "are visible — drop preset= to derive the mesh from the "
                "discovered topology"
            )
        return jax.make_mesh(shape, axes)
    if topology is None:
        topology = DeviceTopology.discover()
    return topology.mesh()


def data_axes(mesh) -> Tuple[str, ...]:
    """Axes that shard the batch (pod ⊗ data when present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
