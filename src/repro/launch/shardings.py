"""Sharding rules for inputs, caches and optimizer state.

Weights use 2-D sharding — FSDP over the batch axes ⊗ TP over "model"
(see repro.models.transformer.param_pspecs). This module adds the rest:

- batch inputs shard over ("pod","data") when divisible;
- decode caches: batch over the data axes and *sequence* over "model" —
  sequence-parallel KV. GSPMD then partitions the attention softmax into
  the exact flash-style log-sum-exp combine (partial max/sum + cheap
  all-reduce), which is what makes gemma3's 4 GB/layer global-attention
  KV at 524k tokens fit;
- SSM decode state: batch over data axes, heads (or head-dim) over "model";
- optimizer state mirrors the parameter sharding.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.common import InputShape
from repro.models.config import ModelConfig

Pytree = Any


def _sz(mesh_axes: Dict[str, int], axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh_axes.get(axes, 1)
    n = 1
    for a in axes:
        n *= mesh_axes.get(a, 1)
    return n


def _maybe(dim: int, axes, mesh_axes):
    """Shard `dim` over `axes` when evenly divisible, else replicate."""
    if axes is None:
        return None
    return axes if dim % max(_sz(mesh_axes, axes), 1) == 0 else None


def batch_pspecs(
    cfg: ModelConfig, shape: InputShape, mesh_axes: Dict[str, int], dp, model: str
) -> Dict[str, P]:
    b = shape.batch
    # Progressive fallback: shard the batch over the longest prefix of the
    # data axes that divides it (fsdp variant: weights span all axes but a
    # 256-batch still shards over (pod, data) on the 512-chip mesh).
    dp_spec = None
    for k in range(len(dp), 0, -1):
        cand = dp[:k] if k > 1 else dp[0]
        if b % max(_sz(mesh_axes, cand), 1) == 0:
            dp_spec = cand
            break
    specs: Dict[str, P] = {}
    if cfg.embed_inputs:
        specs["tokens"] = P(dp_spec, None)
    else:
        specs["embeds"] = P(dp_spec, None, None)
    if shape.kind == "train":
        specs["labels"] = P(dp_spec, None)
    if cfg.mrope_sections is not None:
        specs["positions"] = P(None, dp_spec, None)
    return specs


def cache_pspecs(
    cfg: ModelConfig, cache_shapes: Pytree, mesh_axes: Dict[str, int], dp, model: str
) -> Pytree:
    """PartitionSpec tree matching jax.eval_shape(init_cache, ...)."""
    dp_ax = dp if len(dp) > 1 else dp[0]

    def rule(path, leaf):
        names = [getattr(p, "key", None) for p in path]
        name = names[-1]
        shp = leaf.shape
        if name == "pos":
            return P()
        if name in ("k", "v"):  # (L, b, S, kv, hd)
            return P(None, _maybe(shp[1], dp_ax, mesh_axes),
                     _maybe(shp[2], model, mesh_axes), None, None)
        if name in ("k_scale", "v_scale"):  # (L, b, S, kv)
            return P(None, _maybe(shp[1], dp_ax, mesh_axes), _maybe(shp[2], model, mesh_axes), None)
        if name in ("k_local", "v_local"):  # (G, r, b, W, kv, hd)
            return P(None, None, _maybe(shp[2], dp_ax, mesh_axes),
                     _maybe(shp[3], model, mesh_axes), None, None)
        if name in ("k_global", "v_global"):  # (G, b, S, kv, hd)
            return P(None, _maybe(shp[1], dp_ax, mesh_axes),
                     _maybe(shp[2], model, mesh_axes), None, None)
        if name in ("conv_x", "conv_B", "conv_C"):  # (L, b, K, ch)
            return P(None, _maybe(shp[1], dp_ax, mesh_axes), None, _maybe(shp[3], model, mesh_axes))
        if name == "state":  # (L, b, nh, ph, n)
            nh_spec = _maybe(shp[2], model, mesh_axes)
            ph_spec = None if nh_spec is not None else _maybe(shp[3], model, mesh_axes)
            return P(None, _maybe(shp[1], dp_ax, mesh_axes), nh_spec, ph_spec, None)
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    return jax.tree_util.tree_unflatten(treedef, [rule(p, leaf) for p, leaf in flat])


def opt_pspecs(param_specs: Pytree, opt_state_shapes) -> Pytree:
    """AdamWState(mu, nu) mirror the parameter sharding; counters replicate."""

    def rule(path, leaf):
        # path through the NamedTuple: ('.mu' | '.nu' | '.count') then params path
        head = getattr(path[0], "name", getattr(path[0], "key", ""))
        if head == "count":
            return P()
        sub = path[1:]
        spec_leaf = param_specs
        for p in sub:
            key = getattr(p, "key", getattr(p, "idx", None))
            spec_leaf = spec_leaf[key]
        return spec_leaf

    flat, treedef = jax.tree_util.tree_flatten_with_path(opt_state_shapes)
    return jax.tree_util.tree_unflatten(treedef, [rule(p, leaf) for p, leaf in flat])


def named(mesh, spec_tree: Pytree) -> Pytree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree, is_leaf=lambda s: isinstance(s, P)
    )


def stream_batch_pspec(leaf_shape, mesh_axes: Dict[str, int], dp="data") -> P:
    """Spec for one scan-stream leaf, shape ``(R, b, ...)``: rounds stay on
    dim 0 (the scan axis is never sharded), the per-round batch dim 1 shards
    over the data axes when divisible, trailing dims replicate."""
    shp = tuple(leaf_shape)
    if len(shp) < 2:
        return P()
    return P(None, _maybe(shp[1], dp, mesh_axes), *([None] * (len(shp) - 2)))


def stream_shardings(mesh, stream: Pytree) -> Pytree:
    """NamedShardings for a whole stream pytree of ``(R, b, ...)`` arrays."""
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return jax.tree.map(
        lambda x: NamedSharding(mesh, stream_batch_pspec(x.shape, axes)), stream
    )


def state_shardings(mesh, state: Pytree) -> Pytree:
    """Replicated NamedShardings for the engine-state carry.

    The pipelined engine's ``EngineState`` (stage params, Fisher rings,
    deltas, optimizer and compensation state) is the data-parallel
    *replicated* plane — every data replica holds the full pipeline, only
    the batch axis shards. Committing the carry to ``P()`` keeps GSPMD from
    inventing a partition for it and makes the scan's round-to-round
    dataflow identical to the single-device layout."""
    rep = NamedSharding(mesh, P())
    return jax.tree.map(lambda _: rep, state)
