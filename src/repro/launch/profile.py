"""Profile-store CLI: measure, tune, inspect, and diff planner inputs.

The planner's numbers come from one of three places — the analytic
TPU-v5e roofline, a persisted on-device measurement, or an online
refinement of one — and this tool is how those measurements get made and
examined outside a training run.

Subcommands:
  measure     time real fwd/bwd blocks for one model geometry and persist
              the resulting ModelProfile (re-running is a store hit: no
              re-measurement)
  tune        sweep the kernel knobs (packed vs per-leaf, block sizes;
              --buckets adds the EngineCache segment-bucket ladder) and
              record the winners for this backend
  show        list every readable store entry
  plan-delta  plan the same (model, budget) from the analytic and the
              measured profile and print what changed

Examples:
  PYTHONPATH=src python -m repro.launch.profile measure --arch h2o-danube-1.8b \
      --smoke --batch 2 --seq 32
  PYTHONPATH=src python -m repro.launch.profile tune --buckets
  PYTHONPATH=src python -m repro.launch.profile plan-delta --arch mamba2-780m \
      --smoke --budget-gb 2
"""

from __future__ import annotations

import argparse
import json
import math

from repro.core import planner as planner_lib
from repro.models.registry import get_config
from repro.profile import (
    ProfileStore,
    autotune,
    backend_fingerprint,
    default_store,
    measurement_runs,
    resolve_profile,
)


def _store(args) -> ProfileStore:
    return ProfileStore(args.store) if args.store else default_store()


def _config(args):
    return get_config(args.arch, smoke=args.smoke)


def cmd_measure(args) -> None:
    store = _store(args)
    before = measurement_runs()
    profile = resolve_profile(
        _config(args), args.batch, args.seq,
        prefer="measured", store=store, repeats=args.repeats,
    )
    fresh = measurement_runs() > before
    print(f"backend: {backend_fingerprint()}")
    print(f"store:   {store.root}")
    print(f"entry:   {'measured now' if fresh else 'cache hit (no re-measurement)'}")
    ly = profile.layers[1] if len(profile.layers) > 1 else profile.layers[0]
    print(
        f"profile: provenance={profile.provenance} layers={len(profile.layers)} "
        f"t_fwd={ly.t_fwd*1e3:.3f}ms t_bwd={ly.t_bwd*1e3:.3f}ms "
        f"w={ly.w_bytes/2**20:.2f}MiB a={ly.a_bytes/2**20:.2f}MiB"
    )


def cmd_tune(args) -> None:
    store = _store(args)
    blocks = tuple(int(b) for b in args.blocks.split(",")) if args.blocks else None
    kwargs = {"tune_buckets": args.buckets, "repeats": args.repeats}
    if blocks:
        kwargs["blocks"] = blocks
    tuned = autotune(store, **kwargs)
    print(f"backend: {backend_fingerprint()}")
    print(f"store:   {store.root}")
    print(f"pack:    {tuned.pack}" + (f" block={tuned.pack_block}" if tuned.pack else ""))
    if tuned.segment_buckets is not None:
        print(f"buckets: {list(tuned.segment_buckets)}")
    print("(env vars REPRO_PACK / REPRO_PACK_BLOCK / REPRO_SEGMENT_BUCKETS still win)")


def cmd_show(args) -> None:
    store = _store(args)
    entries = store.entries()
    print(f"store: {store.root} ({len(entries)} entries)")
    for record in entries:
        key = record.get("key", {})
        payload = record.get("payload", {})
        kind = record.get("kind", "?")
        if kind == "layer_profile":
            detail = (
                f"model={key.get('model_name')} batch={key.get('batch')} "
                f"seq={key.get('seq')} provenance={payload.get('provenance')}"
            )
        else:
            detail = f"pack={payload.get('pack')} block={payload.get('pack_block')}"
            if payload.get("segment_buckets"):
                detail += f" buckets={payload['segment_buckets']}"
        print(f"  [{kind} schema={record.get('schema')}] {detail}")
        if args.json:
            print(json.dumps(record, indent=2, default=str))


def _plan_line(tag: str, plan: planner_lib.Plan) -> str:
    return (
        f"  {tag:<9} P={plan.partition.num_stages} "
        f"N={len(plan.config.active_workers())} R={plan.rate:.4f} "
        f"M={plan.memory/2**20:.1f}MiB feasible={plan.feasible} "
        f"provenance={plan.profile_provenance}"
    )


def cmd_plan_delta(args) -> None:
    store = _store(args)
    cfg = _config(args)
    budget = math.inf if args.budget_gb <= 0 else args.budget_gb * 2**30
    plans = {}
    for prefer in ("analytic", "measured"):
        profile = resolve_profile(
            cfg, args.batch, args.seq, prefer=prefer, store=store,
            repeats=args.repeats,
        )
        t_d = planner_lib.default_data_interval(profile)
        plans[prefer] = planner_lib.plan(
            profile, t_d, budget, max_workers=args.max_workers
        )
    a, m = plans["analytic"], plans["measured"]
    print(f"plan-delta for {cfg.name} batch={args.batch} seq={args.seq}:")
    print(_plan_line("analytic", a))
    print(_plan_line("measured", m))
    same = (
        tuple(a.partition.bounds) == tuple(m.partition.bounds)
        and len(a.config.active_workers()) == len(m.config.active_workers())
    )
    if same:
        print("  -> identical structure; measured numbers confirm the roofline")
    else:
        print("  -> the measured profile changes the chosen pipeline")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--store", default=None, help="store root (default REPRO_PROFILE_DIR)")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("measure", help="measure + persist one model geometry")
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=2)
    p.add_argument("--seq", type=int, default=32)
    p.add_argument("--repeats", type=int, default=5)
    p.set_defaults(fn=cmd_measure)

    p = sub.add_parser("tune", help="sweep kernel knobs, record winners")
    p.add_argument("--buckets", action="store_true",
                   help="also tune the EngineCache segment-bucket ladder")
    p.add_argument("--blocks", default=None, help="comma-separated block candidates")
    p.add_argument("--repeats", type=int, default=5)
    p.set_defaults(fn=cmd_tune)

    p = sub.add_parser("show", help="list store entries")
    p.add_argument("--json", action="store_true", help="dump full records")
    p.set_defaults(fn=cmd_show)

    p = sub.add_parser("plan-delta", help="analytic vs measured plan, same budget")
    p.add_argument("--arch", required=True)
    p.add_argument("--smoke", action="store_true")
    p.add_argument("--batch", type=int, default=2)
    p.add_argument("--seq", type=int, default=32)
    p.add_argument("--budget-gb", type=float, default=0.0, help="0 = unconstrained")
    p.add_argument("--max-workers", type=int, default=8)
    p.add_argument("--repeats", type=int, default=3)
    p.set_defaults(fn=cmd_plan_delta)

    args = ap.parse_args()
    args.fn(args)


if __name__ == "__main__":
    main()
