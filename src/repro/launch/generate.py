"""Token-generation driver: batched prefill + decode with per-family caches.

Formerly ``repro.launch.serve`` — that name now belongs to the multi-tenant
OCL serving CLI over ``repro.serve.FerretServer``; generation moved here.

Example:
  PYTHONPATH=src python -m repro.launch.generate --arch mamba2-780m --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import transformer as T
from repro.models.registry import get_config


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0, help="0 = greedy")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    rng = jax.random.PRNGKey(args.seed)
    params = T.init_params(cfg, rng)
    max_len = args.prompt_len + args.gen

    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(make_decode_step(cfg))

    if cfg.embed_inputs:
        prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0, cfg.vocab_size)
        batch = {"tokens": prompts}
    else:
        batch = {
            "embeds": jax.random.normal(
                rng, (args.batch, args.prompt_len, cfg.d_model),
                dtype=jnp.dtype(cfg.compute_dtype),
            )
        }

    t0 = time.time()
    logits, cache = jax.block_until_ready(prefill(params, batch))
    t_prefill = time.time() - t0

    toks = []
    t0 = time.time()
    next_tok = jnp.argmax(logits, axis=-1)
    for i in range(args.gen):
        if args.temperature > 0:
            rng, sub = jax.random.split(rng)
            next_tok = jax.random.categorical(sub, logits / args.temperature, axis=-1)
        toks.append(np.asarray(next_tok))
        if cfg.embed_inputs:
            step_batch = {"tokens": next_tok[:, None]}
        else:
            emb = jax.random.normal(
                jax.random.fold_in(rng, i), (args.batch, 1, cfg.d_model),
                dtype=jnp.dtype(cfg.compute_dtype),
            )
            step_batch = {"embeds": emb}
        logits, cache = decode(params, cache, step_batch)
        next_tok = jnp.argmax(logits, axis=-1)
    jax.block_until_ready(logits)
    t_decode = time.time() - t0

    total_tokens = args.batch * args.gen
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill: {t_prefill*1e3:.1f} ms ({args.batch*args.prompt_len/t_prefill:.0f} tok/s)")
    print(f"decode : {t_decode*1e3:.1f} ms total, {t_decode/args.gen*1e3:.2f} ms/step, "
          f"{total_tokens/t_decode:.0f} tok/s")
    print("sample tokens[0]:", [int(t[0]) for t in toks][:16])


if __name__ == "__main__":
    main()
