"""Deterministic fault-injection plane: ``FaultPlan`` → ``FaultInjector``.

Every recovery path in the repo — feeder replay, Supervisor retries,
checkpoint fallback, serve-loop tenant isolation — exists because real
streams throw stalls, NaNs, torn writes, and dead workers at a system
that must keep learning. This module makes those failures *first-class
inputs*: a ``FaultPlan`` is a declarative, seeded list of faults, and a
``FaultInjector`` fires them at **named injection points** threaded
through the layers that can fail:

====================  =====================================================
point                 kinds
====================  =====================================================
``stream.take``       ``stall`` (arg = seconds), ``error`` (transient take
                      failure — raised before any round is consumed)
``stream.prefetch``   ``feeder_death`` (the background prefetch worker dies
                      before touching the source)
``engine.step``       ``transient`` (retryable device error), ``nan``
                      (poisoned batch → non-finite loss; only observable
                      under a Supervisor), ``device_loss`` (lost capacity —
                      escalates to an elastic shrink-replan)
``checkpoint.write``  ``crash_mid_write`` (process dies with a torn tmp
                      payload), ``corrupt_payload`` (post-write bit rot in
                      the committed shard)
``serve.step``        ``tenant_crash`` (a tenant's serving step dies)
``serve.loop``        ``drain`` (SIGTERM-style graceful drain request)
====================  =====================================================

Determinism: a spec fires on hit-counts of its point (``after`` hits are
skipped, then ``times`` consecutive hits fire), never on wall-clock or
RNG state at fire time, so a seeded plan replays the same fault sequence
on every run — chaos tests are regression tests. ``FaultPlan.storm(seed)``
derives a multi-layer plan from one seed (same seed → same plan).

The injector records every fired fault (``records``) with a monotonic
timestamp; recovery sites call ``resolved(point)`` when they have healed
the oldest outstanding fault at that point, giving per-fault recovery
latency for the chaos-soak benchmark (``BENCH_faults.json``).

Wiring: injection points consult the process-global injector installed by
``repro.faults.inject(plan)`` (a context manager) — with nothing
installed every point is a no-op costing one function call. The module
depends only on the standard library, so any layer may import it without
cycles.
"""

from __future__ import annotations

import contextlib
import dataclasses
import random
import threading
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple


class FaultError(RuntimeError):
    """Base class for injected failures."""


class TransientFaultError(FaultError):
    """A retryable failure raised *before* any side effect took place.

    The contract matters for exactly-once: code that raises this (or maps
    an injected fault to it) guarantees no stream round was consumed and
    no state was mutated, so a retry from the same position is safe.
    """


class FeederDeathError(TransientFaultError):
    """The background prefetch worker died before touching the source."""


class TenantCrashError(FaultError):
    """A serve-layer tenant step crashed (scheduling thread, not the run)."""


#: every known injection point → the fault kinds it understands
POINT_KINDS: Dict[str, Tuple[str, ...]] = {
    "stream.take": ("stall", "error"),
    "stream.prefetch": ("feeder_death",),
    "engine.step": ("transient", "nan", "device_loss"),
    "checkpoint.write": ("crash_mid_write", "corrupt_payload"),
    "serve.step": ("tenant_crash",),
    "serve.loop": ("drain",),
}


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One declarative fault: fire ``kind`` at ``point``.

    ``after`` hits of the point are skipped, then the next ``times``
    hits fire (hit = one ``fire()`` call whose context matches ``match``).
    ``arg`` is kind-specific (stall seconds). ``match`` filters on the
    fire-time context — e.g. ``(("tenant", "t1"),)`` targets one tenant,
    ``(("supervised", True),)`` restricts a NaN poisoning to supervised
    segments where something can actually detect it.
    """

    point: str
    kind: str
    after: int = 0
    times: int = 1
    arg: float = 0.0
    match: Tuple[Tuple[str, Any], ...] = ()

    def __post_init__(self):
        kinds = POINT_KINDS.get(self.point)
        if kinds is None:
            raise ValueError(
                f"unknown injection point {self.point!r}; known: "
                f"{sorted(POINT_KINDS)}"
            )
        if self.kind not in kinds:
            raise ValueError(
                f"point {self.point!r} has no fault kind {self.kind!r}; "
                f"known: {kinds}"
            )
        if self.after < 0 or self.times < 1:
            raise ValueError(f"need after >= 0 and times >= 1, got {self}")

    def matches(self, ctx: Dict[str, Any]) -> bool:
        return all(ctx.get(k) == v for k, v in self.match)


@dataclasses.dataclass
class FaultRecord:
    """One fired fault, plus when (if ever) the system recovered from it."""

    point: str
    kind: str
    hit: int  # the point's hit index (per matching spec) that fired
    t_fired: float  # time.perf_counter() at fire time
    ctx: Dict[str, Any]
    t_recovered: Optional[float] = None

    @property
    def recovered(self) -> bool:
        return self.t_recovered is not None

    @property
    def recovery_latency_s(self) -> Optional[float]:
        if self.t_recovered is None:
            return None
        return self.t_recovered - self.t_fired

    def to_json(self) -> Dict[str, Any]:
        return {
            "point": self.point,
            "kind": self.kind,
            "hit": self.hit,
            "ctx": {k: repr(v) for k, v in self.ctx.items()},
            "recovered": self.recovered,
            "recovery_latency_s": self.recovery_latency_s,
        }


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, replayable set of fault specs (+ the seed it came from)."""

    specs: Tuple[FaultSpec, ...] = ()
    seed: int = 0

    def __post_init__(self):
        object.__setattr__(self, "specs", tuple(self.specs))

    @classmethod
    def storm(
        cls,
        seed: int = 0,
        layers: Iterable[str] = ("stream", "engine", "checkpoint", "serve"),
        intensity: int = 1,
        supervised: bool = True,
        tenant: Optional[str] = None,
    ) -> "FaultPlan":
        """A seeded multi-layer fault storm: same seed → same plan.

        One fault of every kind per requested layer per unit of
        ``intensity``, with trigger offsets drawn from the seeded RNG at
        *plan construction* (never at fire time), so the storm is fully
        determined before the run starts. ``supervised=False`` drops the
        NaN poisoning (nothing would detect it); ``tenant`` pins the
        serve-layer crash to one tenant.
        """
        rng = random.Random(seed)
        layers = tuple(layers)
        specs: List[FaultSpec] = []
        t_match = (("tenant", tenant),) if tenant is not None else ()
        for _ in range(max(1, int(intensity))):
            if "stream" in layers:
                specs.append(
                    FaultSpec("stream.take", "stall", after=rng.randrange(1, 4),
                              arg=0.01 + 0.02 * rng.random())
                )
                specs.append(
                    FaultSpec("stream.take", "error", after=rng.randrange(4, 7))
                )
                specs.append(
                    FaultSpec("stream.prefetch", "feeder_death",
                              after=rng.randrange(0, 3))
                )
            if "engine" in layers:
                specs.append(
                    FaultSpec("engine.step", "transient", after=rng.randrange(1, 3))
                )
                if supervised:
                    specs.append(
                        FaultSpec("engine.step", "nan", after=rng.randrange(4, 7),
                                  match=(("supervised", True),))
                    )
            if "checkpoint" in layers:
                specs.append(
                    FaultSpec("checkpoint.write", "crash_mid_write",
                              after=rng.randrange(0, 2))
                )
                specs.append(
                    FaultSpec("checkpoint.write", "corrupt_payload",
                              after=rng.randrange(2, 4))
                )
            if "serve" in layers:
                specs.append(
                    FaultSpec("serve.step", "tenant_crash",
                              after=rng.randrange(1, 4), match=t_match)
                )
        return cls(specs=tuple(specs), seed=seed)

    def kinds(self) -> List[str]:
        return sorted({f"{s.point}:{s.kind}" for s in self.specs})


class FaultInjector:
    """Fires a ``FaultPlan`` at named injection points, deterministically.

    Thread-safe: points are hit from the serve loop, trainer threads, and
    the feeder's prefetch worker concurrently; per-spec hit counters and
    the record log live behind one lock. ``fire`` returns the first
    triggered spec (all matching specs still advance their counters) or
    ``None`` — the call site maps the spec's kind onto its own failure
    mode (sleep, raise, corrupt, drain).
    """

    def __init__(self, plan: Optional[FaultPlan] = None):
        self.plan = plan or FaultPlan()
        self._hits: List[int] = [0] * len(self.plan.specs)
        self._lock = threading.Lock()
        self.records: List[FaultRecord] = []

    # -- firing ------------------------------------------------------------
    def fire(self, point: str, **ctx: Any) -> Optional[FaultSpec]:
        """One hit at ``point``; the triggered spec, or ``None``."""
        triggered: Optional[Tuple[FaultSpec, int]] = None
        with self._lock:
            for i, spec in enumerate(self.plan.specs):
                if spec.point != point or not spec.matches(ctx):
                    continue
                hit = self._hits[i]
                self._hits[i] = hit + 1
                if spec.after <= hit < spec.after + spec.times and triggered is None:
                    triggered = (spec, hit)
            if triggered is None:
                return None
            spec, hit = triggered
            self.records.append(
                FaultRecord(
                    point=point, kind=spec.kind, hit=hit,
                    t_fired=time.perf_counter(), ctx=dict(ctx),
                )
            )
            return spec

    def resolved(self, point: str) -> Optional[FaultRecord]:
        """Mark the oldest unrecovered fault at ``point`` as healed now.

        Recovery sites call this after the retry/rollback/fallback that
        absorbed the failure succeeds; a point with nothing outstanding
        is a no-op (recovery code cannot tell an injected fault from a
        genuine one, and should not have to)."""
        now = time.perf_counter()
        with self._lock:
            for rec in self.records:
                if rec.point == point and rec.t_recovered is None:
                    rec.t_recovered = now
                    return rec
            return None

    # -- observability -----------------------------------------------------
    @property
    def fired(self) -> int:
        with self._lock:
            return len(self.records)

    def unrecovered(self) -> List[FaultRecord]:
        with self._lock:
            return [r for r in self.records if not r.recovered]

    def summary(self) -> Dict[str, Any]:
        """JSON-safe chaos report (what ``BENCH_faults.json`` embeds)."""
        with self._lock:
            records = [r.to_json() for r in self.records]
        lat = [
            r["recovery_latency_s"] for r in records if r["recovery_latency_s"]
            is not None
        ]
        return {
            "seed": self.plan.seed,
            "planned_kinds": self.plan.kinds(),
            "fired": len(records),
            "recovered": sum(1 for r in records if r["recovered"]),
            "recovery_latency_max_s": max(lat) if lat else None,
            "recovery_latency_mean_s": (sum(lat) / len(lat)) if lat else None,
            "records": records,
        }


# ---------------------------------------------------------------------------
# Process-global wiring (what the injection points consult)
# ---------------------------------------------------------------------------

_ACTIVE: Optional[FaultInjector] = None
_ACTIVE_LOCK = threading.Lock()


def install(injector: Optional[FaultInjector]) -> None:
    """Install (or, with ``None``, clear) the process-global injector."""
    global _ACTIVE
    with _ACTIVE_LOCK:
        _ACTIVE = injector


def active() -> Optional[FaultInjector]:
    return _ACTIVE


@contextlib.contextmanager
def inject(plan_or_injector):
    """Run a block under fault injection; always uninstalls on exit.

        with repro.faults.inject(FaultPlan.storm(seed=7)) as chaos:
            result = session.run("elastic", ...)
        assert not chaos.unrecovered()
    """
    injector = (
        plan_or_injector
        if isinstance(plan_or_injector, FaultInjector)
        else FaultInjector(plan_or_injector)
    )
    install(injector)
    try:
        yield injector
    finally:
        install(None)


def fire(point: str, **ctx: Any) -> Optional[FaultSpec]:
    """Hit ``point`` on the active injector; ``None`` when none installed."""
    inj = _ACTIVE
    if inj is None:
        return None
    return inj.fire(point, **ctx)


def resolved(point: str) -> None:
    """Report recovery at ``point`` to the active injector (if any)."""
    inj = _ACTIVE
    if inj is not None:
        inj.resolved(point)


def specs_for(plan: FaultPlan, point: str) -> Sequence[FaultSpec]:
    """The plan's specs targeting one point (test/bench convenience)."""
    return [s for s in plan.specs if s.point == point]
