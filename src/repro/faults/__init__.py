"""repro.faults: deterministic fault injection + the error taxonomy.

See ``repro.faults.plan`` for the model. Quick use:

    from repro import faults

    plan = faults.FaultPlan.storm(seed=7)
    with faults.inject(plan) as chaos:
        result = session.run("elastic", supervisor_cfg=sup_cfg)
    assert not chaos.unrecovered()
"""

from repro.faults.plan import (
    POINT_KINDS,
    FaultError,
    FaultInjector,
    FaultPlan,
    FaultRecord,
    FaultSpec,
    FeederDeathError,
    TenantCrashError,
    TransientFaultError,
    active,
    fire,
    inject,
    install,
    resolved,
    specs_for,
)

__all__ = [
    "POINT_KINDS",
    "FaultError",
    "FaultInjector",
    "FaultPlan",
    "FaultRecord",
    "FaultSpec",
    "FeederDeathError",
    "TenantCrashError",
    "TransientFaultError",
    "active",
    "fire",
    "inject",
    "install",
    "resolved",
    "specs_for",
]
