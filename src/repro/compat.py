"""Cross-version jax API compatibility helpers.

The container pins jax 0.4.37; several APIs this repo uses moved or changed
shape around that release. Every call site goes through this shim so a
future jax bump is a one-line change here instead of a repo-wide sweep:

- ``jax.tree.flatten_with_path`` only exists from 0.4.38 on (0.4.37 has it
  in ``jax.tree_util``).
- ``jax.shard_map`` graduated from ``jax.experimental.shard_map`` after
  0.4.37.
- ``jax.lax.pvary`` (varying-manual-axes marker) doesn't exist yet in
  0.4.37; data-wise it is the identity, so the fallback is a no-op.
- ``Compiled.cost_analysis()`` returns a list of per-computation dicts on
  older jax and a single dict on newer.
"""

from __future__ import annotations

import jax

if hasattr(jax.tree, "flatten_with_path"):  # jax >= 0.4.38
    tree_flatten_with_path = jax.tree.flatten_with_path
else:
    tree_flatten_with_path = jax.tree_util.tree_flatten_with_path

if hasattr(jax, "shard_map"):  # jax >= 0.4.38-ish graduation
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map  # noqa: F401

if hasattr(jax.lax, "pvary"):
    pvary = jax.lax.pvary
else:

    def pvary(x, axis_names):  # identity: pvary only marks replication info
        del axis_names
        return x


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized to one flat dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca
