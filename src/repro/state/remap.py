"""Lossless engine-state remapping across partition boundaries.

The merge/re-split machinery that carries live state through an elastic
re-plan (paper §5.2) used to live as loose functions in
``runtime/elastic_trainer.py`` and covered stage params, optimizer moments
and Iter-Fisher λ statistics — but **not** the gradient-accumulation and
Δθ rings, which were silently re-zeroed at every cross-partition switch
(the in-flight compensation state the paper's Alg. 1 exists to maintain).

``StateRemapper`` closes that gap. At a partition boundary it distinguishes
two cases by what happens to the *schedule*:

1. **Same-schedule switch** (pipeline config and stage count unchanged,
   only the layer→stage bounds moved): the schedule — and therefore every
   stage's push/pop/ring-slot pattern — continues unchanged, so the rings
   are remapped **slot-wise**: each ring slot is a stage-params-shaped
   tree, merged into the whole-model view under the old bounds and
   re-split under the new ones, then re-stacked. No gradient information
   is discarded; layers that stay on their stage continue bit-exactly.

2. **Schedule-restarting switch** (stage count or pipeline config
   changed): the ring geometry and slot accounting no longer apply, so
   carrying ring *contents* would be inert — the restarted schedule
   overwrites every slot (``push_reset``) before reading it. Instead the
   remapper **flushes**: it walks the old schedule prefix to find every
   in-flight accumulation group (slot + accumulated count per stage) and
   applies each pending mean gradient through the optimizer before the
   merge/re-split, so every backward round computed before the switch
   reaches the weights. The flush is applied without Iter-Fisher
   compensation — at the boundary the gradient is applied to the weights
   it was computed against (τ=0), which is exactly the case compensation
   is a no-op for. Δθ history is re-time-indexed onto the new ring depth
   (newest ``min(K_old, K_new)`` entries land in the slots the new
   schedule treats as "previous updates"; genuinely-new slots are
   zero-padded).

Either way ``rounds_lost == 0``: nothing in flight is discarded. The only
way to lose rounds is the documented escape hatch ``carry_rings=False``,
which drops the rings and *reports* how many accumulated backward rounds
that discarded.

These functions were previously importable from
``repro.runtime.elastic_trainer``; those names still work but emit a
``DeprecationWarning``.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple
import warnings

import jax
import jax.numpy as jnp

from repro.core import compensation as comp_lib
from repro.models.config import ModelConfig
from repro.optim.optimizers import AdamWState, Optimizer, SGDState
from repro.state.engine_state import EngineState

Pytree = Any


# ---------------------------------------------------------------------------
# Merge/re-split primitives (moved from runtime/elastic_trainer.py)
# ---------------------------------------------------------------------------


def _merge_resplit(
    model_cfg: ModelConfig, stage_trees: Sequence[Pytree], new_bounds
) -> List[Pytree]:
    """Merge stage-params-shaped trees and re-split on ``new_bounds``.

    Works for anything that mirrors the stage-param structure: the params
    themselves, optimizer moments, and Iter-Fisher EMA statistics.
    """
    from repro.models import transformer as T

    merged = T.merge_stage_params(model_cfg, list(stage_trees))
    return T.split_stage_params(model_cfg, merged, new_bounds)


def _overlaps(old_bounds, lo: int, hi: int) -> List[Tuple[int, int]]:
    """(old stage index, #overlapping layers) for new-stage span [lo, hi)."""
    out = []
    for i in range(len(old_bounds) - 1):
        n = min(hi, old_bounds[i + 1]) - max(lo, old_bounds[i])
        if n > 0:
            out.append((i, n))
    return out


def remap_stage_params(
    model_cfg: ModelConfig, stage_params: Sequence[Pytree], new_bounds
) -> List[Pytree]:
    return _merge_resplit(model_cfg, stage_params, new_bounds)


def remap_opt_states(
    model_cfg: ModelConfig,
    opt_states: Sequence[Any],
    old_bounds,
    new_bounds,
    optimizer: Optimizer,
    new_stage_params: Sequence[Pytree],
) -> Tuple[Any, ...]:
    """Carry per-parameter optimizer moments through a partition change.

    Moments mirror the stage-param tree, so they take the same
    merge/re-split path as the weights. Per-stage scalars that cannot be
    split per-layer (the Adam bias-correction count) take the conservative
    minimum over the old stages a new stage overlaps. Optimizers this
    module does not know structurally are re-initialized.
    """
    first = opt_states[0]
    P_new = len(new_bounds) - 1
    if isinstance(first, AdamWState):
        mu = _merge_resplit(model_cfg, [s.mu for s in opt_states], new_bounds)
        nu = _merge_resplit(model_cfg, [s.nu for s in opt_states], new_bounds)
        out = []
        for j in range(P_new):
            ov = _overlaps(old_bounds, new_bounds[j], new_bounds[j + 1])
            count = jnp.min(jnp.stack([opt_states[i].count for i, _ in ov]))
            out.append(AdamWState(mu=mu[j], nu=nu[j], count=count))
        return tuple(out)
    if isinstance(first, SGDState):
        mom = _merge_resplit(model_cfg, [s.momentum for s in opt_states], new_bounds)
        return tuple(SGDState(momentum=m) for m in mom)
    return tuple(optimizer.init(sp) for sp in new_stage_params)


def remap_comp_states(
    model_cfg: ModelConfig,
    comp_states: Sequence[comp_lib.CompensationState],
    old_bounds,
    new_bounds,
) -> Tuple[comp_lib.CompensationState, ...]:
    """Carry Iter-Fisher λ and its EMA statistics through a partition change.

    v_r/v_a mirror the stage params (merge/re-split; the fixed-λ mode's
    empty placeholders pass through unchanged). λ is a per-stage scalar:
    a new stage takes the layer-overlap-weighted mean of the old stages it
    covers; ``steps`` takes the overlap maximum (EMA warm-up state).
    """
    v_r = _merge_resplit(model_cfg, [s.v_r for s in comp_states], new_bounds)
    v_a = _merge_resplit(model_cfg, [s.v_a for s in comp_states], new_bounds)
    out = []
    for j in range(len(new_bounds) - 1):
        ov = _overlaps(old_bounds, new_bounds[j], new_bounds[j + 1])
        w = jnp.asarray([n for _, n in ov], jnp.float32)
        lams = jnp.stack([comp_states[i].lam for i, _ in ov])
        steps = jnp.max(jnp.stack([comp_states[i].steps for i, _ in ov]))
        out.append(
            comp_lib.CompensationState(
                lam=jnp.sum(w * lams) / jnp.sum(w),
                v_r=v_r[j],
                v_a=v_a[j],
                steps=steps,
            )
        )
    return tuple(out)


def remap_ring_trees(
    model_cfg: ModelConfig,
    rings: Sequence[Pytree],
    new_bounds,
    num_slots: int,
) -> Tuple[Pytree, ...]:
    """Slot-wise merge/re-split of per-stage ring arrays.

    Ring leaves carry a leading slot axis ``(num_slots, *param_shape)``
    while the partitioner slices leaf axis 0 (the layer axis), so the
    merge/re-split cannot apply to the ring tree directly. Instead each
    slot — a stage-params-shaped tree — is extracted, merged under the old
    bounds, re-split under the new ones, and the per-stage results are
    re-stacked along the slot axis. Lossless: slot contents are permuted
    between stages, never recomputed or zeroed.
    """
    per_slot = []
    for s in range(num_slots):
        slot_trees = [
            jax.tree.map(lambda a, s=s: a[s], ring) for ring in rings
        ]
        per_slot.append(_merge_resplit(model_cfg, slot_trees, new_bounds))
    P_new = len(new_bounds) - 1
    return tuple(
        jax.tree.map(
            lambda *leaves: jnp.stack(list(leaves)),
            *[per_slot[s][j] for s in range(num_slots)],
        )
        for j in range(P_new)
    )


# ---------------------------------------------------------------------------
# In-flight accounting against a schedule prefix
# ---------------------------------------------------------------------------


def pending_groups(schedule, upto: int) -> List[Dict[int, int]]:
    """In-flight accumulation groups after ``upto`` rounds of ``schedule``.

    Returns, per stage, an insertion-ordered ``{ring_slot: accumulated
    count}`` of every group that was pushed into but whose pop has not
    fired within the first ``upto`` rounds — both still-filling groups and
    completed groups whose delayed apply lands beyond the prefix. O(upto·P)
    host work on the numpy schedule arrays.
    """
    P = schedule.num_stages
    pending: List[Dict[int, int]] = [{} for _ in range(P)]
    push_slot = schedule.push_slot
    push_reset = schedule.push_reset
    pop_slot = schedule.pop_slot
    for m in range(min(upto, schedule.num_rounds)):
        for j in range(P):
            ps = int(push_slot[m, j])
            if ps >= 0:
                if bool(push_reset[m, j]):
                    # slot recycled: any stale entry is overwritten, and the
                    # group re-enters in start order
                    pending[j].pop(ps, None)
                    pending[j][ps] = 0
                pending[j][ps] = pending[j].get(ps, 0) + 1
            pp = int(pop_slot[m, j])
            if pp >= 0:
                pending[j].pop(pp, None)
    return pending


def rounds_in_flight(schedule, upto: int) -> int:
    """Accumulated-but-unapplied backward rounds after ``upto`` rounds.

    The max over stages (stages run the same stream, so the max — not the
    sum — is the number of stream rounds whose contribution would be lost
    if the rings were dropped here).
    """
    pending = pending_groups(schedule, upto)
    return max((sum(g.values()) for g in pending), default=0)


def applied_updates(schedule, upto: int) -> List[int]:
    """Per-stage count of optimizer updates applied in the first ``upto``
    rounds (positions the Δθ ring's newest slot for re-time-indexing)."""
    import numpy as np

    upto = min(upto, schedule.num_rounds)
    return [
        int(np.sum(schedule.pop_slot[:upto, j] >= 0))
        for j in range(schedule.num_stages)
    ]


def retime_deltas(
    deltas: Sequence[Pytree],
    upd_counts: Sequence[int],
    k_old: int,
    k_new: int,
) -> Tuple[Pytree, ...]:
    """Re-time-index Δθ rings from depth ``k_old`` to ``k_new``.

    Old update ``u`` lives at slot ``u % k_old``; under the new ring the
    pre-boundary updates are conceptually updates ``-1, -2, …``, i.e. the
    newest carried entry lands at slot ``k_new - 1`` and older ones walk
    backwards. Only entries actually written (``upd_counts``) are carried
    — genuinely-new slots stay zero.
    """
    out = []
    for j, dring in enumerate(deltas):
        keep = min(k_old, k_new, int(upd_counts[j]))

        def _retime(a, keep=keep, upd=int(upd_counts[j])):
            new = jnp.zeros((k_new, *a.shape[1:]), a.dtype)
            for i in range(keep):
                src = (upd - 1 - i) % k_old
                new = new.at[k_new - 1 - i].set(a[src])
            return new

        out.append(jax.tree.map(_retime, dring))
    return tuple(out)


# ---------------------------------------------------------------------------
# The remapper
# ---------------------------------------------------------------------------


class StateRemapper:
    """Moves a live ``EngineState`` onto a new partition, losslessly.

    One remapper per (model config, optimizer) pair; see the module
    docstring for the same-schedule vs schedule-restarting taxonomy.
    ``carry_rings=False`` is the explicit escape hatch: rings are dropped
    (the pre-refactor behavior) and the returned ``rounds_lost`` reports
    the in-flight backward rounds that discarded.
    """

    def __init__(self, model_cfg: ModelConfig, optimizer: Optimizer):
        self.model_cfg = model_cfg
        self.optimizer = optimizer

    def remap(
        self,
        state: EngineState,
        new_bounds: Sequence[int],
        *,
        new_geometry=None,
        same_schedule: bool = False,
        old_schedule=None,
        rounds_into_schedule: int = 0,
        carry_rings: bool = True,
    ) -> Tuple[EngineState, int]:
        """Remap ``state`` onto ``new_bounds``.

        new_geometry: the ``RingGeometry`` of the destination schedule
        (required when the schedule restarts and Δθ history is carried).
        same_schedule: the destination continues the *same* schedule
        (stage count and pipeline config unchanged) — rings remap
        slot-wise and the schedule origin survives.
        old_schedule / rounds_into_schedule: the schedule the rings were
        filled under and how many rounds of it ran — required to flush
        (or to count losses for ``carry_rings=False``).

        Returns ``(remapped_state, rounds_lost)``; ``rounds_lost`` is 0
        unless ``carry_rings=False`` discarded in-flight groups.
        """
        if state.bounds is None:
            raise ValueError("EngineState.bounds is unset — cannot remap")
        old_bounds = list(state.bounds)
        new_bounds = [int(b) for b in new_bounds]
        bounds_changed = old_bounds != new_bounds

        stage_params = list(state.stage_params)
        opt_states = state.opt_states
        comp_states = state.comp_states
        rings = state.rings
        deltas = state.deltas
        # slot depth of ``deltas`` when it reaches the merge/re-split below
        # (a flush re-times it onto the destination depth; otherwise it
        # stays at the shared same-schedule geometry)
        delta_depth: Optional[int] = None
        rounds_lost = 0

        if rings is not None and not carry_rings:
            if old_schedule is not None:
                rounds_lost = rounds_in_flight(old_schedule, rounds_into_schedule)
            else:
                warnings.warn(
                    "carry_rings=False without the old schedule: in-flight "
                    "rounds were dropped but cannot be counted",
                    stacklevel=2,
                )
            rings = deltas = None
        elif rings is not None and not same_schedule:
            # The destination schedule restarts: slot accounting no longer
            # applies, so apply every in-flight group now (flush) instead of
            # carrying contents the restarted schedule would overwrite.
            if old_schedule is None:
                raise ValueError(
                    "schedule-restarting remap needs the old schedule to "
                    "flush in-flight groups; pass carry_rings=False to drop "
                    "them explicitly"
                )
            pending = pending_groups(old_schedule, rounds_into_schedule)
            for j, groups in enumerate(pending):
                for slot, count in groups.items():
                    if count <= 0:
                        continue
                    g = jax.tree.map(
                        lambda a, slot=slot, count=count: a[slot] / count,
                        rings[j],
                    )
                    stage_params[j], opt_j = self.optimizer.update(
                        stage_params[j], g, opt_states[j]
                    )
                    opt_states = (
                        opt_states[:j] + (opt_j,) + opt_states[j + 1 :]
                    )
            k_old = old_schedule.delta_ring
            k_new = None if new_geometry is None else new_geometry.delta_ring
            if deltas is not None and k_new is not None:
                deltas = retime_deltas(
                    deltas,
                    applied_updates(old_schedule, rounds_into_schedule),
                    k_old,
                    k_new,
                )
                delta_depth = k_new
            else:
                deltas = None
            # nothing is in flight after the flush: fresh zero rings under
            # the new geometry are exact, not an approximation
            rings = None

        if not bounds_changed:
            new_sp: Sequence[Pytree] = stage_params
            new_opts, new_comps = opt_states, comp_states
        else:
            new_sp = remap_stage_params(self.model_cfg, stage_params, new_bounds)
            new_opts = (
                None
                if opt_states is None
                else remap_opt_states(
                    self.model_cfg, opt_states, old_bounds, new_bounds,
                    self.optimizer, new_sp,
                )
            )
            new_comps = (
                None
                if comp_states is None
                else remap_comp_states(
                    self.model_cfg, comp_states, old_bounds, new_bounds
                )
            )
            if rings is not None or deltas is not None:
                geom = state.geometry
                if geom is None and new_geometry is not None:
                    geom = new_geometry
                if geom is None:
                    raise ValueError(
                        "ring remap needs the ring geometry (EngineState."
                        "geometry or new_geometry)"
                    )
                if rings is not None:
                    # same-schedule switch: ring geometry is identical by
                    # construction (it depends only on (config, P))
                    rings = remap_ring_trees(
                        self.model_cfg, rings, new_bounds, geom.ring_size
                    )
                if deltas is not None:
                    # flushed deltas already sit at the destination depth;
                    # same-schedule deltas share the unchanged geometry
                    deltas = remap_ring_trees(
                        self.model_cfg, deltas, new_bounds,
                        delta_depth if delta_depth is not None else geom.delta_ring,
                    )

        geometry = state.geometry if same_schedule else (new_geometry or state.geometry)
        return (
            EngineState(
                stage_params=tuple(new_sp),
                rings=rings,
                deltas=deltas,
                opt_states=None if new_opts is None else tuple(new_opts),
                comp_states=None if new_comps is None else tuple(new_comps),
                bounds=tuple(new_bounds),
                geometry=geometry,
                sched_origin=state.sched_origin if same_schedule else None,
            ),
            int(rounds_lost),
        )
