"""Typed engine-state plane: the one container for live pipeline state.

``FerretEngine`` state used to be a positional 5-tuple
``(stage_params, rings, deltas, opt_states, comp_states)`` threaded through
``core/ferret.py`` and ``runtime/elastic_trainer.py`` — easy to unpack in
the wrong order, and easy to *silently drop* pieces of (the old
``remap_engine_state`` discarded the rings without any signal).
``EngineState`` names the five components and carries the metadata a
remap/checkpoint/drain needs to interpret them:

- ``bounds``      — the partition the per-stage trees are split on
- ``geometry``    — the grad-accum/Δθ ring depths the ring arrays are shaped
                    for (``repro.core.schedule.RingGeometry``)
- ``sched_origin``— the global stream round the rings' schedule build
                    started at (continuation slices re-anchor here)

The metadata rides as pytree *aux data* (static, hashable), the five
components as keyed children — so ``jax.tree.map``, checkpoint
flatten/unflatten (``n:<field>`` key paths), and the Supervisor's host
snapshot all treat an ``EngineState`` as a first-class pytree. The jitted
scan itself still carries the plain tuple: ``FerretEngine.run`` unwraps at
the jit boundary (``as_tuple``) and re-wraps the result, so metadata
changes (a new ``sched_origin`` every segment) never retrace the compiled
executable.

Tuple compatibility: ``state[0]`` … ``state[4]``, ``len(state)`` and 5-way
unpacking all keep working, so existing call sites migrate incrementally.
"""

from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, Iterator, Optional, Tuple

import jax

Pytree = Any

# child order is the legacy positional-tuple order — as_tuple/from_tuple
# and the pytree flatten below all rely on it
_CHILDREN = ("stage_params", "rings", "deltas", "opt_states", "comp_states")


@dataclasses.dataclass(frozen=True)
class EngineState:
    """Live state of a ``FerretEngine`` run, plus where it came from.

    ``rings``/``deltas``/``opt_states``/``comp_states`` may be ``None``
    before the first segment runs — ``FerretEngine.init_state`` fills the
    gaps (zero rings, fresh optimizer/compensation state).
    """

    stage_params: Tuple[Pytree, ...]
    rings: Optional[Tuple[Pytree, ...]] = None
    deltas: Optional[Tuple[Pytree, ...]] = None
    opt_states: Optional[Tuple[Any, ...]] = None
    comp_states: Optional[Tuple[Any, ...]] = None
    # -- static metadata (pytree aux data, never traced) --
    bounds: Optional[Tuple[int, ...]] = None
    geometry: Optional[Any] = None  # repro.core.schedule.RingGeometry
    sched_origin: Optional[int] = None

    NUM_COMPONENTS: ClassVar[int] = len(_CHILDREN)

    # -- positional-tuple compatibility ----------------------------------
    def as_tuple(self) -> Tuple:
        """The legacy ``(stage_params, rings, deltas, opts, comps)`` tuple.

        This is also the exact structure the jitted scan carries — see
        ``FerretEngine.run`` for the boundary conversion.
        """
        return tuple(getattr(self, name) for name in _CHILDREN)

    @classmethod
    def from_tuple(
        cls,
        state: Tuple,
        *,
        bounds: Optional[Tuple[int, ...]] = None,
        geometry: Optional[Any] = None,
        sched_origin: Optional[int] = None,
    ) -> "EngineState":
        """Wrap a legacy 5-tuple (or another ``EngineState``)."""
        if isinstance(state, EngineState):
            return dataclasses.replace(
                state, bounds=bounds if bounds is not None else state.bounds,
                geometry=geometry if geometry is not None else state.geometry,
                sched_origin=(
                    sched_origin if sched_origin is not None else state.sched_origin
                ),
            )
        sp, rings, deltas, opts, comps = state
        return cls(
            stage_params=tuple(sp),
            rings=None if rings is None else tuple(rings),
            deltas=None if deltas is None else tuple(deltas),
            opt_states=None if opts is None else tuple(opts),
            comp_states=None if comps is None else tuple(comps),
            bounds=None if bounds is None else tuple(int(b) for b in bounds),
            geometry=geometry,
            sched_origin=None if sched_origin is None else int(sched_origin),
        )

    def __iter__(self) -> Iterator:
        return iter(self.as_tuple())

    def __len__(self) -> int:
        return self.NUM_COMPONENTS

    def __getitem__(self, idx):
        return self.as_tuple()[idx]

    # -- convenience ------------------------------------------------------
    def replace(self, **changes) -> "EngineState":
        return dataclasses.replace(self, **changes)

    @property
    def num_stages(self) -> int:
        return len(self.stage_params)

    @property
    def has_rings(self) -> bool:
        return self.rings is not None


def _flatten_with_keys(state: EngineState):
    children = tuple(
        (jax.tree_util.GetAttrKey(name), getattr(state, name))
        for name in _CHILDREN
    )
    aux = (state.bounds, state.geometry, state.sched_origin)
    return children, aux


def _flatten(state: EngineState):
    children = tuple(getattr(state, name) for name in _CHILDREN)
    aux = (state.bounds, state.geometry, state.sched_origin)
    return children, aux


def _unflatten(aux, children) -> EngineState:
    bounds, geometry, sched_origin = aux
    sp, rings, deltas, opts, comps = children
    return EngineState(
        stage_params=sp, rings=rings, deltas=deltas,
        opt_states=opts, comp_states=comps,
        bounds=bounds, geometry=geometry, sched_origin=sched_origin,
    )


jax.tree_util.register_pytree_with_keys(
    EngineState, _flatten_with_keys, _unflatten, _flatten
)
