"""Unified engine-state plane.

``EngineState`` is the typed container for live ``FerretEngine`` state
(stage params, grad-accum rings, Δθ rings, optimizer moments, Iter-Fisher
λ statistics) plus the metadata — partition bounds, ring geometry,
schedule origin — that remapping, checkpointing and drain/restore need to
interpret it. ``StateRemapper`` moves an ``EngineState`` across partition
boundaries losslessly (slot-wise ring remap on same-schedule switches,
in-flight flush on schedule-restarting ones).

The loose ``remap_*`` functions moved here from
``repro.runtime.elastic_trainer``; the old import paths still work with a
``DeprecationWarning``.
"""

from repro.state.engine_state import EngineState
from repro.state.remap import (
    StateRemapper,
    applied_updates,
    pending_groups,
    remap_comp_states,
    remap_opt_states,
    remap_ring_trees,
    remap_stage_params,
    retime_deltas,
    rounds_in_flight,
)

__all__ = [
    "EngineState",
    "StateRemapper",
    "applied_updates",
    "pending_groups",
    "remap_comp_states",
    "remap_opt_states",
    "remap_ring_trees",
    "remap_stage_params",
    "retime_deltas",
    "rounds_in_flight",
]
