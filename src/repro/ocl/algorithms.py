"""OCL algorithm building blocks (paper Table 2): Vanilla, ER, MIR, LwF, MAS.

The algorithms themselves are first-class plugin classes in
``repro.ocl.registry`` (resolved by name through ``@register_algorithm`` /
``get_algorithm``); the session layer ``repro.api`` is the front door.
This module keeps:

- ``OCLConfig`` — the shared hyper-parameter record (``method`` selects the
  registered algorithm),
- the shared math (``ReplayBuffer``, KD loss, MAS importance/penalty),
- deprecated shims (``make_ocl_step``, ``wrap_staged_model``,
  ``mix_replay_into_stream``) that delegate to the registry so pre-registry
  call sites keep working.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


@dataclasses.dataclass(frozen=True)
class OCLConfig:
    method: str = "vanilla"  # any name in repro.ocl.registry (vanilla | er | ...)
    replay_size: int = 5000  # paper §12: buffer 5e3
    replay_batch: int = 8
    mir_candidates: int = 32
    lwf_weight: float = 1.0
    lwf_temp: float = 2.0
    mas_weight: float = 0.1
    refresh_every: int = 0  # sequential path: teacher/Ω refresh period (0 = entry only)
    seed: int = 0


# ---------------------------------------------------------------------------
# Replay buffer (host-side reservoir)
# ---------------------------------------------------------------------------


class ReplayBuffer:
    def __init__(self, capacity: int, seed: int = 0):
        self.capacity = capacity
        self.rng = np.random.default_rng(seed)
        self.rows: list = []
        self.seen = 0

    def add(self, row: Dict[str, np.ndarray]) -> None:
        self.seen += 1
        if len(self.rows) < self.capacity:
            self.rows.append(row)
        else:
            k = self.rng.integers(0, self.seen)
            if k < self.capacity:
                self.rows[k] = row

    def add_batch(self, batch: Dict[str, np.ndarray]) -> None:
        b = next(iter(batch.values())).shape[0]
        for i in range(b):
            self.add({k: np.asarray(v[i]) for k, v in batch.items()})

    def sample(self, n: int) -> Optional[Dict[str, np.ndarray]]:
        if not self.rows:
            return None
        idx = self.rng.integers(0, len(self.rows), size=n)
        keys = self.rows[0].keys()
        return {k: np.stack([self.rows[i][k] for i in idx]) for k in keys}

    def __len__(self) -> int:
        return len(self.rows)


# ---------------------------------------------------------------------------
# Loss building blocks
# ---------------------------------------------------------------------------


def _kd_loss(student_logits: jax.Array, teacher_logits: jax.Array, temp: float) -> jax.Array:
    """LwF distillation: KL(teacher ‖ student) at temperature T."""
    t = jax.nn.log_softmax(teacher_logits.astype(jnp.float32) / temp, axis=-1)
    s = jax.nn.log_softmax(student_logits.astype(jnp.float32) / temp, axis=-1)
    return jnp.mean(jnp.sum(jnp.exp(t) * (t - s), axis=-1)) * temp * temp


def mas_importance(loss_free_forward: Callable, params: Pytree, batches: list) -> Pytree:
    """MAS Ω: E |∂ ‖f(x)‖² / ∂θ| accumulated over representative batches."""

    def sq_norm(p, batch):
        out = loss_free_forward(p, batch)
        return jnp.mean(jnp.sum(jnp.square(out.astype(jnp.float32)), axis=-1))

    omega = None
    for batch in batches:
        g = jax.grad(sq_norm)(params, batch)
        g = jax.tree.map(lambda a: jnp.abs(a.astype(jnp.float32)), g)
        omega = g if omega is None else jax.tree.map(jnp.add, omega, g)
    n = max(len(batches), 1)
    return jax.tree.map(lambda a: a / n, omega)


def mas_penalty(params: Pytree, ref: Pytree, omega: Pytree) -> jax.Array:
    terms = jax.tree.map(
        lambda p, r, o: jnp.sum(o * jnp.square(p.astype(jnp.float32) - r.astype(jnp.float32))),
        params, ref, omega,
    )
    return sum(jax.tree.leaves(terms))


# ---------------------------------------------------------------------------
# Deprecated shims → repro.ocl.registry
# ---------------------------------------------------------------------------


def make_ocl_step(
    ocl: OCLConfig,
    loss_fn: Callable,  # (params, batch) -> (loss, metrics)
    forward_fn: Callable,  # (params, batch) -> logits (for LwF/MIR/MAS)
    optimizer,
):
    """Deprecated: use ``repro.ocl.registry.make_sequential_step``.

    Returns the registry-built jitted ``step(params, opt_state, batch,
    extras)`` and ``mir_select`` for ``ocl.method``, preserving the original
    return signature. ``extras`` may hold 'replay', 'teacher',
    'mas_ref'/'mas_omega'; missing pieces degrade to Vanilla gracefully.
    """
    from repro.ocl.registry import get_algorithm, make_sequential_step

    step, _eval_fn, helpers = make_sequential_step(
        get_algorithm(ocl), loss_fn, forward_fn, optimizer
    )
    return step, helpers.mir_select


def wrap_staged_model(staged, ocl: OCLConfig, teacher_logits_key: str = "teacher_logits"):
    """Deprecated: use ``get_algorithm(ocl).wrap_staged(staged)``."""
    from repro.ocl.registry import get_algorithm

    if teacher_logits_key != "teacher_logits":
        raise ValueError(
            "the registry LwF wrapper reads the fixed stream field "
            f"'teacher_logits'; got teacher_logits_key={teacher_logits_key!r}"
        )
    return get_algorithm(ocl).wrap_staged(staged)


def mix_replay_into_stream(
    stream: Dict[str, np.ndarray],
    ocl: OCLConfig,
    fields: Tuple[str, ...] = ("tokens", "labels"),
) -> Dict[str, np.ndarray]:
    """Deprecated: use ``get_algorithm(ocl).prepare_stream(stream)``."""
    from repro.ocl.registry import _mix_replay

    if ocl.method not in ("er", "mir"):
        return stream
    return _mix_replay(stream, ocl, fields)
