"""OCL algorithms (paper Table 2): Vanilla, ER, MIR, LwF, MAS.

Two integration paths:

1. ``make_ocl_step`` — exact algorithms for the sequential (non-pipelined)
   trainer used by the skip baselines and Oracle: true MIR (virtual-update
   interference scoring), LwF distillation against a task-boundary teacher,
   MAS importance-weighted regularization.

2. ``wrap_staged_model`` — the same algorithms as loss wrappers for the
   Ferret pipeline engine. Replay items ride inside the per-round batch
   (host-side reservoir); the teacher and MAS state are segment constants
   (the engine re-jits per stream segment, refreshing them at task
   boundaries — the paper snapshots at the same granularity). MIR inside
   the one-scan engine uses max-current-loss candidate selection as the
   interference proxy (documented deviation; the exact variant is in path 1).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import StagedModel
from repro.optim.optimizers import Optimizer

Pytree = Any


@dataclasses.dataclass(frozen=True)
class OCLConfig:
    method: str = "vanilla"  # vanilla | er | mir | lwf | mas
    replay_size: int = 5000  # paper §12: buffer 5e3
    replay_batch: int = 8
    mir_candidates: int = 32
    lwf_weight: float = 1.0
    lwf_temp: float = 2.0
    mas_weight: float = 0.1
    seed: int = 0


# ---------------------------------------------------------------------------
# Replay buffer (host-side reservoir)
# ---------------------------------------------------------------------------


class ReplayBuffer:
    def __init__(self, capacity: int, seed: int = 0):
        self.capacity = capacity
        self.rng = np.random.default_rng(seed)
        self.rows: list = []
        self.seen = 0

    def add(self, row: Dict[str, np.ndarray]) -> None:
        self.seen += 1
        if len(self.rows) < self.capacity:
            self.rows.append(row)
        else:
            k = self.rng.integers(0, self.seen)
            if k < self.capacity:
                self.rows[k] = row

    def add_batch(self, batch: Dict[str, np.ndarray]) -> None:
        b = next(iter(batch.values())).shape[0]
        for i in range(b):
            self.add({k: np.asarray(v[i]) for k, v in batch.items()})

    def sample(self, n: int) -> Optional[Dict[str, np.ndarray]]:
        if not self.rows:
            return None
        idx = self.rng.integers(0, len(self.rows), size=n)
        keys = self.rows[0].keys()
        return {k: np.stack([self.rows[i][k] for i in idx]) for k in keys}

    def __len__(self) -> int:
        return len(self.rows)


# ---------------------------------------------------------------------------
# Loss building blocks
# ---------------------------------------------------------------------------


def _kd_loss(student_logits: jax.Array, teacher_logits: jax.Array, temp: float) -> jax.Array:
    """LwF distillation: KL(teacher ‖ student) at temperature T."""
    t = jax.nn.log_softmax(teacher_logits.astype(jnp.float32) / temp, axis=-1)
    s = jax.nn.log_softmax(student_logits.astype(jnp.float32) / temp, axis=-1)
    return jnp.mean(jnp.sum(jnp.exp(t) * (t - s), axis=-1)) * temp * temp


def mas_importance(loss_free_forward: Callable, params: Pytree, batches: list) -> Pytree:
    """MAS Ω: E |∂ ‖f(x)‖² / ∂θ| accumulated over representative batches."""

    def sq_norm(p, batch):
        out = loss_free_forward(p, batch)
        return jnp.mean(jnp.sum(jnp.square(out.astype(jnp.float32)), axis=-1))

    omega = None
    for batch in batches:
        g = jax.grad(sq_norm)(params, batch)
        g = jax.tree.map(lambda a: jnp.abs(a.astype(jnp.float32)), g)
        omega = g if omega is None else jax.tree.map(jnp.add, omega, g)
    n = max(len(batches), 1)
    return jax.tree.map(lambda a: a / n, omega)


def mas_penalty(params: Pytree, ref: Pytree, omega: Pytree) -> jax.Array:
    terms = jax.tree.map(
        lambda p, r, o: jnp.sum(o * jnp.square(p.astype(jnp.float32) - r.astype(jnp.float32))),
        params, ref, omega,
    )
    return sum(jax.tree.leaves(terms))


# ---------------------------------------------------------------------------
# Path 1: exact sequential OCL step (used by baselines/Oracle)
# ---------------------------------------------------------------------------


def make_ocl_step(
    ocl: OCLConfig,
    loss_fn: Callable,  # (params, batch) -> (loss, metrics)
    forward_fn: Callable,  # (params, batch) -> logits (for LwF/MIR/MAS)
    optimizer: Optimizer,
):
    """Returns jitted ``step(params, opt_state, batch, extras)``.

    ``extras`` is a dict that may hold: 'replay' (stacked replay batch),
    'candidates' (MIR candidate pool), 'teacher' (LwF teacher params),
    'mas_ref'/'mas_omega'. Missing pieces degrade to Vanilla gracefully.
    """

    def total_loss(params, batch, extras):
        loss, metrics = loss_fn(params, batch)
        if ocl.method in ("er", "mir") and extras.get("replay") is not None:
            r_loss, _ = loss_fn(params, extras["replay"])
            loss = loss + r_loss
        if ocl.method == "lwf" and extras.get("teacher") is not None:
            student = forward_fn(params, batch)
            teacher = forward_fn(extras["teacher"], batch)
            loss = loss + ocl.lwf_weight * _kd_loss(student, teacher, ocl.lwf_temp)
        if ocl.method == "mas" and extras.get("mas_omega") is not None:
            loss = loss + ocl.mas_weight * mas_penalty(
                params, extras["mas_ref"], extras["mas_omega"]
            )
        return loss, metrics

    @jax.jit
    def step(params, opt_state, batch, extras):
        (loss, metrics), grads = jax.value_and_grad(total_loss, has_aux=True)(
            params, batch, extras
        )
        new_params, new_opt = optimizer.update(params, grads, opt_state)
        return new_params, new_opt, loss, metrics

    @jax.jit
    def mir_select(params, opt_state, batch, candidates):
        """True MIR: virtual step on the new batch, keep the replay candidates

        whose loss increases the most."""
        (_, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        virt_params, _ = optimizer.update(params, grads, opt_state)

        def per_item_loss(p, cand):
            def one(i):
                item = jax.tree.map(lambda a: a[i : i + 1], cand)
                return loss_fn(p, item)[0]

            n = jax.tree.leaves(cand)[0].shape[0]
            return jnp.stack([one(i) for i in range(n)])

        before = per_item_loss(params, candidates)
        after = per_item_loss(virt_params, candidates)
        interference = after - before
        _, top = jax.lax.top_k(interference, ocl.replay_batch)
        return jax.tree.map(lambda a: a[top], candidates)

    return step, mir_select


# ---------------------------------------------------------------------------
# Path 2: loss wrappers for the pipeline engine
# ---------------------------------------------------------------------------


def wrap_staged_model(
    staged: StagedModel,
    ocl: OCLConfig,
    teacher_logits_key: str = "teacher_logits",
) -> StagedModel:
    """Augment the staged loss with replay / LwF terms carried in the batch.

    Expected optional batch fields (host-prepared, stacked over rounds):
    - 'replay_mask' (b,)           : 1.0 where the row is a replay item
    - 'teacher_logits' (b, s, V)   : LwF teacher outputs for these tokens
    MAS rides through ``param_penalty`` (see FerretTrainer), not the batch.
    """
    base_loss = staged.loss

    def loss(logits, batch):
        ce, metrics = base_loss(logits, batch)
        if ocl.method == "lwf" and teacher_logits_key in batch:
            ce = ce + ocl.lwf_weight * _kd_loss(
                logits, batch[teacher_logits_key], ocl.lwf_temp
            )
        return ce, metrics

    return StagedModel(staged.num_stages, staged.forward_stage, loss)


def mix_replay_into_stream(
    stream: Dict[str, np.ndarray],
    ocl: OCLConfig,
    fields: Tuple[str, ...] = ("tokens", "labels"),
) -> Dict[str, np.ndarray]:
    """Host-side ER: extend each round's batch with reservoir samples.

    Online accuracy stays computed on the *new* rows via 'new_mask'."""
    if ocl.method not in ("er", "mir"):
        return stream
    R = next(iter(stream.values())).shape[0]
    buf = ReplayBuffer(ocl.replay_size, seed=ocl.seed)
    out = {k: [] for k in fields}
    new_mask = []
    rb = ocl.replay_batch
    for m in range(R):
        row = {k: stream[k][m] for k in fields}
        samp = buf.sample(rb)
        if samp is None:
            samp = {k: np.repeat(row[k][:1], rb, axis=0) for k in fields}
        for k in fields:
            out[k].append(np.concatenate([row[k], samp[k]], axis=0))
        b_new = row[fields[0]].shape[0]
        new_mask.append(
            np.concatenate([np.ones(b_new, np.float32), np.zeros(rb, np.float32)])
        )
        buf.add_batch(row)
    mixed = {k: np.stack(v) for k, v in out.items()}
    mixed["new_mask"] = np.stack(new_mask)
    for k in stream:
        if k not in mixed:
            mixed[k] = stream[k]
    return mixed
