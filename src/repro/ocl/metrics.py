"""OCL evaluation metrics (paper §2 / §6.1).

- online accuracy  oacc_A(t) = Σ_{i≤t} acc(y^i, ŷ^i) / t        [11]
- agm  = log(exp(oacc_A − oacc_B) / (M_A / M_B))                 (Eq. 18)
- tagm = log(exp(tacc_A − tacc_B) / (M_A / M_B))                 (Eq. 17)
- empirical adaptation rate R_A^T = Σ_t e^{-c r_A^t} V_{D^t} / T (Def. 4.1)
"""

from __future__ import annotations

import math
from typing import Sequence

import numpy as np


def online_accuracy(per_item_acc: Sequence[float]) -> float:
    """Running mean of pre-update prediction accuracy over the stream."""
    a = np.asarray(per_item_acc, dtype=np.float64)
    return float(a.mean()) if a.size else 0.0


def online_accuracy_curve(per_item_acc: Sequence[float]) -> np.ndarray:
    a = np.asarray(per_item_acc, dtype=np.float64)
    return np.cumsum(a) / np.arange(1, a.size + 1)


def agm(oacc_a: float, oacc_b: float, mem_a: float, mem_b: float) -> float:
    """Eq. 18: Online Accuracy Gain per unit of Memory (higher is better).

    Accuracies in the same units the paper uses (percentage points)."""
    return math.log(math.exp(oacc_a - oacc_b) / (mem_a / mem_b))


def tagm(tacc_a: float, tacc_b: float, mem_a: float, mem_b: float) -> float:
    """Eq. 17: Test Accuracy Gain per unit of Memory."""
    return math.log(math.exp(tacc_a - tacc_b) / (mem_a / mem_b))


def adaptation_rate_empirical(
    delays: Sequence[float], c: float = 1.0, values: Sequence[float] | None = None
) -> float:
    """Def. 4.1 with measured per-item processing delays r_A^t.

    delays: seconds from arrival to the parameter update that consumed the
    item; +inf (or np.inf) for discarded items."""
    d = np.asarray(delays, dtype=np.float64)
    v = np.ones_like(d) if values is None else np.asarray(values, dtype=np.float64)
    contrib = np.where(np.isinf(d), 0.0, np.exp(-c * d) * v)
    return float(contrib.sum() / max(d.size, 1))
