"""Pluggable OCL algorithm registry: the ``method: str`` switch as classes.

Every OCL algorithm is one ``OCLAlgorithm`` subclass registered under a
name. An instance owns *everything* the algorithm needs on both execution
paths, so the pipelined trainers (``FerretTrainer``, the elastic trainer's
per-segment re-jit) and the exact sequential runner consume the same
object instead of each re-implementing a string dispatch:

pipeline path (one jit'd scan over the stream):
    ``prepare_stream``   host-side stream augmentation before the run
                         (ER/MIR replay mixing, LwF teacher logits);
                         applied per pulled chunk, in stream order, on the
                         streaming-native trainers
    ``wrap_staged``      loss wrapper over a ``StagedModel``
    ``engine_penalty``   parameter-space loss term for the pipeline engine
                         (MAS Ω-pull) — the staged loss sees only
                         ``(logits, batch)``, this hook sees the weights
    ``engine_penalty_extras``  the segment-constant state that term needs
                         (Ω, reference weights), re-read at every segment
                         boundary and passed through the jitted scan as an
                         argument, so a refresh never retraces
    ``segment_refresh``  hook at elastic segment boundaries — refresh
                         segment-constant state (e.g. the LwF teacher, the
                         MAS Ω anchor) for the remaining stream

sequential path (exact per-item predict-then-train loop):
    ``sequential_loss_extra``  extra loss terms (jit-traceable; state rides
                               in the ``extras`` pytree)
    ``host_extras``            build ``extras`` for the next step (replay
                               sample, MIR selection, teacher params, Ω)
    ``observe``                post-step host update (reservoir add)
    ``sequential_refresh``     snapshot teacher / recompute MAS Ω

Register your own from anywhere:

    from repro.api import OCLAlgorithm, register_algorithm

    @register_algorithm
    class MyMethod(OCLAlgorithm):
        name = "my-method"
        def wrap_staged(self, staged): ...

    FerretSession(model, algorithm="my-method", stream=stream).run()
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Callable, ClassVar, Dict, List, Optional, Type, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import StagedModel
from repro.ocl.algorithms import (
    OCLConfig,
    ReplayBuffer,
    _kd_loss,
    mas_importance,
    mas_penalty,
)

Pytree = Any

_REGISTRY: Dict[str, Type["OCLAlgorithm"]] = {}


def register_algorithm(cls: Type["OCLAlgorithm"]) -> Type["OCLAlgorithm"]:
    """Class decorator: register ``cls`` under ``cls.name``."""
    name = getattr(cls, "name", None)
    if not name or not isinstance(name, str):
        raise ValueError(f"{cls!r} needs a string class attribute `name`")
    _REGISTRY[name] = cls
    return cls


def available_algorithms() -> List[str]:
    return sorted(_REGISTRY)


def get_algorithm(
    spec: Union[str, OCLConfig, "OCLAlgorithm"],
    cfg: Optional[OCLConfig] = None,
) -> "OCLAlgorithm":
    """Resolve an algorithm name / config / instance to an instance.

    - ``OCLAlgorithm`` instance → returned as-is.
    - ``OCLConfig``            → looked up by its ``method`` field.
    - ``str``                  → looked up by name; ``cfg`` (or a default
      ``OCLConfig`` with that method) parameterizes it.
    """
    if isinstance(spec, OCLAlgorithm):
        return spec
    if isinstance(spec, OCLConfig):
        name, cfg = spec.method, spec
    else:
        name = spec
        if cfg is None:
            cfg = OCLConfig(method=name)
        elif cfg.method != name:
            cfg = dataclasses.replace(cfg, method=name)
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown OCL algorithm {name!r}; registered algorithms: "
            f"{', '.join(available_algorithms())}. Add your own with "
            "@repro.api.register_algorithm."
        )
    return _REGISTRY[name](cfg)


@dataclasses.dataclass
class PrepareContext:
    """What ``prepare_stream`` may use beyond the raw stream.

    ``forward_fn(params, batch) -> logits`` runs the live model; ``params``
    are the weights entering the stream (the LwF teacher snapshot).
    """

    params: Pytree
    forward_fn: Callable[[Pytree, Dict[str, jnp.ndarray]], jax.Array]


class OCLAlgorithm:
    """Base algorithm: Vanilla behaviour; subclasses override the hooks."""

    name: ClassVar[str] = "vanilla"

    def __init__(self, cfg: Optional[OCLConfig] = None):
        self.cfg = cfg or OCLConfig(method=self.name)
        self.reset()

    # -- lifecycle ---------------------------------------------------------
    def reset(self) -> None:
        """Clear host-side state (replay buffer, teacher, Ω)."""

    def engine_fingerprint(self) -> Any:
        """Hashable identity of the *traced* code this algorithm bakes into
        a compiled engine (``wrap_staged`` + ``engine_penalty``).

        Two instances with equal fingerprints may share one compiled
        ``FerretEngine`` through a shared ``EngineCache`` — this is what
        lets same-geometry tenants of the multi-tenant server reuse one
        compile. The built-ins' traced code closes only over ``self.cfg``
        (host-side state such as replay buffers and Ω *values* ride the
        scan as arguments), so class identity + config is exact. A custom
        algorithm whose wrapped loss closes over per-instance state must
        override this — returning ``repro.core.ferret.IdentityKey(self)``
        restores strict per-instance engines.
        """
        cls = type(self)
        return (cls.__module__, cls.__qualname__, self.cfg)

    # -- pipeline path -----------------------------------------------------
    def prepare_stream(
        self, stream: Dict[str, np.ndarray], ctx: Optional[PrepareContext] = None
    ) -> Dict[str, np.ndarray]:
        """Host-side stream augmentation before the pipelined run.

        Called once on the whole materialized stream (pipelined runner) or
        chunk-wise in stream order, each round exactly once (the
        incremental elastic path). Implementations must keep the round
        count unchanged and make chunk-wise application equal whole-stream
        application: keep per-round work local, or chain stateful work
        (e.g. a reservoir) through instance state reset in ``reset()``.
        At an elastic re-plan the trainer re-anchors ``ctx.params`` at the
        live weights — the incremental counterpart of ``segment_refresh``.
        """
        return stream

    def wrap_staged(self, staged: StagedModel) -> StagedModel:
        return staged

    def engine_penalty(self) -> Optional[Callable]:
        """Parameter-space penalty for the pipeline engine, or ``None``.

        The staged loss sees only ``(logits, batch)``; this hook is how an
        algorithm adds a loss term over the *weights* (MAS/EWC pulls).
        Returns ``penalty_fn(params, extras) -> scalar`` where ``params``
        is a params-shaped pytree and ``extras`` the matching slice of
        ``engine_penalty_extras``. The engine evaluates it per pipeline
        stage on that stage's slice of the weights and sums, so the
        penalty must decompose as a sum over parameter groups — leaf-wise
        penalties (MAS, EWC, L2-to-reference) all do.
        """
        return None

    def engine_penalty_extras(self) -> Optional[Dict[str, Pytree]]:
        """Current state for ``engine_penalty``: a flat dict of
        params-shaped pytrees (e.g. ``{"omega": Ω, "ref": θ*}``).

        Trainers re-read this at every segment boundary (after
        ``prepare_stream`` / ``segment_refresh`` have run), split each
        entry on the live partition, and pass it through the jitted scan
        as an argument — a same-shape refresh reuses the compiled engine.
        Must be non-``None`` whenever ``engine_penalty`` is.
        """
        return None

    def segment_refresh(
        self,
        params: Pytree,
        stream_tail: Dict[str, np.ndarray],
        ctx: Optional[PrepareContext] = None,
    ) -> Optional[Dict[str, np.ndarray]]:
        """Refresh segment-constant state at an elastic re-plan boundary.

        ``params`` are the live (merged) weights; ``stream_tail`` is the
        not-yet-consumed remainder of the prepared stream. May return
        updated arrays for existing stream fields (same tail shapes);
        ``None`` means nothing to refresh.
        """
        return None

    # -- sequential path ---------------------------------------------------
    def sequential_loss_extra(
        self,
        params: Pytree,
        batch: Dict[str, jnp.ndarray],
        extras: Dict[str, Any],
        loss_fn: Callable,
        forward_fn: Callable,
    ) -> jax.Array:
        """Extra loss terms; jit-traceable, state arrives via ``extras``."""
        return jnp.zeros((), jnp.float32)

    def host_extras(
        self, params: Pytree, opt_state: Any, batch: Dict[str, jnp.ndarray], helpers
    ) -> Dict[str, Any]:
        """Host-side step preparation → the ``extras`` pytree for this step."""
        return {}

    def observe(self, batch: Dict[str, jnp.ndarray]) -> None:
        """Post-step host update (e.g. reservoir add)."""

    def sequential_refresh(self, params: Pytree, recent: List[Dict]) -> None:
        """Periodic boundary hook: snapshot teacher / recompute Ω."""

    def bind_forward(self, forward_fn: Callable) -> None:
        """Sequential runner wires the model's forward (MAS Ω needs it)."""
        self._forward_fn = forward_fn


# ---------------------------------------------------------------------------
# Replay mixing (shared by ER and MIR on the pipeline path)
# ---------------------------------------------------------------------------


def _mix_replay(
    stream: Dict[str, np.ndarray],
    cfg: OCLConfig,
    fields=("tokens", "labels"),
    buf: Optional[ReplayBuffer] = None,
) -> Dict[str, np.ndarray]:
    """Host-side ER: extend each round's batch with reservoir samples.

    Online accuracy stays computed on the *new* rows via 'new_mask'.
    ``buf`` lets a caller chain calls over consecutive stream chunks (the
    incremental elastic path): because mixing is strictly sequential per
    round, chunk-wise preparation with one persistent buffer is
    bit-identical to preparing the whole stream at once."""
    R = next(iter(stream.values())).shape[0]
    if buf is None:
        buf = ReplayBuffer(cfg.replay_size, seed=cfg.seed)
    out: Dict[str, list] = {k: [] for k in fields}
    new_mask = []
    rb = cfg.replay_batch
    for m in range(R):
        row = {k: stream[k][m] for k in fields}
        samp = buf.sample(rb)
        if samp is None:
            samp = {k: np.repeat(row[k][:1], rb, axis=0) for k in fields}
        for k in fields:
            out[k].append(np.concatenate([row[k], samp[k]], axis=0))
        b_new = row[fields[0]].shape[0]
        new_mask.append(
            np.concatenate([np.ones(b_new, np.float32), np.zeros(rb, np.float32)])
        )
        buf.add_batch(row)
    mixed = {k: np.stack(v) for k, v in out.items()}
    mixed["new_mask"] = np.stack(new_mask)
    for k in stream:
        if k not in mixed:
            mixed[k] = stream[k]
    return mixed


# ---------------------------------------------------------------------------
# The five integrated algorithms (paper Table 2)
# ---------------------------------------------------------------------------


@register_algorithm
class Vanilla(OCLAlgorithm):
    """Plain online SGD on the arriving items."""

    name = "vanilla"


@register_algorithm
class ER(OCLAlgorithm):
    """Experience Replay: reservoir buffer, replayed alongside new items."""

    name = "er"

    def reset(self) -> None:
        self.buffer = ReplayBuffer(self.cfg.replay_size, seed=self.cfg.seed)
        # stream-prep reservoir: persists across chunk-wise prepare_stream
        # calls (incremental elastic path) so that preparing the stream one
        # segment at a time equals preparing it whole; reset() (run start)
        # starts both paths from the same state
        self._prep_buf = ReplayBuffer(self.cfg.replay_size, seed=self.cfg.seed)

    # pipeline: replay rows ride inside the per-round batch; chunk-wise
    # calls in stream order chain through the persistent reservoir
    def prepare_stream(self, stream, ctx=None):
        return _mix_replay(stream, self.cfg, buf=self._prep_buf)

    # sequential: exact — sample the buffer each step
    def sequential_loss_extra(self, params, batch, extras, loss_fn, forward_fn):
        if extras.get("replay") is None:
            return jnp.zeros((), jnp.float32)
        r_loss, _ = loss_fn(params, extras["replay"])
        return r_loss

    def host_extras(self, params, opt_state, batch, helpers):
        return {"replay": self._sample_replay()}

    def _sample_replay(self):
        samp = self.buffer.sample(self.cfg.replay_batch)
        return None if samp is None else {k: jnp.asarray(v) for k, v in samp.items()}

    def observe(self, batch) -> None:
        self.buffer.add_batch({k: np.asarray(v) for k, v in batch.items()})


@register_algorithm
class MIR(ER):
    """Maximally Interfered Retrieval.

    Sequential path is exact (virtual update, top-k interference over a
    candidate pool). Inside the one-scan pipeline engine the replay rows
    are reservoir-sampled like ER — the documented deviation; interference
    scoring needs the virtual update, which is a sequential construct.
    """

    name = "mir"

    def host_extras(self, params, opt_state, batch, helpers):
        n_cand = self.cfg.mir_candidates
        if len(self.buffer) >= max(self.cfg.replay_batch * 2, 4):
            cand = self.buffer.sample(n_cand)
            cand_j = {k: jnp.asarray(v) for k, v in cand.items()}
            sel = helpers.mir_select(params, opt_state, batch, cand_j)
            return {"replay": sel}
        return {"replay": self._sample_replay()}


@register_algorithm
class LwF(OCLAlgorithm):
    """Learning without Forgetting: distill against a teacher snapshot."""

    name = "lwf"

    def reset(self) -> None:
        self.teacher: Optional[Pytree] = None

    # pipeline: teacher logits are a host-prepared stream field; the staged
    # loss adds the KD term wherever the field is present.
    def prepare_stream(self, stream, ctx=None):
        if ctx is None:
            return stream
        self.teacher = ctx.params
        out = dict(stream)
        out["teacher_logits"] = self._teacher_logits(stream, ctx)
        return out

    def wrap_staged(self, staged: StagedModel) -> StagedModel:
        cfg = self.cfg
        base_loss = staged.loss

        def loss(logits, batch):
            ce, metrics = base_loss(logits, batch)
            if "teacher_logits" in batch:
                ce = ce + cfg.lwf_weight * _kd_loss(
                    logits, batch["teacher_logits"], cfg.lwf_temp
                )
            return ce, metrics

        return StagedModel(staged.num_stages, staged.forward_stage, loss)

    def segment_refresh(self, params, stream_tail, ctx=None):
        """Re-snapshot the teacher at the segment boundary (the paper
        refreshes at the same granularity the engine re-jits)."""
        if ctx is None or "teacher_logits" not in stream_tail:
            return None
        self.teacher = params
        refreshed = PrepareContext(params=params, forward_fn=ctx.forward_fn)
        return {"teacher_logits": self._teacher_logits(stream_tail, refreshed)}

    def _teacher_logits(self, stream, ctx: PrepareContext) -> np.ndarray:
        # the incremental elastic path calls prepare_stream once per pulled
        # chunk: cache the jitted teacher forward per forward_fn so segments
        # reuse one compilation (a re-plan hands over a fresh forward_fn and
        # recompiles once, like the materialized tail refresh did)
        if getattr(self, "_fwd_src", None) is not ctx.forward_fn:
            self._fwd_src = ctx.forward_fn
            self._fwd_jit = jax.jit(ctx.forward_fn)
        fwd = self._fwd_jit
        rounds = []
        R = next(iter(stream.values())).shape[0]
        for m in range(R):
            batch = {
                k: jnp.asarray(v[m])
                for k, v in stream.items()
                if k in ("tokens", "labels", "x")
            }
            rounds.append(np.asarray(fwd(ctx.params, batch)))
        return np.stack(rounds)

    # sequential: exact — KD against the teacher params
    def sequential_loss_extra(self, params, batch, extras, loss_fn, forward_fn):
        if extras.get("teacher") is None:
            return jnp.zeros((), jnp.float32)
        student = forward_fn(params, batch)
        teacher = forward_fn(extras["teacher"], batch)
        return self.cfg.lwf_weight * _kd_loss(student, teacher, self.cfg.lwf_temp)

    def host_extras(self, params, opt_state, batch, helpers):
        if self.teacher is None:
            self.teacher = params  # anchor at stream entry
        return {"teacher": self.teacher}

    def sequential_refresh(self, params, recent) -> None:
        self.teacher = params


@register_algorithm
class MAS(OCLAlgorithm):
    """Memory Aware Synapses: Ω-weighted quadratic pull to a reference.

    Exact on *both* paths. The sequential loop applies the penalty through
    ``sequential_loss_extra``; the pipeline path rides the
    ``FerretEngine`` parameter-penalty hook (``engine_penalty``): Ω and
    the reference weights are anchored at stream entry from the first
    arriving round — the same anchor the sequential path uses — and
    refreshed at elastic re-plan boundaries from the most recent rounds
    (``segment_refresh``, the granularity at which the engine re-jits).
    """

    name = "mas"

    # fields an importance/teacher forward can consume (mirrors LwF)
    _FWD_FIELDS = ("tokens", "labels", "x", "mask")

    def reset(self) -> None:
        self.omega: Optional[Pytree] = None
        self.ref: Optional[Pytree] = None
        # recent rounds seen by the pipeline-path stream prep: the Ω
        # refresh sample at a re-plan boundary (the incremental trainers
        # never retain the consumed stream, so the algorithm keeps the
        # window itself — the twin of the sequential loop's `recent` deque)
        self._recent: collections.deque = collections.deque(maxlen=4)

    # -- pipeline path: Ω/ref maintained host-side, applied in-engine ------
    def prepare_stream(self, stream, ctx=None):
        R = next(iter(stream.values())).shape[0]
        # only the last maxlen rounds survive the deque — skip building
        # per-round dicts the window would immediately evict
        for m in range(max(0, R - self._recent.maxlen), R):
            self._recent.append({
                k: np.asarray(v[m]) for k, v in stream.items()
                if k in self._FWD_FIELDS
            })
        if self.omega is None and ctx is not None and R > 0:
            # anchor at stream entry: importance from the first round,
            # reference at the weights entering the stream — exactly the
            # sequential path's first-step anchor
            first = {
                k: jnp.asarray(stream[k][0]) for k in stream
                if k in self._FWD_FIELDS
            }
            self.omega = mas_importance(ctx.forward_fn, ctx.params, [first])
            self.ref = ctx.params
        return stream

    def engine_penalty(self) -> Optional[Callable]:
        weight = self.cfg.mas_weight

        def fn(params, extras):
            return weight * mas_penalty(params, extras["ref"], extras["omega"])

        return fn

    def engine_penalty_extras(self) -> Optional[Dict[str, Pytree]]:
        if self.omega is None:
            return None
        return {"omega": self.omega, "ref": self.ref}

    def segment_refresh(self, params, stream_tail, ctx=None):
        """Re-anchor Ω/ref at a re-plan boundary from the live weights and
        the most recent rounds (nothing in the stream itself changes)."""
        if ctx is None or not self._recent:
            return None
        batches = [
            {k: jnp.asarray(v) for k, v in b.items()} for b in self._recent
        ]
        self.omega = mas_importance(ctx.forward_fn, params, batches)
        self.ref = params
        return None

    # -- sequential path: exact, unchanged ---------------------------------
    def sequential_loss_extra(self, params, batch, extras, loss_fn, forward_fn):
        if extras.get("mas_omega") is None:
            return jnp.zeros((), jnp.float32)
        return self.cfg.mas_weight * mas_penalty(
            params, extras["mas_ref"], extras["mas_omega"]
        )

    def host_extras(self, params, opt_state, batch, helpers):
        if self.omega is None:
            # anchor at stream entry: importance from the first batch
            self.sequential_refresh(params, [batch])
        return {"mas_omega": self.omega, "mas_ref": self.ref}

    def sequential_refresh(self, params, recent) -> None:
        if not recent:
            return
        fwd = getattr(self, "_forward_fn", None)
        if fwd is None:
            return
        self.omega = mas_importance(fwd, params, list(recent))
        self.ref = params


# ---------------------------------------------------------------------------
# Sequential step builder (exact path, shared by sequential/baseline runners)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SequentialHelpers:
    """Jitted helpers handed to ``host_extras`` (MIR's selection step)."""

    mir_select: Callable


def make_sequential_step(
    algo: OCLAlgorithm,
    loss_fn: Callable,  # (params, batch) -> (loss, metrics)
    forward_fn: Callable,  # (params, batch) -> logits
    optimizer,
):
    """Jitted ``step(params, opt_state, batch, extras)`` for ``algo``.

    The plugin replacement for ``repro.ocl.algorithms.make_ocl_step``: the
    extra loss terms come from ``algo.sequential_loss_extra`` instead of a
    method-string switch. Also returns ``(eval_fn, helpers)`` — a jitted
    predict-only pass and the MIR selection helper.
    """

    def total_loss(params, batch, extras):
        loss, metrics = loss_fn(params, batch)
        loss = loss + algo.sequential_loss_extra(
            params, batch, extras, loss_fn, forward_fn
        )
        return loss, metrics

    @jax.jit
    def step(params, opt_state, batch, extras):
        (loss, metrics), grads = jax.value_and_grad(total_loss, has_aux=True)(
            params, batch, extras
        )
        new_params, new_opt = optimizer.update(params, grads, opt_state)
        return new_params, new_opt, loss, metrics

    @jax.jit
    def eval_fn(params, batch):
        return loss_fn(params, batch)

    @jax.jit
    def mir_select(params, opt_state, batch, candidates):
        """True MIR: virtual step on the new batch, keep the replay
        candidates whose loss increases the most."""
        (_, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        virt_params, _ = optimizer.update(params, grads, opt_state)

        def per_item_loss(p, cand):
            def one(i):
                item = jax.tree.map(lambda a: a[i : i + 1], cand)
                return loss_fn(p, item)[0]

            n = jax.tree.leaves(cand)[0].shape[0]
            return jnp.stack([one(i) for i in range(n)])

        before = per_item_loss(params, candidates)
        after = per_item_loss(virt_params, candidates)
        interference = after - before
        _, top = jax.lax.top_k(interference, algo.cfg.replay_batch)
        return jax.tree.map(lambda a: a[top], candidates)

    return step, eval_fn, SequentialHelpers(mir_select=mir_select)
