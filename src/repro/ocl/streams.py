"""Synthetic high-frequency data streams with controllable distribution shift.

The container ships no image datasets (MNIST/CIFAR/CLEAR...), so the paper's
benchmark *protocols* are reproduced over generated streams (documented in
DESIGN.md §9). Three stream families cover the paper's three regimes:

- ``iid``        : stationary distribution (CORe50-iid-style)
- ``split``      : K tasks presented sequentially, disjoint class subsets
                   (Split-MNIST/CIFAR-style class-incremental)
- ``drift``      : slowly rotating class prototypes (CLEAR-style natural
                   distribution shift)

Two modalities:
- classification vectors (x ∈ R^d, y ∈ [C)) for the paper-scale MLP/ConvNet
  analogues, and
- token sequences for the LM architectures (next-token prediction over a
  drifting Markov source), so Ferret runs on the assigned archs end-to-end.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    kind: str = "drift"  # iid | split | drift
    modality: str = "tokens"  # tokens | vectors
    length: int = 512  # number of stream items (rounds)
    batch: int = 1  # items arrive one microbatch at a time
    seed: int = 0

    # vectors modality
    dim: int = 32
    num_classes: int = 10
    noise: float = 0.25

    # tokens modality
    vocab: int = 256
    seq: int = 32
    markov_order: int = 1

    # shift controls
    num_tasks: int = 5  # split: number of sequential tasks
    drift_rate: float = 0.02  # drift: radians of prototype rotation per item


def _rotate(protos: np.ndarray, angle: float) -> np.ndarray:
    """Rotate prototypes in every consecutive (2i, 2i+1) plane — all feature
    dims drift, like natural covariate shift."""
    c, s = np.cos(angle), np.sin(angle)
    out = protos.copy()
    d = protos.shape[1] - protos.shape[1] % 2
    x0, x1 = protos[:, 0:d:2].copy(), protos[:, 1:d:2].copy()
    out[:, 0:d:2] = c * x0 - s * x1
    out[:, 1:d:2] = s * x0 + c * x1
    return out


def make_stream(cfg: StreamConfig) -> Dict[str, np.ndarray]:
    """Materializes the stream as stacked arrays over rounds.

    vectors: {'x': (R, b, dim), 'labels': (R, b)}
    tokens : {'tokens': (R, b, seq), 'labels': (R, b, seq)}
    """
    rng = np.random.default_rng(cfg.seed)
    R, b = cfg.length, cfg.batch
    if cfg.modality == "vectors":
        return _vector_stream(cfg, rng)
    if cfg.modality == "tokens":
        return _token_stream(cfg, rng)
    raise ValueError(cfg.modality)


def _vector_stream(cfg: StreamConfig, rng) -> Dict[str, np.ndarray]:
    R, b, d, C = cfg.length, cfg.batch, cfg.dim, cfg.num_classes
    protos = rng.normal(size=(C, d)).astype(np.float32)
    protos /= np.linalg.norm(protos, axis=1, keepdims=True)
    xs = np.zeros((R, b, d), np.float32)
    ys = np.zeros((R, b), np.int32)
    for m in range(R):
        if cfg.kind == "drift":
            protos = _rotate(protos, cfg.drift_rate)
            allowed = np.arange(C)
        elif cfg.kind == "split":
            task = min(m * cfg.num_tasks // R, cfg.num_tasks - 1)
            per = C // cfg.num_tasks
            allowed = np.arange(task * per, (task + 1) * per)
        else:
            allowed = np.arange(C)
        y = rng.choice(allowed, size=b)
        xs[m] = protos[y] + cfg.noise * rng.normal(size=(b, d))
        ys[m] = y
    return {"x": xs, "labels": ys}


def _token_stream(cfg: StreamConfig, rng) -> Dict[str, np.ndarray]:
    """Markov token source whose transition matrix drifts / switches by task."""
    R, b, V, s = cfg.length, cfg.batch, cfg.vocab, cfg.seq

    def random_transition():
        # sparse-ish transition: each state prefers ~4 successors
        T = rng.random((V, V)).astype(np.float32) ** 8
        T /= T.sum(axis=1, keepdims=True)
        return T

    T0, T1 = random_transition(), random_transition()
    toks = np.zeros((R, b, s + 1), np.int64)
    state = rng.integers(0, V, size=(b,))
    for m in range(R):
        if cfg.kind == "split":
            task = min(m * cfg.num_tasks // R, cfg.num_tasks - 1)
            mix = task / max(cfg.num_tasks - 1, 1)
        elif cfg.kind == "drift":
            mix = min(1.0, m * cfg.drift_rate)
        else:
            mix = 0.0
        T = (1.0 - mix) * T0 + mix * T1
        cum = np.cumsum(T, axis=1)
        seqs = np.zeros((b, s + 1), np.int64)
        seqs[:, 0] = state
        for t in range(1, s + 1):
            u = rng.random(b)[:, None]
            seqs[:, t] = (cum[seqs[:, t - 1]] < u).sum(axis=1)
        state = seqs[:, -1]
        toks[m] = seqs
    return {
        "tokens": toks[:, :, :-1].astype(np.int32),
        "labels": toks[:, :, 1:].astype(np.int32),
    }
