"""Online Continual Learning substrate: streams, metrics, algorithms, baselines."""

from repro.ocl.metrics import online_accuracy, agm, tagm, adaptation_rate_empirical
from repro.ocl.streams import StreamConfig, make_stream
from repro.ocl.algorithms import OCLConfig, make_ocl_step
from repro.ocl.baselines import AdmissionPolicy, make_admission_mask

__all__ = [
    "online_accuracy",
    "agm",
    "tagm",
    "adaptation_rate_empirical",
    "StreamConfig",
    "make_stream",
    "OCLConfig",
    "make_ocl_step",
    "AdmissionPolicy",
    "make_admission_mask",
]
