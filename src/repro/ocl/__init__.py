"""Online Continual Learning substrate: streams, metrics, algorithms, baselines.

The algorithms live in the plugin registry (``repro.ocl.registry``); the
user-facing session layer is ``repro.api``.
"""

from repro.ocl.algorithms import OCLConfig, make_ocl_step
from repro.ocl.baselines import AdmissionPolicy, make_admission_mask
from repro.ocl.metrics import adaptation_rate_empirical, agm, online_accuracy, tagm
from repro.ocl.registry import (
    OCLAlgorithm,
    available_algorithms,
    get_algorithm,
    register_algorithm,
)
from repro.ocl.streams import StreamConfig, make_stream

__all__ = [
    "AdmissionPolicy",
    "OCLAlgorithm",
    "OCLConfig",
    "StreamConfig",
    "adaptation_rate_empirical",
    "agm",
    "available_algorithms",
    "get_algorithm",
    "make_admission_mask",
    "make_ocl_step",
    "make_stream",
    "online_accuracy",
    "register_algorithm",
    "tagm",
]
