"""Stream-admission baselines (paper §6.1).

Every method decides *which* stream items get trained and *when*, given the
arrival interval t^d and per-item training time t^train:

- Oracle      : trains every item with zero delay (ideal upper bound)
- 1-Skip      : trains one item at a time; items arriving mid-training are
                dropped [29]
- Random-N    : buffers the latest B unprocessed items, trains a random N
- Last-N      : same, trains the newest N
- Camel       : same, trains a diversity coreset of size N [46]
                (greedy k-center on raw features — Camel's coreset spirit)

Output: an AdmissionTrace — per item, whether it was trained and its delay
r^t (∞ if dropped) — which feeds both the sequential trainer and the
empirical adaptation-rate metric (Def. 4.1).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class AdmissionPolicy:
    method: str = "oracle"  # oracle | one_skip | random_n | last_n | camel
    buffer: int = 16  # B
    select: int = 4  # N
    seed: int = 0


@dataclasses.dataclass
class AdmissionTrace:
    trained_at: np.ndarray  # (R,) float — wall-time the item's update finished (inf = dropped)
    delays: np.ndarray  # (R,) float — r^t
    order: List[int]  # training order (indices into the stream)

    @property
    def admitted(self) -> np.ndarray:
        return np.isfinite(self.delays)


def make_admission_mask(
    policy: AdmissionPolicy,
    num_items: int,
    t_d: float,
    t_train: float,
    features: Optional[np.ndarray] = None,  # (R, d) for camel
) -> AdmissionTrace:
    rng = np.random.default_rng(policy.seed)
    arrive = np.arange(num_items) * t_d
    delays = np.full(num_items, np.inf)
    done_at = np.full(num_items, np.inf)
    order: List[int] = []

    if policy.method == "oracle":
        for i in range(num_items):
            delays[i] = 0.0
            done_at[i] = arrive[i]
            order.append(i)
        return AdmissionTrace(done_at, delays, order)

    if policy.method == "one_skip":
        free = 0.0
        for i in range(num_items):
            if arrive[i] >= free:
                start = arrive[i]
                free = start + t_train
                delays[i] = free - arrive[i]
                done_at[i] = free
                order.append(i)
        return AdmissionTrace(done_at, delays, order)

    # Buffered policies: every service cycle (N·t_train), select N from the
    # latest ≤B unprocessed arrivals.
    B, N = policy.buffer, policy.select
    cycle = N * t_train
    t = 0.0
    next_item = 0
    pending: List[int] = []
    while next_item < num_items or pending:
        # absorb arrivals up to time t
        while next_item < num_items and arrive[next_item] <= t:
            pending.append(next_item)
            next_item += 1
        pending = pending[-B:]  # only the latest B are kept
        if not pending:
            if next_item >= num_items:
                break
            t = arrive[next_item]
            continue
        if policy.method == "random_n":
            sel = list(rng.choice(pending, size=min(N, len(pending)), replace=False))
        elif policy.method == "last_n":
            sel = pending[-N:]
        elif policy.method == "camel":
            sel = _kcenter_select(pending, features, N, rng)
        else:
            raise ValueError(policy.method)
        finish = t + cycle
        for k, i in enumerate(sorted(sel)):
            delays[i] = (t + (k + 1) * t_train) - arrive[i]
            done_at[i] = t + (k + 1) * t_train
            order.append(i)
        pending = [i for i in pending if i not in set(sel)]
        t = finish
    return AdmissionTrace(done_at, delays, order)


def _kcenter_select(pending: List[int], features: Optional[np.ndarray], N: int, rng):
    if features is None:
        return pending[-N:]
    pts = features[pending]
    chosen = [int(rng.integers(0, len(pending)))]
    dists = np.linalg.norm(pts - pts[chosen[0]], axis=-1)
    while len(chosen) < min(N, len(pending)):
        nxt = int(np.argmax(dists))
        chosen.append(nxt)
        dists = np.minimum(dists, np.linalg.norm(pts - pts[nxt], axis=-1))
    return [pending[i] for i in chosen]
