"""Gradient compression for cross-pod data-parallel reduction.

At 512+ chips the DP all-reduce of full fp32 gradients dominates the
collective term of the roofline. Two standard compressors with
error-feedback (so compression error is re-injected next step and the
method stays convergent):

- top-k sparsification (keep the k largest-magnitude entries per leaf)
- int8 stochastic-free linear quantization (per-leaf scale)

Both are pure functions: compress -> (to-be-reduced tensor, new residual).
The launcher applies them *before* ``psum`` so the wire format is what is
actually reduced.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    method: str = "none"  # none | topk | int8
    topk_frac: float = 0.01  # fraction of entries kept per leaf
    min_leaf_size: int = 4096  # smaller leaves pass through uncompressed


def init_error_feedback(params: Pytree) -> Pytree:
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def _topk_leaf(g: jax.Array, resid: jax.Array, frac: float, min_size: int):
    g32 = g.astype(jnp.float32) + resid
    n = g.size
    if n < min_size:
        return g32, jnp.zeros_like(g32)
    k = max(1, int(n * frac))
    flat = g32.reshape(-1)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = (jnp.abs(flat) >= thresh).astype(jnp.float32)
    kept = (flat * mask).reshape(g32.shape)
    return kept, g32 - kept


def _int8_leaf(g: jax.Array, resid: jax.Array, min_size: int):
    g32 = g.astype(jnp.float32) + resid
    if g.size < min_size:
        return g32, jnp.zeros_like(g32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, g32 - deq


def compress_gradients(
    cfg: CompressionConfig, grads: Pytree, residual: Pytree
) -> Tuple[Pytree, Pytree]:
    """Returns (compressed_grads, new_residual). ``none`` passes through."""
    if cfg.method == "none":
        return grads, residual
    if cfg.method == "topk":
        out = jax.tree.map(
            lambda g, r: _topk_leaf(g, r, cfg.topk_frac, cfg.min_leaf_size), grads, residual
        )
    elif cfg.method == "int8":
        out = jax.tree.map(lambda g, r: _int8_leaf(g, r, cfg.min_leaf_size), grads, residual)
    else:
        raise ValueError(f"unknown compression {cfg.method!r}")
    def is_pair(t):
        return isinstance(t, tuple)

    comp = jax.tree.map(lambda t: t[0], out, is_leaf=is_pair)
    new_resid = jax.tree.map(lambda t: t[1], out, is_leaf=is_pair)
    return comp, new_resid
