from repro.optim.optimizers import Optimizer, adamw, sgd
from repro.optim.compression import (
    CompressionConfig,
    compress_gradients,
    init_error_feedback,
)

__all__ = [
    "Optimizer",
    "adamw",
    "sgd",
    "CompressionConfig",
    "compress_gradients",
    "init_error_feedback",
]
