"""Pytree optimizers (self-contained; no optax in the container).

The interface mirrors optax but supports Ferret's per-stage partial
updates: an Optimizer is a pair of pure functions over arbitrary pytrees,
so each pipeline stage can carry its own optimizer state.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Pytree], Pytree]
    update: Callable[[Pytree, Pytree, Pytree], Tuple[Pytree, Pytree]]
    # update(params, grads, state) -> (new_params, new_state)
    # Hashable structural identity of the update rule (constructor name +
    # hyperparameters). Two Optimizer objects with equal fingerprints are
    # interchangeable inside a compiled engine, so engine caches key on it
    # and same-geometry tenants built from separate adamw(...) calls still
    # share compiles. None → identity-keyed (never shared).
    fingerprint: Any = None


class AdamWState(NamedTuple):
    mu: Pytree
    nu: Pytree
    count: jax.Array


def adamw(
    lr: float = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    grad_clip: float = 0.0,
) -> Optimizer:
    def init(params: Pytree) -> AdamWState:
        def zeros():
            return jax.tree.map(
                lambda p: jnp.zeros_like(p, dtype=jnp.float32), params
            )

        return AdamWState(mu=zeros(), nu=zeros(), count=jnp.zeros((), jnp.int32))

    def update(params: Pytree, grads: Pytree, state: AdamWState):
        if grad_clip > 0.0:
            gnorm = jnp.sqrt(
                sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
            )
            scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)
        count = state.count + 1
        b1c = 1.0 - b1 ** count.astype(jnp.float32)
        b2c = 1.0 - b2 ** count.astype(jnp.float32)

        def leaf(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            mhat = m / b1c
            vhat = v / b2c
            step = lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32))
            return (p.astype(jnp.float32) - step).astype(p.dtype), m, v

        out = jax.tree.map(leaf, params, grads, state.mu, state.nu)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(new_mu, new_nu, count)

    return Optimizer(
        init=init, update=update,
        fingerprint=("adamw", lr, b1, b2, eps, weight_decay, grad_clip),
    )


class SGDState(NamedTuple):
    momentum: Pytree


def sgd(lr: float = 1e-3, momentum: float = 0.0) -> Optimizer:
    def init(params: Pytree) -> SGDState:
        return SGDState(
            momentum=jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        )

    def update(params: Pytree, grads: Pytree, state: SGDState):
        def leaf(p, g, m):
            m = momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

        out = jax.tree.map(leaf, params, grads, state.momentum)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_params, SGDState(new_m)

    return Optimizer(init=init, update=update, fingerprint=("sgd", lr, momentum))
