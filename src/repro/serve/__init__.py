"""repro.serve: multi-tenant online continual learning on one device.

``FerretServer`` admits N independent tenant sessions — each its own
stream, OCL algorithm, and elastic memory share — multiplexed onto one
shared bucketed ``EngineCache`` (same-geometry tenants reuse compiled
engines), with per-tenant admission control (``TenantFeed``), a global
``MemoryPool`` re-divided live as tenants join and leave, and a segment
-granular ``Scheduler`` deciding who runs next.
"""

from repro.serve.admission import TenantFeed
from repro.serve.pool import MemoryPool
from repro.serve.scheduler import (
    DeficitRoundRobinScheduler,
    RoundRobinScheduler,
    Scheduler,
)
from repro.serve.server import FerretServer, ServedSegment, TenantHandle

__all__ = [
    "DeficitRoundRobinScheduler",
    "FerretServer",
    "MemoryPool",
    "RoundRobinScheduler",
    "Scheduler",
    "ServedSegment",
    "TenantFeed",
    "TenantHandle",
]
