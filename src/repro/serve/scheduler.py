"""Tenant schedulers: which ready tenant runs its next segment.

The server asks ``select(ready, weights)`` once per serving decision,
runs one segment for the chosen tenant, and reports the consumed rounds
back through ``charge``. Segments are the scheduling quantum — a tenant
holds the device for exactly one segment, so reaction latency to joins,
leaves, and budget changes is bounded by the segment length.
"""

from __future__ import annotations

from typing import Dict, List


class Scheduler:
    """Scheduler protocol; implementations must be deterministic given the
    same call sequence (the serve loop is replayable)."""

    def select(self, ready: List[str], weights: Dict[str, float]) -> str:
        """Pick the next tenant from ``ready`` (non-empty, admission
        order)."""
        raise NotImplementedError

    def charge(self, name: str, rounds: int) -> None:
        """Account ``rounds`` consumed by ``name``'s completed segment."""

    def forget(self, name: str) -> None:
        """Drop any per-tenant state (the tenant left or finished)."""


class RoundRobinScheduler(Scheduler):
    """Cycle through ready tenants in admission order, ignoring weights."""

    def __init__(self) -> None:
        self._last: str = ""

    def select(self, ready: List[str], weights: Dict[str, float]) -> str:
        if self._last in ready:
            pick = ready[(ready.index(self._last) + 1) % len(ready)]
        else:
            pick = ready[0]
        self._last = pick
        return pick


class DeficitRoundRobinScheduler(Scheduler):
    """Weighted fair scheduling at segment granularity (deficit-style).

    Each tenant carries a *virtual service* counter: the rounds it has
    consumed, normalized by its weight. The ready tenant furthest behind
    (smallest ``service / weight`` — equivalently, the largest deficit
    against a weight-proportional ideal) runs next and is charged what it
    actually consumed. A bursty tenant cannot starve a light one — the
    light tenant's normalized service stays behind until it wins — and
    weights skew sustained throughput proportionally. A tenant that joins
    late starts *at* the current virtual time instead of at zero, so it
    gets its fair share going forward without a catch-up burst.

    ``quantum`` only seeds the tie-break granularity kept for API
    compatibility; service accounting is driven by ``charge``.
    """

    def __init__(self, quantum: float = 8.0):
        if quantum <= 0:
            raise ValueError(f"quantum must be > 0, got {quantum}")
        self.quantum = float(quantum)
        self._service: Dict[str, float] = {}  # weight-normalized rounds served
        self._weight: Dict[str, float] = {}

    def select(self, ready: List[str], weights: Dict[str, float]) -> str:
        known = [n for n in ready if n in self._service]
        floor = min((self._service[n] for n in known), default=0.0)
        for name in ready:
            self._weight[name] = weights.get(name, 1.0)
            if name not in self._service:
                self._service[name] = floor  # join at current virtual time
        # min is stable: ties resolve to admission order (ready's order)
        return min(ready, key=lambda n: self._service[n])

    def charge(self, name: str, rounds: int) -> None:
        self._service[name] = (
            self._service.get(name, 0.0) + float(rounds) / self._weight.get(name, 1.0)
        )

    def forget(self, name: str) -> None:
        self._service.pop(name, None)
        self._weight.pop(name, None)
