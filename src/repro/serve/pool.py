"""The global device-memory pool, divided among live tenants by weight.

One number — the device's memory budget — is split into per-tenant shares
proportional to tenant weight. Joins and leaves re-divide the pool; the
server pushes the new shares into every live tenant's elastic trainer
(``request_budget``), which re-enters the Alg. 2+3 planner at the next
segment boundary. An infinite pool (the Ferret_M+ regime) hands every
tenant an unconstrained share.
"""

from __future__ import annotations

import math
from typing import Dict, List


class MemoryPool:
    """Weighted proportional shares of one memory budget."""

    def __init__(self, budget_bytes: float = math.inf):
        if budget_bytes <= 0:
            raise ValueError(f"budget_bytes must be > 0, got {budget_bytes}")
        self.budget_bytes = float(budget_bytes)
        self._weights: Dict[str, float] = {}  # insertion-ordered

    @property
    def tenants(self) -> List[str]:
        return list(self._weights)

    def join(self, name: str, weight: float = 1.0) -> float:
        """Add a tenant; returns its share under the new division."""
        if weight <= 0:
            raise ValueError(f"weight must be > 0, got {weight}")
        if name in self._weights:
            raise ValueError(f"tenant {name!r} already holds a pool share")
        self._weights[name] = float(weight)
        return self.share(name)

    def leave(self, name: str) -> None:
        """Release a tenant's share back to the pool (re-divided among the
        rest)."""
        del self._weights[name]

    def share(self, name: str) -> float:
        """``name``'s current share in bytes (inf under an infinite pool)."""
        weight = self._weights[name]
        if math.isinf(self.budget_bytes):
            return math.inf
        return self.budget_bytes * weight / sum(self._weights.values())

    def shares(self) -> Dict[str, float]:
        return {name: self.share(name) for name in self._weights}
