"""Admission control: bounded push-queues feeding tenant streams.

A ``TenantFeed`` is the producer-facing edge of the multi-tenant server:
clients ``push`` per-round batches, the serving side consumes the feed as
an ordinary ``StreamSource``. The queue depth is bounded — when a tenant's
feed outruns its share of the device, the admission ``policy`` decides
what gives:

- ``"reject"``      — ``push`` returns ``False``; the producer backs off
                      (backpressure surfaces at the edge).
- ``"drop_oldest"`` — the stalest *queued* round is evicted to make room;
                      in OCL terms the tenant skips forward to fresher
                      data (the paper's stream-pressure regime: a learner
                      that falls behind trains on what is still current).
- ``"drop_newest"`` — the incoming round is dropped, the queue keeps its
                      backlog (arrival-order fidelity over freshness).

Every queued round carries its arrival timestamp; the server pops the
timestamps of consumed rounds segment by segment to report per-round
serving latency (arrival → segment completion). Rounds already handed to
a trainer are never evicted — exactly-once consumption is preserved by
the trainer's replay-buffered feeder on top of this queue.
"""

from __future__ import annotations

import collections
import threading
import time
from typing import Dict, List, Optional

import numpy as np

from repro.api.streams import Batch, StreamSource

_POLICIES = ("reject", "drop_oldest", "drop_newest")


class TenantFeed(StreamSource):
    """A bounded, thread-safe push queue exposed as a ``StreamSource``.

    ``take`` blocks until at least one round is queued (or the feed is
    closed) and then returns *what is available* up to ``n`` — it never
    waits for a full segment, so a scheduler sizing segments to
    ``available_rounds()`` stays non-blocking. ``length`` is ``None``
    (live feed); ``remaining`` becomes known once the feed is closed.
    """

    def __init__(self, max_rounds: int = 64, policy: str = "reject"):
        if max_rounds < 1:
            raise ValueError(f"max_rounds must be >= 1, got {max_rounds}")
        if policy not in _POLICIES:
            raise ValueError(
                f"unknown admission policy {policy!r}; choose from {_POLICIES}"
            )
        self.max_rounds = int(max_rounds)
        self.policy = policy
        self._rows: collections.deque = collections.deque()
        self._arrivals: collections.deque = collections.deque()  # ts per queued round
        self._consumed_arrivals: collections.deque = collections.deque()
        self._lock = threading.Lock()
        self._not_empty = threading.Condition(self._lock)
        self._closed = False
        self.pushed = 0  # rounds accepted into the queue
        self.dropped = 0  # rounds rejected or evicted by the policy

    # -- producer side -----------------------------------------------------
    def push(self, row: Batch) -> bool:
        """Queue one round ``{field: (b, ...)}``; ``False`` if admission
        dropped it (``reject``/``drop_newest``) or evicted another for it
        (``drop_oldest`` still returns ``True`` — *this* round got in)."""
        now = time.perf_counter()
        with self._not_empty:
            if self._closed:
                raise RuntimeError("push() on a closed TenantFeed")
            if len(self._rows) >= self.max_rounds:
                self.dropped += 1
                if self.policy in ("reject", "drop_newest"):
                    return False
                self._rows.popleft()  # drop_oldest: evict the stalest round
                self._arrivals.popleft()
            self._rows.append({k: np.asarray(v) for k, v in row.items()})
            self._arrivals.append(now)
            self.pushed += 1
            self._not_empty.notify_all()
            return True

    def push_many(self, rows: Dict[str, np.ndarray]) -> int:
        """Push a stacked ``(R, b, ...)`` burst round by round; returns how
        many were admitted."""
        n = next(iter(rows.values())).shape[0]
        admitted = 0
        for m in range(n):
            admitted += bool(self.push({k: v[m] for k, v in rows.items()}))
        return admitted

    def close(self) -> None:
        """No more pushes; consumers drain what is queued, then end."""
        with self._not_empty:
            self._closed = True
            self._not_empty.notify_all()

    # -- observability -----------------------------------------------------
    @property
    def closed(self) -> bool:
        return self._closed

    def available_rounds(self) -> int:
        """Rounds queued right now (what a ``take`` would get unblocked)."""
        with self._lock:
            return len(self._rows)

    def pop_consumed_arrivals(self, n: int) -> List[float]:
        """Arrival timestamps of the ``n`` oldest consumed rounds (FIFO —
        consumption order equals completion order per tenant, so the
        server calls this once per completed segment)."""
        with self._lock:
            take = min(n, len(self._consumed_arrivals))
            return [self._consumed_arrivals.popleft() for _ in range(take)]

    # -- StreamSource protocol ---------------------------------------------
    @property
    def length(self) -> Optional[int]:
        return None  # live feed: total length is unknowable up front

    @property
    def remaining(self) -> Optional[int]:
        with self._lock:
            return len(self._rows) if self._closed else None

    def take(self, n: int) -> Optional[Batch]:
        with self._not_empty:
            while not self._rows and not self._closed:
                self._not_empty.wait()
            if not self._rows:
                return None  # closed and drained
            m = min(n, len(self._rows))
            rows = [self._rows.popleft() for _ in range(m)]
            for _ in range(m):
                self._consumed_arrivals.append(self._arrivals.popleft())
            return {k: np.stack([r[k] for r in rows]) for k in rows[0]}
