"""FerretServer: N tenant OCL sessions multiplexed onto one device.

Each tenant is an independent ``FerretSession`` — its own stream, its own
OCL algorithm, its own elastic memory share — opened as a *steppable*
elastic run (``ElasticRun``). The server owns what is shared:

- one bucketed ``EngineCache``: same-geometry tenants (equal model config,
  algorithm fingerprint, optimizer fingerprint, lr, compensation, and
  planned partition) reuse one compiled engine; the engine's ``exec_lock``
  keeps concurrent use race-free.
- one ``MemoryPool``: the device budget divided by tenant weight and
  re-divided live on every join/leave/finish — running tenants pick the
  new share up through ``request_budget`` (the elastic trainer's
  segment-boundary re-plan path, Alg. 2+3).
- one ``Scheduler``: each serving decision runs exactly one segment of
  one ready tenant, so the device stays saturated under bursty arrival
  while reaction latency stays bounded by the segment length.

Tenants fed by a ``TenantFeed`` get admission control (bounded queue,
reject/drop policy) and non-blocking scheduling: segments are sized to
what the feed has actually buffered, so a tenant with an open-but-idle
feed never stalls the serve loop. Per-round serving latency (arrival →
segment completion) is reported per segment from the feed's arrival
timestamps.
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import signal
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Union

import numpy as np

from repro import faults as faults_lib
from repro.api.results import StreamResult
from repro.faults import TenantCrashError
from repro.api.session import FerretSession
from repro.api.streams import BufferedStreamSource, LimitedStreamSource, StreamSource
from repro.core.ferret import EngineCache
from repro.models.config import ModelConfig
from repro.serve.admission import TenantFeed
from repro.serve.pool import MemoryPool
from repro.serve.scheduler import DeficitRoundRobinScheduler, Scheduler

Batch = Dict[str, np.ndarray]


@dataclasses.dataclass
class ServedSegment:
    """One scheduling decision's outcome: one segment of one tenant."""

    tenant: str
    report: Any  # runtime.SegmentReport
    round_latencies_s: Optional[List[float]]  # arrival → completion (feed tenants)


class _Tenant:
    """Internal per-tenant state; the public face is ``TenantHandle``."""

    def __init__(
        self, name, weight, session, tenant_feed, segment_rounds, max_rounds,
        supervisor_cfg, resume_from=None,
    ):
        self.name = name
        self.weight = weight
        self.session: FerretSession = session
        self.tenant_feed: Optional[TenantFeed] = tenant_feed
        self.segment_rounds = segment_rounds
        self.max_rounds = max_rounds
        self.supervisor_cfg = supervisor_cfg
        self.resume_from = resume_from  # drain-checkpoint dir to resume from
        self.run = None  # ElasticRun once started (lazily, on first ready step)
        self.stepping = False  # a segment is executing outside the server lock
        self.done = False
        self.rounds_served = 0
        self.crash_count = 0  # consecutive failed steps (reset on success)
        self.latencies_s: List[float] = []


class TenantHandle:
    """Thin per-tenant view over the underlying ``FerretSession``.

    The handle is how a client talks to its admitted tenant: push rounds
    into its feed, watch its budget/progress, leave, and read the final
    ``StreamResult``. It holds no state of its own — everything delegates
    to the server, so a handle stays valid after the tenant finishes.
    """

    def __init__(self, server: "FerretServer", name: str):
        self._server = server
        self.name = name

    @property
    def session(self) -> FerretSession:
        return self._server._tenant(self.name).session

    @property
    def budget_bytes(self) -> float:
        """The tenant's current share of the memory pool."""
        return self._server.pool.share(self.name)

    @property
    def done(self) -> bool:
        with self._server._lock:
            return self.name in self._server._results

    @property
    def rounds_served(self) -> int:
        with self._server._lock:
            t = self._server._tenants.get(self.name)
            if t is not None:
                return t.rounds_served
        res = self.result()
        return 0 if res is None else res.rounds

    @property
    def round_latencies_s(self) -> List[float]:
        """Arrival → completion latency of every served round (feed
        tenants; empty for pull sources, which have no arrival times)."""
        with self._server._lock:
            t = self._server._tenants.get(self.name)
            if t is not None:
                return list(t.latencies_s)
            return list(self._server._latencies.get(self.name, ()))

    # -- feed passthrough --------------------------------------------------
    def push(self, row: Batch) -> bool:
        return self._feed().push(row)

    def push_many(self, rows: Batch) -> int:
        return self._feed().push_many(rows)

    def close_feed(self) -> None:
        self._feed().close()

    def _feed(self) -> TenantFeed:
        feed = self._server._tenant(self.name).tenant_feed
        if feed is None:
            raise RuntimeError(
                f"tenant {self.name!r} is not fed by a TenantFeed — it pulls "
                "from the stream it was admitted with"
            )
        return feed

    # -- lifecycle ---------------------------------------------------------
    def leave(self) -> StreamResult:
        return self._server.leave(self.name)

    def result(self) -> Optional[StreamResult]:
        with self._server._lock:
            return self._server._results.get(self.name)

    def summary(self) -> str:
        res = self.result()
        if res is not None:
            return f"{self.name}: {res.summary()}"
        return (
            f"{self.name}: serving, rounds={self.rounds_served} "
            f"budget={self._server.pool.share(self.name) / 2**20:.1f}MiB"
            if math.isfinite(self._server.pool.budget_bytes)
            else f"{self.name}: serving, rounds={self.rounds_served} budget=inf"
        )


class FerretServer:
    """Admit, schedule, and elastically budget N concurrent OCL tenants.

        server = FerretServer(budget_bytes=8 * 2**30)
        a = server.admit(model_cfg, algorithm="er", stream=feed_a)
        b = server.admit(model_cfg, algorithm="er", stream=arrays_b)
        ...
        results = server.serve()          # drive everything to completion

    ``admit`` with ``stream=None`` creates a ``TenantFeed`` the client
    pushes rounds into through the returned handle. The serve loop is
    single-threaded by design — ``step()`` is one scheduling decision —
    but admission, pushes, and ``leave`` are safe from other threads, and
    multiple threads may drive ``step()`` concurrently (distinct tenants
    execute in parallel; same-geometry tenants serialize on their shared
    engine's ``exec_lock``).
    """

    def __init__(
        self,
        budget_bytes: float = math.inf,
        *,
        engine_cache: Optional[EngineCache] = None,
        scheduler: Optional[Scheduler] = None,
        segment_rounds: int = 8,
        smoke: bool = True,
        profile_feedback: bool = False,
        max_tenant_crashes: int = 3,
        topology=None,
    ):
        # topology: the discovered DeviceTopology every admitted tenant
        # session runs under (None / "discover" / a DeviceTopology, same
        # contract as FerretSession) — one shared hardware world, so
        # same-geometry tenants also share topology-keyed compiled engines
        from repro.runtime.topology import as_topology

        self.topology = as_topology(topology)
        self.engine_cache = engine_cache or EngineCache()
        # host-side: tenants refine their persisted profiles from observed
        # segment wall-clock (repro.profile.bridge.observe_segment)
        self.profile_feedback = bool(profile_feedback)
        self.pool = MemoryPool(budget_bytes)
        self.scheduler = scheduler or DeficitRoundRobinScheduler(
            quantum=float(segment_rounds)
        )
        self.segment_rounds = int(segment_rounds)
        self.smoke = smoke
        # a tenant failing this many *consecutive* steps is quarantined:
        # finalized with whatever it completed, so it cannot starve or
        # kill the serve loop for its siblings
        self.max_tenant_crashes = int(max_tenant_crashes)
        self._tenants: Dict[str, _Tenant] = {}  # insertion = admission order
        self._results: Dict[str, StreamResult] = {}
        self._latencies: Dict[str, List[float]] = {}
        self._quarantined: Dict[str, str] = {}  # name -> reason
        self._model_cache: Dict[Any, ModelConfig] = {}
        self._lock = threading.RLock()
        self._counter = 0
        self._draining = False

    # -- admission ---------------------------------------------------------
    def admit(
        self,
        model: Union[ModelConfig, str],
        algorithm: Any = "vanilla",
        stream: Optional[Union[StreamSource, Batch]] = None,
        *,
        name: Optional[str] = None,
        weight: float = 1.0,
        batch: Optional[int] = None,
        seq: Optional[int] = None,
        lr: float = 5e-3,
        compensation: Any = None,
        ocl: Any = None,
        max_workers: Optional[int] = 8,
        max_stages: Optional[int] = None,
        segment_rounds: Optional[int] = None,
        max_rounds: Optional[int] = None,
        supervisor_cfg: Any = None,
        params: Any = None,
        seed: int = 0,
        resume_from: Optional[str] = None,
    ) -> TenantHandle:
        """Admit one tenant session; the pool re-divides immediately.

        ``stream=None`` creates a ``TenantFeed`` (push-fed tenant; use
        ``handle.push``/``push_many``/``close_feed``). ``max_rounds``
        bounds the tenant's run; ``segment_rounds`` overrides the server's
        scheduling quantum for this tenant. ``supervisor_cfg`` runs the
        tenant's segments supervised (checkpoints, NaN rollback) in its
        own per-tenant checkpoint namespace. ``resume_from`` points at the
        tenant's drain-checkpoint directory from a previous server's
        ``drain()`` — the run resumes that state exactly where the drain
        stopped it (seekable sources are positioned at the saved cursor).
        """
        with self._lock:
            if name is None:
                name = f"tenant{self._counter}"
            self._counter += 1
            if name in self._tenants or name in self._results:
                raise ValueError(f"tenant name {name!r} already in use")
            model_cfg = self._intern_model(model)
            tenant_feed = stream if isinstance(stream, TenantFeed) else None
            if stream is None:
                tenant_feed = TenantFeed()
                stream = tenant_feed
            share = self.pool.join(name, weight)
            try:
                session = FerretSession(
                    model_cfg, budget=share, algorithm=algorithm, stream=stream,
                    batch=batch, seq=seq, lr=lr, compensation=compensation,
                    ocl=ocl, max_workers=max_workers, max_stages=max_stages,
                    params=params, seed=seed, smoke=self.smoke,
                    profile_feedback=self.profile_feedback,
                    topology=self.topology,
                )
            except Exception:
                self.pool.leave(name)
                raise
            if supervisor_cfg is not None:
                # per-tenant checkpoint namespace: same cfg for every
                # tenant must not collide on one directory
                supervisor_cfg = dataclasses.replace(
                    supervisor_cfg,
                    checkpoint_dir=os.path.join(
                        supervisor_cfg.checkpoint_dir, f"tenant_{name}"
                    ),
                )
            tenant = _Tenant(
                name=name, weight=weight, session=session,
                tenant_feed=tenant_feed,
                segment_rounds=int(segment_rounds or self.segment_rounds),
                max_rounds=max_rounds, supervisor_cfg=supervisor_cfg,
                resume_from=resume_from,
            )
            self._tenants[name] = tenant
            self._rebalance_locked()
            return TenantHandle(self, name)

    def leave(self, name: str) -> StreamResult:
        """Remove a tenant now: its run stops at the current segment
        boundary (everything consumed stays accounted), its pool share is
        re-divided among the rest, and its final result is returned."""
        with self._lock:
            tenant = self._tenants.get(name)
            if tenant is None:
                if name in self._results:
                    return self._results[name]
                raise KeyError(f"unknown tenant {name!r}")
            if tenant.stepping:
                raise RuntimeError(
                    f"tenant {name!r} is mid-segment — leave() between steps"
                )
            tenant.done = True  # no further scheduling
        raw = tenant.run.stop() if tenant.run is not None else None
        self._finalize(tenant, raw)
        return self._results[name]

    # -- scheduling --------------------------------------------------------
    def step(self) -> Optional[ServedSegment]:
        """One scheduling decision: run one segment of one ready tenant.

        Returns ``None`` when no tenant is ready (every live feed is open
        but empty) or when the stepped tenant turned out to be finished —
        check ``active_tenants`` to distinguish idle from done.
        """
        with self._lock:
            ready = [t.name for t in self._tenants.values() if self._ready(t)]
            if not ready:
                return None
            weights = {t.name: t.weight for t in self._tenants.values()}
            pick = self.scheduler.select(ready, weights)
            tenant = self._tenants[pick]
            tenant.stepping = True
        try:
            return self._step_tenant(tenant)
        finally:
            tenant.stepping = False

    def serve(
        self,
        *,
        max_segments: Optional[int] = None,
        timeout_s: Optional[float] = None,
        poll_s: float = 0.005,
    ) -> Dict[str, StreamResult]:
        """Drive the scheduler until every tenant finishes (or a cap hits).

        Tenants with open live feeds never finish on their own — close
        their feeds (or pass ``max_segments``/``timeout_s``) to bound the
        call. Returns the results of every finished tenant so far.
        """
        served = 0
        t0 = time.perf_counter()
        while self._tenants:
            if max_segments is not None and served >= max_segments:
                break
            if timeout_s is not None and time.perf_counter() - t0 > timeout_s:
                break
            spec = faults_lib.fire("serve.loop")
            if spec is not None and spec.kind == "drain":
                self.request_drain()  # an injected SIGTERM
            if self._draining:
                break  # the caller drains (drain()) or restarts
            if self.step() is not None:
                served += 1
            elif self._tenants:
                time.sleep(poll_s)  # everyone is waiting on an open feed
        return self.results()

    # -- graceful drain ----------------------------------------------------
    def request_drain(self) -> None:
        """Ask the serve loop to stop at the next segment boundary.

        Safe from any thread (and from a signal handler): nothing is
        interrupted mid-segment; ``serve()`` returns once the in-flight
        decision completes, and ``drain()`` then checkpoints every tenant.
        """
        self._draining = True

    @property
    def draining(self) -> bool:
        return self._draining

    def install_signal_handler(self, signum: int = signal.SIGTERM) -> None:
        """Route ``SIGTERM`` (or another signal) into ``request_drain``.

        Main thread only (CPython restriction). The previous handler is
        not chained — install last.
        """
        signal.signal(signum, lambda _sig, _frame: self.request_drain())

    def drain(self, checkpoint_dir: str) -> Dict[str, Dict[str, Any]]:
        """Stop every live tenant at its segment boundary and checkpoint it.

        Each tenant's end-of-segment state (weights, optimizer moments,
        Iter-Fisher statistics, the in-flight gradient-accumulation and
        Δθ rings, partition bounds, stream cursor, budget) is saved under
        ``checkpoint_dir/tenant_<name>`` via the trainer's live snapshot;
        an atomic ``drain_manifest.json`` records the admission metadata
        a restart needs. A new server re-admits with
        ``admit(..., resume_from=<tenant dir>)`` and every stream resumes
        exactly where it stopped — zero rounds lost, zero re-trained, and
        (when the restart plans the same partition) **bit-exact** with the
        uninterrupted run: the rings carry, so the restarted engine
        re-enters the same schedule with identical state.

        Tenants that never started (nothing consumed) get no checkpoint
        (``"checkpoint": None``): a restart starts them from scratch,
        which is still exactly-once. Returns the manifest.
        """
        self.request_drain()
        # let in-flight segments (other serving threads) reach a boundary
        while True:
            with self._lock:
                if not any(t.stepping for t in self._tenants.values()):
                    break
            time.sleep(0.001)
        with self._lock:
            tenants = list(self._tenants.values())
        os.makedirs(checkpoint_dir, exist_ok=True)
        manifest: Dict[str, Dict[str, Any]] = {}
        for tenant in tenants:
            entry: Dict[str, Any] = {
                "weight": tenant.weight,
                "rounds_served": tenant.rounds_served,
                "algorithm": tenant.session.algorithm.name,
                "checkpoint": None,
                "cursor": 0,
            }
            raw = None
            if tenant.run is not None:
                raw = tenant.run.abort()  # stop() for healthy runs
                tenant_dir = os.path.join(checkpoint_dir, f"tenant_{tenant.name}")
                path = tenant.run.trainer.save_live_checkpoint(tenant_dir)
                rs = tenant.run.trainer.live_resume_state()
                if path is not None:
                    entry["checkpoint"] = tenant_dir
                    entry["cursor"] = int(rs.cursor)
            self._finalize(tenant, raw)
            manifest[tenant.name] = entry
        tmp = os.path.join(checkpoint_dir, "drain_manifest.json.tmp")
        with open(tmp, "w") as f:
            json.dump(manifest, f, indent=2)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, os.path.join(checkpoint_dir, "drain_manifest.json"))
        faults_lib.resolved("serve.loop")  # an injected drain is now healed
        return manifest

    @staticmethod
    def load_drain_manifest(checkpoint_dir: str) -> Dict[str, Dict[str, Any]]:
        """Read a ``drain()`` manifest (what to re-admit, and from where)."""
        with open(os.path.join(checkpoint_dir, "drain_manifest.json")) as f:
            return json.load(f)

    # -- observability -----------------------------------------------------
    def results(self) -> Dict[str, StreamResult]:
        """Final ``StreamResult`` per finished tenant (admission order)."""
        with self._lock:
            return dict(self._results)

    @property
    def active_tenants(self) -> List[str]:
        with self._lock:
            return list(self._tenants)

    @property
    def quarantined_tenants(self) -> Dict[str, str]:
        """Tenants removed after repeated crashes: name → last error."""
        with self._lock:
            return dict(self._quarantined)

    @property
    def compile_count(self) -> int:
        """Fresh engine compiles across all tenants — the same-geometry
        sharing headline (< tenant count when geometry is shared)."""
        return self.engine_cache.misses

    # -- internals ---------------------------------------------------------
    def _tenant(self, name: str) -> _Tenant:
        with self._lock:
            tenant = self._tenants.get(name)
            if tenant is None:
                raise KeyError(f"unknown (or finished) tenant {name!r}")
            return tenant

    def _intern_model(self, model: Union[ModelConfig, str]) -> ModelConfig:
        if not isinstance(model, str):
            return model
        key = (model, self.smoke)
        cached = self._model_cache.get(key)
        if cached is None:
            from repro.models.registry import get_config

            cached = get_config(model, smoke=self.smoke)
            self._model_cache[key] = cached
        return cached

    def _rebalance_locked(self) -> None:
        """Push the pool's current division into every live tenant."""
        for tenant in self._tenants.values():
            if tenant.done:
                continue
            share = self.pool.share(tenant.name)
            if tenant.run is not None:
                # running: re-plan at the next segment boundary
                tenant.run.trainer.request_budget(share)
            else:
                # not started: the trainer it will build reads this config
                tenant.session.ferret_cfg = dataclasses.replace(
                    tenant.session.ferret_cfg, budget_bytes=share
                )

    def _ready(self, tenant: _Tenant) -> bool:
        """Can one segment run for this tenant without blocking the loop?"""
        if tenant.done or tenant.stepping:
            return False
        if tenant.tenant_feed is None:
            return True  # pull source: take() resolves immediately (or ends)
        if tenant.tenant_feed.closed:
            return True  # drains what is buffered, then finishes
        avail = self._available(tenant)
        return avail is None or avail > 0

    def _available(self, tenant: _Tenant) -> Optional[int]:
        """Rounds obtainable for this tenant without blocking: everything
        buffered along the source chain plus the feed's queue. ``None``
        when the chain bottoms out in an unbounded pull source (no queue
        to observe — assume available)."""
        n = 0
        if tenant.run is not None:
            feeder = tenant.run.trainer._feeder
            if feeder is None:
                return None  # between open and first pull
            source: Any = feeder
        else:
            source = tenant.session._live_stream or tenant.session.stream
        while True:
            if isinstance(source, BufferedStreamSource):
                n += source.pending_round_count()
                source = source.source
            elif isinstance(source, LimitedStreamSource):
                source = source.source
            elif isinstance(source, TenantFeed):
                return n + source.available_rounds()
            else:
                rem = source.remaining
                return None if rem is None else n + rem

    def _segment_cap(self, tenant: _Tenant) -> Callable[[int], int]:
        """Dynamic segment sizing: at every boundary, take what the feed
        has buffered (≥ 1 so the run can observe exhaustion), capped at
        the tenant's scheduling quantum."""
        base = tenant.segment_rounds

        def cap(cursor: int, tenant=tenant, base=base) -> int:
            avail = self._available(tenant)
            if avail is None:
                return base
            return max(1, min(base, avail))

        return cap

    def _step_tenant(self, tenant: _Tenant) -> Optional[ServedSegment]:
        # executes OUTSIDE the server lock: one tenant's segment never
        # blocks admissions, pushes, or other tenants' steps
        if tenant.run is None and not self._start_tenant(tenant):
            return None
        try:
            spec = faults_lib.fire("serve.step", tenant=tenant.name)
            if spec is not None and spec.kind == "tenant_crash":
                # fired *before* run.step(): the run stays healthy, so a
                # later scheduling decision can retry it
                raise TenantCrashError(
                    f"injected crash in tenant {tenant.name!r}"
                )
            report = tenant.run.step()
        except Exception as e:  # one tenant's failure must not kill the loop
            return self._tenant_crashed(tenant, e)
        if tenant.crash_count:
            tenant.crash_count = 0
            faults_lib.resolved("serve.step")
        t_done = time.perf_counter()
        if report is None:
            self._finalize(tenant, tenant.run.result())
            return None
        seg_len = report.end - report.start
        latencies = None
        if tenant.tenant_feed is not None:
            arrivals = tenant.tenant_feed.pop_consumed_arrivals(seg_len)
            latencies = [t_done - a for a in arrivals]
        with self._lock:
            tenant.rounds_served += seg_len
            if latencies:
                tenant.latencies_s.extend(latencies)
            self.scheduler.charge(tenant.name, seg_len)
        return ServedSegment(
            tenant=tenant.name, report=report, round_latencies_s=latencies
        )

    def _start_tenant(self, tenant: _Tenant) -> bool:
        """Lazy start: open the steppable run on first ready step (shape
        inference peeks the feed, so starting earlier could block)."""
        try:
            tenant.run = tenant.session.open_stream_run(
                engine_cache=self.engine_cache,
                max_rounds=tenant.max_rounds,
                segment_rounds=self._segment_cap(tenant),
                supervisor_cfg=tenant.supervisor_cfg,
                resume_from=tenant.resume_from,
            )
        except ValueError:
            # an already-exhausted feed with no batch/seq to infer from:
            # nothing was consumed, nothing can run — finish empty
            self._finalize(tenant, None)
            return False
        return True

    def _tenant_crashed(
        self, tenant: _Tenant, exc: BaseException
    ) -> Optional[ServedSegment]:
        """Contain one tenant's failed step: retry, then quarantine.

        Consecutive failures under ``max_tenant_crashes`` with a healthy
        run are left for a later scheduling decision to retry (the stream
        stays exactly-once: a failed step consumed nothing, or its
        generator rewound). A broken run (the exception escaped the
        segment generator) or a tenant over the limit is quarantined:
        aborted with the segments it completed salvaged into its final
        result, so siblings and the shared ``EngineCache`` are untouched.
        """
        with self._lock:
            tenant.crash_count += 1
            broken = tenant.run is not None and tenant.run.broken
            retry = tenant.crash_count < self.max_tenant_crashes and not broken
        if retry:
            return None
        raw = tenant.run.abort() if tenant.run is not None else None
        with self._lock:
            self._quarantined[tenant.name] = f"{type(exc).__name__}: {exc}"
        self._finalize(tenant, raw)
        faults_lib.resolved("serve.step")
        return None

    def _finalize(self, tenant: _Tenant, raw: Any) -> None:
        from repro.api.runners import stream_result_from_elastic

        algo = tenant.session.algorithm.name
        if raw is not None:
            result = stream_result_from_elastic(
                raw, runner="serve", algorithm=algo,
                model_cfg=tenant.session.model_cfg,
            )
        else:
            result = StreamResult(
                runner="serve", algorithm=algo, online_acc=0.0,
                online_acc_curve=np.zeros(0), losses=np.zeros(0), rounds=0,
                admitted_frac=0.0,
                memory_bytes=float(tenant.session.model_cfg.param_count()) * 4.0,
                empirical_rate=0.0, final_params=None,
            )
        with self._lock:
            tenant.done = True
            self._results[tenant.name] = result
            self._latencies[tenant.name] = list(tenant.latencies_s)
            self._tenants.pop(tenant.name, None)
            if tenant.name in self.pool.tenants:
                self.pool.leave(tenant.name)
            self.scheduler.forget(tenant.name)
            self._rebalance_locked()  # the freed share grows everyone else
