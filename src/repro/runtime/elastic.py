"""Elastic scaling: resource changes re-enter the paper's own planner.

A device/host loss (or gain) changes two planner inputs:
  1. the per-chip memory budget share M, and
  2. the per-layer profile (per-chip t^f/t^b scale with the TP degree).

Ferret's bi-level planner (Alg. 2+3) was built to answer exactly the
question "best pipeline under memory budget M", so elasticity is a
re-plan + checkpoint-restore: no bespoke rebalancing logic. This is the
paper's memory-adaptivity claim operationalized as fault tolerance.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import planner as planner_lib
from repro.core.profiler import ModelProfile, profile_for
from repro.models.config import ModelConfig

HBM_PER_CHIP = 16 * 2**30  # TPU v5e


def _is_topology(cluster) -> bool:
    from repro.runtime.topology import DeviceTopology

    return isinstance(cluster, DeviceTopology)


class DeviceLossError(RuntimeError):
    """A device/host dropped out mid-run.

    Unlike a NaN or a timeout, this is not retryable in place: the lost
    capacity is gone, so the supervisor escalates straight to the elastic
    planner (shrink-replan) instead of burning its retry budget.

    ``lost_devices`` sizes the topology shrink the handler performs: the
    elastic trainer rebuilds its ``DeviceTopology`` over
    ``device_count - lost_devices`` survivors and replans from there.
    """

    def __init__(self, *args, lost_devices: int = 1):
        super().__init__(*args)
        self.lost_devices = int(lost_devices)


@dataclasses.dataclass
class ClusterSpec:
    chips: int
    hbm_per_chip: int = HBM_PER_CHIP

    @property
    def total_hbm(self) -> int:
        return self.chips * self.hbm_per_chip

    @classmethod
    def from_topology(cls, topology) -> "ClusterSpec":
        """A cluster view of a discovered ``DeviceTopology`` — the legacy
        scalar bridge for callers that still budget from chip totals."""
        return cls(
            chips=topology.device_count,
            hbm_per_chip=topology.memory_per_device,
        )


class ElasticPlanner:
    """Re-plans the pipeline when the cluster shrinks or grows."""

    def __init__(
        self,
        model_cfg: ModelConfig,
        batch: int,
        seq: int,
        decay_c: float = 1.0,
        memory_fraction: float = 0.9,  # budget headroom for runtime buffers
        max_workers: Optional[int] = 8,
    ):
        self.model_cfg = model_cfg
        self.batch = batch
        self.seq = seq
        self.decay_c = decay_c
        self.memory_fraction = memory_fraction
        self.max_workers = max_workers

    def profile_for(self, cluster) -> ModelProfile:
        """Store-aware Alg. 3 ``profile(θ)``: a persisted on-device
        measurement for this geometry (scaled to the cluster's shape) when
        one exists, the analytic roofline otherwise — so a topology-shrink
        replan after ``Supervisor.on_fatal`` runs from real numbers.

        ``cluster`` is a legacy ``ClusterSpec`` (TP/FSDP-style per-chip
        division over ``chips``) or a discovered ``DeviceTopology``
        (data-parallel scaling: times and activations divide by the data
        axis, weights replicate — ``profile.bridge.for_topology``).
        """
        if _is_topology(cluster):
            from repro.profile.bridge import for_topology

            base = profile_for(self.model_cfg, self.batch, self.seq)
            return for_topology(base, cluster)
        return profile_for(
            self.model_cfg, self.batch, self.seq, chips=cluster.chips
        )

    def replan(self, cluster) -> planner_lib.Plan:
        profile = self.profile_for(cluster)
        t_d = planner_lib.default_data_interval(profile)
        return planner_lib.plan(
            profile,
            t_d,
            self.budget_for(cluster),
            c=self.decay_c,
            max_workers=self.max_workers,
            topology=cluster if _is_topology(cluster) else None,
        )

    def degradation(self, before: planner_lib.Plan, after: planner_lib.Plan) -> float:
        """Fractional adaptation-rate loss from the resource change."""
        if before.rate <= 0:
            return 0.0
        return max(0.0, 1.0 - after.rate / before.rate)

    def budget_for(self, cluster) -> float:
        """The memory budget M the planner gets for this cluster shape.

        A ``DeviceTopology`` budgets *per device* (data-parallel replicas
        hold the whole pipeline, only the model axis multiplies memory);
        the legacy ``ClusterSpec`` keeps its scalar-total semantics.
        """
        if _is_topology(cluster):
            return cluster.plan_budget(self.memory_fraction)
        return self.memory_fraction * cluster.total_hbm
