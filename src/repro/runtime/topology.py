"""Discovered device topology: the one description of the hardware world.

Every layer that used to pretend the world is one device — the mesh
builder (``launch/mesh.py``), the sharding rules (``launch/shardings.py``),
the engine's jitted scan (``core/pipeline.py``), the planner budget
(``core/planner.py`` / ``runtime/elastic.py``) and the elastic trainer's
device-loss handling — now consumes a ``DeviceTopology``:

- **discovery**: ``DeviceTopology.discover()`` reads ``jax.devices()`` /
  ``jax.process_index()`` once and freezes the result (device count and
  kind, process count/index, a ``(data, model)`` mesh shape, per-device
  memory). Nothing here touches jax at *import* time — the dry-run sets
  ``XLA_FLAGS`` before first jax init and only then discovers.
- **planning**: ``plan_budget()`` is the per-device memory bound the
  planner uses instead of a scalar cluster total — data-parallel replicas
  do not add budget (each device holds the full pipeline footprint); only
  the model axis spans devices.
- **elasticity**: ``shrink(lost_devices)`` is the topology-shrink event a
  ``DeviceLossError`` escalates into — a new topology over the surviving
  devices, which the elastic trainer re-plans and re-meshes around.
- **multi-host**: ``is_main()`` is the HomebrewNLP/olmax gating idiom —
  exactly one process writes checkpoints/benchmarks; all processes
  participate in collectives.

A topology of size 1 (``is_trivial``) degenerates to the historical
single-device path everywhere: no mesh is built, no array is re-placed,
and results are bit-identical to a run that never heard of topologies.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Optional, Tuple

import numpy as np

# Per-device memory fallback when the backend reports none (CPU fake
# devices, older runtimes): TPU v5e HBM. Override with
# REPRO_DEVICE_MEM_BYTES or the memory_per_device= argument.
DEFAULT_MEMORY_PER_DEVICE = 16 * 2**30

# Fraction of per-device memory handed to the planner (headroom for XLA
# scratch, collectives buffers, host transfers) — matches the historical
# ElasticPlanner.memory_fraction default.
DEFAULT_MEMORY_FRACTION = 0.9


def _device_memory(device, override: Optional[int]) -> int:
    if override is not None:
        return int(override)
    env = os.environ.get("REPRO_DEVICE_MEM_BYTES", "").strip()
    if env:
        return int(env)
    try:
        stats = device.memory_stats()
    except Exception:
        stats = None
    if stats and stats.get("bytes_limit"):
        return int(stats["bytes_limit"])
    return DEFAULT_MEMORY_PER_DEVICE


@dataclasses.dataclass(frozen=True)
class DeviceTopology:
    """A frozen description of the devices a run executes on.

    ``mesh_shape`` is ``(data, model)``: the data axis shards the batch
    (pure replication of weights), the model axis is the spatial pipeline /
    tensor axis (``core/stage_parallel.py``). ``data * model`` must equal
    ``device_count``.
    """

    device_count: int
    device_kind: str = "cpu"
    process_count: int = 1
    process_index: int = 0
    mesh_shape: Tuple[int, int] = (1, 1)
    memory_per_device: int = DEFAULT_MEMORY_PER_DEVICE

    def __post_init__(self):
        d, m = self.mesh_shape
        if d * m != self.device_count:
            raise ValueError(
                f"mesh_shape {self.mesh_shape} does not cover "
                f"device_count={self.device_count}"
            )
        if self.device_count < 1:
            raise ValueError("device_count must be >= 1")

    # -- discovery ---------------------------------------------------------
    @classmethod
    def discover(
        cls,
        *,
        model_axis: int = 1,
        max_devices: Optional[int] = None,
        memory_per_device: Optional[int] = None,
    ) -> "DeviceTopology":
        """Read the world from jax: one call, at run start.

        ``model_axis`` devices are grouped along the model/stage axis
        (default 1: pure data parallelism); the rest form the data axis.
        ``max_devices`` restricts discovery to a prefix of
        ``jax.devices()`` — how tests carve a 4-device topology out of an
        8-fake-device host. The device count is rounded *down* to a
        multiple of ``model_axis`` so the mesh always covers it.
        """
        import jax

        devices = jax.devices()
        n = len(devices) if max_devices is None else min(max_devices, len(devices))
        model_axis = max(1, int(model_axis))
        if model_axis > n:
            raise ValueError(
                f"model_axis={model_axis} exceeds the {n} visible devices"
            )
        n -= n % model_axis
        return cls(
            device_count=n,
            device_kind=str(devices[0].device_kind),
            process_count=int(jax.process_count()),
            process_index=int(jax.process_index()),
            mesh_shape=(n // model_axis, model_axis),
            memory_per_device=_device_memory(devices[0], memory_per_device),
        )

    @classmethod
    def trivial(cls, device_kind: str = "cpu") -> "DeviceTopology":
        """The single-device topology: degenerates to the legacy path."""
        return cls(device_count=1, device_kind=device_kind)

    # -- derived views -----------------------------------------------------
    @property
    def data_parallel(self) -> int:
        return self.mesh_shape[0]

    @property
    def model_parallel(self) -> int:
        return self.mesh_shape[1]

    @property
    def is_trivial(self) -> bool:
        return self.device_count == 1 and self.process_count == 1

    @property
    def total_memory_bytes(self) -> int:
        return self.device_count * self.memory_per_device

    def is_main(self) -> bool:
        """The multi-host gating idiom: exactly one process does host-side
        I/O (checkpoints, bench artifacts); every process computes."""
        return self.process_index == 0

    def fingerprint(self) -> Tuple:
        """Hashable identity for compile/engine caches: two topologies with
        the same fingerprint lower to the same partitioned executable."""
        return (
            "topo", self.device_count, self.device_kind,
            self.process_count, self.mesh_shape,
        )

    def describe(self) -> dict:
        """JSON-ready summary (bench payloads, manifests)."""
        return {
            "device_count": self.device_count,
            "device_kind": self.device_kind,
            "process_count": self.process_count,
            "mesh_shape": list(self.mesh_shape),
            "memory_per_device": int(self.memory_per_device),
        }

    # -- planning ----------------------------------------------------------
    def plan_budget(self, memory_fraction: float = DEFAULT_MEMORY_FRACTION) -> float:
        """The memory bound M the planner gets under this topology.

        Per-device memory bounds the plan: a data-parallel replica holds
        the *whole* pipeline footprint, so extra data-parallel devices add
        throughput, never budget. Only the model axis — stages spread
        across devices — multiplies the bound.
        """
        return memory_fraction * self.memory_per_device * self.model_parallel

    # -- elasticity --------------------------------------------------------
    def shrink(self, lost_devices: int = 1) -> "DeviceTopology":
        """The topology after losing ``lost_devices`` devices.

        The surviving devices re-mesh: the model axis is kept when it
        still divides the survivor count, otherwise it collapses to 1
        (stage span cannot straddle a hole); the data axis takes the rest.
        Shrinking below one device raises — there is nothing to replan on.
        """
        survivors = self.device_count - int(lost_devices)
        if survivors < 1:
            raise ValueError(
                f"cannot shrink {self.device_count} devices by {lost_devices}"
            )
        model = self.model_parallel if survivors % self.model_parallel == 0 else 1
        return dataclasses.replace(
            self,
            device_count=survivors,
            mesh_shape=(survivors // model, model),
        )

    # -- mesh construction -------------------------------------------------
    def mesh(self, axis_names: Tuple[str, str] = ("data", "model")):
        """A jax ``Mesh`` over the first ``device_count`` visible devices.

        Built lazily (never at import, never in ``discover``) so topology
        objects stay cheap, picklable metadata; a shrunken topology meshes
        over the surviving prefix of ``jax.devices()``.
        """
        import jax

        devices = jax.devices()
        if len(devices) < self.device_count:
            raise RuntimeError(
                f"topology wants {self.device_count} devices but only "
                f"{len(devices)} are visible"
            )
        arr = np.array(devices[: self.device_count]).reshape(self.mesh_shape)
        return jax.sharding.Mesh(arr, axis_names)


def as_topology(value) -> Optional[DeviceTopology]:
    """Normalize a topology argument: ``None`` stays ``None`` (legacy
    single-device path), ``"discover"`` runs discovery, a ``DeviceTopology``
    passes through."""
    if value is None or isinstance(value, DeviceTopology):
        return value
    if value == "discover":
        return DeviceTopology.discover()
    raise TypeError(
        f"topology= accepts None, 'discover' or a DeviceTopology, got {value!r}"
    )
