from repro.runtime.supervisor import Supervisor, SupervisorCfg
from repro.runtime.elastic import ElasticPlanner

__all__ = ["Supervisor", "SupervisorCfg", "ElasticPlanner"]
