from repro.core.ferret import EngineCache
from repro.runtime.elastic import ClusterSpec, DeviceLossError, ElasticPlanner
from repro.runtime.elastic_trainer import (
    BudgetEvent,
    ElasticRun,
    ElasticStreamResult,
    ElasticStreamTrainer,
    ResumeState,
    SegmentReport,
)
from repro.runtime.supervisor import Supervisor, SupervisorCfg
from repro.runtime.topology import DeviceTopology, as_topology

__all__ = [
    "BudgetEvent",
    "ClusterSpec",
    "DeviceLossError",
    "DeviceTopology",
    "ElasticPlanner",
    "ElasticRun",
    "ElasticStreamResult",
    "ElasticStreamTrainer",
    "EngineCache",
    "ResumeState",
    "SegmentReport",
    "Supervisor",
    "SupervisorCfg",
    "as_topology",
]
