from repro.core.ferret import EngineCache
from repro.runtime.elastic import ClusterSpec, DeviceLossError, ElasticPlanner
from repro.runtime.elastic_trainer import (
    BudgetEvent,
    ElasticRun,
    ElasticStreamResult,
    ElasticStreamTrainer,
    ResumeState,
    SegmentReport,
)
from repro.runtime.supervisor import Supervisor, SupervisorCfg

__all__ = [
    "BudgetEvent",
    "ClusterSpec",
    "ElasticRun",
    "DeviceLossError",
    "ElasticPlanner",
    "ElasticStreamResult",
    "ElasticStreamTrainer",
    "EngineCache",
    "ResumeState",
    "SegmentReport",
    "Supervisor",
    "SupervisorCfg",
]
