"""Fault-tolerant step-loop supervisor.

Wraps any jitted step function with the failure handling a 1000-node OCL
deployment needs:

- **NaN/Inf detection**  — a poisoned update (bad batch, numeric blow-up,
  silent data corruption — SDC) triggers a rollback to the last checkpoint
  instead of propagating garbage into the stream-serving model.
- **Timeout / crash detection** — steps that exceed a deadline count as
  failures (on a real pod: a missing heartbeat from a host). After
  ``max_retries`` consecutive failures the supervisor escalates to the
  elastic planner (runtime/elastic.py) to re-plan on fewer resources.
- **Straggler mitigation is admission control** — uniquely for OCL, a slow
  step does not stall the system: the data pipeline's bounded queue drops
  stale items (the paper's 1-Skip semantics), so the supervisor only has to
  keep the *model* healthy, not the stream. The dropped count is reported
  per step for the adaptation-rate accounting.
- **Exactly-once stream consumption** — the stream cursor rides inside the
  checkpoint extras; a restart resumes the source where the checkpoint
  left it, so no item is silently skipped or double-trained.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpointing.checkpoint import CheckpointManager
from repro.faults import TransientFaultError
from repro.runtime.elastic import DeviceLossError

Pytree = Any

# Errors worth retrying in place: numeric blow-ups roll back to the last
# checkpoint, deadline misses and transient device hiccups just re-run the
# attempt. Everything else (bar DeviceLossError, which escalates to a
# shrink-replan) is persistent — a bug or a broken environment that retries
# cannot fix — and is surfaced immediately with no retry burn-down.
_TRANSIENT = (FloatingPointError, TimeoutError, TransientFaultError)


@dataclasses.dataclass(frozen=True)
class SupervisorCfg:
    checkpoint_dir: str
    checkpoint_every: int = 100
    keep: int = 3
    step_timeout_s: float = 300.0
    max_retries: int = 3
    nan_check_every: int = 10  # device->host sync cadence for the NaN probe
    backoff_base_s: float = 0.0  # 0 disables sleeping between retries
    backoff_cap_s: float = 30.0


@dataclasses.dataclass
class StepReport:
    step: int
    loss: float
    restarted: bool
    dropped_items: int
    duration_s: float


class Supervisor:
    def __init__(
        self,
        cfg: SupervisorCfg,
        step_fn: Callable,  # (state, batch) -> (state, metrics dict with 'loss')
        init_state: Pytree,
        on_fatal: Optional[Callable] = None,  # escalate to elastic re-plan
        extras_hook: Optional[Callable[[Dict], None]] = None,
    ):
        self.cfg = cfg
        self.step_fn = step_fn
        self.state = init_state
        self.on_fatal = on_fatal
        # receives checkpoint extras (stream cursor, replay buffer) on every
        # restore — including mid-run rollbacks, where dropping them would
        # silently double-train items and break exactly-once
        self.extras_hook = extras_hook
        self.manager = CheckpointManager(
            cfg.checkpoint_dir, keep=cfg.keep, every_steps=cfg.checkpoint_every
        )
        self.step = 0
        self.failures = 0
        # Cumulative device-loss accounting: each DeviceLossError escalation
        # adds its ``lost_devices`` here, so a driver can see how far the
        # topology has shrunk across the run's whole lifetime.
        self.device_losses = 0

    # ------------------------------------------------------------------
    def try_restore(self, extras_hook: Optional[Callable[[Dict], None]] = None) -> bool:
        try:
            state, step, extras = self.manager.restore_latest(self.state)
        except FileNotFoundError:
            return False
        self.state = state
        self.step = step
        hook = extras_hook or self.extras_hook
        if hook:
            hook(extras)
        return True

    # ------------------------------------------------------------------
    def _backoff(self) -> None:
        if self.cfg.backoff_base_s <= 0:
            return
        delay = self.cfg.backoff_base_s * (2 ** max(0, self.failures - 1))
        time.sleep(min(delay, self.cfg.backoff_cap_s))

    def run_step(self, batch: Dict, extras: Optional[Dict] = None, dropped: int = 0) -> StepReport:
        restarted = False
        for attempt in range(self.cfg.max_retries + 1):
            # per-attempt deadline: a retry must not inherit the failed
            # attempt's elapsed time, or it spuriously re-times-out
            t0 = time.time()
            try:
                new_state, metrics = self.step_fn(self.state, batch)
                loss = metrics["loss"]
                if self.step % self.cfg.nan_check_every == 0:
                    loss_val = float(jax.device_get(loss))
                    if not np.isfinite(loss_val):
                        raise FloatingPointError(f"non-finite loss {loss_val} @ step {self.step}")
                else:
                    loss_val = float("nan")  # not synced this step
                dt = time.time() - t0
                if dt > self.cfg.step_timeout_s:
                    raise TimeoutError(f"step took {dt:.1f}s > {self.cfg.step_timeout_s}s")
                # success
                self.state = new_state
                self.step += 1
                self.failures = 0
                if self.manager.should_save(self.step):
                    self.manager.save_async(self.step, self.state, extras)
                return StepReport(self.step, loss_val, restarted, dropped, dt)
            except DeviceLossError as e:
                # Lost capacity cannot come back through retries: escalate
                # immediately so the handler can request a topology shrink —
                # the elastic trainer rebuilds its mesh over the survivors
                # (e.lost_devices of them gone), replans under the smaller
                # per-device budget, and remaps live EngineState — then
                # surface the error to the caller, which rebuilds on the
                # smaller footprint.
                self.failures = 0
                self.device_losses += getattr(e, "lost_devices", 1)
                if self.on_fatal is not None:
                    self.on_fatal(e)
                raise
            except _TRANSIENT as e:
                self.failures += 1
                restarted = True
                if self.failures > self.cfg.max_retries:
                    if self.on_fatal is not None:
                        self.on_fatal(e)
                    raise
                if isinstance(e, TransientFaultError):
                    # raised before any side effect (the error taxonomy's
                    # contract): the current state is clean, just re-attempt
                    self._backoff()
                    continue
                # numeric poison / deadline miss: roll back to the last good
                # checkpoint, handing extras (stream cursor, replay buffer)
                # back through the same hook as try_restore — exactly-once
                try:
                    self.state, self.step, rb_extras = self.manager.restore_latest(self.state)
                    if self.extras_hook:
                        self.extras_hook(rb_extras)
                except FileNotFoundError:
                    pass  # no checkpoint yet: retry from current state
                self._backoff()
            except Exception as e:
                # persistent failure (bug, broken env): retries cannot fix
                # it — surface immediately without burning the retry budget
                if self.on_fatal is not None:
                    self.on_fatal(e)
                raise
        raise RuntimeError("unreachable")

    # ------------------------------------------------------------------
    def finalize(self, extras: Optional[Dict] = None) -> None:
        self.manager.save_async(self.step, self.state, extras)
        self.manager.wait()
