"""Budget-elastic streaming trainer: live re-plan + state remap (paper §5.2).

Ferret's headline claim is adaptivity to *varying* memory budgets (Ferret_M,
Alg. 2+3), but a plan is chosen once per run everywhere else in the repo.
This module runs one stream in **segments**: when the memory budget changes
mid-stream — a scheduled ``BudgetEvent``, a callback, or a simulated device
loss escalated through ``Supervisor.on_fatal`` — it

  1. re-enters the planner for the new budget (Alg. 3 ∘ Alg. 2),
  2. rebuilds the ``EngineSchedule``/``FerretEngine`` for the new partition
     (the worker-interleave ``phase`` continues from the stream cursor), and
  3. **remaps live state across partition boundaries** through
     ``repro.state.StateRemapper``: stage params are merged
     (``T.merge_stage_params``) and re-split on the new
     ``plan.partition.bounds``; per-parameter optimizer moments,
     Iter-Fisher λ statistics, *and the gradient-accumulation/Δθ rings*
     all travel with them — no learned or in-flight state is thrown
     away. Across *same-schedule* boundaries (stage count and pipeline
     config unchanged — segment caps, callable polls, A→A switches, and
     bounds-only re-partitions) each segment runs a slice of one
     per-structure schedule build (``slice_schedule``; construction is
     causal, so slicing one big build *is* the continuation) and the
     rings continue — remapped slot-wise when the bounds moved. A
     schedule-*restarting* switch (stage count or config changed)
     flushes every in-flight accumulation group into the weights before
     the remap, so ``rounds_lost_per_switch == 0`` either way; the only
     way to drop in-flight rounds is the explicit
     ``carry_rings=False`` escape hatch, which reports what it dropped.

Compile-once hot path: engines are cached in an ``EngineCache`` keyed on
``(partition bounds, ring geometry, bucketed segment length)``. Segment
lengths are padded up to a small geometric bucket set with *inert*
schedule rounds (identity on engine state), so repeated and A→B→A budget
switches reuse already-compiled scans instead of re-tracing; hit/miss
counts ride in ``ElasticStreamResult``.

Incremental streaming: ``run_stream`` consumes a ``StreamSource`` directly
(a dict-of-arrays is wrapped in a compat ``ArrayStreamSource``). The
segment loop pulls ``take(segment_rounds)`` per segment through a
``BufferedStreamSource`` feeder — peak stream residency is
O(segment_rounds + prefetch window) on host *and* device, never O(R) —
and prefetches segment k+1 on a background thread while segment k runs on
device. Unknown stream length (``length=None``) works end to end: the
per-structure schedule is grown causally (a longer ``build_schedule`` is
bit-identical on its prefix — the same continuation ``warmup=`` computes),
and the run ends when the source does. The algorithm's pipeline-path
stream preparation (``prepare_stream``: ER replay mixing, LwF teacher
logits) is applied per pulled chunk, exactly once and in stream order, so
the incremental run is bit-exact with the materialized whole-stream
preparation.

The stream cursor advances only when a segment completes: the feeder
retains every handed-out round until the segment is acked, so a failed or
re-planned segment replays the *same* rounds from the retained buffer —
no item is lost and none is consumed twice, without requiring ``seek`` on
unbounded sources.

A crashed run resumes the same way: ``load_resume_state`` reads the newest
per-segment checkpoint (state + the partition it was split on + the stream
cursor from the manifest extras), remaps it onto whatever partition the
*restart's* budget plans, and ``run_stream(..., resume=...)`` continues
from the saved cursor — seekable sources are positioned there; a live
(non-seekable) source must already be positioned at the resume cursor.
Every stream item is still consumed exactly once.

Note: this trainer is the internal engine behind the ``"elastic"`` runner
of ``repro.api.FerretSession`` — prefer the session layer for new code.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import math
import os
import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.streams import (
    BufferedStreamSource,
    LimitedStreamSource,
    StreamSource,
    coerce_trainer_stream,
)
from repro.checkpointing.checkpoint import (
    CheckpointCorruptError,
    checkpoint_schema,
    latest_checkpoint,
    plan_manifest,
    restore_checkpoint,
    save_checkpoint,
    verify_checkpoint,
)
from repro.faults import TransientFaultError
from repro import faults as faults_lib
from repro.core import compensation as comp_lib
from repro.core import planner as planner_lib
from repro.core import schedule as sched_lib
from repro.core.ferret import (
    EngineCache,
    FerretConfig,
    IdentityKey,
    StreamResult,
    empirical_adaptation_rate,
    split_penalty_extras,
    stage_penalty_fn,
)
from repro.core.pipeline import FerretEngine, staged_from_transformer
from repro.core.profiler import ModelProfile, profile_for
from repro.core.schedule import RingGeometry
from repro.models import shard_hints as shard_hints_lib
from repro.models.config import ModelConfig
from repro.ocl.registry import OCLAlgorithm, PrepareContext, get_algorithm
from repro.optim.optimizers import Optimizer, adamw
from repro.runtime.elastic import DeviceLossError
from repro.runtime.supervisor import Supervisor, SupervisorCfg
from repro.state import StateRemapper
from repro.state import remap as state_remap
from repro.state.engine_state import EngineState

Pytree = Any
BudgetSchedule = Union[Sequence["BudgetEvent"], Callable[[int], Optional[float]]]

# A segment that keeps losing devices faster than shrink-replans can help is
# a cluster problem, not a planning problem — surface it instead of looping.
_MAX_FAULTS_PER_SEGMENT = 5


@dataclasses.dataclass(frozen=True)
class BudgetEvent:
    """From stream round ``round`` on, the memory budget is ``budget_bytes``."""

    round: int
    budget_bytes: float


@dataclasses.dataclass
class SegmentReport:
    start: int  # first stream round of the segment (inclusive)
    end: int  # one past the last round
    budget_bytes: float
    replanned: bool  # did this segment start with a re-plan + remap?
    replan_s: float  # host-side planner time (0.0 when not replanned)
    remap_s: float  # merge/re-split remap time (0.0 when not replanned)
    run_s: float  # engine build + compile + scan wall time
    result: StreamResult
    cache_hit: bool = False  # compiled scan reused from the engine cache
    rounds_compiled: int = 0  # bucketed scan length this segment ran under
    take_s: float = 0.0  # wall time blocked pulling this segment's rounds
    # in-flight accumulated backward rounds discarded entering this segment
    # (0 on the default lossless path: rings are carried or flushed; only
    # the carry_rings=False escape hatch, or a geometry-mismatched resume,
    # can make this non-zero)
    rounds_lost: int = 0


@dataclasses.dataclass
class ElasticStreamResult:
    segments: List[SegmentReport]
    online_acc: float
    online_acc_curve: np.ndarray  # continuous across segments (no restart)
    losses: np.ndarray
    admitted_frac: float
    empirical_rate: float  # round-weighted across segments
    final_params: Pytree
    rounds: int  # stream rounds consumed this run (each exactly once)
    num_replans: int
    num_faults: int
    engine_cache_hits: int = 0  # compiled-scan reuses during this run
    engine_cache_misses: int = 0  # fresh compiles during this run
    peak_buffered_rounds: int = 0  # max stream rounds resident in the feeder
    stream_wait_s: float = 0.0  # total un-overlapped time blocked on the source
    # max over segments of SegmentReport.rounds_lost: 0 means every switch
    # this run made was lossless (in-flight rings carried or flushed)
    rounds_lost_per_switch: int = 0


# ---------------------------------------------------------------------------
# State remap across partition boundaries — moved to repro.state.
# The old import paths below keep working but warn; new code should use
# repro.state.StateRemapper / repro.state.remap_* directly.
# ---------------------------------------------------------------------------


def _deprecated_remap(name: str, target: Callable) -> Callable:
    @functools.wraps(target)
    def wrapper(*args, **kwargs):
        warnings.warn(
            f"repro.runtime.elastic_trainer.{name} moved to "
            f"repro.state.{name}; this alias will be removed",
            DeprecationWarning,
            stacklevel=2,
        )
        return target(*args, **kwargs)

    wrapper.__name__ = name
    wrapper.__qualname__ = name
    return wrapper


remap_stage_params = _deprecated_remap(
    "remap_stage_params", state_remap.remap_stage_params
)
remap_opt_states = _deprecated_remap(
    "remap_opt_states", state_remap.remap_opt_states
)
remap_comp_states = _deprecated_remap(
    "remap_comp_states", state_remap.remap_comp_states
)


def remap_engine_state(
    model_cfg: ModelConfig,
    engine_state,
    old_bounds,
    new_bounds,
    optimizer: Optimizer,
):
    """Deprecated: use ``repro.state.StateRemapper`` instead.

    This legacy helper keeps its historical contract — it returns only
    ``(stage_params, opt_states, comp_states)`` and **drops the rings** —
    but no longer does so silently: the warning below names the lossless
    replacement. ``StateRemapper.remap`` carries (or flushes) the rings
    and reports ``rounds_lost``; ``carry_rings=False`` is its documented
    escape hatch for the old behavior.
    """
    warnings.warn(
        "repro.runtime.elastic_trainer.remap_engine_state drops the "
        "gradient-accumulation/Δθ rings; use repro.state.StateRemapper "
        "for a lossless remap (carry_rings=False reproduces this "
        "behavior explicitly)",
        DeprecationWarning,
        stacklevel=2,
    )
    stages, _rings, _deltas, opts, comps = engine_state
    new_sp = state_remap.remap_stage_params(model_cfg, list(stages), new_bounds)
    new_opts = state_remap.remap_opt_states(
        model_cfg, opts, old_bounds, new_bounds, optimizer, new_sp
    )
    new_comps = state_remap.remap_comp_states(
        model_cfg, comps, old_bounds, new_bounds
    )
    return new_sp, new_opts, new_comps


# ---------------------------------------------------------------------------
# Crash-restore: checkpointed state → a new partition
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ResumeState:
    """Live state recovered from a checkpoint, plus where it came from.

    ``bounds`` is the partition the per-stage trees are split on (from the
    checkpoint manifest); ``cursor`` is the first not-yet-consumed stream
    round. ``run_stream(..., resume=...)`` remaps onto the restart's plan.
    """

    stage_params: List[Pytree]
    opt_states: Tuple
    comp_states: Tuple
    bounds: List[int]
    cursor: int
    budget_bytes: float
    # ring plane (schema-2 checkpoints): the gradient-accumulation and Δθ
    # rings plus the schedule coordinates they are valid under. ``None``
    # rings (schema-1 checkpoints, or a geometry mismatch at resume) mean
    # the restart re-warms its accumulation from zero.
    rings: Optional[Tuple] = None
    deltas: Optional[Tuple] = None
    sched_origin: Optional[int] = None
    geometry: Optional[RingGeometry] = None


# ---------------------------------------------------------------------------
# Steppable runs
# ---------------------------------------------------------------------------

_STOP = object()  # sent into the run generator to end at a segment boundary


class ElasticRun:
    """A steppable handle over one elastic stream run.

    ``step()`` executes exactly one segment (blocking until its rounds are
    available) and returns the ``SegmentReport``, or ``None`` once the
    source is exhausted — at which point ``result()`` holds the final
    ``ElasticStreamResult``. ``stop()`` ends the run early at the current
    segment boundary with everything consumed so far accounted. This is
    the primitive the multi-tenant ``FerretServer`` interleaves across
    tenants: one ``step()`` per scheduling decision, budget re-divisions
    landing through ``trainer.request_budget`` between steps.
    """

    def __init__(self, trainer: "ElasticStreamTrainer", gen, params: Pytree):
        self.trainer = trainer
        self._gen = gen
        self._params = params
        self._started = False
        self._finished = False
        self._broken = False  # an exception escaped the segment generator
        self._result: Optional[ElasticStreamResult] = None
        self.segments: List[SegmentReport] = []

    @property
    def finished(self) -> bool:
        return self._finished

    @property
    def broken(self) -> bool:
        """Did an exception escape a ``step()``? A broken run cannot step
        again (the generator is dead) — ``abort()`` salvages a partial
        result from the segments that did complete."""
        return self._broken

    def buffered_rounds(self) -> int:
        """Rounds pulled into the run's feeder and not yet consumed."""
        feeder = self.trainer._feeder
        return 0 if feeder is None else feeder.pending_round_count()

    def step(self) -> Optional[SegmentReport]:
        """Run exactly one segment; ``None`` once the source is exhausted."""
        if self._finished:
            return None
        try:
            self._started = True
            report = self._gen.send(None)  # None = keep going (starts the gen)
        except StopIteration as stop:
            self._finished = True
            self._result = stop.value
            return None
        except BaseException:
            # the generator is dead (its finally already closed the
            # feeder); mark it so abort() can salvage a partial result
            self._broken = True
            raise
        self.segments.append(report)
        return report

    def stop(self) -> ElasticStreamResult:
        """End the run at the current segment boundary.

        Every round consumed so far stays accounted (exactly-once); an
        unstarted run returns an empty result without touching the source.
        """
        if self._finished:
            return self._result
        self._finished = True
        if not self._started:
            self._gen.close()
            self._result = _empty_elastic_result(self._params)
            return self._result
        try:
            self._gen.send(_STOP)
        except StopIteration as stop:
            self._result = stop.value
        else:  # pragma: no cover — the generator always honors _STOP
            self._gen.close()
            raise RuntimeError("elastic run generator ignored the stop request")
        return self._result

    def abort(self) -> ElasticStreamResult:
        """End the run even after an escaped exception, losing nothing
        already accounted.

        A healthy run stops at the current boundary (same as ``stop()``).
        A broken run's generator is dead, so the completed segments are
        re-assembled into a partial ``ElasticStreamResult`` — the server's
        tenant-quarantine path uses this so one crashing tenant still
        returns what it finished instead of poisoning the serve loop.
        """
        if self._finished:
            return self._result
        if not self._broken:
            return self.stop()
        self._finished = True
        self._gen.close()
        self._result = self._salvage_result()
        return self._result

    def _salvage_result(self) -> ElasticStreamResult:
        segs = self.segments
        if not segs:
            return _empty_elastic_result(self._params)
        # per-segment curves are cumulative within the segment; invert to
        # raw per-round accuracies, then rebuild the continuous curve
        accs = []
        for s in segs:
            c = np.asarray(s.result.online_acc_curve, dtype=np.float64)
            n = np.arange(1, c.size + 1)
            raw = c * n
            raw[1:] -= c[:-1] * n[:-1]
            accs.append(raw)
        acc_cat = np.concatenate(accs)
        consumed = sum(s.end - s.start for s in segs)
        rs = self.trainer.live_resume_state()
        if rs is not None:
            from repro.models import transformer as T

            final_params = T.merge_stage_params(
                self.trainer.model_cfg, list(rs.stage_params)
            )
        else:
            final_params = self._params
        admitted = sum(
            s.result.admitted_frac * (s.end - s.start) for s in segs
        ) / max(consumed, 1)
        rate = sum(
            s.result.empirical_rate * (s.end - s.start) for s in segs
        ) / max(consumed, 1)
        return ElasticStreamResult(
            segments=list(segs),
            online_acc=float(acc_cat.mean()),
            online_acc_curve=np.cumsum(acc_cat) / np.arange(1, acc_cat.size + 1),
            losses=np.concatenate([np.asarray(s.result.losses) for s in segs]),
            admitted_frac=admitted,
            empirical_rate=rate,
            final_params=final_params,
            rounds=int(consumed),
            num_replans=sum(1 for s in segs if s.replanned),
            num_faults=0,  # fault count lived in the dead generator
            rounds_lost_per_switch=max(
                (s.rounds_lost for s in segs), default=0
            ),
        )

    def result(self) -> ElasticStreamResult:
        if not self._finished:
            raise RuntimeError(
                "run still open: step() to exhaustion or stop() first"
            )
        return self._result

    def close(self) -> None:
        """``stop()`` that is safe to call on an already-finished run."""
        if not self._finished:
            if self._broken:
                self.abort()
            else:
                self.stop()


def _empty_elastic_result(params: Pytree) -> ElasticStreamResult:
    return ElasticStreamResult(
        segments=[], online_acc=0.0, online_acc_curve=np.zeros(0),
        losses=np.zeros(0), admitted_frac=0.0, empirical_rate=0.0,
        final_params=params, rounds=0, num_replans=0, num_faults=0,
    )


# ---------------------------------------------------------------------------
# The elastic trainer
# ---------------------------------------------------------------------------


class ElasticStreamTrainer:
    """Runs one stream across a schedule of memory budgets, re-planning and
    remapping live state at every budget change instead of restarting."""

    def __init__(
        self,
        model_cfg: ModelConfig,
        ferret_cfg: FerretConfig,
        batch: int,
        seq: int,
        optimizer: Optional[Optimizer] = None,
        profile: Optional[ModelProfile] = None,
        algorithm: Optional[Union[str, OCLAlgorithm]] = None,
        engine_cache: Optional[EngineCache] = None,
        carry_rings: bool = True,
        topology=None,
    ):
        from repro.runtime.topology import as_topology

        self.model_cfg = model_cfg
        self.cfg = ferret_cfg
        self.batch = batch
        self.seq = seq
        # Topology-aware execution: ``topology`` (a DeviceTopology or
        # "discover") bounds every plan by per-device memory, scales the
        # profile for the data-parallel replicas, runs the engine scans
        # under the topology's mesh, and turns a DeviceLossError into a
        # topology *shrink* (request_shrink) instead of a budget scale.
        # topology=None — and a trivial 1-device topology — is the exact
        # historical single-device path.
        self.topology = as_topology(topology)
        self._mesh = (
            None
            if self.topology is None or self.topology.is_trivial
            else self.topology.mesh()
        )
        self._shard_hints = shard_hints_lib.for_topology(self.topology)
        # store-aware default (Alg. 3 profile(θ)): a persisted on-device
        # measurement for this geometry wins, analytic roofline otherwise.
        # Kept *single-device*: plan_for applies the topology scaling, so a
        # topology shrink replans from the right per-replica numbers.
        self.profile = profile or profile_for(model_cfg, batch, seq)
        self.t_d = ferret_cfg.t_d or planner_lib.default_data_interval(
            self._effective_profile()
        )
        self.optimizer = optimizer or adamw(lr=ferret_cfg.lr)
        self.algorithm = (
            get_algorithm(algorithm, ferret_cfg.ocl)
            if algorithm is not None
            else get_algorithm(ferret_cfg.ocl)
        )
        # carry_rings=False is the documented escape hatch back to the
        # pre-refactor behavior: every re-plan drops the in-flight
        # gradient-accumulation/Δθ rings instead of carrying or flushing
        # them, and the discarded backward rounds are reported per segment
        # as SegmentReport.rounds_lost. Default True: lossless switches.
        self.carry_rings = bool(carry_rings)
        self._remapper = StateRemapper(model_cfg, self.optimizer)
        # Compiled engines survive across run_stream calls on one trainer;
        # pass a shared EngineCache to also share across trainers, or
        # EngineCache(enabled=False) to disable bucketing + reuse.
        self.engine_cache = engine_cache or EngineCache()
        # Cache-key scope: a compiled engine bakes in the model, the
        # algorithm's loss wrapper, the optimizer update rule, lr and
        # compensation config — trainers differing in any of these must
        # never share an engine through a shared EngineCache, even for
        # equal bounds. The scope is *structural* where structure is
        # exact (frozen model config, the algorithm's engine_fingerprint,
        # the optimizer's hyperparameter fingerprint), so same-geometry
        # tenants built from separate-but-equal pieces share one compile;
        # a fingerprint-less optimizer falls back to IdentityKey, which
        # pins the referent so a recycled id can never alias.
        self._cache_scope = self._compute_cache_scope()
        self._pending_budget: Optional[float] = None
        # a topology shrink requested between segments (Supervisor.on_fatal
        # / request_shrink): consumed at the next boundary, where the mesh,
        # cache scope and plan all rebuild over the survivors
        self._pending_topology = None
        # memo for the per-stage split of the algorithm's penalty extras:
        # (bounds, extras dict, split) — recomputed only when the anchor
        # objects or the partition change, so steady-state segments skip
        # the O(model) re-split/re-upload (the entry pins the keyed
        # objects, so identity comparison cannot alias a recycled id)
        self._penalty_split: Optional[Tuple] = None
        # live-run snapshot read by fatal_handler: initialized here so a
        # Supervisor wired *before* the first segment (or between runs) can
        # escalate a device loss into a shrink request instead of tripping
        # over attributes that only exist once run_stream is underway
        self._current_budget: float = float(ferret_cfg.budget_bytes)
        self._current_plan: Optional[planner_lib.Plan] = None
        self._prep_ctx: Optional[PrepareContext] = None
        # the live run's feeder (set while a run/_run_gen is underway):
        # schedulers peek its pending-round count to size segments
        self._feeder: Optional[BufferedStreamSource] = None
        # end-of-segment state snapshot for graceful drain (see
        # live_resume_state / save_live_checkpoint)
        self._live_resume: Optional[ResumeState] = None

    # -- budget control ---------------------------------------------------
    def request_budget(self, budget_bytes: float) -> None:
        """Ask for a re-plan at the next segment boundary (fault path).

        This is what a ``Supervisor.on_fatal`` handler calls when a device
        loss shrinks the cluster: the current segment's failed attempt is
        abandoned (state unchanged), and the re-run happens under the new
        budget from the same stream cursor.
        """
        self._pending_budget = float(budget_bytes)

    def request_shrink(self, lost_devices: int = 1) -> None:
        """Ask for a topology shrink at the next segment boundary.

        This is the device-loss escalation under a discovered topology:
        the trainer's ``DeviceTopology`` loses ``lost_devices`` devices,
        and at the boundary the mesh is rebuilt over the survivors, the
        planner re-enters under the shrunken topology's per-device budget
        and re-scaled profile, and live ``EngineState`` remaps through
        ``StateRemapper`` (``rounds_lost == 0`` on the default lossless
        path). Raises when the trainer has no topology (use
        ``request_budget`` / ``fatal_handler``'s scale path) or when no
        device would survive.
        """
        if self.topology is None:
            raise RuntimeError(
                "request_shrink needs a topology-aware trainer "
                "(ElasticStreamTrainer(topology=...)); use request_budget "
                "for scalar budget shrinks"
            )
        self._pending_topology = self.topology.shrink(lost_devices)

    def fatal_handler(self, scale: float = 0.5) -> Callable[[BaseException], None]:
        """An ``on_fatal`` callback for device-loss escalation.

        Topology-aware trainers turn a ``DeviceLossError`` into a topology
        shrink (``request_shrink(e.lost_devices)``): mesh, plan and cache
        scope rebuild over the surviving devices at the next boundary.
        Without a topology — or when nothing would survive the shrink —
        the legacy policy applies: ``scale`` models the surviving fraction
        of the cluster and shrinks the budget. Under an unconstrained
        budget (Ferret_M+) that shrink is taken relative to the live
        plan's actual footprint — ``inf × scale`` would be a no-op.
        """

        def handler(exc: BaseException) -> None:
            if (
                self.topology is not None
                and not self.topology.is_trivial
                and isinstance(exc, DeviceLossError)
            ):
                try:
                    self.request_shrink(getattr(exc, "lost_devices", 1))
                    return
                except ValueError:
                    pass  # no survivors: fall through to the budget scale
            base = self._current_budget
            if not math.isfinite(base):
                # before the first segment no plan snapshot exists yet —
                # plan for the configured budget instead of crashing
                plan = self._current_plan or self.plan_for(base)
                base = plan.memory
            self.request_budget(base * scale)

        return handler

    def _effective_profile(self) -> ModelProfile:
        """The profile the planner sees: topology-scaled when one is set
        (times and activations divide by the data-parallel width, weights
        replicate), the raw single-device profile otherwise."""
        if self.topology is None:
            return self.profile
        from repro.profile.bridge import for_topology

        return for_topology(self.profile, self.topology)

    def _compute_cache_scope(self) -> Tuple:
        # Cache-key scope: a compiled engine bakes in the model, the
        # algorithm's loss wrapper, the optimizer update rule, lr,
        # compensation config — and, when topology-aware, the topology it
        # was partitioned over (a shrink must never reuse an executable
        # compiled for the lost mesh). The scope is *structural* where
        # structure is exact (frozen model config, the algorithm's
        # engine_fingerprint, the optimizer's hyperparameter fingerprint),
        # so same-geometry tenants built from separate-but-equal pieces
        # share one compile; a fingerprint-less optimizer falls back to
        # IdentityKey, which pins the referent so a recycled id can never
        # alias.
        opt_fp = self.optimizer.fingerprint
        scope = (
            self.model_cfg,
            self.algorithm.engine_fingerprint(),
            opt_fp if opt_fp is not None else IdentityKey(self.optimizer),
            self.cfg.lr,
            self.cfg.compensation,
        )
        if self.topology is not None:
            scope = scope + (self.topology.fingerprint(),)
        return scope

    def _set_topology(self, topology) -> None:
        """Swap the live topology (a consumed shrink): rebuild the mesh
        over the survivors and re-key the engine cache so the next segment
        compiles — and future same-topology segments reuse — executables
        partitioned for the new world."""
        self.topology = topology
        self._mesh = None if topology.is_trivial else topology.mesh()
        self._shard_hints = shard_hints_lib.for_topology(topology)
        self._cache_scope = self._compute_cache_scope()
        if self.cfg.t_d is None:
            self.t_d = planner_lib.default_data_interval(
                self._effective_profile()
            )

    def plan_for(self, budget_bytes: float) -> planner_lib.Plan:
        return planner_lib.plan(
            self._effective_profile(),
            self.t_d,
            budget_bytes,
            c=self.cfg.decay_c,
            V_D=self.cfg.data_value,
            max_workers=self.cfg.max_workers,
            max_stages=self.cfg.max_stages,
            topology=self.topology,
        )

    # -- main entry -------------------------------------------------------
    def run_stream(
        self,
        params: Pytree,
        stream: Union[Dict[str, np.ndarray], StreamSource],
        schedule: BudgetSchedule = (),
        *,
        segment_rounds: Optional[Union[int, Callable[[int], int]]] = None,
        supervisor_cfg: Optional[SupervisorCfg] = None,
        fault_rounds: Sequence[int] = (),
        fault_budget_scale: float = 0.5,
        resume: Optional[ResumeState] = None,
        prefetch: bool = True,
    ) -> ElasticStreamResult:
        """Run a stream across the budget ``schedule``, segment by segment.

        stream: a ``StreamSource`` (consumed incrementally — rounds are
        pulled per segment, never materialized up front) or a dict of
        ``(R, b, ...)`` arrays (compat; wrapped in an ``ArrayStreamSource``
        and still consumed per segment). Unbounded sources
        (``length=None``) run until the source ends; cap them upstream
        (``LimitedStreamSource`` / ``session.run(max_rounds=...)``) for a
        bounded run. The algorithm's ``prepare_stream`` is applied per
        pulled chunk, exactly once, in stream order — pass *raw* rounds,
        not pre-prepared ones.
        schedule: ``BudgetEvent`` list (budget switches at fixed rounds) or a
        callable ``round -> budget_bytes | None`` polled at segment
        boundaries (None keeps the current budget).
        segment_rounds: optional cap on segment length; callable schedules
        and fault injection are only observed at segment boundaries, so this
        bounds their reaction latency. Defaults to 16 for callable
        schedules and for unbounded sources (which need finite segments).
        May itself be a callable ``cursor -> rounds`` re-evaluated at every
        boundary — how the multi-tenant server sizes segments to what a
        live feed has actually buffered instead of blocking a shared serve
        loop on a fixed-size ``take``.
        supervisor_cfg: when given, every segment executes as one supervised
        step — NaN rollback, retries, async checkpoints (plan + cursor in
        the manifest extras), and ``on_fatal`` escalation all active.
        fault_rounds: stream rounds at which a device loss is simulated
        (each fires once); the escalation path shrinks the budget by
        ``fault_budget_scale`` and re-plans. The failed segment re-runs
        from the feeder's retained buffer — exactly-once without ``seek``.
        resume: state recovered by ``load_resume_state`` — the run starts
        at ``resume.cursor`` with the checkpointed state remapped from
        ``resume.bounds`` onto this run's planned partition. Seekable
        sources (arrays) are positioned at the cursor; a live feed must
        already be positioned there.
        prefetch: pull segment k+1 from the source on a background thread
        while segment k runs on device.
        """
        run = self.open_stream(
            params, stream, schedule,
            segment_rounds=segment_rounds, supervisor_cfg=supervisor_cfg,
            fault_rounds=fault_rounds, fault_budget_scale=fault_budget_scale,
            resume=resume, prefetch=prefetch,
        )
        try:
            while run.step() is not None:
                pass
        finally:
            run.close()
        return run.result()

    def open_stream(
        self,
        params: Pytree,
        stream: Union[Dict[str, np.ndarray], StreamSource],
        schedule: BudgetSchedule = (),
        *,
        segment_rounds: Optional[Union[int, Callable[[int], int]]] = None,
        supervisor_cfg: Optional[SupervisorCfg] = None,
        fault_rounds: Sequence[int] = (),
        fault_budget_scale: float = 0.5,
        resume: Optional[ResumeState] = None,
        prefetch: bool = True,
    ) -> "ElasticRun":
        """Open the stream as a *steppable* run (same options as
        ``run_stream``): each ``ElasticRun.step()`` executes exactly one
        segment and returns its ``SegmentReport``; ``stop()`` ends the run
        at the current boundary with every consumed round accounted. This
        is the multiplexing primitive of the multi-tenant server — a
        scheduler interleaves ``step()`` calls across tenants, and budget
        re-divisions land through ``request_budget`` between steps.

        One trainer drives at most one open run at a time (the run borrows
        the trainer's live-state snapshot fields).
        """
        gen = self._run_gen(
            params, stream, schedule,
            segment_rounds=segment_rounds, supervisor_cfg=supervisor_cfg,
            fault_rounds=fault_rounds, fault_budget_scale=fault_budget_scale,
            resume=resume, prefetch=prefetch,
        )
        return ElasticRun(self, gen, params)

    def _run_gen(
        self,
        params: Pytree,
        stream: Union[Dict[str, np.ndarray], StreamSource],
        schedule: BudgetSchedule,
        *,
        segment_rounds,
        supervisor_cfg: Optional[SupervisorCfg],
        fault_rounds: Sequence[int],
        fault_budget_scale: float,
        resume: Optional[ResumeState],
        prefetch: bool,
    ):
        """The segment loop as a generator: yields one ``SegmentReport``
        per segment, receives ``_STOP`` to end at a boundary, and returns
        the final ``ElasticStreamResult`` (``StopIteration.value``)."""
        from repro.models import transformer as T

        source = coerce_trainer_stream(stream, "ElasticStreamTrainer.run_stream")
        events, budget_fn = self._normalize_schedule(schedule)
        pending_faults = sorted(set(int(r) for r in fault_rounds))

        origin = 0
        if resume is not None:
            origin = int(resume.cursor)
            if not _try_seek(source, origin):
                # non-seekable (live/unbounded) source: it must already be
                # positioned at the resume cursor; the feeder's retained
                # buffer still guarantees exactly-once within this run
                pass
        remaining = source.remaining
        R: Optional[int] = None if remaining is None else origin + int(remaining)
        if callable(schedule) and segment_rounds is None:
            segment_rounds = 16
        if segment_rounds is None and (R is None or _base_is_unbounded(source)):
            # a live feed needs finite segments even when a max_rounds cap
            # makes its length known — one O(R) segment would materialize
            # the whole window and defeat the O(segment) residency bound
            segment_rounds = 16

        # per-run preparation context: the algorithm's pipeline-path stream
        # prep (replay mixing, teacher logits) anchors at the params
        # entering the stream, exactly like the materialized whole-stream
        # preparation did; re-plans refresh it (see _refresh_buffered)
        self._prep_ctx = PrepareContext(
            params=params,
            forward_fn=lambda p, b: T.forward(self.model_cfg, p, b)[0],
        )
        feeder = BufferedStreamSource(
            source, transform=self._prepare_rows, prefetch=prefetch
        )
        self._feeder = feeder
        self._live_resume = None  # stale snapshot from a prior run

        event_idx = 0
        budget = self.cfg.budget_bytes
        if budget_fn is not None:
            b0 = budget_fn(0)
            budget = float(b0) if b0 is not None else budget
        while event_idx < len(events) and events[event_idx].round <= 0:
            budget = events[event_idx].budget_bytes
            event_idx += 1
        self._current_budget = budget
        plan = self.plan_for(budget)
        self._current_plan = plan
        bounds = list(plan.partition.bounds)
        opt_states: Optional[Tuple] = None  # None → engine initializes fresh
        comp_states: Optional[Tuple] = None
        cursor = origin
        # Same-structure continuation state: ``prev_plan`` is the plan the
        # carried rings are valid under, ``sched_origin`` the round its
        # schedule structure started at, and ``full_sched`` the one O(R)
        # build for that structure — each segment is a pure slice of it,
        # so host-side schedule work stays O(R) per structure instead of
        # O(R²) over the stream.
        prev_plan: Optional[planner_lib.Plan] = None
        sched_origin = cursor
        full_sched: Optional[sched_lib.EngineSchedule] = None
        rings = deltas = None
        if resume is not None:
            old_bounds = list(resume.bounds)
            geom_now = sched_lib.ring_geometry(
                plan.config, plan.partition.num_stages
            )
            if old_bounds != bounds:
                # Cross-partition restore: the checkpointed run's schedule
                # cannot be reconstructed here, so the rings do not survive
                # — params, moments and λ statistics remap; gradient
                # accumulation re-warms from zero.
                if resume.rings is not None:
                    warnings.warn(
                        "resume partition differs from the restart's plan: "
                        "checkpointed accumulation/Δθ rings were dropped; "
                        "gradient accumulation re-warms over the next "
                        f"~{geom_now.ring_size} rounds",
                        stacklevel=2,
                    )
                stage_params = state_remap.remap_stage_params(
                    self.model_cfg, list(resume.stage_params), bounds
                )
                opt_states = state_remap.remap_opt_states(
                    self.model_cfg, tuple(resume.opt_states), old_bounds,
                    bounds, self.optimizer, stage_params,
                )
                comp_states = state_remap.remap_comp_states(
                    self.model_cfg, tuple(resume.comp_states), old_bounds, bounds
                )
            else:
                stage_params = list(resume.stage_params)
                opt_states = tuple(resume.opt_states)
                comp_states = tuple(resume.comp_states)
                if (
                    resume.rings is not None
                    and resume.sched_origin is not None
                    and resume.geometry == geom_now
                ):
                    # Drain→restore continuation: same partition and ring
                    # geometry, so this run re-enters the *same* causal
                    # schedule at the saved origin — rings and Δθ history
                    # carry, making the restarted stream bit-exact with
                    # the uninterrupted one.
                    rings = tuple(resume.rings)
                    deltas = (
                        None if resume.deltas is None else tuple(resume.deltas)
                    )
                    sched_origin = int(resume.sched_origin)
                    prev_plan = plan  # prime the same-structure check
                elif resume.rings is not None:
                    warnings.warn(
                        "checkpointed rings do not match the restart's ring "
                        "geometry (or lack a schedule origin): dropped; "
                        "gradient accumulation re-warms over the next "
                        f"~{geom_now.ring_size} rounds",
                        stacklevel=2,
                    )
        else:
            stage_params = T.split_stage_params(self.model_cfg, params, bounds)

        segments: List[SegmentReport] = []
        acc_all: List[np.ndarray] = []
        loss_all: List[np.ndarray] = []
        admitted_all: List[np.ndarray] = []
        num_faults = 0
        faults_at_cursor = 0
        cache_hits0 = self.engine_cache.hits
        cache_misses0 = self.engine_cache.misses

        try:
            while R is None or cursor < R:
                # ---- budget for this segment: fault request beats the
                # schedule. Events are consumed exactly once, so a
                # fault-shrunk budget is not clobbered by re-reading an
                # already-applied event.
                target = budget
                if budget_fn is not None:
                    b = budget_fn(cursor)
                    if b is not None:
                        target = float(b)
                while event_idx < len(events) and events[event_idx].round <= cursor:
                    target = events[event_idx].budget_bytes
                    event_idx += 1
                if self._pending_budget is not None:
                    target, self._pending_budget = self._pending_budget, None
                replanned, replan_s, remap_s = False, 0.0, 0.0
                seg_rounds_lost = 0
                # A pending topology shrink forces the replan even when the
                # budget number is unchanged (a pure data-parallel loss
                # keeps the per-device bound but changes the mesh, the
                # profile scaling, and the cache scope): the survivors'
                # world replaces the lost one before planning.
                if self._pending_topology is not None:
                    topo, self._pending_topology = self._pending_topology, None
                    self._set_topology(topo)
                    do_replan = True
                else:
                    do_replan = target != budget
                if do_replan:
                    t0 = time.perf_counter()
                    new_plan = self.plan_for(target)
                    replan_s = time.perf_counter() - t0
                    new_bounds = list(new_plan.partition.bounds)
                    P_new = new_plan.partition.num_stages
                    # the schedule depends only on (config, stage count,
                    # phase) — when those survive the switch, the carried
                    # rings stay valid slot-for-slot even across a bounds
                    # change; otherwise the remapper flushes them
                    same_sched = (
                        prev_plan is not None
                        and prev_plan.partition.num_stages == P_new
                        and prev_plan.config == new_plan.config
                    )
                    t0 = time.perf_counter()
                    if opt_states is None:
                        if new_bounds != bounds:
                            # no segment ran yet: only params exist to remap
                            stage_params = state_remap.remap_stage_params(
                                self.model_cfg, stage_params, new_bounds
                            )
                    elif new_bounds != bounds or not same_sched:
                        old_sched = full_sched
                        if old_sched is None and rings is not None:
                            # resumed rings whose schedule was never built
                            # this run (a replan before the first segment):
                            # rebuild the causal prefix they were filled
                            # under so the remapper can flush/account
                            old_sched = sched_lib.build_schedule(
                                plan.config, plan.partition.num_stages,
                                max(cursor - sched_origin, 1),
                                phase=sched_origin,
                            )
                        remapped, seg_rounds_lost = self._remapper.remap(
                            EngineState(
                                stage_params=tuple(stage_params),
                                rings=rings,
                                deltas=deltas,
                                opt_states=tuple(opt_states),
                                comp_states=tuple(comp_states),
                                bounds=tuple(bounds),
                                geometry=sched_lib.ring_geometry(
                                    plan.config, plan.partition.num_stages
                                ),
                                sched_origin=sched_origin,
                            ),
                            new_bounds,
                            new_geometry=sched_lib.ring_geometry(
                                new_plan.config, P_new
                            ),
                            same_schedule=same_sched,
                            old_schedule=old_sched,
                            rounds_into_schedule=cursor - sched_origin,
                            carry_rings=self.carry_rings,
                        )
                        stage_params = list(remapped.stage_params)
                        opt_states = remapped.opt_states
                        comp_states = remapped.comp_states
                        rings = remapped.rings
                        deltas = remapped.deltas
                    remap_s = time.perf_counter() - t0
                    budget, plan, bounds, replanned = target, new_plan, new_bounds, True
                    self._current_budget = budget
                    self._current_plan = plan
                    # segment-boundary hook: the algorithm may refresh
                    # segment-constant state (e.g. the LwF teacher) — the
                    # physically buffered rounds in place, future rounds via
                    # the refreshed preparation context.
                    self._refresh_buffered(feeder, stage_params)

                # ---- pull this segment's rounds (replayed rows first)
                want = self._segment_end(cursor, R, events, segment_rounds) - cursor
                t_take = time.perf_counter()
                rows = feeder.take(want)
                take_s = time.perf_counter() - t_take
                if rows is None:
                    break  # source exhausted
                seg_len = next(iter(rows.values())).shape[0]
                seg_end = cursor + seg_len
                if seg_len < want:
                    R = seg_end  # source ended early: true stream end found
                fault_round = next(
                    (r for r in pending_faults if cursor <= r < seg_end), None
                )

                t0 = time.perf_counter()
                P = plan.partition.num_stages
                same_struct = (
                    prev_plan is not None
                    and prev_plan.partition.num_stages == P
                    and prev_plan.config == plan.config
                )
                if not same_struct:
                    # The schedule restarts here (first segment, or a
                    # stage-count/config change). Ring contents were
                    # already handled by the remapper — flushed into the
                    # weights, Δθ history re-timed — so only the schedule
                    # coordinates reset.
                    sched_origin = cursor
                    full_sched = None
                need = seg_end - sched_origin
                if full_sched is None or full_sched.num_rounds < need:
                    # one causal build per structure; segments slice it. A
                    # bounded stream builds straight to its end; an unknown
                    # end grows geometrically — construction is causal, so
                    # a longer rebuild is bit-identical on its prefix (the
                    # same continuation ``build_schedule(warmup=)``
                    # computes), and doubling keeps total host-side
                    # schedule work O(R) per structure.
                    if R is not None:
                        build_len = max(R - sched_origin, need)
                    else:
                        built = 0 if full_sched is None else full_sched.num_rounds
                        build_len = max(need, 2 * built, 64)
                    full_sched = sched_lib.build_schedule(
                        plan.config, P, build_len, phase=sched_origin
                    )
                bucket_rounds = self.engine_cache.bucket_len(seg_len)
                engine_sched = sched_lib.pad_schedule(
                    sched_lib.slice_schedule(
                        full_sched, cursor - sched_origin, seg_end - sched_origin
                    ),
                    bucket_rounds,
                )
                struct_key = (self._cache_scope, tuple(bounds))
                compile_key = struct_key + (
                    engine_sched.ring_size, engine_sched.delta_ring, bucket_rounds,
                    self.batch, self.seq, tuple(sorted(rows)),
                )

                def _factory(bounds=bounds, engine_sched=engine_sched):
                    staged = self.algorithm.wrap_staged(
                        staged_from_transformer(self.model_cfg, bounds)
                    )
                    return FerretEngine(
                        staged, engine_sched, self.optimizer,
                        self.cfg.compensation, lr=self.cfg.lr,
                        penalty_fn=stage_penalty_fn(self.algorithm),
                        mesh=self._mesh, hints=self._shard_hints,
                    )

                engine = self.engine_cache.engine_for(struct_key, _factory)
                # exec_lock spans seen → set_schedule → run → record: a
                # shared engine (multi-tenant, same geometry) never has its
                # schedule swapped under an in-flight scan, and concurrent
                # first-users cannot both count a miss for one compile
                with engine.exec_lock:
                    cache_hit = self.engine_cache.seen(compile_key)
                    engine.set_schedule(engine_sched)
                    state = engine.init_state(
                        stage_params, opt_states, comp_states,
                        rings=rings, deltas=deltas,
                        bounds=bounds, sched_origin=sched_origin,
                    )
                    # only this segment's rounds ever reach the device:
                    # stream residency stays O(segment), not O(R)
                    seg_stream = {k: jnp.asarray(v) for k, v in rows.items()}
                    if bucket_rounds > seg_len:
                        # bucket padding: repeat the last item (inert
                        # schedule rounds never admit it, so state/metrics
                        # are untouched)
                        seg_stream = {
                            k: jnp.concatenate(
                                [v, jnp.repeat(v[-1:], bucket_rounds - seg_len, axis=0)]
                            )
                            for k, v in seg_stream.items()
                        }
                    # overlap: pull segment k+1 on the host while k computes
                    if R is None or seg_end < R:
                        nxt = self._segment_end(seg_end, R, events, segment_rounds)
                        feeder.prefetch(nxt - seg_end)
                    # segment-constant penalty extras (MAS Ω/ref): re-read
                    # at every boundary so a re-plan refresh is picked up;
                    # rides the compiled scan as an argument, never a
                    # retrace
                    penalty = (
                        self._split_penalty_cached(bounds)
                        if engine.penalty_fn is not None else None
                    )
                    try:
                        final_state, ys = self._execute_segment(
                            engine, state, seg_stream, supervisor_cfg,
                            fault_round, fault_budget_scale, plan, cursor, seg_end,
                            budget, penalty, sched_origin=sched_origin,
                        )
                        if faults_at_cursor:
                            # a previously-faulted segment just completed:
                            # close out its recovery latency
                            faults_lib.resolved("engine.step")
                        faults_at_cursor = 0
                    except (DeviceLossError, TransientFaultError) as e:
                        # Re-run this segment from the same cursor — state
                        # is unchanged and the feeder re-serves the retained
                        # rows, so the stream stays exactly-once. Injected
                        # faults fire once; a genuine device loss may not
                        # have gone through a Supervisor, so make sure a
                        # shrink was requested, and bail out if shrinking
                        # stops making progress. A transient error re-runs
                        # at the *same* budget: lost capacity shrinks the
                        # plan, a hiccup does not.
                        feeder.rewind()
                        if fault_round is not None:
                            pending_faults.remove(fault_round)
                        num_faults += 1
                        faults_at_cursor += 1
                        if (
                            isinstance(e, DeviceLossError)
                            and self._pending_budget is None
                            and self._pending_topology is None
                        ):
                            self.fatal_handler(fault_budget_scale)(e)
                        if faults_at_cursor > _MAX_FAULTS_PER_SEGMENT:
                            raise
                        continue
                    feeder.ack()  # segment complete: retained rows consumed
                    run_s = time.perf_counter() - t0
                    # account the compile/hit only now: a faulted attempt
                    # above never compiled, and must not poison the perf
                    # counters
                    self.engine_cache.record(compile_key, cache_hit)

                ys = {k: v[:seg_len] for k, v in ys.items()}  # drop bucket padding
                stage_params = list(final_state.stage_params)
                rings = tuple(final_state.rings)
                deltas = tuple(final_state.deltas)
                opt_states = tuple(final_state.opt_states)
                comp_states = tuple(final_state.comp_states)
                prev_plan = plan
                if self.cfg.profile_feedback and cache_hit:
                    # online refinement: fold observed wall-clock (cache-hit
                    # segments only — a compile would swamp the signal) into
                    # the profile + store; the *next* replan (BudgetEvent,
                    # request_budget, on_fatal) plans from these numbers
                    from repro.profile.bridge import observe_segment

                    refined = observe_segment(
                        self.model_cfg, self.batch, self.seq,
                        self.profile, plan, bucket_rounds, run_s,
                    )
                    if refined is not None:
                        self.profile = refined[0]
                        if self.cfg.t_d is None:
                            self.t_d = planner_lib.default_data_interval(self.profile)

                acc = np.asarray(ys["acc"], dtype=np.float64)
                admitted = np.asarray(ys["admitted"], dtype=np.float64)
                result = StreamResult(
                    online_acc=float(acc.mean()),
                    online_acc_curve=np.cumsum(acc) / np.arange(1, seg_len + 1),
                    losses=np.asarray(ys["loss"]),
                    admitted_frac=float(admitted.mean()),
                    memory_bytes=plan.memory,
                    planned_rate=plan.rate,
                    empirical_rate=empirical_adaptation_rate(self.cfg, plan, admitted, seg_len),
                    lam_curve=np.asarray(ys["lam"]),
                    plan=plan,
                )
                segments.append(
                    SegmentReport(
                        start=cursor, end=seg_end, budget_bytes=budget,
                        replanned=replanned, replan_s=replan_s, remap_s=remap_s,
                        run_s=run_s, result=result,
                        cache_hit=cache_hit, rounds_compiled=bucket_rounds,
                        take_s=take_s, rounds_lost=seg_rounds_lost,
                    )
                )
                acc_all.append(acc)
                loss_all.append(np.asarray(ys["loss"]))
                admitted_all.append(admitted)
                cursor = seg_end
                # live end-of-segment snapshot: what a graceful drain
                # checkpoints (save_live_checkpoint) so a restart resumes
                # from this exact boundary — exactly-once across restarts
                self._live_resume = ResumeState(
                    stage_params=list(stage_params),
                    opt_states=tuple(opt_states),
                    comp_states=tuple(comp_states),
                    bounds=list(bounds),
                    cursor=cursor,
                    budget_bytes=budget,
                    rings=tuple(rings),
                    deltas=tuple(deltas),
                    sched_origin=int(sched_origin),
                    geometry=RingGeometry(
                        ring_size=int(engine_sched.ring_size),
                        delta_ring=int(engine_sched.delta_ring),
                    ),
                )
                # hand the segment to the driver; a _STOP reply ends the
                # run at this boundary with everything consumed accounted
                if (yield segments[-1]) is _STOP:
                    break
        finally:
            feeder.close()
            self._feeder = None

        acc_cat = np.concatenate(acc_all) if acc_all else np.zeros(0)
        admitted_cat = np.concatenate(admitted_all) if admitted_all else np.zeros(0)
        final_params = T.merge_stage_params(self.model_cfg, list(stage_params))
        self.final_params = final_params
        consumed = sum(s.end - s.start for s in segments)
        # round-weighted over the rounds this run actually consumed — a
        # resumed run covers R - resume.cursor rounds, and dividing by the
        # full stream length would dilute the rate by the skipped prefix
        rate = sum(
            s.result.empirical_rate * (s.end - s.start) for s in segments
        ) / max(consumed, 1)
        return ElasticStreamResult(
            segments=segments,
            online_acc=float(acc_cat.mean()) if acc_cat.size else 0.0,
            online_acc_curve=np.cumsum(acc_cat) / np.arange(1, acc_cat.size + 1),
            losses=np.concatenate(loss_all) if loss_all else np.zeros(0),
            admitted_frac=float(admitted_cat.mean()) if admitted_cat.size else 0.0,
            empirical_rate=rate,
            final_params=final_params,
            rounds=int(consumed),
            num_replans=sum(1 for s in segments if s.replanned),
            num_faults=num_faults,
            engine_cache_hits=self.engine_cache.hits - cache_hits0,
            engine_cache_misses=self.engine_cache.misses - cache_misses0,
            peak_buffered_rounds=feeder.peak_buffered_rounds,
            stream_wait_s=feeder.take_wait_s,
            rounds_lost_per_switch=max(
                (s.rounds_lost for s in segments), default=0
            ),
        )

    # -- graceful drain ---------------------------------------------------
    def live_resume_state(self) -> Optional[ResumeState]:
        """The last completed segment's end-of-segment state snapshot.

        ``None`` until the open run completes a segment. Unlike the
        supervised per-segment checkpoints (optional, I/O-bound), this is
        always maintained — it is what a server drain saves.
        """
        return self._live_resume

    def save_live_checkpoint(self, directory: str) -> Optional[str]:
        """Checkpoint the live snapshot for an exactly-once restart.

        Writes the full engine-state tuple — stage params, the in-flight
        gradient-accumulation and Δθ rings, optimizer moments and
        compensation state — plus the partition bounds, stream cursor,
        budget, and the ring/schedule coordinates as extras: everything
        ``load_drain_state`` needs to resume this run on a fresh process
        *bit-exactly* (schema 2; schema-1 drains lacked the rings).
        Returns the checkpoint path, or ``None`` when no segment has
        completed yet (nothing consumed → a restart starts from scratch,
        still exactly-once).
        """
        rs = self._live_resume
        if rs is None:
            return None
        budget = rs.budget_bytes
        extras = {
            "bounds": [int(b) for b in rs.bounds],
            "cursor": int(rs.cursor),
            "budget_bytes": float(budget) if math.isfinite(budget) else "inf",
        }
        if rs.sched_origin is not None:
            extras["sched_origin"] = int(rs.sched_origin)
        if rs.geometry is not None:
            extras["ring_size"] = int(rs.geometry.ring_size)
            extras["delta_ring"] = int(rs.geometry.delta_ring)
        if rs.rings is not None and rs.geometry is not None:
            state = (
                list(rs.stage_params),
                tuple(rs.rings),
                tuple(rs.deltas),
                tuple(rs.opt_states),
                tuple(rs.comp_states),
            )
        else:  # ring-less snapshot: fall back to the schema-1 payload shape
            extras.pop("ring_size", None)
            extras.pop("delta_ring", None)
            state = (
                list(rs.stage_params),
                tuple(rs.opt_states),
                tuple(rs.comp_states),
            )
        return save_checkpoint(directory, rs.cursor, state, extras)

    def load_drain_state(self, params_template: Pytree, directory: str) -> ResumeState:
        """Recover a ``save_live_checkpoint`` snapshot for ``resume=``.

        Corrupt checkpoints are quarantined with fallback-to-previous-good
        (the directory may hold several drains). ``params_template`` only
        provides shapes/dtypes; the saved bounds may differ from what this
        process plans — ``run_stream(resume=...)`` remaps.

        Schema 2 drains carry the accumulation/Δθ rings and the schedule
        coordinates they are valid under, so a same-plan restart continues
        bit-exactly. Schema 1 drains (pre-ring) still load — forward
        migration fills ``rings=None`` and the restart re-warms its
        accumulation, with a warning naming the horizon.
        """
        from repro.models import transformer as T

        while True:
            path = latest_checkpoint(directory)
            if path is None:
                raise FileNotFoundError(f"no drain checkpoint under {directory!r}")
            try:
                manifest = verify_checkpoint(path)
                schema = checkpoint_schema(manifest)
                extras = manifest["extras"]
                bounds = [int(b) for b in extras["bounds"]]
                raw_budget = extras.get("budget_bytes", "inf")
                budget = math.inf if raw_budget == "inf" else float(raw_budget)
                split = T.split_stage_params(self.model_cfg, params_template, bounds)
                opts_t = tuple(self.optimizer.init(sp) for sp in split)
                comps_t = tuple(
                    comp_lib.init_state(sp, self.cfg.compensation) for sp in split
                )
                with_rings = schema >= 2 and "ring_size" in extras
                if with_rings:
                    # ring shapes come from the saved geometry — no engine
                    # or schedule rebuild needed to shape the template
                    ring_size = int(extras["ring_size"])
                    delta_ring = int(extras["delta_ring"])
                    f32 = jnp.float32
                    rings_t = tuple(
                        jax.tree.map(
                            lambda p: jnp.zeros((ring_size, *p.shape), f32), sp
                        )
                        for sp in split
                    )
                    deltas_t = tuple(
                        jax.tree.map(
                            lambda p: jnp.zeros((delta_ring, *p.shape), f32), sp
                        )
                        for sp in split
                    )
                    template = (list(split), rings_t, deltas_t, opts_t, comps_t)
                else:
                    template = (list(split), opts_t, comps_t)
                state, _step, _extras = restore_checkpoint(path, template)
            except CheckpointCorruptError:
                # quarantine and fall back to the previous drain, same as
                # restore_latest_good — but re-deriving the per-candidate
                # template (bounds may differ between drains)
                try:
                    os.replace(path, path + ".corrupt")
                except OSError:
                    pass
                continue
            if with_rings:
                return ResumeState(
                    stage_params=list(state[0]),
                    opt_states=tuple(state[3]),
                    comp_states=tuple(state[4]),
                    bounds=bounds,
                    cursor=int(extras["cursor"]),
                    budget_bytes=budget,
                    rings=tuple(state[1]),
                    deltas=tuple(state[2]),
                    sched_origin=(
                        int(extras["sched_origin"])
                        if "sched_origin" in extras else None
                    ),
                    geometry=RingGeometry(
                        ring_size=int(extras["ring_size"]),
                        delta_ring=int(extras["delta_ring"]),
                    ),
                )
            warnings.warn(
                f"schema-{schema} drain checkpoint has no accumulation/Δθ "
                "rings: the restart re-warms its accumulation from zero "
                "(a few rounds of in-flight gradients are not replayed)",
                stacklevel=2,
            )
            return ResumeState(
                stage_params=list(state[0]),
                opt_states=tuple(state[1]),
                comp_states=tuple(state[2]),
                bounds=bounds,
                cursor=int(extras["cursor"]),
                budget_bytes=budget,
            )

    # -- crash restore ----------------------------------------------------
    def load_resume_state(self, params_template: Pytree, checkpoint_dir: str) -> ResumeState:
        """Recover the newest per-segment checkpoint under ``checkpoint_dir``.

        The manifest extras (written by supervised segments via
        ``plan_manifest``) say which partition the per-stage state was
        split on and where the stream cursor was; the state itself is
        restored into a template rebuilt from the *saved* budget's plan.
        ``params_template`` only provides shapes/dtypes (e.g. freshly
        initialized params) — its values are overwritten by the restore.
        """
        seg_dirs = sorted(
            d for d in os.listdir(checkpoint_dir) if d.startswith("seg_")
        )
        path = None
        for seg in reversed(seg_dirs):
            path = latest_checkpoint(os.path.join(checkpoint_dir, seg))
            if path is not None:
                break
        if path is None:
            raise FileNotFoundError(
                f"no segment checkpoint under {checkpoint_dir!r}"
            )
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        schema = checkpoint_schema(manifest)
        extras = manifest["extras"]
        bounds = [int(b) for b in extras["bounds"]]
        cursor = int(extras["cursor"])
        raw_budget = extras.get("budget_bytes", "inf")
        budget = math.inf if raw_budget == "inf" else float(raw_budget)
        plan = self.plan_for(budget)
        if list(plan.partition.bounds) != bounds:
            raise ValueError(
                "cannot rebuild the saved plan: planning for budget "
                f"{raw_budget} gives bounds {list(plan.partition.bounds)} "
                f"but the checkpoint was split on {bounds} — the profile "
                "or planner limits changed since the checkpoint was taken"
            )
        from repro.models import transformer as T

        staged = self.algorithm.wrap_staged(
            staged_from_transformer(self.model_cfg, bounds)
        )
        # ring shapes depend only on plan.config, not the segment length
        sched = sched_lib.build_schedule(plan.config, len(bounds) - 1, 1)
        engine = FerretEngine(
            staged, sched, self.optimizer, self.cfg.compensation, lr=self.cfg.lr
        )
        template = engine.init_state(
            T.split_stage_params(self.model_cfg, params_template, bounds)
        )
        if schema < 2:
            # schema-1 supervised checkpoints stored the positional
            # 5-tuple (index key paths); restore into the tuple view and
            # migrate forward. Rings are present in the payload but carry
            # no schedule origin, so the restart cannot re-enter the
            # schedule they were filled under — drop them and re-warm.
            state, _step, _extras = restore_checkpoint(path, template.as_tuple())
            warnings.warn(
                f"schema-{schema} segment checkpoint: accumulation/Δθ rings "
                "have no schedule origin and were dropped; gradient "
                "accumulation re-warms over the next "
                f"~{engine.sched.ring_size} rounds",
                stacklevel=2,
            )
            return ResumeState(
                stage_params=list(state[0]),
                opt_states=tuple(state[3]),
                comp_states=tuple(state[4]),
                bounds=bounds,
                cursor=cursor,
                budget_bytes=budget,
            )
        state, _step, _extras = restore_checkpoint(path, template)
        sched_origin = (
            int(extras["sched_origin"]) if "sched_origin" in extras else None
        )
        geometry = None
        if "ring_size" in extras:
            geometry = RingGeometry(
                ring_size=int(extras["ring_size"]),
                delta_ring=int(extras["delta_ring"]),
            )
        return ResumeState(
            stage_params=list(state.stage_params),
            opt_states=tuple(state.opt_states),
            comp_states=tuple(state.comp_states),
            bounds=bounds,
            cursor=cursor,
            budget_bytes=budget,
            rings=tuple(state.rings),
            deltas=tuple(state.deltas),
            sched_origin=sched_origin,
            geometry=geometry,
        )

    # -- internals --------------------------------------------------------
    def _split_penalty_cached(self, bounds) -> Tuple:
        """Per-stage split of the algorithm's penalty extras, memoized.

        The anchor objects (MAS Ω/ref) only change at a re-plan refresh,
        but segments are frequent — reuse the split (and its stable jit
        argument identity) until the extras or the partition actually
        change, instead of re-splitting two model-sized trees per segment.
        """
        extras = self.algorithm.engine_penalty_extras()
        cached = self._penalty_split
        if cached is not None and extras is not None:
            c_bounds, c_extras, c_split = cached
            if (
                c_bounds == tuple(bounds)
                and c_extras.keys() == extras.keys()
                and all(c_extras[k] is extras[k] for k in extras)
            ):
                return c_split
        split = split_penalty_extras(self.algorithm, self.model_cfg, bounds)
        self._penalty_split = (tuple(bounds), extras, split)
        return split

    def _prepare_rows(self, rows: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        """The feeder's one-shot transform: per-chunk stream preparation.

        Chunks arrive in stream order and are prepared exactly once, so a
        stateful preparation (ER's reservoir mixing) chained over chunks is
        bit-identical to the materialized whole-stream preparation, and a
        rewound (faulted) segment replays prepared rows without advancing
        the algorithm's state twice.
        """
        algo = self.algorithm
        if type(algo).prepare_stream is OCLAlgorithm.prepare_stream:
            return rows  # identity prep: skip the call entirely
        return algo.prepare_stream(rows, self._prep_ctx)

    def _refresh_buffered(self, feeder: BufferedStreamSource, stage_params) -> None:
        """The algorithm's segment-boundary refresh hook, incrementally.

        The materialized path refreshed the whole un-consumed tail at a
        re-plan. Here the tail is split in two: rounds already pulled into
        the feeder are refreshed in place via ``segment_refresh``; rounds
        not yet pulled are covered by re-anchoring the preparation context
        at the live weights, so subsequent ``prepare_stream`` calls produce
        exactly what a whole-tail refresh would have.
        """
        algo = self.algorithm
        prep_default = type(algo).prepare_stream is OCLAlgorithm.prepare_stream
        refresh_default = type(algo).segment_refresh is OCLAlgorithm.segment_refresh
        if prep_default and refresh_default:
            return  # no prep and no refresh: skip the O(model-size) merge
        from repro.models import transformer as T

        merged = T.merge_stage_params(self.model_cfg, list(stage_params))
        ctx = PrepareContext(
            params=merged,
            forward_fn=lambda p, b: T.forward(self.model_cfg, p, b)[0],
        )
        self._prep_ctx = ctx
        if refresh_default:
            return
        # the refresh hook fires even when nothing is physically buffered
        # (state-only refreshes like the MAS Ω re-anchor have no rows to
        # rewrite); returned field updates only apply to buffered rows
        tail = feeder.buffered_rows()
        tail = (
            {} if tail is None else {k: np.asarray(v) for k, v in tail.items()}
        )
        updated = algo.segment_refresh(merged, tail, ctx)
        if not updated or not tail:
            return
        out = dict(tail)
        for k, arr in updated.items():
            if k in out:
                out[k] = np.asarray(arr)
        feeder.replace_buffered(out)

    def _execute_segment(
        self,
        engine: FerretEngine,
        state,
        seg_stream: Dict[str, jnp.ndarray],
        supervisor_cfg: Optional[SupervisorCfg],
        fault_round: Optional[int],
        fault_budget_scale: float,
        plan: planner_lib.Plan,
        cursor: int,
        seg_end: int,
        budget: float,
        penalty=None,
        *,
        sched_origin: Optional[int] = None,
    ):
        """One segment, either direct or as a single supervised step."""
        out: Dict[str, Any] = {}
        seg_len = seg_end - cursor  # engine may run bucket-padded rounds
        supervised = supervisor_cfg is not None

        def _injected(kind_nan_ok: bool):
            """The ``engine.step`` injection point (before any state change).

            ``transient`` raises retry-safe, ``device_loss`` raises the
            escalation path, ``nan`` returns True to poison the monitored
            loss (only observable under a Supervisor's NaN probe — specs
            gate on the ``supervised`` ctx key).
            """
            spec = faults_lib.fire("engine.step", cursor=cursor, supervised=supervised)
            if spec is None:
                return False
            if spec.kind == "transient":
                raise TransientFaultError("injected transient engine error")
            if spec.kind == "device_loss":
                # spec.arg sizes the loss (0 → the default single device),
                # so a topology-aware run shrinks by exactly that many
                raise DeviceLossError(
                    "injected device loss",
                    lost_devices=max(1, int(spec.arg)),
                )
            return spec.kind == "nan" and kind_nan_ok

        def step_fn(st, batch):
            if fault_round is not None:
                raise DeviceLossError(
                    f"simulated device loss at stream round {fault_round}"
                )
            poison = _injected(kind_nan_ok=True)
            new_st, ys = engine.run(st, batch, penalty)
            out["ys"] = ys
            # monitored loss over the real rounds only — bucket-padding
            # rows are zeros and must not dilute NaN checks / thresholds
            loss = jnp.mean(ys["loss"][:seg_len])
            if poison:
                loss = loss * jnp.nan  # a poisoned batch: NaN probe trips
            return new_st, {"loss": loss}

        if supervisor_cfg is None:
            if fault_round is not None:
                raise DeviceLossError(
                    f"simulated device loss at stream round {fault_round}"
                )
            _injected(kind_nan_ok=False)
            return engine.run(state, seg_stream, penalty)

        # Per-segment checkpoint dir: state shapes are partition-dependent,
        # so a NaN/timeout rollback inside this segment must never restore a
        # checkpoint written under a different partition.
        seg_cfg = dataclasses.replace(
            supervisor_cfg,
            checkpoint_dir=f"{supervisor_cfg.checkpoint_dir}/seg_{cursor:06d}",
        )
        sup = Supervisor(
            seg_cfg,
            step_fn,
            state,
            on_fatal=self.fatal_handler(fault_budget_scale),
        )
        # Saves happen only after the segment step succeeds, i.e. the saved
        # state is the *end-of-segment* state — the cursor must say so, or a
        # restore would re-consume the whole segment.
        rep = sup.run_step(
            seg_stream,
            extras=plan_manifest(
                plan, cursor=seg_end, budget_bytes=budget,
                sched_origin=sched_origin,
                ring_size=engine.sched.ring_size,
                delta_ring=engine.sched.delta_ring,
            ),
        )
        if rep.restarted:
            # the Supervisor recovered in place (NaN rollback / transient
            # retry): close out the injected fault's recovery latency
            faults_lib.resolved("engine.step")
        sup.manager.wait()
        return sup.state, out["ys"]

    @staticmethod
    def _normalize_schedule(schedule: BudgetSchedule):
        if callable(schedule):
            return [], schedule
        events = sorted(
            (BudgetEvent(int(e.round), float(e.budget_bytes)) for e in schedule),
            key=lambda e: e.round,
        )
        return events, None

    @staticmethod
    def _segment_end(cursor, R, events, segment_rounds) -> int:
        """Next segment boundary; ``R is None`` (unknown stream end) relies
        on ``segment_rounds``, which ``run_stream`` defaults for that case.
        A callable ``segment_rounds`` is re-evaluated here, at every
        boundary — dynamic segment sizing (clamped to ≥ 1 so the loop
        always makes progress)."""
        cap = segment_rounds(cursor) if callable(segment_rounds) else segment_rounds
        if cap is not None:
            cap = max(1, int(cap))
        end = R if R is not None else cursor + cap
        for e in events:
            if cursor < e.round < end:
                end = e.round
        if cap is not None:
            end = min(end, cursor + cap)
        return end


def _base_is_unbounded(source: StreamSource) -> bool:
    """Is the underlying feed unbounded (walking cap/buffer wrappers)?"""
    while isinstance(source, (BufferedStreamSource, LimitedStreamSource)):
        source = source.source
    return source.length is None


def _try_seek(source: StreamSource, round_idx: int) -> bool:
    """Position ``source`` at an absolute round if it supports seeking."""
    if isinstance(source, BufferedStreamSource):
        return source.try_seek(round_idx)
    seek = getattr(source, "seek", None)
    if seek is None:
        return False
    seek(round_idx)
    return True
