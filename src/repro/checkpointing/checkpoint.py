"""Fault-tolerant checkpointing for the OCL trainer.

Design (no orbax/tensorstore in the container — self-contained):

- A checkpoint = one ``.npz`` per host shard + a tiny JSON manifest.
- Writes are **atomic and durable**: payloads land under
  ``step_XXXX.tmp/``, every payload file and the manifest are fsync'd,
  the tmp directory is fsync'd, then renamed into place, and the parent
  directory is fsync'd — a crash at any point leaves either the previous
  checkpoint set or the complete new one, never a torn latest.
- Payloads are **checksummed**: the manifest records per-file sha256 +
  byte counts, so a restore detects torn or bit-rotted payloads (the
  failure fsync+rename cannot prevent) instead of loading garbage.
- Restores **fall back to the previous good checkpoint**: a corrupt
  latest is quarantined to ``<name>.corrupt`` (the profile store's
  idiom) and the next newest is tried — one bad write never strands a
  recovery.
- Writes are **async** (background thread): training never blocks on I/O;
  the manager keeps at most one in-flight save and coalesces backpressure.
- Checkpoints are **mesh-shape-agnostic**: arrays are saved in logical
  (unsharded) form; the restorer re-shards onto whatever mesh the restart
  has — this is what makes elastic restarts (runtime/elastic.py) possible.
- OCL extras ride along: optimizer state, Iter-Fisher λ statistics, the
  stream cursor (exactly-once), and the replay buffer.

Fault injection (``repro.faults``): the ``checkpoint.write`` point fires
inside ``save_checkpoint`` — ``crash_mid_write`` kills the process
mid-payload (torn tmp, no rename), ``corrupt_payload`` flips bytes in the
committed shard after the rename (bit rot). Both are what the hardening
above recovers from; the chaos suite asserts it.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro import faults as faults_lib
from repro.faults import FaultError

Pytree = Any

_SEP = "|"

# Manifest schema history:
#   1 (implicit — manifests without a "schema" key): engine state saved
#     without the gradient-accumulation/Δθ rings or ring-geometry metadata.
#   2: rings ride in the payload and the extras carry the ring geometry
#     (ring_size/delta_ring) + schedule origin, so a restore is bit-exact
#     instead of re-warming compensation. v1 checkpoints still load via
#     forward migration (rings re-zeroed, with a warning reporting the
#     re-warm horizon) — see ElasticStreamTrainer.load_drain_state /
#     load_resume_state.
CHECKPOINT_SCHEMA_VERSION = 2


def checkpoint_schema(manifest: Dict[str, Any]) -> int:
    """Schema version of a manifest (1 for pre-versioning checkpoints)."""
    return int(manifest.get("schema", 1))


class CheckpointCorruptError(ValueError):
    """A checkpoint failed verification (checksum/structure mismatch)."""


def _flatten(tree: Pytree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return f"k:{p.key}"
    if hasattr(p, "idx"):
        return f"i:{p.idx}"
    if hasattr(p, "name"):
        return f"n:{p.name}"
    return f"r:{p}"


def _unflatten_into(template: Pytree, flat: Dict[str, np.ndarray]) -> Pytree:
    paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_and_leaves:
        key = _SEP.join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs live {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: str) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return  # platform without O_RDONLY dir opens: best-effort
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _sha256(path: str) -> Tuple[str, int]:
    h = hashlib.sha256()
    n = 0
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
            n += len(chunk)
    return h.hexdigest(), n


def save_checkpoint(
    directory: str,
    step: int,
    state: Pytree,
    extras: Optional[Dict[str, Any]] = None,
) -> str:
    """Synchronous atomic+durable save. Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    shard = os.path.join(tmp, "shard_0.npz")
    np.savez(shard, **flat)

    spec = faults_lib.fire("checkpoint.write", step=step, directory=directory)
    if spec is not None and spec.kind == "crash_mid_write":
        # simulate the process dying mid-payload: truncate the shard (a
        # torn write) and abort before the rename — the atomicity contract
        # means the previous checkpoint set is untouched
        size = os.path.getsize(shard)
        with open(shard, "r+b") as f:
            f.truncate(max(1, size // 2))
        raise FaultError(f"injected crash mid-checkpoint-write at step {step}")

    # durability: the payload is fsync'd *before* it is checksummed into
    # the manifest, and the manifest before the rename publishes either
    _fsync_file(shard)
    digest, nbytes = _sha256(shard)
    manifest = {
        "step": step,
        "schema": CHECKPOINT_SCHEMA_VERSION,
        "time": time.time(),
        "num_leaves": len(flat),
        "extras": extras or {},
        "files": {"shard_0.npz": {"sha256": digest, "bytes": nbytes}},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(tmp)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _fsync_dir(directory)

    if spec is not None and spec.kind == "corrupt_payload":
        # simulate post-write bit rot in the committed shard: fsync and
        # rename cannot prevent this — only the checksum verification on
        # restore can catch it (and fall back to the previous good)
        committed = os.path.join(final, "shard_0.npz")
        with open(committed, "r+b") as f:
            f.seek(os.path.getsize(committed) // 2)
            f.write(b"\xde\xad\xbe\xef")
    return final


def _checkpoint_dirs(directory: str):
    return sorted(
        d
        for d in os.listdir(directory)
        if d.startswith("step_")
        and not d.endswith(".tmp")
        and not d.endswith(".corrupt")
    )


def latest_checkpoint(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    cands = _checkpoint_dirs(directory)
    return os.path.join(directory, cands[-1]) if cands else None


def verify_checkpoint(path: str) -> Dict[str, Any]:
    """Structural + checksum verification; returns the manifest.

    Raises ``CheckpointCorruptError`` on an unreadable manifest, a listed
    payload that is missing, or a checksum/byte-count mismatch (a torn or
    bit-rotted payload). Checkpoints from before payload checksumming
    (no ``files`` key) pass structural checks only.
    """
    mpath = os.path.join(path, "manifest.json")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorruptError(f"unreadable manifest under {path}: {e}") from e
    if not isinstance(manifest, dict) or "step" not in manifest:
        raise CheckpointCorruptError(f"malformed manifest under {path}")
    for name, meta in (manifest.get("files") or {}).items():
        fpath = os.path.join(path, name)
        if not os.path.exists(fpath):
            raise CheckpointCorruptError(f"{path}: payload {name} missing")
        digest, nbytes = _sha256(fpath)
        if nbytes != int(meta.get("bytes", -1)) or digest != meta.get("sha256"):
            raise CheckpointCorruptError(
                f"{path}: payload {name} failed checksum — torn or corrupt"
            )
    return manifest


def restore_checkpoint(
    path_or_dir: str, template: Pytree, verify: bool = True
) -> Tuple[Pytree, int, Dict[str, Any]]:
    """Restore into the shapes/dtypes of ``template`` (re-shard on device_put).

    Given a directory of checkpoints, restores the newest *good* one:
    corrupt candidates are quarantined to ``<name>.corrupt`` and the next
    newest is tried (see ``restore_latest_good``). Given one checkpoint
    path, verifies it (``verify=False`` skips checksums) and restores it.
    """
    path = path_or_dir
    if not os.path.exists(os.path.join(path, "manifest.json")):
        if os.path.basename(path).startswith("step_"):
            raise CheckpointCorruptError(f"no manifest under {path}")
        return restore_latest_good(path_or_dir, template, verify=verify)
    if verify:
        manifest = verify_checkpoint(path)
    else:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
    try:
        with np.load(os.path.join(path, "shard_0.npz")) as z:
            flat = {k: z[k] for k in z.files}
    except Exception as e:  # zipfile/OSError/ValueError: torn payload
        raise CheckpointCorruptError(f"unreadable payload under {path}: {e}") from e
    state = _unflatten_into(template, flat)
    return state, int(manifest["step"]), manifest.get("extras", {})


def restore_latest_good(
    directory: str, template: Pytree, verify: bool = True
) -> Tuple[Pytree, int, Dict[str, Any]]:
    """Restore the newest checkpoint that passes verification.

    Corrupt candidates (checksum mismatch, unreadable manifest/payload)
    are quarantined to ``<name>.corrupt`` — mirroring the profile store's
    corrupt-entry quarantine — and the scan continues with the next
    newest. Raises ``FileNotFoundError`` only when no candidate survives.
    """
    if not os.path.isdir(directory):
        raise FileNotFoundError(f"no checkpoint under {directory}")
    for name in reversed(_checkpoint_dirs(directory)):
        path = os.path.join(directory, name)
        try:
            out = restore_checkpoint(path, template, verify=verify)
        except CheckpointCorruptError:
            try:
                os.replace(path, path + ".corrupt")
            except OSError:
                pass
            continue
        # a successful restore is the recovery site for any outstanding
        # write fault (torn tmp, corrupted-then-quarantined latest)
        faults_lib.resolved("checkpoint.write")
        return out
    raise FileNotFoundError(f"no (good) checkpoint under {directory}")


def plan_manifest(
    plan,
    cursor: Optional[int] = None,
    budget_bytes: Optional[float] = None,
    sched_origin: Optional[int] = None,
    ring_size: Optional[int] = None,
    delta_ring: Optional[int] = None,
) -> Dict[str, Any]:
    """JSON-safe checkpoint extras describing a live pipeline plan.

    Rides in the manifest so an elastic restart (runtime/elastic_trainer.py)
    can resume the stream exactly where it stopped (``cursor``) and knows
    which partition the saved per-stage state was split on (``bounds``) —
    the restorer remaps to the new plan's bounds before resuming.
    ``sched_origin`` / ``ring_size`` / ``delta_ring`` (schema ≥ 2) describe
    the geometry the saved rings are shaped for, so a resume with matching
    geometry continues the schedule bit-exactly instead of re-warming.
    """
    out: Dict[str, Any] = {
        "bounds": [int(b) for b in plan.partition.bounds],
        "num_stages": int(plan.partition.num_stages),
        "rate": float(plan.rate),
        "memory_bytes": float(plan.memory),
        "feasible": bool(plan.feasible),
    }
    if cursor is not None:
        out["cursor"] = int(cursor)
    if budget_bytes is not None:
        # inf round-trips through json.dump as Infinity; stringify instead.
        out["budget_bytes"] = (
            float(budget_bytes) if budget_bytes != float("inf") else "inf"
        )
    if sched_origin is not None:
        out["sched_origin"] = int(sched_origin)
    if ring_size is not None:
        out["ring_size"] = int(ring_size)
    if delta_ring is not None:
        out["delta_ring"] = int(delta_ring)
    return out


class CheckpointManager:
    """Async writer with bounded in-flight saves + retention policy."""

    def __init__(self, directory: str, keep: int = 3, every_steps: int = 100):
        self.directory = directory
        self.keep = keep
        self.every_steps = every_steps
        self._inflight: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.every_steps == 0

    def save_async(self, step: int, state: Pytree, extras: Optional[Dict] = None) -> None:
        self.wait()  # coalesce: at most one in-flight save
        state_host = jax.tree.map(np.asarray, state)  # snapshot before mutation

        def _go():
            try:
                save_checkpoint(self.directory, step, state_host, extras)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._last_error = e

        self._inflight = threading.Thread(target=_go, daemon=True)
        self._inflight.start()

    def wait(self) -> None:
        if self._inflight is not None:
            self._inflight.join()
            self._inflight = None
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise err

    def _gc(self) -> None:
        cands = _checkpoint_dirs(self.directory)
        for d in cands[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, d), ignore_errors=True)
        # a crash mid-write leaves a dead step_*.tmp behind; clear any tmp
        # whose final form never landed so the directory never accretes
        # torn payloads
        for d in os.listdir(self.directory):
            if d.endswith(".tmp"):
                full = os.path.join(self.directory, d)
                if not os.path.exists(full[: -len(".tmp")]):
                    shutil.rmtree(full, ignore_errors=True)

    def restore_latest(self, template: Pytree):
        """Newest *good* checkpoint (corrupt ones quarantined + skipped)."""
        return restore_latest_good(self.directory, template)
