"""Fault-tolerant checkpointing for the OCL trainer.

Design (no orbax/tensorstore in the container — self-contained):

- A checkpoint = one ``.npz`` per host shard + a tiny JSON manifest.
- Writes are **atomic**: payloads land under ``step_XXXX.tmp/`` and the
  directory is renamed only after everything (incl. manifest) is fsync'd —
  a crash mid-write can never corrupt the latest checkpoint.
- Writes are **async** (background thread): training never blocks on I/O;
  the manager keeps at most one in-flight save and coalesces backpressure.
- Checkpoints are **mesh-shape-agnostic**: arrays are saved in logical
  (unsharded) form; the restorer re-shards onto whatever mesh the restart
  has — this is what makes elastic restarts (runtime/elastic.py) possible.
- OCL extras ride along: optimizer state, Iter-Fisher λ statistics, the
  stream cursor (exactly-once), and the replay buffer.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

Pytree = Any

_SEP = "|"


def _flatten(tree: Pytree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return f"k:{p.key}"
    if hasattr(p, "idx"):
        return f"i:{p.idx}"
    if hasattr(p, "name"):
        return f"n:{p.name}"
    return f"r:{p}"


def _unflatten_into(template: Pytree, flat: Dict[str, np.ndarray]) -> Pytree:
    paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths_and_leaves:
        key = _SEP.join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: ckpt {arr.shape} vs live {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(
    directory: str,
    step: int,
    state: Pytree,
    extras: Optional[Dict[str, Any]] = None,
) -> str:
    """Synchronous atomic save. Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    np.savez(os.path.join(tmp, "shard_0.npz"), **flat)
    manifest = {
        "step": step,
        "time": time.time(),
        "num_leaves": len(flat),
        "extras": extras or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_checkpoint(directory: str) -> Optional[str]:
    if not os.path.isdir(directory):
        return None
    cands = sorted(
        d for d in os.listdir(directory) if d.startswith("step_") and not d.endswith(".tmp")
    )
    return os.path.join(directory, cands[-1]) if cands else None


def restore_checkpoint(
    path_or_dir: str, template: Pytree
) -> Tuple[Pytree, int, Dict[str, Any]]:
    """Restore into the shapes/dtypes of ``template`` (re-shard on device_put)."""
    path = path_or_dir
    if not os.path.exists(os.path.join(path, "manifest.json")):
        found = latest_checkpoint(path_or_dir)
        if found is None:
            raise FileNotFoundError(f"no checkpoint under {path_or_dir}")
        path = found
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "shard_0.npz")) as z:
        flat = {k: z[k] for k in z.files}
    state = _unflatten_into(template, flat)
    return state, int(manifest["step"]), manifest.get("extras", {})


def plan_manifest(
    plan, cursor: Optional[int] = None, budget_bytes: Optional[float] = None
) -> Dict[str, Any]:
    """JSON-safe checkpoint extras describing a live pipeline plan.

    Rides in the manifest so an elastic restart (runtime/elastic_trainer.py)
    can resume the stream exactly where it stopped (``cursor``) and knows
    which partition the saved per-stage state was split on (``bounds``) —
    the restorer remaps to the new plan's bounds before resuming.
    """
    out: Dict[str, Any] = {
        "bounds": [int(b) for b in plan.partition.bounds],
        "num_stages": int(plan.partition.num_stages),
        "rate": float(plan.rate),
        "memory_bytes": float(plan.memory),
        "feasible": bool(plan.feasible),
    }
    if cursor is not None:
        out["cursor"] = int(cursor)
    if budget_bytes is not None:
        # inf round-trips through json.dump as Infinity; stringify instead.
        out["budget_bytes"] = (
            float(budget_bytes) if budget_bytes != float("inf") else "inf"
        )
    return out


class CheckpointManager:
    """Async writer with bounded in-flight saves + retention policy."""

    def __init__(self, directory: str, keep: int = 3, every_steps: int = 100):
        self.directory = directory
        self.keep = keep
        self.every_steps = every_steps
        self._inflight: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.every_steps == 0

    def save_async(self, step: int, state: Pytree, extras: Optional[Dict] = None) -> None:
        self.wait()  # coalesce: at most one in-flight save
        state_host = jax.tree.map(np.asarray, state)  # snapshot before mutation

        def _go():
            try:
                save_checkpoint(self.directory, step, state_host, extras)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._last_error = e

        self._inflight = threading.Thread(target=_go, daemon=True)
        self._inflight.start()

    def wait(self) -> None:
        if self._inflight is not None:
            self._inflight.join()
            self._inflight = None
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise err

    def _gc(self) -> None:
        cands = sorted(
            d for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for d in cands[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, d), ignore_errors=True)

    def restore_latest(self, template: Pytree):
        return restore_checkpoint(self.directory, template)
