"""Public jit'd wrappers for the Pallas kernels (with jnp reference fallback).

Dispatch policy
---------------
- On TPU, the Pallas kernels are used (``pl.pallas_call`` with explicit
  BlockSpec VMEM tiling).
- On CPU (this container), the kernels only execute under
  ``interpret=True`` — correct but slow — so the default execution path is
  the jnp reference, and the Pallas path is exercised by the kernel tests
  and by setting ``REPRO_USE_PALLAS=1`` (interpret mode) / running on TPU.
"""

from __future__ import annotations

import os
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref


def _backend() -> str:
    return jax.default_backend()


def _use_pallas() -> bool:
    env = os.environ.get("REPRO_USE_PALLAS", "").strip()
    if env == "1":
        return True
    if env == "0":
        return False
    return _backend() == "tpu"


def _pallas_interpret() -> bool:
    return _backend() != "tpu"


# ---------------------------------------------------------------------------
# SSD chunked scan (Mamba-2)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
    chunk: int,
    initial_state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    slen = x.shape[1]
    pad = (-slen) % chunk
    if pad:
        # dt=0 padding is a no-op on the state (decay exp(0)=1, increment 0).
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    if _use_pallas():
        from repro.kernels import ssd_scan as _k

        y, state = _k.ssd_scan_pallas(
            x, dt, A, B, C, chunk, initial_state, interpret=_pallas_interpret()
        )
    else:
        y, state = _ref.ssd_scan_ref(x, dt, A, B, C, chunk, initial_state)
    return (y[:, :slen] if pad else y), state


@jax.jit
def ssd_decode_step(
    x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array, C: jax.Array, state: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    # Single-token recurrence is tiny & fusion-friendly; XLA handles it.
    return _ref.ssd_decode_step_ref(x, dt, A, B, C, state)


# ---------------------------------------------------------------------------
# Iter-Fisher gradient compensation
# ---------------------------------------------------------------------------


def _tuned():
    """Persisted autotune record for this backend (all-None when absent).

    Lazy + exception-safe: dispatch must keep working with no store on
    disk, a corrupt store, or during partial imports.
    """
    try:
        from repro.profile.autotune import tuned_defaults

        return tuned_defaults()
    except Exception:
        from repro.profile.autotune import TunedDefaults

        return TunedDefaults()


def _use_packed() -> bool:
    # Flat-packed single-launch path (repro.kernels.packing). Precedence:
    # REPRO_PACK=1/0 forces either way; else a measured autotune record
    # for this backend decides; else packed only on real TPU — on CPU
    # (interpret mode included) the per-leaf path measures ~7× faster
    # (BENCH_hotpath.json), so guessing "packed" there ships a regression.
    env = os.environ.get("REPRO_PACK", "").strip()
    if env == "1":
        return True
    if env == "0":
        return False
    tuned = _tuned()
    if tuned.pack is not None:
        return bool(tuned.pack)
    return _use_pallas() and _backend() == "tpu"


def _pack_block():
    # PackSpec grid tile: REPRO_PACK_BLOCK env > tuned winner > None
    # (packing.BLOCK module default).
    env = os.environ.get("REPRO_PACK_BLOCK", "").strip()
    if env:
        return int(env)
    tuned = _tuned()
    return int(tuned.pack_block) if tuned.pack_block else None


def iter_fisher_compensate(grad: jax.Array, deltas: jax.Array, lam: jax.Array) -> jax.Array:
    """Apply τ iterative Fisher compensations; deltas: (τ, *grad.shape).

    The kernel pads ragged sizes internally, so every leaf takes the fast
    path (no ``size % 128`` gate).
    """
    if _use_pallas():
        from repro.kernels import iter_fisher as _k

        return _k.iter_fisher_compensate_pallas(
            grad, deltas, lam, interpret=_pallas_interpret()
        )
    return _ref.iter_fisher_compensate_ref(grad, deltas, lam)


def iter_fisher_leaf_stats(
    grad: jax.Array,
    delta: jax.Array,
    v_r: jax.Array,
    v_a: jax.Array,
    alpha: float,
):
    """Per-leaf λ-statistics + EMA updates. Returns (v_r', v_a', s1, s2)."""
    if _use_pallas():
        from repro.kernels import iter_fisher as _k

        return _k.iter_fisher_leaf_stats_pallas(
            grad, delta, v_r, v_a, alpha, interpret=_pallas_interpret()
        )
    return _ref.iter_fisher_leaf_stats_ref(grad, delta, v_r, v_a, alpha)


def iter_fisher_compensate_tree(
    grad, deltas, lam: jax.Array, packed: Optional[bool] = None
):
    """Whole-pytree compensation: one kernel launch regardless of leaf count.

    ``packed=None`` honors ``REPRO_PACK`` (default on); ``packed=False``
    dispatches per leaf (the O(leaves) reference path, kept for
    benchmarking and cross-checks).
    """
    if _use_packed() if packed is None else packed:
        from repro.kernels import packing

        return packing.compensate_tree(
            grad, deltas, lam,
            use_pallas=_use_pallas(), interpret=_pallas_interpret(),
        )
    return jax.tree.map(lambda g, d: iter_fisher_compensate(g, d, lam), grad, deltas)


def iter_fisher_stats_tree(
    grad, delta, v_r, v_a, alpha: float, packed: Optional[bool] = None
):
    """Whole-pytree λ-statistics: (v_r', v_a', Σ s1, Σ s2), one launch.

    Both paths accumulate s1/s2 as on-device fp32 scalars — never as host
    Python floats.
    """
    if _use_packed() if packed is None else packed:
        from repro.kernels import packing

        return packing.stats_tree(
            grad, delta, v_r, v_a, alpha,
            use_pallas=_use_pallas(), interpret=_pallas_interpret(),
        )
    new_vr, new_va = [], []
    s1 = jnp.zeros((), jnp.float32)
    s2 = jnp.zeros((), jnp.float32)
    leaves = zip(
        jax.tree.leaves(grad), jax.tree.leaves(delta),
        jax.tree.leaves(v_r), jax.tree.leaves(v_a),
    )
    for g, d, vr, va in leaves:
        nvr, nva, l1, l2 = iter_fisher_leaf_stats(g, d, vr, va, alpha)
        new_vr.append(nvr)
        new_va.append(nva)
        s1 = s1 + l1
        s2 = s2 + l2
    treedef = jax.tree.structure(grad)
    return (
        jax.tree.unflatten(treedef, new_vr),
        jax.tree.unflatten(treedef, new_va),
        s1,
        s2,
    )
