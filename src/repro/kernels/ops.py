"""Public jit'd wrappers for the Pallas kernels (with jnp reference fallback).

Dispatch policy
---------------
- On TPU, the Pallas kernels are used (``pl.pallas_call`` with explicit
  BlockSpec VMEM tiling).
- On CPU (this container), the kernels only execute under
  ``interpret=True`` — correct but slow — so the default execution path is
  the jnp reference, and the Pallas path is exercised by the kernel tests
  and by setting ``REPRO_USE_PALLAS=1`` (interpret mode) / running on TPU.
"""

from __future__ import annotations

import os
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref


def _backend() -> str:
    return jax.default_backend()


def _use_pallas() -> bool:
    env = os.environ.get("REPRO_USE_PALLAS", "").strip()
    if env == "1":
        return True
    if env == "0":
        return False
    return _backend() == "tpu"


def _pallas_interpret() -> bool:
    return _backend() != "tpu"


# ---------------------------------------------------------------------------
# SSD chunked scan (Mamba-2)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
    chunk: int,
    initial_state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    slen = x.shape[1]
    pad = (-slen) % chunk
    if pad:
        # dt=0 padding is a no-op on the state (decay exp(0)=1, increment 0).
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    if _use_pallas():
        from repro.kernels import ssd_scan as _k

        y, state = _k.ssd_scan_pallas(
            x, dt, A, B, C, chunk, initial_state, interpret=_pallas_interpret()
        )
    else:
        y, state = _ref.ssd_scan_ref(x, dt, A, B, C, chunk, initial_state)
    return (y[:, :slen] if pad else y), state


@jax.jit
def ssd_decode_step(
    x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array, C: jax.Array, state: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    # Single-token recurrence is tiny & fusion-friendly; XLA handles it.
    return _ref.ssd_decode_step_ref(x, dt, A, B, C, state)


# ---------------------------------------------------------------------------
# Iter-Fisher gradient compensation
# ---------------------------------------------------------------------------


def iter_fisher_compensate(grad: jax.Array, deltas: jax.Array, lam: jax.Array) -> jax.Array:
    """Apply τ iterative Fisher compensations; deltas: (τ, *grad.shape)."""
    if _use_pallas() and grad.ndim >= 1 and grad.size % 128 == 0:
        from repro.kernels import iter_fisher as _k

        return _k.iter_fisher_compensate_pallas(
            grad, deltas, lam, interpret=_pallas_interpret()
        )
    return _ref.iter_fisher_compensate_ref(grad, deltas, lam)


def iter_fisher_leaf_stats(
    grad: jax.Array,
    delta: jax.Array,
    v_r: jax.Array,
    v_a: jax.Array,
    alpha: float,
):
    """Per-leaf λ-statistics + EMA updates. Returns (v_r', v_a', s1, s2)."""
    if _use_pallas() and grad.ndim >= 1 and grad.size % 128 == 0:
        from repro.kernels import iter_fisher as _k

        return _k.iter_fisher_leaf_stats_pallas(
            grad, delta, v_r, v_a, alpha, interpret=_pallas_interpret()
        )
    return _ref.iter_fisher_leaf_stats_ref(grad, delta, v_r, v_a, alpha)
