"""Pallas TPU kernel: fused Iter-Fisher gradient compensation.

The compensation inner loop (Eq. 9) is elementwise over every parameter and
runs once per stage-update:

    for i in 0..τ-1:   g ← g + λ · g ⊙ g ⊙ Δθ_i

A naïve XLA lowering materializes τ intermediate g arrays (τ+1 HBM round
trips). The kernel streams one VMEM tile of g and the τ matching Δθ tiles,
iterates in registers/VMEM, and writes once: HBM traffic drops from
(2τ+... ) to (τ+2) array passes and the λ-statistics pass fuses the same
way. Blocks are (8·128)-aligned 1-D tiles of the flattened parameter.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4096  # elements per tile (multiple of 8·128 lanes)


# ---------------------------------------------------------------------------
# compensation kernel
# ---------------------------------------------------------------------------


def _compensate_kernel(lam_ref, g_ref, d_ref, o_ref, *, tau: int):
    g = g_ref[...].astype(jnp.float32)
    lam = lam_ref[0].astype(jnp.float32)
    for i in range(tau):
        delta = d_ref[i, :].astype(jnp.float32)
        g = g + lam * g * g * delta
    o_ref[...] = g.astype(o_ref.dtype)


def iter_fisher_compensate_pallas(
    grad: jax.Array, deltas: jax.Array, lam: jax.Array, interpret: bool = False
) -> jax.Array:
    """grad: any shape; deltas: (τ, *grad.shape); lam: scalar."""
    shape = grad.shape
    tau = deltas.shape[0]
    if tau == 0:
        return grad
    n = grad.size
    pad = (-n) % BLOCK
    gf = jnp.pad(grad.reshape(-1), (0, pad))
    df = jnp.pad(deltas.reshape(tau, -1), ((0, 0), (0, pad)))
    nb = gf.shape[0] // BLOCK

    out = pl.pallas_call(
        functools.partial(_compensate_kernel, tau=tau),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # λ broadcast to every tile
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((tau, BLOCK), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(gf.shape, grad.dtype),
        interpret=interpret,
    )(lam.reshape(1), gf, df)
    return out[:n].reshape(shape)


# ---------------------------------------------------------------------------
# λ-statistics kernel (EMA updates + partial dot products)
# ---------------------------------------------------------------------------


def _stats_kernel(g_ref, d_ref, vr_ref, va_ref, nvr_ref, nva_ref, s1_ref, s2_ref, *, alpha: float):
    g = g_ref[...].astype(jnp.float32)
    d = d_ref[...].astype(jnp.float32)
    vr = vr_ref[...].astype(jnp.float32)
    va = va_ref[...].astype(jnp.float32)

    dv_r = (1.0 - alpha) * (g - vr)
    s1_ref[0] = jnp.sum(dv_r * va)
    s2_ref[0] = jnp.sum(va * va)
    nvr_ref[...] = (alpha * vr + (1.0 - alpha) * g).astype(nvr_ref.dtype)
    nva_ref[...] = (alpha * va + (1.0 - alpha) * (g * g * d)).astype(nva_ref.dtype)


def iter_fisher_leaf_stats_pallas(
    grad: jax.Array,
    delta: jax.Array,
    v_r: jax.Array,
    v_a: jax.Array,
    alpha: float,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    shape = grad.shape
    n = grad.size
    pad = (-n) % BLOCK
    def flat(a):
        return jnp.pad(a.reshape(-1).astype(jnp.float32), (0, pad))

    gf, df, vrf, vaf = flat(grad), flat(delta), flat(v_r), flat(v_a)
    nb = gf.shape[0] // BLOCK

    nvr, nva, s1b, s2b = pl.pallas_call(
        functools.partial(_stats_kernel, alpha=alpha),
        grid=(nb,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,)) for _ in range(4)],
        out_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(gf.shape, v_r.dtype),
            jax.ShapeDtypeStruct(gf.shape, v_a.dtype),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=interpret,
    )(gf, df, vrf, vaf)
    return (
        nvr[:n].reshape(shape),
        nva[:n].reshape(shape),
        jnp.sum(s1b),
        jnp.sum(s2b),
    )
