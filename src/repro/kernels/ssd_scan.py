"""Pallas TPU kernel: Mamba-2 SSD chunked scan.

TPU adaptation of the paper-adjacent SSD algorithm (arXiv:2405.21060): the
sequence is tiled into chunks of Q tokens; each grid step keeps one
(Q × headdim) input tile, the (Q × state) B/C tiles and the running
(headdim × state) SSM state in VMEM, does the three MXU contractions
(C·Bᵀ intra-chunk, W·x, state outer-product) at f32, and carries the state
across the sequential chunk axis in a VMEM scratch accumulator — the HBM
traffic is exactly one read of x/dt/B/C and one write of y per token.

Grid: (batch·heads, num_chunks); the chunk axis is the minor (sequential)
grid dimension, so the state scratch persists across it.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,  # (1, Q, 1, p)
    dt_ref,  # (1, Q, 1)
    A_ref,  # (1,)
    B_ref,  # (1, Q, n)
    C_ref,  # (1, Q, n)
    s0_ref,  # (1, 1, p, n)
    y_ref,  # out (1, Q, 1, p)
    sf_ref,  # out (1, 1, p, n)
    state,  # scratch (p, n) f32
    *,
    num_chunks: int,
):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state[...] = s0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (Q, p)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    A = A_ref[0].astype(jnp.float32)  # scalar
    B = B_ref[0].astype(jnp.float32)  # (Q, n)
    C = C_ref[0].astype(jnp.float32)  # (Q, n)

    dA = dt * A
    cs = jnp.cumsum(dA)  # (Q,) inclusive; ≤ 0 since A < 0

    s_in = state[...]
    # carried-state contribution: y_off[l] = exp(cs[l]) · C_l · s_in
    y_off = jnp.dot(C, s_in.T, preferred_element_type=jnp.float32) * jnp.exp(cs)[:, None]

    # intra-chunk: W[l,s] = (C_l·B_s) e^{cs_l - cs_s} dt_s for s ≤ l
    G = jnp.dot(C, B.T, preferred_element_type=jnp.float32)  # (Q, Q)
    Q = x.shape[0]
    li = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(li >= si, jnp.exp(cs[:, None] - cs[None, :]), 0.0)
    W = G * L * dt[None, :]
    y_diag = jnp.dot(W, x, preferred_element_type=jnp.float32)  # (Q, p)

    # state recurrence to the chunk end
    decay_end = jnp.exp(cs[-1] - cs)  # (Q,)
    inc = jnp.dot((x * (dt * decay_end)[:, None]).T, B, preferred_element_type=jnp.float32)
    new_state = s_in * jnp.exp(cs[-1]) + inc  # (p, n)
    state[...] = new_state

    y_ref[0, :, 0, :] = (y_off + y_diag).astype(y_ref.dtype)
    sf_ref[0, 0] = new_state.astype(sf_ref.dtype)


def ssd_scan_pallas(
    x: jax.Array,  # (b, l, h, p)
    dt: jax.Array,  # (b, l, h)
    A: jax.Array,  # (h,)
    B: jax.Array,  # (b, l, n)
    C: jax.Array,  # (b, l, n)
    chunk: int,
    initial_state: Optional[jax.Array] = None,  # (b, h, p, n)
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    b, l, h, p = x.shape
    n = B.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    if initial_state is None:
        initial_state = jnp.zeros((b, h, p, n), jnp.float32)

    y, sf = pl.pallas_call(
        functools.partial(_ssd_kernel, num_chunks=nc),
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bh, c: (bh // h, c, bh % h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bh, c: (bh // h, c, bh % h)),
            pl.BlockSpec((1,), lambda bh, c: (bh % h,)),
            pl.BlockSpec((1, chunk, n), lambda bh, c: (bh // h, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, c: (bh // h, c, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bh, c: (bh // h, bh % h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda bh, c: (bh // h, c, bh % h, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bh, c: (bh // h, bh % h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, initial_state)
    return y, sf
