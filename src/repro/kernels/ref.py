"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *reference semantics*; kernels must match them to
``assert_allclose`` tolerances across shape/dtype sweeps (see
``tests/test_kernels.py``). The model code calls these through
``repro.kernels.ops`` which dispatches kernel vs. reference.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Iter-Fisher gradient compensation (paper Eq. 8 / Alg. 1 inner loop)
# ---------------------------------------------------------------------------


def iter_fisher_compensate_ref(
    grad: jax.Array,
    deltas: jax.Array,  # (tau, *grad.shape): θ^{t+i} − θ^{t+i-1} for i = 0..τ-1
    lam: jax.Array,  # scalar λ
) -> jax.Array:
    """Iteratively apply  g ← g + λ · g ⊙ g ⊙ Δθ_i  for each staleness step.

    This is Eq. 9: A_I(... A_I(∇L(D;θ), θ^{t}, θ^{t-1}) ..., θ^{t+τ-1}, θ^{t+τ-2}).
    The iteration carries fp32 and casts back once at the end — the same
    accumulation the Pallas kernels (per-leaf and flat-packed) do, so all
    three paths agree for low-precision grads too.
    """

    def body(g32, delta):
        g32 = g32 + lam * g32 * g32 * delta.astype(jnp.float32)
        return g32, None

    out, _ = jax.lax.scan(body, grad.astype(jnp.float32), deltas)
    return out.astype(grad.dtype)


def iter_fisher_leaf_stats_ref(
    grad: jax.Array,
    delta: jax.Array,  # θ^t − θ^{t-1}
    v_r: jax.Array,
    v_a: jax.Array,
    alpha: float,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Per-leaf λ statistics (paper Eq. 10–12 / Alg. 1 lines 3–7).

    Returns (new_v_r, new_v_a, s1, s2) where

    dv_r = (1-α)(g − v_r)                     # Eq. 12: D − E
    s1   = Σ dv_r ⊙ v_a   (old v_a)           # for ∂/∂λ ‖dv_r − λ v_a‖²
    s2   = Σ v_a ⊙ v_a    (old v_a)
    v_r  ← α v_r + (1-α) g
    v_a  ← α v_a + (1-α) (g ⊙ g ⊙ Δθ)

    The caller combines s1/s2 over all leaves to update the *global* λ:
    λ ← λ − η_λ (−2 Σ s1 + 2 λ Σ s2 + 2 ν λ).
    """
    g = grad.astype(jnp.float32)
    d = delta.astype(jnp.float32)
    vr = v_r.astype(jnp.float32)
    va = v_a.astype(jnp.float32)

    dv_r = (1.0 - alpha) * (g - vr)
    s1 = jnp.sum(dv_r * va)
    s2 = jnp.sum(va * va)

    new_vr = alpha * vr + (1.0 - alpha) * g
    new_va = alpha * va + (1.0 - alpha) * (g * g * d)
    return new_vr.astype(v_r.dtype), new_va.astype(v_a.dtype), s1, s2


# ---------------------------------------------------------------------------
# Mamba-2 SSD chunked scan (state-space duality)
# ---------------------------------------------------------------------------


def _segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., l, s] = sum_{i=s+1..l} x[..., i], -inf above diag.

    x: (..., Q)  ->  (..., Q, Q) lower-triangular (inclusive of diagonal = 0
    on the diagonal since the sum over an empty range is 0).
    """
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)  # inclusive
    diff = cs[..., :, None] - cs[..., None, :]  # cs[l] - cs[s]
    mask = jnp.tril(jnp.ones((Q, Q), dtype=bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan_ref(
    x: jax.Array,  # (b, l, h, p)
    dt: jax.Array,  # (b, l, h)  positive (already softplus'd)
    A: jax.Array,  # (h,)       negative
    B: jax.Array,  # (b, l, n)
    C: jax.Array,  # (b, l, n)
    chunk: int,
    initial_state: Optional[jax.Array] = None,  # (b, h, p, n)
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan (Mamba-2). Returns (y (b,l,h,p), final_state (b,h,p,n)).

    Semantics: s_t = exp(dt_t A) s_{t-1} + dt_t B_t x_t ;  y_t = C_t · s_t.
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    assert l % chunk == 0, f"seq {l} not divisible by chunk {chunk}"
    c = l // chunk
    f32 = jnp.float32

    xc = x.reshape(b, c, chunk, h, p).astype(f32)
    dtc = dt.reshape(b, c, chunk, h).astype(f32)
    Bc = B.reshape(b, c, chunk, n).astype(f32)
    Cc = C.reshape(b, c, chunk, n).astype(f32)

    dA = dtc * A.astype(f32)  # (b, c, Q, h)
    dA_cs = jnp.cumsum(dA, axis=2)  # inclusive cumsum within chunk

    # ---- intra-chunk (diagonal blocks) ----
    Lmat = jnp.exp(_segsum(jnp.moveaxis(dA, -1, 2)))  # (b, c, h, Q, Q)
    y_diag = jnp.einsum(
        "bcln,bcsn,bchls,bcsh,bcshp->bclhp", Cc, Bc, Lmat, dtc, xc
    )

    # ---- per-chunk end states ----
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (b, c, Q, h)
    chunk_states = jnp.einsum("bcln,bclh,bclh,bclhp->bchpn", Bc, decay_to_end, dtc, xc)

    # ---- inter-chunk recurrence over chunk boundary states ----
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])  # (b, c, h)
    if initial_state is None:
        s0 = jnp.zeros((b, h, p, n), dtype=f32)
    else:
        s0 = initial_state.astype(f32)

    def scan_body(s_prev, inp):
        decay_c, state_c = inp  # (b, h), (b, h, p, n)
        s_before = s_prev
        s_after = s_prev * decay_c[:, :, None, None] + state_c
        return s_after, s_before

    decays = jnp.moveaxis(chunk_decay, 1, 0)  # (c, b, h)
    states = jnp.moveaxis(chunk_states, 1, 0)  # (c, b, h, p, n)
    final_state, states_before = jax.lax.scan(scan_body, s0, (decays, states))
    states_before = jnp.moveaxis(states_before, 0, 1)  # (b, c, h, p, n)

    # ---- contribution of carried-in state to each position ----
    state_decay = jnp.exp(dA_cs)  # (b, c, Q, h)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc, states_before, state_decay)

    y = (y_diag + y_off).reshape(b, l, h, p)
    return y.astype(x.dtype), final_state


def ssd_decode_step_ref(
    x: jax.Array,  # (b, h, p)
    dt: jax.Array,  # (b, h)
    A: jax.Array,  # (h,)
    B: jax.Array,  # (b, n)
    C: jax.Array,  # (b, n)
    state: jax.Array,  # (b, h, p, n)
) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrent SSD update. Returns (y (b,h,p), new_state)."""
    f32 = jnp.float32
    dA = jnp.exp(dt.astype(f32) * A.astype(f32))  # (b, h)
    inc = jnp.einsum("bh,bhp,bn->bhpn", dt.astype(f32), x.astype(f32), B.astype(f32))
    new_state = state.astype(f32) * dA[:, :, None, None] + inc
    y = jnp.einsum("bhpn,bn->bhp", new_state, C.astype(f32))
    return y.astype(x.dtype), new_state.astype(state.dtype)
