"""Flat-packed Iter-Fisher megakernels: one launch per compensation step.

The engine calls the compensator once per stage-update on a parameter
*pytree*.  Dispatching one ``pl.pallas_call`` per leaf (the previous
``repro.kernels.iter_fisher`` path) costs O(leaves) kernel launches per
step, and the old ``size % 128 == 0`` gate silently dropped most biases
and norm scales to the jnp reference.  This module removes both costs:

- ``PackSpec`` lays the whole pytree out in one contiguous fp32 buffer.
  Each leaf starts at an 8·128-aligned offset; the gaps are zero-padded.
  Zero is the identity for every Iter-Fisher quantity (Δθ = 0 ⇒ no
  compensation; g = v_r = v_a = 0 ⇒ no statistics), so padding never
  leaks into results.  Specs are computed once per partition structure
  and cached by (treedef, shapes, dtypes).
- ``compensate_tree`` / ``stats_tree`` run the Eq. 9 inner loop and the
  Alg. 1 λ-statistics as **one** ``pl.pallas_call`` each over the packed
  buffer — the λ-statistics s1/s2 block-reduce on-device in the same data
  pass (per-grid-step partials, race-free on sequential and parallel
  grids alike, plus a tiny on-device epilogue sum).  When packing is
  forced without Pallas (``REPRO_PACK=1`` on CPU), the same packed buffer
  goes through the jnp reference in one fused elementwise op instead of
  an O(leaves) Python loop.

``KERNEL_LAUNCHES`` counts actual ``pl.pallas_call`` invocations so tests
and ``benchmarks/bench_hotpath.py`` can assert the launch count is 1
regardless of leaf count.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref as _ref
from repro.kernels.iter_fisher import BLOCK  # default tile size for all kernels

Pytree = Any

ALIGN = 8 * 128  # fp32 VPU tile: every leaf starts on an (8, 128) boundary
assert BLOCK % ALIGN == 0, "packed grid tile must cover whole leaf slots"


def _resolve_block(block: Optional[int]) -> int:
    """The grid tile for this call: explicit argument > tuned/env default
    (``ops._pack_block``) > the module default. Must cover whole
    ALIGN-aligned leaf slots so a leaf never straddles two grid steps."""
    if block is None:
        from repro.kernels import ops

        block = ops._pack_block()
    if block is None:
        return BLOCK
    block = int(block)
    if block <= 0 or block % ALIGN != 0:
        raise ValueError(f"pack block must be a positive multiple of {ALIGN}, got {block}")
    return block

# pl.pallas_call invocations issued by this module (trace-time counter).
KERNEL_LAUNCHES = 0


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Packing layout
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PackSpec:
    """Flat layout of one pytree: leaf i occupies ``[offsets[i],
    offsets[i] + sizes[i])`` of a ``(total,)`` fp32 buffer; the tail of its
    ALIGN-rounded slot (and of the BLOCK-rounded buffer) is zero padding."""

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[str, ...]
    offsets: Tuple[int, ...]
    sizes: Tuple[int, ...]
    slots: Tuple[int, ...]  # ALIGN-rounded width of each leaf's slot
    total: int  # BLOCK-multiple buffer length

    @property
    def num_leaves(self) -> int:
        return len(self.sizes)


_SPEC_CACHE: Dict[Tuple, PackSpec] = {}


def pack_spec(tree: Pytree, block: Optional[int] = None) -> PackSpec:
    """The (cached) flat layout for ``tree``'s structure and leaf shapes.

    ``block`` is the kernel grid tile the buffer length rounds up to
    (default: the tuned/module block); specs are cached per block since
    ``total`` depends on it.
    """
    block = _resolve_block(block)
    leaves, treedef = jax.tree.flatten(tree)
    shapes = tuple(tuple(leaf.shape) for leaf in leaves)
    dtypes = tuple(str(jnp.asarray(leaf).dtype) for leaf in leaves)
    key = (treedef, shapes, dtypes, block)
    spec = _SPEC_CACHE.get(key)
    if spec is None:
        sizes, slots, offsets = [], [], []
        cursor = 0
        for shape in shapes:
            size = 1
            for d in shape:
                size *= d
            slot = max(_round_up(size, ALIGN), ALIGN)
            offsets.append(cursor)
            sizes.append(size)
            slots.append(slot)
            cursor += slot
        spec = PackSpec(
            treedef=treedef,
            shapes=shapes,
            dtypes=dtypes,
            offsets=tuple(offsets),
            sizes=tuple(sizes),
            slots=tuple(slots),
            total=max(_round_up(cursor, block), block),
        )
        _SPEC_CACHE[key] = spec
    return spec


def pack(spec: PackSpec, tree: Pytree, lead: int = 0) -> jax.Array:
    """Pack ``tree`` into a ``(*lead_dims, total)`` fp32 buffer.

    ``lead`` leading axes of every leaf (e.g. the stacked-Δθ axis) are kept;
    the remaining axes flatten into the leaf's slot. Gaps are zeros.
    Implemented as dynamic-update-slices into one zero buffer — XLA turns
    the chain into in-place writes, measurably cheaper than pad+concat.
    """
    leaves = jax.tree.leaves(tree)
    lead_shape = tuple(leaves[0].shape[:lead]) if leaves else ()
    out = jnp.zeros(lead_shape + (spec.total,), jnp.float32)
    for leaf, off in zip(leaves, spec.offsets):
        flat = jnp.asarray(leaf).reshape(lead_shape + (-1,)).astype(jnp.float32)
        out = jax.lax.dynamic_update_slice(out, flat, (0,) * lead + (off,))
    return out


def unpack(
    spec: PackSpec, flat: jax.Array, dtypes: Optional[Tuple[str, ...]] = None
) -> Pytree:
    """Invert ``pack`` for a ``(total,)`` buffer (casts back per-leaf)."""
    dtypes = dtypes or spec.dtypes
    leaves = [
        flat[off : off + size].reshape(shape).astype(dtype)
        for off, size, shape, dtype in zip(spec.offsets, spec.sizes, spec.shapes, dtypes)
    ]
    return jax.tree.unflatten(spec.treedef, leaves)


# ---------------------------------------------------------------------------
# Packed kernels (inputs are (total,) / (τ, total) fp32, total % BLOCK == 0)
# ---------------------------------------------------------------------------


def _compensate_kernel(lam_ref, g_ref, d_ref, o_ref, *, tau: int):
    g = g_ref[...]
    lam = lam_ref[0]
    for i in range(tau):
        g = g + lam * g * g * d_ref[i, :]
    o_ref[...] = g


def compensate_packed(
    gflat: jax.Array,
    dflat: jax.Array,
    lam: jax.Array,
    interpret: bool = False,
    block: Optional[int] = None,
) -> jax.Array:
    """Eq. 9 over the packed buffer: one launch for the whole pytree."""
    global KERNEL_LAUNCHES
    block = _resolve_block(block)
    tau = dflat.shape[0]
    if tau == 0:
        return gflat
    nb = gflat.shape[0] // block
    KERNEL_LAUNCHES += 1
    return pl.pallas_call(
        functools.partial(_compensate_kernel, tau=tau),
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # λ broadcast to every tile
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((tau, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(gflat.shape, jnp.float32),
        interpret=interpret,
    )(jnp.asarray(lam).reshape(1).astype(jnp.float32), gflat, dflat)


def _stats_kernel(g_ref, d_ref, vr_ref, va_ref, nvr_ref, nva_ref, s1_ref, s2_ref,
                  *, alpha: float):
    # Each grid step writes its own s1/s2 partial (race-free on any
    # backend, sequential or parallel grid); the BLOCK→1 reduction happens
    # here in the same data pass, the tiny nb→1 epilogue sum outside.
    g, d, vr, va = g_ref[...], d_ref[...], vr_ref[...], va_ref[...]
    dv_r = (1.0 - alpha) * (g - vr)
    s1_ref[0] = jnp.sum(dv_r * va)
    s2_ref[0] = jnp.sum(va * va)
    nvr_ref[...] = alpha * vr + (1.0 - alpha) * g
    nva_ref[...] = alpha * va + (1.0 - alpha) * (g * g * d)


def stats_packed(
    gflat: jax.Array,
    dflat: jax.Array,
    vrflat: jax.Array,
    vaflat: jax.Array,
    alpha: float,
    interpret: bool = False,
    block: Optional[int] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Alg. 1 λ-statistics over the packed buffer: one launch, s1/s2
    block-reduced on-device in the same pass. Returns (v_r', v_a', s1, s2)."""
    global KERNEL_LAUNCHES
    block = _resolve_block(block)
    nb = gflat.shape[0] // block
    KERNEL_LAUNCHES += 1
    nvr, nva, s1b, s2b = pl.pallas_call(
        functools.partial(_stats_kernel, alpha=alpha),
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)) for _ in range(4)],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(gflat.shape, jnp.float32),
            jax.ShapeDtypeStruct(gflat.shape, jnp.float32),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=interpret,
    )(gflat, dflat, vrflat, vaflat)
    return nvr, nva, jnp.sum(s1b), jnp.sum(s2b)


# ---------------------------------------------------------------------------
# Tree-level entrypoints (pack → one kernel / one fused jnp op → unpack)
# ---------------------------------------------------------------------------


def compensate_tree(
    grad: Pytree,
    deltas: Pytree,  # per leaf: (τ, *leaf.shape), oldest first
    lam: jax.Array,
    use_pallas: bool = False,
    interpret: bool = False,
    block: Optional[int] = None,
) -> Pytree:
    """Whole-pytree Iter-Fisher compensation in a single pass."""
    leaves_d = jax.tree.leaves(deltas)
    tau = leaves_d[0].shape[0] if leaves_d else 0
    if tau == 0:
        return grad
    block = _resolve_block(block)
    spec = pack_spec(grad, block)
    gflat = pack(spec, grad)
    dflat = pack(spec, deltas, lead=1)
    if use_pallas:
        out = compensate_packed(gflat, dflat, lam, interpret=interpret, block=block)
    else:
        out = _ref.iter_fisher_compensate_ref(gflat, dflat, lam)
    return unpack(spec, out)


def stats_tree(
    grad: Pytree,
    delta: Pytree,
    v_r: Pytree,
    v_a: Pytree,
    alpha: float,
    use_pallas: bool = False,
    interpret: bool = False,
    block: Optional[int] = None,
) -> Tuple[Pytree, Pytree, jax.Array, jax.Array]:
    """Whole-pytree λ-statistics: (v_r', v_a', Σ s1, Σ s2) in a single pass.

    The returned s1/s2 are on-device fp32 scalars — there is no per-leaf
    host accumulation anywhere on this path.
    """
    block = _resolve_block(block)
    spec = pack_spec(grad, block)
    gflat = pack(spec, grad)
    dflat = pack(spec, delta)
    vrflat = pack(spec, v_r)
    vaflat = pack(spec, v_a)
    if use_pallas:
        nvr, nva, s1, s2 = stats_packed(
            gflat, dflat, vrflat, vaflat, alpha, interpret, block=block
        )
    else:
        nvr, nva, s1, s2 = _ref.iter_fisher_leaf_stats_ref(
            gflat, dflat, vrflat, vaflat, alpha
        )
    vr_dtypes = tuple(str(leaf.dtype) for leaf in jax.tree.leaves(v_r))
    va_dtypes = tuple(str(leaf.dtype) for leaf in jax.tree.leaves(v_a))
    return (
        unpack(spec, nvr, vr_dtypes),
        unpack(spec, nva, va_dtypes),
        s1,
        s2,
    )
