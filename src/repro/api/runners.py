"""Runner protocol + registry: four execution modes, one result shape.

A ``Runner`` turns (session, params, stream-of-arrays) into the unified
``repro.api.StreamResult``. The four built-ins cover the repo's execution
modes, previously reachable only through divergent entrypoints:

- ``pipelined``  — plan once, run the fine-grained async pipeline engine
                   (was ``FerretTrainer.run_stream``); streaming-native:
                   consumes a ``StreamSource`` segment by segment
- ``elastic``    — segmented run under a varying budget with live replan +
                   state remap (was ``ElasticStreamTrainer.run_stream``)
- ``sequential`` — exact per-item predict-then-train loop (the Oracle;
                   alias ``oracle``), with the OCL algorithm's exact
                   sequential path (true MIR, LwF teacher, MAS Ω)
- ``baseline``   — the same sequential loop gated by a stream-admission
                   policy (1-Skip / Random-N / Last-N / Camel)

Register your own:

    from repro.api import Runner, register_runner

    @register_runner
    class MyRunner(Runner):
        name = "my-runner"
        def run(self, session, params, stream, **opts): ...
"""

from __future__ import annotations

import collections
from typing import Any, Dict, List, Optional, Type, Union

import jax.numpy as jnp
import numpy as np

from repro.api.results import StreamResult
from repro.ocl import metrics as metrics_lib
from repro.ocl.baselines import AdmissionPolicy, make_admission_mask
from repro.ocl.registry import make_sequential_step

Pytree = Any

_RUNNERS: Dict[str, Type["Runner"]] = {}


def register_runner(cls: Type["Runner"]) -> Type["Runner"]:
    """Class decorator: register ``cls`` under ``cls.name`` (+ aliases)."""
    name = getattr(cls, "name", None)
    if not name or not isinstance(name, str):
        raise ValueError(f"{cls!r} needs a string class attribute `name`")
    _RUNNERS[name] = cls
    for alias in getattr(cls, "aliases", ()):
        _RUNNERS[alias] = cls
    return cls


def available_runners() -> List[str]:
    return sorted(_RUNNERS)


def get_runner(spec: Union[str, "Runner"]) -> "Runner":
    if isinstance(spec, Runner):
        return spec
    if spec not in _RUNNERS:
        raise ValueError(
            f"unknown runner {spec!r}; registered runners: "
            f"{', '.join(available_runners())}. Add your own with "
            "@repro.api.register_runner."
        )
    return _RUNNERS[spec]()


class Runner:
    """Base runner. ``consumes_source`` says the runner takes a
    ``StreamSource`` and pulls rounds incrementally (no up-front
    materialization; stream preparation happens inside the runner, per
    pulled chunk) — the session then resolves the stream to a source
    instead of arrays. Both pipeline-path built-ins (pipelined, elastic)
    declare it.

    ``prepare_stream`` says a *materializing* runner wants the session to
    run the algorithm's whole-stream preparation (replay mixing, LwF
    teacher logits) before handing the arrays over — kept for custom
    runners; the sequential paths manage replay/teacher state exactly,
    per step, instead.

    Concrete runners declare their options explicitly — a misspelled
    option to ``session.run`` raises ``TypeError`` instead of being
    silently ignored."""

    name: str = ""
    aliases: tuple = ()
    prepare_stream: bool = False
    consumes_source: bool = False

    def run(
        self, session, params: Pytree, stream: Dict[str, np.ndarray], **opts
    ) -> StreamResult:
        raise NotImplementedError


def _rounds(stream: Dict[str, np.ndarray]) -> int:
    return next(iter(stream.values())).shape[0]


def _model_bytes(model_cfg) -> float:
    return float(model_cfg.param_count()) * 4.0


# ---------------------------------------------------------------------------
# Pipelined + elastic (the planned pipeline engine)
# ---------------------------------------------------------------------------


@register_runner
class PipelinedRunner(Runner):
    """Single-plan fine-grained async pipeline (Ferret proper).

    Streaming-native: the session hands over a ``StreamSource`` (unbounded
    live feeds included) and the trainer pulls ``take(segment_rounds)``
    per segment through a prefetching feeder — peak stream residency stays
    O(segment), never O(R), and the chunked run is bit-exact with the
    materialized single-scan run. Stream preparation (ER mixing, LwF
    teacher logits) runs inside the trainer, per pulled chunk; algorithms
    with a parameter-space penalty (MAS) apply it through the
    ``FerretEngine`` hook instead of degrading to Vanilla."""

    name = "pipelined"
    consumes_source = True

    def run(self, session, params, stream, *, segment_rounds=None, prefetch=True):
        from repro.core.ferret import FerretTrainer

        trainer = FerretTrainer(
            session.model_cfg, session.ferret_cfg,
            batch=session.batch, seq=session.seq,
            optimizer=session.optimizer, profile=session.profile,
            algorithm=session.algorithm,
            topology=getattr(session, "topology", None),
        )
        raw = trainer.run_stream(
            params, stream, segment_rounds=segment_rounds, prefetch=prefetch
        )
        return StreamResult(
            runner=self.name,
            algorithm=session.algorithm.name,
            online_acc=raw.online_acc,
            online_acc_curve=raw.online_acc_curve,
            losses=np.asarray(raw.losses),
            # consumed-rounds accounting, same semantics as the elastic
            # runner (a capped/early-ending source reports what it ran)
            rounds=int(raw.rounds),
            admitted_frac=raw.admitted_frac,
            memory_bytes=raw.memory_bytes,
            empirical_rate=raw.empirical_rate,
            final_params=trainer.final_params,
            plan=raw.plan,
            extras={
                "raw": raw,
                "lam_curve": raw.lam_curve,
                "peak_buffered_rounds": raw.peak_buffered_rounds,
                "stream_wait_s": raw.stream_wait_s,
            },
        )


@register_runner
class ElasticRunner(Runner):
    """Segmented run under a (possibly varying) budget: live replan + state
    remap at every budget change, crash-restore via ``resume=``.

    Consumes its stream incrementally: the session hands over a
    ``StreamSource`` (unbounded live feeds included) and the trainer pulls
    ``take(segment_rounds)`` per segment with prefetch — stream residency
    stays O(segment), never O(R). Stream preparation (ER mixing, LwF
    teacher logits) runs inside the trainer, per pulled chunk."""

    name = "elastic"
    consumes_source = True

    def run(
        self, session, params, stream, *,
        schedule=(), segment_rounds=None, supervisor_cfg=None,
        fault_rounds=(), fault_budget_scale=0.5, resume=None,
        engine_cache=None, prefetch=True,
    ):
        from repro.runtime.elastic_trainer import ElasticStreamTrainer

        trainer = ElasticStreamTrainer(
            session.model_cfg, session.ferret_cfg,
            batch=session.batch, seq=session.seq,
            optimizer=session.optimizer, profile=session.profile,
            algorithm=session.algorithm,
            engine_cache=engine_cache,
            topology=getattr(session, "topology", None),
        )
        raw = trainer.run_stream(
            params, stream, schedule,
            segment_rounds=segment_rounds, supervisor_cfg=supervisor_cfg,
            fault_rounds=fault_rounds, fault_budget_scale=fault_budget_scale,
            resume=resume, prefetch=prefetch,
        )
        return stream_result_from_elastic(
            raw, runner=self.name, algorithm=session.algorithm.name,
            model_cfg=session.model_cfg,
        )


def stream_result_from_elastic(
    raw, *, runner: str, algorithm: str, model_cfg
) -> StreamResult:
    """Fold an ``ElasticStreamResult`` into the unified ``StreamResult``.

    Shared by the elastic runner and the multi-tenant server's per-tenant
    reporting, so both surfaces present identical accounting."""
    # a zero-round stream plans nothing: report the resident weights,
    # not the inf that max(..., default=...) used to produce
    peak_mem = max(
        (s.result.memory_bytes for s in raw.segments),
        default=_model_bytes(model_cfg),
    )
    return StreamResult(
        runner=runner,
        algorithm=algorithm,
        online_acc=raw.online_acc,
        online_acc_curve=raw.online_acc_curve,
        losses=np.asarray(raw.losses),
        rounds=raw.rounds,
        admitted_frac=raw.admitted_frac,
        memory_bytes=peak_mem,
        empirical_rate=raw.empirical_rate,
        final_params=raw.final_params,
        plan=raw.segments[0].result.plan if raw.segments else None,
        segments=list(raw.segments),
        num_replans=raw.num_replans,
        engine_cache_hits=raw.engine_cache_hits,
        engine_cache_misses=raw.engine_cache_misses,
        extras={
            "raw": raw,
            "num_faults": raw.num_faults,
            "peak_buffered_rounds": raw.peak_buffered_rounds,
            "stream_wait_s": raw.stream_wait_s,
            # 0 ⇔ every budget switch this run made was lossless (in-flight
            # accumulation rings carried or flushed, never dropped)
            "rounds_lost_per_switch": raw.rounds_lost_per_switch,
            # stream-wide λ trajectory, same key the pipelined runner
            # reports (stitched across segments here)
            "lam_curve": (
                np.concatenate([s.result.lam_curve for s in raw.segments])
                if raw.segments else np.zeros(0)
            ),
        },
    )


# ---------------------------------------------------------------------------
# Sequential paths (exact OCL algorithms; Oracle + admission baselines)
# ---------------------------------------------------------------------------


def _sequential_loop(session, params, stream, trained_mask=None):
    """Exact predict-then-train loop with the algorithm's sequential path.

    Accuracy is measured pre-update (online accuracy); ``trained_mask``
    gates the parameter update (admission baselines) while prediction
    still happens for every item.
    """
    from repro.models import transformer as T
    from repro.models.layers import cross_entropy_loss

    cfg = session.model_cfg
    algo = session.algorithm
    algo.reset()

    def loss_fn(p, batch):
        logits, _aux = T.forward(cfg, p, batch)
        ce = cross_entropy_loss(logits, batch["labels"], batch.get("mask"))
        acc = jnp.mean((jnp.argmax(logits, -1) == batch["labels"]).astype(jnp.float32))
        return ce, {"acc": acc}

    def forward_fn(p, batch):
        return T.forward(cfg, p, batch)[0]

    algo.bind_forward(forward_fn)
    opt = session.optimizer
    opt_state = opt.init(params)
    step, eval_fn, helpers = make_sequential_step(algo, loss_fn, forward_fn, opt)

    R = _rounds(stream)
    refresh = int(algo.cfg.refresh_every)
    recent: collections.deque = collections.deque(maxlen=4)
    losses, accs = [], []
    skip_fields = ("new_mask", "teacher_logits")
    for m in range(R):
        batch = {
            k: jnp.asarray(v[m]) for k, v in stream.items() if k not in skip_fields
        }
        if refresh > 0 and m > 0 and m % refresh == 0:
            algo.sequential_refresh(params, list(recent))
        extras = algo.host_extras(params, opt_state, batch, helpers)
        if trained_mask is None or bool(trained_mask[m]):
            params, opt_state, loss, metrics = step(params, opt_state, batch, extras)
        else:
            loss, metrics = eval_fn(params, batch)
        algo.observe(batch)
        recent.append(batch)
        losses.append(float(loss))
        accs.append(float(metrics["acc"]))
    return params, np.asarray(losses), np.asarray(accs)


def _sequential_result(
    session, runner_name, params, losses, accs, delays, admitted, memory, extras
) -> StreamResult:
    fc = session.ferret_cfg
    values = np.full(delays.shape, fc.data_value, np.float64)
    rate = metrics_lib.adaptation_rate_empirical(delays, c=fc.decay_c, values=values)
    return StreamResult(
        runner=runner_name,
        algorithm=session.algorithm.name,
        online_acc=float(accs.mean()) if accs.size else 0.0,
        online_acc_curve=np.cumsum(accs) / np.arange(1, accs.size + 1),
        losses=losses,
        rounds=int(accs.size),
        admitted_frac=float(np.mean(admitted)) if len(admitted) else 0.0,
        memory_bytes=memory,
        empirical_rate=rate,
        final_params=params,
        extras=extras,
    )


@register_runner
class SequentialRunner(Runner):
    """Oracle: every item trained on arrival, zero delay."""

    name = "sequential"
    aliases = ("oracle", "sequential-oracle")

    def run(self, session, params, stream):
        R = _rounds(stream)
        params, losses, accs = _sequential_loop(session, params, stream)
        return _sequential_result(
            session, self.name, params, losses, accs,
            delays=np.zeros(R), admitted=np.ones(R, bool),
            memory=_model_bytes(session.model_cfg), extras={},
        )


@register_runner
class BaselineRunner(Runner):
    """Stream-admission baselines: the sequential loop gated by a policy.

    opts: ``policy`` (an ``AdmissionPolicy`` or a method name such as
    ``"one_skip"``), ``slowdown`` (t_train / t_d — how much slower training
    is than arrival), ``features`` ((R, d) array for Camel's coreset).
    """

    name = "baseline"

    def run(
        self, session, params, stream, *,
        policy: Union[str, AdmissionPolicy] = "one_skip",
        slowdown: float = 3.0, features: Optional[np.ndarray] = None,
    ):
        pol = policy if isinstance(policy, AdmissionPolicy) else AdmissionPolicy(policy)
        R = _rounds(stream)
        trace = make_admission_mask(
            pol, R, t_d=1.0, t_train=float(slowdown), features=features
        )
        params, losses, accs = _sequential_loop(
            session, params, stream, trained_mask=trace.admitted
        )
        memory = _model_bytes(session.model_cfg)
        if pol.method in ("random_n", "last_n", "camel"):
            item_bytes = sum(
                np.asarray(v[0]).nbytes for k, v in stream.items()
            )
            memory += pol.buffer * item_bytes
        return _sequential_result(
            session, self.name, params, losses, accs,
            delays=trace.delays, admitted=trace.admitted, memory=memory,
            extras={"raw": trace, "delays": trace.delays, "policy": pol},
        )
