"""``repro.api`` — the one session layer over the Ferret reproduction.

The paper pitches a *framework*: five integrated OCL algorithms behind one
planner/pipeline engine. This package is that framework's stable surface —
three small protocols and one front door — so adding an algorithm, an
execution mode, or a stream type is additive (register a class) instead of
invasive (edit every trainer and benchmark in lockstep).

Front door::

    from repro.api import FerretSession

    session = FerretSession(model_cfg, budget, "er", stream)
    result = session.run()              # -> unified StreamResult
    result = session.run("elastic", schedule=[BudgetEvent(120, 2**30)])

The three protocols
===================

``Runner`` (repro.api.runners)
    Turns ``(session, params, stream_arrays)`` into a ``StreamResult``.
    Registered by name with ``@register_runner``; resolved by
    ``session.run(name)``. Built-ins: ``pipelined`` (single-plan async
    pipeline engine), ``elastic`` (segmented varying-budget run, live
    replan + state remap, crash-resume), ``sequential`` (exact
    predict-then-train Oracle; alias ``oracle``), ``baseline``
    (admission-policy-gated sequential loop). A runner declares
    ``consumes_source = True`` to receive a ``StreamSource`` and pull
    rounds incrementally (both pipeline-path built-ins do; stream
    preparation then happens inside the trainer, per pulled chunk), or
    ``prepare_stream = True`` to have the session run the algorithm's
    whole-stream preparation before handing over materialized arrays.

``OCLAlgorithm`` (repro.ocl.registry, re-exported here)
    One class per algorithm, registered with ``@register_algorithm`` and
    selected by ``OCLConfig.method`` or by name. An instance owns both
    execution paths: the pipeline path (``prepare_stream`` /
    ``wrap_staged`` / ``engine_penalty`` / ``segment_refresh``) consumed
    by the pipelined and elastic runners, and the exact sequential path
    (``sequential_loss_extra`` / ``host_extras`` / ``observe`` /
    ``sequential_refresh``) consumed by the sequential and baseline
    runners. Built-ins: ``vanilla``, ``er``, ``mir``, ``lwf``, ``mas``.

``StreamSource`` (repro.api.streams)
    An exactly-once producer of dict-of-array stream rounds:
    ``take(n)`` pops up to n stacked rounds, ``materialize(max_rounds)``
    drains to the array form the engines scan over. ``ArrayStreamSource``
    wraps finite arrays (what ``make_stream`` returns),
    ``IterableStreamSource`` wraps generators and live/unbounded feeds,
    ``BufferedStreamSource`` adds replay-buffering + background prefetch
    (the incremental elastic path's feeder), ``LimitedStreamSource`` caps
    a feed at ``max_rounds``, and ``as_stream_source`` coerces dicts /
    ``StreamConfig`` / iterables. The pipelined and elastic runners
    consume a source directly — segment-by-segment ``take()``, no
    up-front materialization; the sequential/baseline runners
    materialize.

Everything returns one ``StreamResult`` (repro.api.results) — runner name,
algorithm name, online accuracy (+curve), per-round losses, admitted
fraction, planned memory, empirical adaptation rate, final params, and
per-segment reports for elastic runs.

The pre-session entrypoints (``FerretTrainer``, ``ElasticStreamTrainer``,
``sequential_oracle_run``, ``wrap_staged_model``, ``make_ocl_step``,
``mix_replay_into_stream``) remain importable as thin shims over the same
machinery.
"""

from repro.api.results import StreamResult
from repro.api.runners import (
    BaselineRunner,
    ElasticRunner,
    PipelinedRunner,
    Runner,
    SequentialRunner,
    available_runners,
    get_runner,
    register_runner,
)
from repro.api.session import FerretSession
from repro.api.streams import (
    ArrayStreamSource,
    BufferedStreamSource,
    IterableStreamSource,
    LimitedStreamSource,
    StreamSource,
    as_stream_source,
    coerce_trainer_stream,
)
from repro.ocl.algorithms import OCLConfig
from repro.ocl.registry import (
    OCLAlgorithm,
    PrepareContext,
    available_algorithms,
    get_algorithm,
    register_algorithm,
)

__all__ = [
    "ArrayStreamSource",
    "BaselineRunner",
    "BufferedStreamSource",
    "ElasticRunner",
    "FerretSession",
    "IterableStreamSource",
    "LimitedStreamSource",
    "OCLAlgorithm",
    "OCLConfig",
    "PipelinedRunner",
    "PrepareContext",
    "Runner",
    "SequentialRunner",
    "StreamResult",
    "StreamSource",
    "as_stream_source",
    "coerce_trainer_stream",
    "available_algorithms",
    "available_runners",
    "get_algorithm",
    "get_runner",
    "register_algorithm",
    "register_runner",
]
