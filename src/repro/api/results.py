"""The one result shape every runner returns.

Before the session layer, each entrypoint returned its own shape
(``core.ferret.StreamResult``, ``runtime.ElasticStreamResult``, ad-hoc
dicts from ``sequential_oracle_run`` / the admission baselines). Every
``repro.api`` runner now returns this ``StreamResult``; the runner-specific
raw object rides in ``extras["raw"]`` when callers need it.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

Pytree = Any


@dataclasses.dataclass
class StreamResult:
    """Unified outcome of running one stream through one runner."""

    runner: str  # registered runner name
    algorithm: str  # registered OCL algorithm name
    online_acc: float  # mean pre-update accuracy over the stream
    online_acc_curve: np.ndarray  # cumulative curve, one entry per consumed round
    losses: np.ndarray  # per-round training loss
    rounds: int  # stream rounds consumed (exactly once)
    admitted_frac: float  # fraction of items that received an update
    memory_bytes: float  # planned/estimated peak memory footprint
    empirical_rate: float  # Def. 4.1 empirical adaptation rate
    final_params: Pytree
    plan: Optional[Any] = None  # planner Plan (pipelined/elastic)
    segments: List[Any] = dataclasses.field(default_factory=list)  # SegmentReports
    num_replans: int = 0
    engine_cache_hits: int = 0  # compiled-scan reuses (elastic runner)
    engine_cache_misses: int = 0  # fresh engine compiles (elastic runner)
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def summary(self) -> str:
        mem = (
            "inf" if not np.isfinite(self.memory_bytes)
            else f"{self.memory_bytes / 2**20:.1f}MiB"
        )
        return (
            f"[{self.runner}/{self.algorithm}] oacc={100 * self.online_acc:.2f}% "
            f"admitted={100 * self.admitted_frac:.0f}% rounds={self.rounds} "
            f"mem={mem} rate={self.empirical_rate:.3f}"
        )
