"""The one result shape every runner returns.

Before the session layer, each entrypoint returned its own shape
(``core.ferret.StreamResult``, ``runtime.ElasticStreamResult``, ad-hoc
dicts from ``sequential_oracle_run`` / the admission baselines). Every
``repro.api`` runner now returns this ``StreamResult``; the runner-specific
raw object rides in ``extras["raw"]`` when callers need it.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

Pytree = Any


@dataclasses.dataclass
class StreamResult:
    """Unified outcome of running one stream through one runner."""

    runner: str  # registered runner name
    algorithm: str  # registered OCL algorithm name
    online_acc: float  # mean pre-update accuracy over the stream
    online_acc_curve: np.ndarray  # cumulative curve, one entry per consumed round
    losses: np.ndarray  # per-round training loss
    rounds: int  # stream rounds consumed (exactly once)
    admitted_frac: float  # fraction of items that received an update
    memory_bytes: float  # planned/estimated peak memory footprint
    empirical_rate: float  # Def. 4.1 empirical adaptation rate
    final_params: Pytree
    plan: Optional[Any] = None  # planner Plan (pipelined/elastic)
    segments: List[Any] = dataclasses.field(default_factory=list)  # SegmentReports
    num_replans: int = 0
    engine_cache_hits: int = 0  # compiled-scan reuses (elastic runner)
    engine_cache_misses: int = 0  # fresh engine compiles (elastic runner)
    extras: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # -- typed accessors over the runner extras -----------------------------
    # The pipeline-path runners report streaming/caching observability as
    # extras entries; these accessors are the supported way to read them —
    # BENCH_* writers and the server's per-tenant reporting use these
    # instead of string-probing the dict (absent entries read as empty).

    @property
    def peak_buffered_rounds(self) -> int:
        """Max stream rounds resident in the feeder (O(segment) bound)."""
        return int(self.extras.get("peak_buffered_rounds", 0))

    @property
    def stream_wait_s(self) -> float:
        """Total un-overlapped wall time blocked on the stream source."""
        return float(self.extras.get("stream_wait_s", 0.0))

    @property
    def lam_curve(self) -> np.ndarray:
        """Per-round Iter-Fisher λ trajectory (empty when not tracked)."""
        return np.asarray(self.extras.get("lam_curve", np.zeros(0)))

    @property
    def cache_counts(self) -> Dict[str, int]:
        """Engine-compile cache accounting for this run."""
        return {"hits": self.engine_cache_hits, "misses": self.engine_cache_misses}

    @property
    def rounds_lost_per_switch(self) -> int:
        """Max in-flight backward rounds dropped at any budget switch.

        0 on the default lossless path (the elastic trainer carries or
        flushes the accumulation rings at every re-plan); non-zero only
        under the explicit ``carry_rings=False`` escape hatch."""
        return int(self.extras.get("rounds_lost_per_switch", 0))

    def metrics(self) -> Dict[str, Any]:
        """The scalar observability surface as one flat typed dict — what
        benchmark writers serialize and the server reports per tenant."""
        return {
            "runner": self.runner,
            "algorithm": self.algorithm,
            "online_acc": float(self.online_acc),
            "admitted_frac": float(self.admitted_frac),
            "rounds": int(self.rounds),
            "memory_bytes": float(self.memory_bytes),
            "empirical_rate": float(self.empirical_rate),
            "num_replans": int(self.num_replans),
            "engine_cache_hits": int(self.engine_cache_hits),
            "engine_cache_misses": int(self.engine_cache_misses),
            "peak_buffered_rounds": self.peak_buffered_rounds,
            "stream_wait_s": self.stream_wait_s,
            "rounds_lost_per_switch": self.rounds_lost_per_switch,
        }

    def summary(self) -> str:
        mem = (
            "inf" if not np.isfinite(self.memory_bytes)
            else f"{self.memory_bytes / 2**20:.1f}MiB"
        )
        return (
            f"[{self.runner}/{self.algorithm}] oacc={100 * self.online_acc:.2f}% "
            f"admitted={100 * self.admitted_frac:.0f}% rounds={self.rounds} "
            f"mem={mem} rate={self.empirical_rate:.3f}"
        )
