"""FerretSession: the front door of the reproduction.

    from repro.api import FerretSession

    session = FerretSession(model_cfg, budget=2 * 2**30, algorithm="er",
                            stream=make_stream(StreamConfig(...)))
    result = session.run()                 # pipelined engine (default)
    result = session.run("elastic", schedule=[BudgetEvent(120, 2**30)])
    result = session.run("sequential")     # exact Oracle loop
    result = session.run("baseline", policy="one_skip")

One call signature across every execution mode and every registered OCL
algorithm; every run returns the unified ``repro.api.StreamResult``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Union

import jax
import numpy as np

from repro.api.results import StreamResult
from repro.api.runners import Runner, get_runner
from repro.api.streams import StreamLike, StreamSource, as_stream_source
from repro.core import planner as planner_lib
from repro.core.compensation import CompensationConfig
from repro.core.ferret import FerretConfig
from repro.core.profiler import ModelProfile, analytic_profile
from repro.models.config import ModelConfig
from repro.ocl.algorithms import OCLConfig
from repro.ocl.registry import OCLAlgorithm, PrepareContext, get_algorithm
from repro.optim.optimizers import Optimizer, adamw

Pytree = Any


class FerretSession:
    """One OCL session: a model, a memory budget, an algorithm, a stream.

    ``model`` is a ``ModelConfig`` or a registered architecture name
    (resolved with ``smoke=True`` reductions by default). ``algorithm`` is
    a registered name, an ``OCLConfig`` (its ``method`` selects), or an
    ``OCLAlgorithm`` instance; when omitted it resolves from ``ocl=`` /
    ``ferret.ocl`` (default ``"vanilla"``). ``stream`` is anything
    ``repro.api.as_stream_source`` accepts; it may also be given per-run.

    ``batch``/``seq`` are inferred from the stream's token arrays when not
    given. The *session* stream is materialized exactly once and cached,
    so successive ``run(...)`` calls compare runners on identical data: a
    bounded stream caches in full (``max_rounds`` slices a prefix), an
    unbounded stream caches the first run's ``max_rounds`` window (asking
    for more later raises). To feed fresh rounds (e.g. successive windows
    of a live source), pass ``stream=`` to ``run`` — explicit streams are
    materialized per call and never cached.
    """

    def __init__(
        self,
        model: Union[ModelConfig, str],
        budget: Optional[float] = None,
        algorithm: Optional[Union[str, OCLConfig, OCLAlgorithm]] = None,
        stream: Optional[StreamLike] = None,
        *,
        runner: Union[str, Runner] = "pipelined",
        batch: Optional[int] = None,
        seq: Optional[int] = None,
        lr: float = 5e-3,
        seed: int = 0,
        compensation: Optional[CompensationConfig] = None,
        ocl: Optional[OCLConfig] = None,
        ferret: Optional[FerretConfig] = None,
        max_workers: Optional[int] = 8,
        max_stages: Optional[int] = None,
        optimizer: Optional[Optimizer] = None,
        profile: Optional[ModelProfile] = None,
        params: Optional[Pytree] = None,
        smoke: bool = True,
    ):
        if isinstance(model, str):
            from repro.models.registry import get_config

            model = get_config(model, smoke=smoke)
        self.model_cfg = model

        if isinstance(algorithm, OCLAlgorithm):
            self.algorithm = algorithm
        elif algorithm is None:
            # no explicit algorithm: honor the method carried by ocl= /
            # ferret.ocl instead of silently defaulting to vanilla
            spec = ocl if ocl is not None else (
                ferret.ocl if ferret is not None else "vanilla"
            )
            self.algorithm = get_algorithm(spec)
        else:
            self.algorithm = get_algorithm(algorithm, ocl)
        if ferret is None:
            ferret = FerretConfig(
                budget_bytes=math.inf if budget is None else budget,
                lr=lr,
                compensation=compensation or CompensationConfig(),
                ocl=self.algorithm.cfg,
                max_workers=max_workers,
                max_stages=max_stages,
            )
        else:
            # explicit FerretConfig wins, but an explicit budget argument
            # overrides its budget_bytes (never silently ignored), and its
            # ocl is kept in sync with the resolved algorithm so both
            # execution paths see one config
            over = {"ocl": self.algorithm.cfg}
            if budget is not None:
                over["budget_bytes"] = budget
            ferret = dataclasses.replace(ferret, **over)
        self.ferret_cfg = ferret

        self.stream: Optional[StreamSource] = (
            as_stream_source(stream) if stream is not None else None
        )
        self.batch = batch
        self.seq = seq
        self.default_runner = runner
        self.seed = seed
        self.optimizer = optimizer or adamw(lr=ferret.lr)
        self.profile = profile
        self._params = params
        self._cached_stream: Optional[Dict[str, np.ndarray]] = None
        self._cache_is_full = False

    # -- lazy pieces -------------------------------------------------------
    @property
    def params(self) -> Pytree:
        if self._params is None:
            from repro.models import transformer as T

            self._params = T.init_params(self.model_cfg, jax.random.PRNGKey(self.seed))
        return self._params

    @params.setter
    def params(self, value: Pytree) -> None:
        self._params = value

    @property
    def plan(self) -> planner_lib.Plan:
        """The pipelined plan for this session's budget (Alg. 3 ∘ Alg. 2)."""
        if (self.batch is None or self.seq is None) and self.stream is not None:
            self._infer_shapes(self._resolve_stream(None, None))
        if self.batch is None or self.seq is None:
            raise ValueError(
                "plan needs batch/seq — pass them to FerretSession or give "
                "the session a stream they can be inferred from"
            )
        profile = self.profile or analytic_profile(self.model_cfg, self.batch, self.seq)
        t_d = self.ferret_cfg.t_d or planner_lib.default_data_interval(profile)
        return planner_lib.plan(
            profile,
            t_d,
            self.ferret_cfg.budget_bytes,
            c=self.ferret_cfg.decay_c,
            V_D=self.ferret_cfg.data_value,
            max_workers=self.ferret_cfg.max_workers,
            max_stages=self.ferret_cfg.max_stages,
        )

    # -- the one entrypoint ------------------------------------------------
    def run(
        self,
        runner: Optional[Union[str, Runner]] = None,
        *,
        stream: Optional[StreamLike] = None,
        params: Optional[Pytree] = None,
        max_rounds: Optional[int] = None,
        **runner_opts,
    ) -> StreamResult:
        """Run the stream through a registered runner. One signature for
        every (runner × algorithm) pair; returns the unified StreamResult."""
        r = get_runner(runner if runner is not None else self.default_runner)
        arrays = self._resolve_stream(stream, max_rounds)
        self._infer_shapes(arrays)
        run_params = params if params is not None else self.params
        self.algorithm.reset()
        if r.prepare_stream:
            from repro.models import transformer as T

            ctx = PrepareContext(
                params=run_params,
                forward_fn=lambda p, b: T.forward(self.model_cfg, p, b)[0],
            )
            arrays = self.algorithm.prepare_stream(arrays, ctx)
        return r.run(self, run_params, arrays, **runner_opts)

    # -- internals ---------------------------------------------------------
    def _resolve_stream(
        self, stream: Optional[StreamLike], max_rounds: Optional[int]
    ) -> Dict[str, np.ndarray]:
        if stream is not None:  # explicit per-run stream: never cached
            return as_stream_source(stream).materialize(max_rounds)
        if self.stream is None:
            raise ValueError(
                "no stream: pass stream= to FerretSession(...) or run(...)"
            )
        # the session stream is materialized exactly once and cached so
        # every run compares runners on identical data: bounded streams
        # cache in full (max_rounds always slices a prefix); unbounded
        # streams cache the first run's window, and asking for more than
        # that window later is an error, never a silent truncation
        if self._cached_stream is None:
            self._cache_is_full = self.stream.length is not None
            self._cached_stream = self.stream.materialize(
                None if self._cache_is_full else max_rounds
            )
        arrays = self._cached_stream
        cached = next(iter(arrays.values())).shape[0]
        if max_rounds is not None and max_rounds > cached and not self._cache_is_full:
            # an unbounded source's cache is only the first run's window;
            # never silently truncate a larger request
            raise ValueError(
                f"the session stream cache holds {cached} rounds but "
                f"max_rounds={max_rounds} was requested — pass stream= to "
                "run(...) to feed fresh rounds from a live source"
            )
        if max_rounds is not None and max_rounds < cached:
            arrays = {k: v[:max_rounds] for k, v in arrays.items()}
        return arrays

    def _infer_shapes(self, arrays: Dict[str, np.ndarray]) -> None:
        if self.batch is not None and self.seq is not None:
            return
        if "tokens" in arrays:
            _, b, s = arrays["tokens"].shape[:3]
            self.batch = self.batch or int(b)
            self.seq = self.seq or int(s)
        elif "x" in arrays:
            self.batch = self.batch or int(arrays["x"].shape[1])
            if self.seq is None:
                raise ValueError(
                    "cannot infer seq from a vector stream — pass seq= to "
                    "FerretSession"
                )
        else:
            raise ValueError(
                "cannot infer batch/seq from stream fields "
                f"{sorted(arrays)} — pass batch=/seq= to FerretSession"
            )
