"""FerretSession: the front door of the reproduction.

    from repro.api import FerretSession

    session = FerretSession(model_cfg, budget=2 * 2**30, algorithm="er",
                            stream=make_stream(StreamConfig(...)))
    result = session.run()                 # pipelined engine (default)
    result = session.run("elastic", schedule=[BudgetEvent(120, 2**30)])
    result = session.run("sequential")     # exact Oracle loop
    result = session.run("baseline", policy="one_skip")

One call signature across every execution mode and every registered OCL
algorithm; every run returns the unified ``repro.api.StreamResult``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Union

import jax
import numpy as np

from repro.api.results import StreamResult
from repro.api.runners import Runner, get_runner
from repro.api.streams import (
    ArrayStreamSource,
    BufferedStreamSource,
    LimitedStreamSource,
    StreamLike,
    StreamSource,
    as_stream_source,
)
from repro.core import planner as planner_lib
from repro.core.compensation import CompensationConfig
from repro.core.ferret import FerretConfig
from repro.core.profiler import ModelProfile, profile_for
from repro.models.config import ModelConfig
from repro.ocl.algorithms import OCLConfig
from repro.ocl.registry import OCLAlgorithm, PrepareContext, get_algorithm
from repro.optim.optimizers import Optimizer, adamw

Pytree = Any


class FerretSession:
    """One OCL session: a model, a memory budget, an algorithm, a stream.

    ``model`` is a ``ModelConfig`` or a registered architecture name
    (resolved with ``smoke=True`` reductions by default). ``algorithm`` is
    a registered name, an ``OCLConfig`` (its ``method`` selects), or an
    ``OCLAlgorithm`` instance; when omitted it resolves from ``ocl=`` /
    ``ferret.ocl`` (default ``"vanilla"``). ``stream`` is anything
    ``repro.api.as_stream_source`` accepts; it may also be given per-run.

    ``batch``/``seq`` are inferred from the stream's token arrays when not
    given (for a live source, from its first round). Only *bounded*
    session streams are cached: they materialize exactly once, so
    successive ``run(...)`` calls compare runners on identical data
    (``max_rounds`` slices a prefix). An unbounded session stream is never
    materialized or cached — each run consumes fresh rounds from the live
    feed, exactly once across runs; bound a single run with
    ``max_rounds``. Explicit per-run streams (``run(stream=...)``) are
    never cached either.

    Runners that declare ``consumes_source = True`` (the pipelined and
    elastic runners — the whole pipeline path) receive a ``StreamSource``
    and pull rounds segment by segment — no up-front materialization,
    host/device stream residency stays O(segment); the sequential/baseline
    runners receive materialized arrays.
    """

    def __init__(
        self,
        model: Union[ModelConfig, str],
        budget: Optional[float] = None,
        algorithm: Optional[Union[str, OCLConfig, OCLAlgorithm]] = None,
        stream: Optional[StreamLike] = None,
        *,
        runner: Union[str, Runner] = "pipelined",
        batch: Optional[int] = None,
        seq: Optional[int] = None,
        lr: float = 5e-3,
        seed: int = 0,
        compensation: Optional[CompensationConfig] = None,
        ocl: Optional[OCLConfig] = None,
        ferret: Optional[FerretConfig] = None,
        max_workers: Optional[int] = 8,
        max_stages: Optional[int] = None,
        optimizer: Optional[Optimizer] = None,
        profile: Optional[Union[ModelProfile, str]] = None,
        profile_feedback: bool = False,
        params: Optional[Pytree] = None,
        smoke: bool = True,
        topology=None,
    ):
        # topology: None (single-device, the default), "discover"
        # (jax.devices()/process_index at session construction), or a
        # DeviceTopology — threaded into every runner so plans are bounded
        # by per-device memory and engine scans run under the topology's
        # mesh (see repro.runtime.topology).
        from repro.runtime.topology import as_topology

        self.topology = as_topology(topology)
        if isinstance(model, str):
            from repro.models.registry import get_config

            model = get_config(model, smoke=smoke)
        self.model_cfg = model

        if isinstance(algorithm, OCLAlgorithm):
            self.algorithm = algorithm
        elif algorithm is None:
            # no explicit algorithm: honor the method carried by ocl= /
            # ferret.ocl instead of silently defaulting to vanilla
            spec = ocl if ocl is not None else (
                ferret.ocl if ferret is not None else "vanilla"
            )
            self.algorithm = get_algorithm(spec)
        else:
            self.algorithm = get_algorithm(algorithm, ocl)
        if ferret is None:
            ferret = FerretConfig(
                budget_bytes=math.inf if budget is None else budget,
                lr=lr,
                compensation=compensation or CompensationConfig(),
                ocl=self.algorithm.cfg,
                max_workers=max_workers,
                max_stages=max_stages,
                profile_feedback=profile_feedback,
            )
        else:
            # explicit FerretConfig wins, but an explicit budget argument
            # overrides its budget_bytes (never silently ignored), and its
            # ocl is kept in sync with the resolved algorithm so both
            # execution paths see one config
            over = {"ocl": self.algorithm.cfg}
            if budget is not None:
                over["budget_bytes"] = budget
            if profile_feedback:
                over["profile_feedback"] = True
            ferret = dataclasses.replace(ferret, **over)
        self.ferret_cfg = ferret

        self.stream: Optional[StreamSource] = (
            as_stream_source(stream) if stream is not None else None
        )
        self.batch = batch
        self.seq = seq
        self.default_runner = runner
        self.seed = seed
        self.optimizer = optimizer or adamw(lr=ferret.lr)
        # profile: a ModelProfile, or a resolution preference string
        # ("analytic" | "auto" | "measured") resolved lazily via the
        # profile store once batch/seq are known (repro.profile.bridge)
        if isinstance(profile, str):
            if profile not in ("analytic", "auto", "measured"):
                raise ValueError(
                    "profile= accepts a ModelProfile or one of "
                    f"'analytic'/'auto'/'measured', got {profile!r}"
                )
            self._profile: Optional[ModelProfile] = None
            self._profile_spec: Optional[str] = profile
        else:
            self._profile = profile
            self._profile_spec = None
        self._params = params
        self._cached_stream: Optional[Dict[str, np.ndarray]] = None
        self._live_stream: Optional[BufferedStreamSource] = None

    # -- lazy pieces -------------------------------------------------------
    @property
    def params(self) -> Pytree:
        if self._params is None:
            from repro.models import transformer as T

            self._params = T.init_params(self.model_cfg, jax.random.PRNGKey(self.seed))
        return self._params

    @params.setter
    def params(self, value: Pytree) -> None:
        self._params = value

    @property
    def profile(self) -> Optional[ModelProfile]:
        """The session's planner profile.

        An explicit ``ModelProfile`` is returned as-is; a string spec
        resolves through ``core.profiler.profile_for`` (store-backed, with
        provenance) once batch/seq are known and is then pinned for the
        session; ``None`` lets the trainers do their own store-aware
        default resolution.
        """
        if self._profile is None and self._profile_spec is not None:
            if self.batch is None or self.seq is None:
                return None  # not yet inferable; trainers resolve later
            self._profile = profile_for(
                self.model_cfg, self.batch, self.seq, prefer=self._profile_spec
            )
        return self._profile

    @profile.setter
    def profile(self, value: Optional[ModelProfile]) -> None:
        self._profile = value
        self._profile_spec = None

    @property
    def plan(self) -> planner_lib.Plan:
        """The pipelined plan for this session's budget (Alg. 3 ∘ Alg. 2)."""
        if (self.batch is None or self.seq is None) and self.stream is not None:
            if self.stream.length is not None:
                self._infer_shapes(self._resolve_stream(None, None))
            else:
                # live feed: shapes come from a peeked first round — the
                # buffered view retains it, so no round is lost to planning
                first = self._session_source.peek(1)
                if first is not None:
                    self._infer_shapes(first)
        if self.batch is None or self.seq is None:
            raise ValueError(
                "plan needs batch/seq — pass them to FerretSession or give "
                "the session a stream they can be inferred from"
            )
        profile = self.profile or profile_for(self.model_cfg, self.batch, self.seq)
        if self.topology is not None:
            from repro.profile.bridge import for_topology

            profile = for_topology(profile, self.topology)
        t_d = self.ferret_cfg.t_d or planner_lib.default_data_interval(profile)
        return planner_lib.plan(
            profile,
            t_d,
            self.ferret_cfg.budget_bytes,
            c=self.ferret_cfg.decay_c,
            V_D=self.ferret_cfg.data_value,
            max_workers=self.ferret_cfg.max_workers,
            max_stages=self.ferret_cfg.max_stages,
            topology=self.topology,
        )

    # -- the one entrypoint ------------------------------------------------
    def run(
        self,
        runner: Optional[Union[str, Runner]] = None,
        *,
        stream: Optional[StreamLike] = None,
        params: Optional[Pytree] = None,
        max_rounds: Optional[int] = None,
        **runner_opts,
    ) -> StreamResult:
        """Run the stream through a registered runner. One signature for
        every (runner × algorithm) pair; returns the unified StreamResult."""
        r = get_runner(runner if runner is not None else self.default_runner)
        run_params = params if params is not None else self.params
        if getattr(r, "consumes_source", False):
            # source-consuming runner (pipelined/elastic): rounds are
            # pulled segment by segment, never materialized up front;
            # stream preparation happens inside the trainer, per chunk
            source = self._resolve_source(stream, max_rounds)
            self.algorithm.reset()
            return r.run(self, run_params, source, **runner_opts)
        arrays = self._resolve_stream(stream, max_rounds)
        self._infer_shapes(arrays)
        self.algorithm.reset()
        if r.prepare_stream:
            from repro.models import transformer as T

            ctx = PrepareContext(
                params=run_params,
                forward_fn=lambda p, b: T.forward(self.model_cfg, p, b)[0],
            )
            arrays = self.algorithm.prepare_stream(arrays, ctx)
        return r.run(self, run_params, arrays, **runner_opts)

    def open_stream_run(
        self,
        *,
        stream: Optional[StreamLike] = None,
        params: Optional[Pytree] = None,
        max_rounds: Optional[int] = None,
        schedule: Any = (),
        segment_rounds: Optional[Any] = None,
        supervisor_cfg: Optional[Any] = None,
        engine_cache: Optional[Any] = None,
        prefetch: bool = True,
        resume_from: Optional[str] = None,
    ):
        """Open the session's stream as a *steppable* elastic run.

        Where ``run("elastic")`` drives the whole stream to completion,
        this returns an ``ElasticRun``: each ``step()`` executes one
        segment, ``stop()`` ends at a boundary with exactly-once
        accounting intact, and ``run.trainer.request_budget(...)`` re-plans
        live between steps. This is the session-level primitive the
        multi-tenant ``repro.serve.FerretServer`` multiplexes — pass a
        shared ``engine_cache`` so same-geometry sessions reuse compiled
        engines. ``segment_rounds`` may be a callable ``cursor -> rounds``
        (dynamic segment sizing).

        ``resume_from`` points at a drain-checkpoint directory written by
        ``trainer.save_live_checkpoint`` (what ``FerretServer.drain``
        leaves per tenant): the run restores that state and continues
        from the saved stream cursor — seekable sources are positioned
        there, so across the drain/restart no round is lost or re-trained.
        """
        from repro.runtime.elastic_trainer import ElasticStreamTrainer

        source = self._resolve_source(stream, max_rounds)
        run_params = params if params is not None else self.params
        self.algorithm.reset()
        trainer = ElasticStreamTrainer(
            self.model_cfg, self.ferret_cfg,
            batch=self.batch, seq=self.seq,
            optimizer=self.optimizer, profile=self.profile,
            algorithm=self.algorithm, engine_cache=engine_cache,
            topology=self.topology,
        )
        resume = (
            trainer.load_drain_state(run_params, resume_from)
            if resume_from is not None
            else None
        )
        return trainer.open_stream(
            run_params, source, schedule,
            segment_rounds=segment_rounds, supervisor_cfg=supervisor_cfg,
            prefetch=prefetch, resume=resume,
        )

    # -- internals ---------------------------------------------------------
    @property
    def _session_source(self) -> BufferedStreamSource:
        """Buffered view over an *unbounded* session stream.

        Created once and shared by every run, so consumption continues
        across runs (each live round is trained on exactly once) and a
        shape-inference peek never loses a round. Non-retaining: the
        consuming trainer wraps this view in its own replay-buffered
        feeder, and a second retention layer here would silently hold
        every round pulled through it for the whole run — O(R) host
        memory, exactly what the incremental path exists to avoid.
        """
        if self._live_stream is None:
            self._live_stream = BufferedStreamSource(self.stream, retain=False)
        return self._live_stream

    def _bounded_arrays(self, max_rounds: Optional[int]) -> Dict[str, np.ndarray]:
        """The bounded session stream, materialized exactly once and cached
        so every run compares runners on identical data; ``max_rounds``
        slices a prefix."""
        if self._cached_stream is None:
            self._cached_stream = self.stream.materialize(None)
        arrays = self._cached_stream
        if max_rounds is not None and max_rounds < next(iter(arrays.values())).shape[0]:
            arrays = {k: v[:max_rounds] for k, v in arrays.items()}
        return arrays

    def _resolve_stream(
        self, stream: Optional[StreamLike], max_rounds: Optional[int]
    ) -> Dict[str, np.ndarray]:
        if stream is not None:  # explicit per-run stream: never cached
            return as_stream_source(stream).materialize(max_rounds)
        if self.stream is None:
            raise ValueError(
                "no stream: pass stream= to FerretSession(...) or run(...)"
            )
        if self.stream.length is not None:
            return self._bounded_arrays(max_rounds)
        # unbounded session stream: never cached — materialize this run's
        # window (max_rounds required) and let consumption continue from
        # there on the next run
        return self._session_source.materialize(max_rounds)

    def _resolve_source(
        self, stream: Optional[StreamLike], max_rounds: Optional[int]
    ) -> StreamSource:
        """Resolve to a ``StreamSource`` for incremental consumption, with
        shapes inferred from the first round instead of a materialized
        stream."""
        if stream is not None:  # explicit per-run stream: never cached
            src: StreamSource = as_stream_source(stream)
            if max_rounds is not None:
                src = LimitedStreamSource(src, max_rounds)
        elif self.stream is None:
            raise ValueError(
                "no stream: pass stream= to FerretSession(...) or run(...)"
            )
        elif self.stream.length is not None:
            # bounded: a fresh cursor over the cached arrays, so successive
            # runs (and other runners) see identical data
            src = ArrayStreamSource(self._bounded_arrays(max_rounds))
        else:
            src = self._session_source
            if max_rounds is not None:
                src = LimitedStreamSource(src, max_rounds)
        if self.batch is None or self.seq is None:
            # non-retaining: the trainer's own feeder provides replay
            # retention; a retaining probe would hold every round of the
            # run (see BufferedStreamSource retain=)
            probe = BufferedStreamSource(src, retain=False)
            first = probe.peek(1)
            if first is None:
                raise ValueError(
                    "cannot infer batch/seq from an exhausted stream — "
                    "pass batch=/seq= to FerretSession"
                )
            self._infer_shapes(first)
            return probe  # retains the peeked round: nothing is lost
        return src

    def _infer_shapes(self, arrays: Dict[str, np.ndarray]) -> None:
        if self.batch is not None and self.seq is not None:
            return
        if "tokens" in arrays:
            _, b, s = arrays["tokens"].shape[:3]
            self.batch = self.batch or int(b)
            self.seq = self.seq or int(s)
        elif "x" in arrays:
            self.batch = self.batch or int(arrays["x"].shape[1])
            if self.seq is None:
                raise ValueError(
                    "cannot infer seq from a vector stream — pass seq= to "
                    "FerretSession"
                )
        else:
            raise ValueError(
                "cannot infer batch/seq from stream fields "
                f"{sorted(arrays)} — pass batch=/seq= to FerretSession"
            )
