"""StreamSource: one iterator abstraction over every stream shape.

The trainers consume dict-of-arrays stacked over rounds (``lax.scan`` xs):
``{"tokens": (R, b, s), "labels": (R, b, s)}``. A ``StreamSource`` produces
exactly that, but decouples *where rounds come from* — a finite in-memory
array, a Python generator, or a live/unbounded feed — from the runners:

- ``ArrayStreamSource``    — finite dict-of-arrays (what ``make_stream``
  returns), with an exactly-once cursor and ``seek`` for resume.
- ``IterableStreamSource`` — any iterator/generator of per-round batch
  dicts ``{k: (b, ...)}``; may be unbounded (``length=None``).
- ``BufferedStreamSource`` — a replay-buffered, prefetching view over any
  source: the feeder of both incremental pipeline paths (the pipelined
  trainer and the elastic trainer). ``take`` retains what it hands out
  until ``ack()``; ``rewind()`` re-serves the un-acked rounds
  (exactly-once fault re-runs without ``seek``); ``prefetch(n)`` pulls the
  next rounds on a background thread while the consumer computes.
- ``LimitedStreamSource``  — at most ``max_rounds`` rounds of a source
  (how ``run(max_rounds=...)`` bounds an unbounded feed).
- ``as_stream_source``     — coercion: sources pass through, dicts wrap,
  ``StreamConfig`` synthesizes, iterables/generators wrap.

``take(n)`` pops up to ``n`` rounds (stacked); ``materialize(max_rounds)``
drains to one stacked dict — unbounded sources require ``max_rounds``.
"""

from __future__ import annotations

import collections
import time
import warnings
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Union

import numpy as np

from repro import faults as faults_lib
from repro.faults import FeederDeathError, TransientFaultError
from repro.ocl.streams import StreamConfig, make_stream

Batch = Dict[str, np.ndarray]


def _concat_chunks(chunks: List[Batch]) -> Batch:
    """Stack a list of round-stacked chunk dicts into one (no copy for 1)."""
    if len(chunks) == 1:
        return chunks[0]
    return {k: np.concatenate([c[k] for c in chunks], axis=0) for k in chunks[0]}


class StreamSource:
    """Base protocol; subclasses implement ``take`` and ``length``."""

    @property
    def length(self) -> Optional[int]:
        """Total rounds, or ``None`` when unbounded/unknown."""
        raise NotImplementedError

    @property
    def remaining(self) -> Optional[int]:
        """Rounds not yet consumed, or ``None`` when unbounded/unknown."""
        raise NotImplementedError

    def take(self, n: int) -> Optional[Batch]:
        """Pop up to ``n`` rounds stacked as ``{k: (m, b, ...)}``, m ≤ n.

        Returns ``None`` once the source is exhausted. Consumption is
        exactly-once: rounds returned here are never returned again.
        """
        raise NotImplementedError

    def materialize(self, max_rounds: Optional[int] = None) -> Batch:
        """Drain (up to ``max_rounds``) into one stacked dict-of-arrays."""
        if max_rounds is None and self.length is None:
            raise ValueError(
                "unbounded StreamSource: pass max_rounds (e.g. "
                "session.run(max_rounds=...)) to bound the run"
            )
        chunks = []
        left = max_rounds if max_rounds is not None else self.remaining
        while left is None or left > 0:
            got = self.take(min(left or 256, 256))
            if got is None:
                break
            chunks.append(got)
            if left is not None:
                left -= next(iter(got.values())).shape[0]
        if not chunks:
            raise ValueError("StreamSource is exhausted — nothing to run")
        keys = chunks[0].keys()
        return {k: np.concatenate([c[k] for c in chunks], axis=0) for k in keys}

    def __iter__(self) -> Iterator[Batch]:
        while True:
            got = self.take(1)
            if got is None:
                return
            yield {k: v[0] for k, v in got.items()}


class ArrayStreamSource(StreamSource):
    """Finite stream backed by stacked arrays, with a consumption cursor."""

    def __init__(self, arrays: Batch):
        if not arrays:
            raise ValueError("empty stream dict")
        lens = {k: v.shape[0] for k, v in arrays.items()}
        if len(set(lens.values())) != 1:
            raise ValueError(f"inconsistent round counts across fields: {lens}")
        self.arrays = {k: np.asarray(v) for k, v in arrays.items()}
        self._length = next(iter(lens.values()))
        self.cursor = 0

    @property
    def length(self) -> Optional[int]:
        return self._length

    @property
    def remaining(self) -> Optional[int]:
        return self._length - self.cursor

    def seek(self, round_idx: int) -> None:
        """Move the cursor (checkpoint resume: skip already-consumed rounds)."""
        if not 0 <= round_idx <= self._length:
            raise ValueError(f"seek({round_idx}) outside [0, {self._length}]")
        self.cursor = round_idx

    def take(self, n: int) -> Optional[Batch]:
        if self.cursor >= self._length:
            return None
        end = min(self.cursor + n, self._length)
        out = {k: v[self.cursor:end] for k, v in self.arrays.items()}
        self.cursor = end
        return out


class IterableStreamSource(StreamSource):
    """Wraps an iterator of per-round batch dicts; may be unbounded."""

    def __init__(self, rounds: Iterable[Batch], length: Optional[int] = None):
        self._it = iter(rounds)
        self._declared = length
        self._consumed = 0
        self._done = False

    @property
    def length(self) -> Optional[int]:
        return self._declared

    @property
    def remaining(self) -> Optional[int]:
        if self._done:
            return 0
        if self._declared is None:
            return None
        return self._declared - self._consumed

    def take(self, n: int) -> Optional[Batch]:
        rows = []
        for _ in range(n):
            try:
                rows.append(next(self._it))
            except StopIteration:
                self._done = True
                break
        if not rows:
            return None
        keys = set(rows[0])
        for i, r in enumerate(rows[1:], 1):
            if set(r) != keys:
                # never silently drop (or KeyError on) fields that drift
                # between rounds — a live feed producing ragged dicts is a
                # producer bug, and the stacked batch must stay rectangular
                raise ValueError(
                    "inconsistent stream fields at round "
                    f"{self._consumed + i}: {sorted(r)} != {sorted(keys)}"
                )
        self._consumed += len(rows)
        return {k: np.stack([np.asarray(r[k]) for r in rows]) for k in rows[0]}


class LimitedStreamSource(StreamSource):
    """At most ``max_rounds`` rounds of ``source``, then exhausted.

    Bounds an unbounded feed for one run (``session.run(max_rounds=...)``).
    ``length`` reports the cap for an unbounded inner source — the inner
    feed may still end earlier, in which case this source ends with it.
    """

    def __init__(self, source: StreamSource, max_rounds: int):
        if max_rounds < 0:
            raise ValueError(f"max_rounds must be >= 0, got {max_rounds}")
        self.source = source
        self.max_rounds = int(max_rounds)
        self._given = 0

    @property
    def length(self) -> Optional[int]:
        inner = self.source.length
        return self.max_rounds if inner is None else min(inner, self.max_rounds)

    @property
    def remaining(self) -> Optional[int]:
        left = self.max_rounds - self._given
        inner = self.source.remaining
        return left if inner is None else min(inner, left)

    def take(self, n: int) -> Optional[Batch]:
        n = min(n, self.max_rounds - self._given)
        if n <= 0:
            return None
        got = self.source.take(n)
        if got is not None:
            self._given += next(iter(got.values())).shape[0]
        return got


class BufferedStreamSource(StreamSource):
    """Replay-buffered, prefetching view over any ``StreamSource``.

    The feeder of the incremental pipeline paths (``core.ferret``'s
    pipelined trainer and ``runtime.elastic_trainer``). Three jobs:

    - **exactly-once under faults**: every round handed out by ``take`` is
      retained until ``ack()``; ``rewind()`` puts the un-acked rounds back
      at the front, so a failed segment re-runs on identical data without
      ``seek`` — unbounded live feeds included.
    - **prefetch**: ``prefetch(n)`` pulls the next ``n`` rounds from the
      inner source on a background thread, overlapping stream arrival
      with the consumer's compute. Prefetched rounds land in the pending
      buffer; nothing is lost if the consumer stops early.
    - **one-shot transform**: ``transform`` (e.g. an OCL algorithm's
      ``prepare_stream``) is applied to each pulled chunk exactly once, in
      stream order, before retention — a rewound segment replays the
      *prepared* rows instead of re-running a stateful preparation.

    Peak host residency is ``peak_buffered_rounds`` — O(segment + prefetch
    window), never O(stream). ``take_wait_s`` accumulates time spent
    blocked on the inner source (the un-overlapped arrival cost).

    ``retain=False`` turns the replay buffer off: ``take`` hands rounds
    out without keeping a copy (``rewind`` becomes a no-op). Use it for
    pass-through views that only exist to ``peek``/share a source — e.g.
    the session's shape-inference probe and its cross-run live-stream
    view — where the *consuming* trainer wraps this view in its own
    retaining feeder; stacking two retaining views would hold every round
    pulled through the inner one for the whole run, O(R) host memory.
    """

    def __init__(
        self,
        source: StreamSource,
        transform: Optional[Callable[[Batch], Batch]] = None,
        prefetch: bool = True,
        retain: bool = True,
    ):
        self.source = source
        self.transform = transform
        self.prefetch_enabled = prefetch
        self.retain = retain
        self._pending: collections.deque = collections.deque()  # transformed
        self._inflight: List[Batch] = []  # handed out, not yet acked
        self._exhausted = False
        self._future = None
        self._pool: Optional[ThreadPoolExecutor] = None
        self.peak_buffered_rounds = 0
        self.take_wait_s = 0.0

    @staticmethod
    def _nrounds(chunk: Batch) -> int:
        return next(iter(chunk.values())).shape[0]

    def _pending_rounds(self) -> int:
        return sum(self._nrounds(c) for c in self._pending)

    def pending_round_count(self) -> int:
        """Rounds pulled from the inner source but not yet handed out.

        A cheap, non-blocking observation (an in-flight prefetch is *not*
        synced): schedulers use it to size the next segment to what is
        physically available instead of blocking a shared serve loop."""
        return self._pending_rounds()

    def _note_peak(self) -> None:
        n = self._pending_rounds() + sum(self._nrounds(c) for c in self._inflight)
        self.peak_buffered_rounds = max(self.peak_buffered_rounds, n)

    def _admit(self, chunk: Optional[Batch]) -> None:
        """Transform-once and retain a chunk pulled from the inner source."""
        if chunk is None:
            self._exhausted = True
            return
        if self.transform is not None:
            chunk = self.transform(chunk)
        self._pending.append(chunk)
        self._note_peak()

    def _inner_take(self, n: int) -> Optional[Batch]:
        """``source.take`` with the ``stream.take`` injection point.

        A ``stall`` fault sleeps (a slow feed — observable in
        ``take_wait_s``, bit-exact otherwise); an ``error`` fault raises
        ``TransientFaultError`` *before* touching the source, so a retry
        consumes nothing twice.
        """
        spec = faults_lib.fire("stream.take", n=n)
        if spec is not None:
            if spec.kind == "stall":
                time.sleep(spec.arg)
                faults_lib.resolved("stream.take")
            elif spec.kind == "error":
                raise TransientFaultError("injected stream.take error")
        return self.source.take(n)

    def _prefetch_take(self, n: int) -> Optional[Batch]:
        """The background worker's take, with the feeder-death point."""
        spec = faults_lib.fire("stream.prefetch", n=n)
        if spec is not None and spec.kind == "feeder_death":
            raise FeederDeathError("injected prefetch feeder death")
        return self._inner_take(n)

    def _sync(self) -> None:
        if self._future is not None:
            (fut, n), self._future = self._future, None
            t0 = time.perf_counter()
            try:
                got = fut.result()
            except FeederDeathError:
                # the feeder thread died before touching the source: fall
                # back to a synchronous pull of the same request —
                # exactly-once holds because the failed take consumed
                # nothing
                self.take_wait_s += time.perf_counter() - t0
                self._pull(n)
                faults_lib.resolved("stream.prefetch")
                return
            except TransientFaultError:
                # the worker's *take* failed (transient, pre-consumption):
                # same synchronous fallback, but the outstanding fault is
                # at the take point, not the prefetch point
                self.take_wait_s += time.perf_counter() - t0
                self._pull(n)
                faults_lib.resolved("stream.take")
                return
            self.take_wait_s += time.perf_counter() - t0
            self._admit(got)

    def _pull(self, n: int) -> None:
        if self._exhausted:
            return
        t0 = time.perf_counter()
        try:
            got = self._inner_take(n)
        except TransientFaultError:
            # transient by contract (raised before any consumption):
            # one immediate retry
            got = self._inner_take(n)
            faults_lib.resolved("stream.take")
        self.take_wait_s += time.perf_counter() - t0
        self._admit(got)

    # -- prefetch ----------------------------------------------------------
    def prefetch(self, n: int) -> None:
        """Start pulling the next ``n`` rounds on a background thread.

        No-op while a prefetch is already in flight, after exhaustion, or
        when prefetching is disabled. The inner source is only ever touched
        by one thread at a time: the worker owns it until the next
        main-thread operation syncs on the future.
        """
        if (
            not self.prefetch_enabled
            or n <= 0
            or self._exhausted
            or self._future is not None
        ):
            return
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="stream-prefetch"
            )
        # the request size rides with the future so a dead feeder can be
        # recovered by a synchronous pull of the same n (see _sync)
        self._future = (self._pool.submit(self._prefetch_take, n), n)

    def close(self) -> None:
        """Drain any in-flight prefetch and stop the worker thread.

        Exception-safe: consumers call this from a ``finally`` while an
        error may already be unwinding, so a *failed* in-flight take is
        dropped here instead of raised — during normal operation the
        background exception re-raises, original traceback attached, at
        the next main-thread sync point (``take``/``peek``/``ack`` path),
        which is where the consumer can act on it. Without the shutdown a
        non-daemon worker blocked on a slow feed outlives the trainer.
        """
        entry, self._future = self._future, None
        if entry is not None:
            try:
                self._admit(entry[0].result())
            except Exception:
                # the consumer is already unwinding its own error; but
                # KeyboardInterrupt/SystemExit must still get through or
                # a hung feed makes the process unstoppable
                pass
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None

    # -- StreamSource protocol --------------------------------------------
    @property
    def length(self) -> Optional[int]:
        return self.source.length

    @property
    def remaining(self) -> Optional[int]:
        inner = self.source.remaining
        if self._exhausted:
            inner = 0
        if inner is None:
            return None
        return inner + self._pending_rounds()

    def take(self, n: int) -> Optional[Batch]:
        self._sync()
        while self._pending_rounds() < n and not self._exhausted:
            self._pull(n - self._pending_rounds())
        if not self._pending:
            return None
        out: List[Batch] = []
        got = 0
        while self._pending and got < n:
            chunk = self._pending.popleft()
            r = self._nrounds(chunk)
            if got + r > n:
                keep = n - got
                self._pending.appendleft({k: v[keep:] for k, v in chunk.items()})
                chunk, r = {k: v[:keep] for k, v in chunk.items()}, keep
            out.append(chunk)
            got += r
        stacked = _concat_chunks(out)
        if self.retain:
            self._inflight.append(stacked)
        self._note_peak()
        return stacked

    def materialize(self, max_rounds: Optional[int] = None) -> Batch:
        out = super().materialize(max_rounds)
        self.ack()
        return out

    # -- exactly-once bookkeeping -----------------------------------------
    def ack(self) -> None:
        """Confirm every handed-out round as consumed (drop the replay copy)."""
        self._inflight.clear()

    def rewind(self) -> None:
        """Put all un-acked rounds back at the front for replay."""
        self._sync()
        for chunk in reversed(self._inflight):
            self._pending.appendleft(chunk)
        self._inflight.clear()

    def try_seek(self, round_idx: int) -> bool:
        """Seek the inner source (resume); discards all buffered rounds."""
        inner = self.source
        ok = (
            inner.try_seek(round_idx)
            if isinstance(inner, BufferedStreamSource)
            else getattr(inner, "seek", None) is not None
        )
        if not ok:
            return False
        self._sync()
        self._pending.clear()
        self._inflight.clear()
        self._exhausted = False
        if not isinstance(inner, BufferedStreamSource):
            inner.seek(round_idx)
        return True

    # -- buffered-tail access (elastic re-plan refresh) --------------------
    def peek(self, n: int = 1) -> Optional[Batch]:
        """The next ``n`` rounds without consuming them (pulled if needed)."""
        self._sync()
        while self._pending_rounds() < n and not self._exhausted:
            self._pull(n - self._pending_rounds())
        if not self._pending:
            return None
        rows: List[Batch] = []
        got = 0
        for chunk in self._pending:
            keep = min(n - got, self._nrounds(chunk))
            rows.append({k: v[:keep] for k, v in chunk.items()})
            got += keep
            if got >= n:
                break
        return _concat_chunks(rows)

    def buffered_rows(self) -> Optional[Batch]:
        """All pending (pulled, not yet handed out) rounds as one stacked
        dict — the physically-held piece of the stream tail an elastic
        re-plan may refresh in place. Requires no un-acked rounds."""
        self._sync()
        if self._inflight:
            raise RuntimeError(
                "buffered_rows with un-acked rounds in flight: ack() or "
                "rewind() first"
            )
        if not self._pending:
            return None
        return _concat_chunks(list(self._pending))

    def replace_buffered(self, rows: Batch) -> None:
        """Swap the pending rounds for refreshed ones (same round count)."""
        self._sync()
        have = self._pending_rounds()
        got = self._nrounds(rows)
        if got != have:
            raise ValueError(
                f"replace_buffered: {got} rounds given, {have} buffered"
            )
        self._pending.clear()
        self._pending.append(rows)


StreamLike = Union[StreamSource, Batch, StreamConfig, Iterable[Batch]]


def as_stream_source(obj: StreamLike, length: Optional[int] = None) -> StreamSource:
    """Coerce anything stream-shaped into a ``StreamSource``."""
    if isinstance(obj, StreamSource):
        return obj
    if isinstance(obj, StreamConfig):
        return ArrayStreamSource(make_stream(obj))
    if isinstance(obj, dict):
        return ArrayStreamSource(obj)
    if hasattr(obj, "__iter__") or hasattr(obj, "__next__"):
        return IterableStreamSource(obj, length=length)
    raise TypeError(
        f"cannot interpret {type(obj).__name__} as a stream: pass a "
        "StreamSource, a dict of (R, b, ...) arrays, a StreamConfig, or an "
        "iterable of per-round batch dicts"
    )


def coerce_trainer_stream(stream: StreamLike, caller: str) -> StreamSource:
    """The trainers' single stream-coercion entry point.

    ``StreamSource`` objects pass straight through. Anything else — in
    particular the historical raw dict-of-arrays form — is coerced via
    ``as_stream_source`` with a ``DeprecationWarning``: the trainer-level
    compat wrapping used to be copy-pasted per trainer, and the session
    layer (``FerretSession(stream=...)``) is the supported place to hand
    over raw arrays.
    """
    if isinstance(stream, StreamSource):
        return stream
    warnings.warn(
        f"passing a raw {type(stream).__name__} stream to {caller} is "
        "deprecated: wrap it with repro.api.as_stream_source(...) or use "
        "FerretSession(stream=...), which accepts raw arrays directly",
        DeprecationWarning,
        stacklevel=3,
    )
    return as_stream_source(stream)
