"""StreamSource: one iterator abstraction over every stream shape.

The trainers consume dict-of-arrays stacked over rounds (``lax.scan`` xs):
``{"tokens": (R, b, s), "labels": (R, b, s)}``. A ``StreamSource`` produces
exactly that, but decouples *where rounds come from* — a finite in-memory
array, a Python generator, or a live/unbounded feed — from the runners:

- ``ArrayStreamSource``    — finite dict-of-arrays (what ``make_stream``
  returns), with an exactly-once cursor and ``seek`` for resume.
- ``IterableStreamSource`` — any iterator/generator of per-round batch
  dicts ``{k: (b, ...)}``; may be unbounded (``length=None``).
- ``as_stream_source``     — coercion: sources pass through, dicts wrap,
  ``StreamConfig`` synthesizes, iterables/generators wrap.

``take(n)`` pops up to ``n`` rounds (stacked); ``materialize(max_rounds)``
drains to one stacked dict — unbounded sources require ``max_rounds``.
"""

from __future__ import annotations

from typing import Dict, Iterable, Iterator, Optional, Union

import numpy as np

from repro.ocl.streams import StreamConfig, make_stream

Batch = Dict[str, np.ndarray]


class StreamSource:
    """Base protocol; subclasses implement ``take`` and ``length``."""

    @property
    def length(self) -> Optional[int]:
        """Total rounds, or ``None`` when unbounded/unknown."""
        raise NotImplementedError

    @property
    def remaining(self) -> Optional[int]:
        """Rounds not yet consumed, or ``None`` when unbounded/unknown."""
        raise NotImplementedError

    def take(self, n: int) -> Optional[Batch]:
        """Pop up to ``n`` rounds stacked as ``{k: (m, b, ...)}``, m ≤ n.

        Returns ``None`` once the source is exhausted. Consumption is
        exactly-once: rounds returned here are never returned again.
        """
        raise NotImplementedError

    def materialize(self, max_rounds: Optional[int] = None) -> Batch:
        """Drain (up to ``max_rounds``) into one stacked dict-of-arrays."""
        if max_rounds is None and self.length is None:
            raise ValueError(
                "unbounded StreamSource: pass max_rounds (e.g. "
                "session.run(max_rounds=...)) to bound the run"
            )
        chunks = []
        left = max_rounds if max_rounds is not None else self.remaining
        while left is None or left > 0:
            got = self.take(min(left or 256, 256))
            if got is None:
                break
            chunks.append(got)
            if left is not None:
                left -= next(iter(got.values())).shape[0]
        if not chunks:
            raise ValueError("StreamSource is exhausted — nothing to run")
        keys = chunks[0].keys()
        return {k: np.concatenate([c[k] for c in chunks], axis=0) for k in keys}

    def __iter__(self) -> Iterator[Batch]:
        while True:
            got = self.take(1)
            if got is None:
                return
            yield {k: v[0] for k, v in got.items()}


class ArrayStreamSource(StreamSource):
    """Finite stream backed by stacked arrays, with a consumption cursor."""

    def __init__(self, arrays: Batch):
        if not arrays:
            raise ValueError("empty stream dict")
        lens = {k: v.shape[0] for k, v in arrays.items()}
        if len(set(lens.values())) != 1:
            raise ValueError(f"inconsistent round counts across fields: {lens}")
        self.arrays = {k: np.asarray(v) for k, v in arrays.items()}
        self._length = next(iter(lens.values()))
        self.cursor = 0

    @property
    def length(self) -> Optional[int]:
        return self._length

    @property
    def remaining(self) -> Optional[int]:
        return self._length - self.cursor

    def seek(self, round_idx: int) -> None:
        """Move the cursor (checkpoint resume: skip already-consumed rounds)."""
        if not 0 <= round_idx <= self._length:
            raise ValueError(f"seek({round_idx}) outside [0, {self._length}]")
        self.cursor = round_idx

    def take(self, n: int) -> Optional[Batch]:
        if self.cursor >= self._length:
            return None
        end = min(self.cursor + n, self._length)
        out = {k: v[self.cursor:end] for k, v in self.arrays.items()}
        self.cursor = end
        return out


class IterableStreamSource(StreamSource):
    """Wraps an iterator of per-round batch dicts; may be unbounded."""

    def __init__(self, rounds: Iterable[Batch], length: Optional[int] = None):
        self._it = iter(rounds)
        self._declared = length
        self._consumed = 0
        self._done = False

    @property
    def length(self) -> Optional[int]:
        return self._declared

    @property
    def remaining(self) -> Optional[int]:
        if self._done:
            return 0
        if self._declared is None:
            return None
        return self._declared - self._consumed

    def take(self, n: int) -> Optional[Batch]:
        rows = []
        for _ in range(n):
            try:
                rows.append(next(self._it))
            except StopIteration:
                self._done = True
                break
        if not rows:
            return None
        self._consumed += len(rows)
        return {k: np.stack([np.asarray(r[k]) for r in rows]) for k in rows[0]}


StreamLike = Union[StreamSource, Batch, StreamConfig, Iterable[Batch]]


def as_stream_source(obj: StreamLike, length: Optional[int] = None) -> StreamSource:
    """Coerce anything stream-shaped into a ``StreamSource``."""
    if isinstance(obj, StreamSource):
        return obj
    if isinstance(obj, StreamConfig):
        return ArrayStreamSource(make_stream(obj))
    if isinstance(obj, dict):
        return ArrayStreamSource(obj)
    if hasattr(obj, "__iter__") or hasattr(obj, "__next__"):
        return IterableStreamSource(obj, length=length)
    raise TypeError(
        f"cannot interpret {type(obj).__name__} as a stream: pass a "
        "StreamSource, a dict of (R, b, ...) arrays, a StreamConfig, or an "
        "iterable of per-round batch dicts"
    )
