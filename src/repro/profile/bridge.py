"""Planner bridge: store-backed ``ModelProfile`` resolution + online refinement.

``resolve_profile`` is the one entry point the planner stack uses to get
per-layer numbers (paper Alg. 3 ``profile(θ)``):

- ``prefer="analytic"`` — the TPU-v5e roofline, always.
- ``prefer="auto"`` — a stored measurement for this (backend, model,
  dtype, geometry) key if one exists, else the analytic fallback. Never
  runs a measurement itself (safe on any planner path).
- ``prefer="measured"`` — a stored measurement if present (the cache-hit
  path: *no* re-measurement), else measure now and persist.

Every returned profile carries ``provenance`` ("analytic" / "measured" /
"online") so plans record what they were derived from.

``observe_segment`` is the feedback half: trainers report observed
segment wall-clock, the bridge compares it against the plan's expected
round time (``cost_model.expected_round_seconds``), EMA-scales the
profile's per-layer times toward the observation, and persists the
refined profile — the next replan (BudgetEvent, ``Supervisor.on_fatal``)
plans from it.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional, Tuple

from repro.profile.store import ProfileStore, default_store, profile_key

PROFILE_KIND = "layer_profile"

# Online-refinement damping: one segment moves the time scale this
# fraction of the way to the observation.
FEEDBACK_ALPHA = 0.5
# Observed/expected clip: one wild segment (GC pause, first-touch) can't
# destroy the profile.
SCALE_CLIP = (0.1, 10.0)

_COUNTER_LOCK = threading.Lock()
_MEASUREMENT_RUNS = 0


def measurement_runs() -> int:
    """Process-wide count of real harness measurements (tests/bench use
    this to assert a store hit skipped re-measurement)."""
    return _MEASUREMENT_RUNS


def _count_measurement() -> None:
    global _MEASUREMENT_RUNS
    with _COUNTER_LOCK:
        _MEASUREMENT_RUNS += 1


# ---------------------------------------------------------------------------
# ModelProfile <-> JSON payload
# ---------------------------------------------------------------------------


def profile_to_payload(profile, timings: Optional[Dict] = None) -> Dict:
    payload = {
        "provenance": profile.provenance,
        "batch": profile.batch,
        "seq": profile.seq,
        "embed_bytes": profile.embed_bytes,
        "layers": [dataclasses.asdict(ly) for ly in profile.layers],
    }
    if timings:
        payload["timings"] = timings
    return payload


def profile_from_payload(payload: Dict):
    from repro.core.profiler import LayerProfile, ModelProfile

    layers = [
        LayerProfile(
            t_fwd=float(ly["t_fwd"]),
            t_bwd=float(ly["t_bwd"]),
            w_bytes=int(ly["w_bytes"]),
            a_bytes=int(ly["a_bytes"]),
            a_internal_bytes=int(ly["a_internal_bytes"]),
        )
        for ly in payload["layers"]
    ]
    return ModelProfile(
        layers=layers,
        embed_bytes=int(payload["embed_bytes"]),
        batch=int(payload["batch"]),
        seq=int(payload["seq"]),
        provenance=str(payload.get("provenance", "measured")),
    )


def for_chips(profile, chips: int):
    """Scale a single-chip profile to ``chips`` data-parallel chips (the
    same division ``analytic_profile(chips=)`` applies)."""
    if chips <= 1:
        return profile
    layers = [
        dataclasses.replace(
            ly,
            t_fwd=ly.t_fwd / chips,
            t_bwd=ly.t_bwd / chips,
            w_bytes=ly.w_bytes // chips,
            a_bytes=ly.a_bytes // chips,
            a_internal_bytes=ly.a_internal_bytes // chips,
        )
        for ly in profile.layers
    ]
    return dataclasses.replace(
        profile, layers=layers, embed_bytes=profile.embed_bytes // chips
    )


def for_topology(profile, topology):
    """Scale a single-device profile to a discovered ``DeviceTopology``.

    Data parallelism divides per-round compute time and per-item activation
    bytes by the replica count (each replica sees batch/dp items) but
    *replicates* weights — so ``w_bytes``/``embed_bytes`` stay per-device,
    unlike ``for_chips`` whose TP/FSDP-style division shards them too.
    The model axis is already the planner's own stage dimension, so it
    never rescales the profile here.
    """
    if topology is None:
        return profile
    dp = topology.data_parallel
    if dp <= 1:
        return profile
    layers = [
        dataclasses.replace(
            ly,
            t_fwd=ly.t_fwd / dp,
            t_bwd=ly.t_bwd / dp,
            a_bytes=ly.a_bytes // dp,
            a_internal_bytes=ly.a_internal_bytes // dp,
        )
        for ly in profile.layers
    ]
    return dataclasses.replace(profile, layers=layers)


# ---------------------------------------------------------------------------
# Resolution (Alg. 3 profile(θ) with provenance)
# ---------------------------------------------------------------------------


def resolve_profile(
    cfg,
    batch: int,
    seq: int,
    *,
    prefer: str = "auto",
    store: Optional[ProfileStore] = None,
    chips: int = 1,
    warmup: int = 2,
    repeats: int = 5,
):
    """A ``ModelProfile`` for the planner; see module docstring for modes."""
    from repro.core.profiler import analytic_profile

    if prefer not in ("analytic", "auto", "measured"):
        raise ValueError(f"unknown profile preference {prefer!r}")
    if prefer == "analytic":
        return analytic_profile(cfg, batch, seq, chips=chips)
    store = store or default_store()
    key = profile_key(cfg, batch, seq)
    try:
        payload = store.get(PROFILE_KIND, key)
    except Exception:
        payload = None
    if payload is not None:
        return for_chips(profile_from_payload(payload), chips)
    if prefer == "measured":
        from repro.profile import harness

        profile, timings = harness.measure_model_profile(
            cfg, batch, seq, warmup=warmup, repeats=repeats
        )
        _count_measurement()
        store.put(PROFILE_KIND, key, profile_to_payload(profile, timings))
        return for_chips(profile, chips)
    return analytic_profile(cfg, batch, seq, chips=chips)


# ---------------------------------------------------------------------------
# Online refinement (observed segment wall-clock -> refreshed store entry)
# ---------------------------------------------------------------------------


def scale_profile(profile, scale: float, provenance: str = "online"):
    """Per-layer times scaled by ``scale`` (byte facts untouched)."""
    layers = [
        dataclasses.replace(ly, t_fwd=ly.t_fwd * scale, t_bwd=ly.t_bwd * scale)
        for ly in profile.layers
    ]
    return dataclasses.replace(profile, layers=layers, provenance=provenance)


def observe_segment(
    cfg,
    batch: int,
    seq: int,
    profile,
    plan,
    rounds: int,
    run_s: float,
    *,
    store: Optional[ProfileStore] = None,
    alpha: float = FEEDBACK_ALPHA,
) -> Optional[Tuple[object, float]]:
    """Fold one observed segment into the stored profile.

    Returns ``(refined_profile, observed_scale)`` — the refined profile is
    also persisted under this geometry's key so subsequent
    ``resolve_profile(prefer="auto"/"measured")`` calls (and therefore
    replans) see it. Returns None when the observation carries no signal
    (zero rounds/time, degenerate plan).
    """
    from repro.core.cost_model import expected_round_seconds

    if rounds <= 0 or run_s <= 0.0:
        return None
    expected = expected_round_seconds(plan.stats, plan.config) * rounds
    if expected <= 0.0:
        return None
    raw = run_s / expected
    lo, hi = SCALE_CLIP
    observed = min(max(raw, lo), hi)
    # damped move toward the observation; repeated segments converge
    eff = 1.0 + alpha * (observed - 1.0)
    refined = scale_profile(profile, eff)
    store = store or default_store()
    try:
        store.put(
            PROFILE_KIND,
            profile_key(cfg, batch, seq),
            profile_to_payload(refined),
        )
    except Exception:
        pass  # read-only store: refinement still applies in-process
    return refined, observed
